"""Elastic training: DPM-driven scale-down/up via checkpoint-reshard.

Runs with 8 simulated devices (2 "pods" x 4) on CPU: trains a small model
on a 2-pod mesh, then a CloudPowerCap/DPM decision powers one pod off ->
the ElasticController checkpoints, rebuilds a 1-pod mesh, restores the state
resharded, and training resumes; later the pod returns and we scale back up.
The loss curve is continuous across both transitions.

  python examples/elastic_training.py
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import sys                                                  # noqa: E402
import tempfile                                             # noqa: E402

sys.path.insert(0, "src")

import jax                                                  # noqa: E402
from jax.sharding import (Mesh, NamedSharding,              # noqa: E402
                          PartitionSpec as P)

from repro import configs                                   # noqa: E402
from repro.launch.mesh import AxisType, make_mesh_compat    # noqa: E402
from repro.checkpoint import Checkpointer                   # noqa: E402
from repro.data.pipeline import SyntheticTokens             # noqa: E402
from repro.optim.adamw import AdamW                         # noqa: E402
from repro.runtime.elastic import ElasticController         # noqa: E402
from repro.runtime.train_loop import (init_train_state,    # noqa: E402
                                      make_train_step)

BATCH, SEQ = 8, 64


def make_mesh(n_pods: int) -> Mesh:
    devs = jax.devices()[:n_pods * 4]
    return make_mesh_compat((len(devs),), ("data",),
                            devices=devs, axis_types=(AxisType.Auto,))


def make_shardings(mesh, target):
    # Replicated params, batch-sharded data (pure DP example).
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), target)


def batch_shardings(mesh):
    return NamedSharding(mesh, P("data", None))


def main():
    cfg = configs.get_smoke("granite_8b")
    opt = AdamW(learning_rate=3e-3)
    data = SyntheticTokens(cfg.vocab_size, SEQ, BATCH, seed=1)
    tmp = tempfile.mkdtemp(prefix="elastic_")
    ctl = ElasticController(Checkpointer(tmp), make_mesh, make_shardings)

    mesh = make_mesh(2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run_steps(mesh, state, n):
        losses = []
        with mesh:
            for _ in range(n):
                b = data.next_batch()
                batch = {"tokens": jax.device_put(b.tokens,
                                                  batch_shardings(mesh)),
                         "labels": jax.device_put(b.labels,
                                                  batch_shardings(mesh)),
                         "weights": jax.device_put(b.weights,
                                                   batch_shardings(mesh))}
                state, m = step_fn(state, batch)
                losses.append(float(m["loss"]))
        return state, losses

    print(f"phase 1: 2 pods ({mesh.devices.size} devices)")
    state, l1 = run_steps(mesh, state, 20)
    print(f"  loss {l1[0]:.3f} -> {l1[-1]:.3f}")

    print("DPM: low demand -> power off pod1; resize 2 -> 1 pods")
    mesh, state = ctl.resize(state, int(state.step), 2, 1, "dpm-poweroff",
                             {"data": data.state_dict()})
    print(f"phase 2: 1 pod ({mesh.devices.size} devices)")
    state, l2 = run_steps(mesh, state, 20)
    print(f"  loss {l2[0]:.3f} -> {l2[-1]:.3f}")
    assert l2[0] < l1[0], "training state survived the resize"

    print("DPM: demand spike -> power pod1 back on; resize 1 -> 2 pods")
    mesh, state = ctl.resize(state, int(state.step), 1, 2, "dpm-poweron")
    state, l3 = run_steps(mesh, state, 20)
    print(f"phase 3: 2 pods, loss {l3[0]:.3f} -> {l3[-1]:.3f}")
    assert l3[-1] < l1[0]
    print("resize history:", [(e.from_pods, e.to_pods, e.reason)
                              for e in ctl.history])
    print("OK: loss continuous across both elastic transitions")


if __name__ == "__main__":
    main()
