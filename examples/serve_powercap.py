"""Serving with CloudPowerCap: capacity-aware routing + DPM consolidation.

Two replicas serve batched greedy decoding.  The CloudPowerCap manager
reshapes the power budget at runtime: first a cap rebalance shifts traffic,
then low demand lets DPM power one replica off and the freed Watts raise the
survivor's cap -- the router follows automatically via sync_capacities.

  PYTHONPATH=src python examples/serve_powercap.py
"""

import sys

sys.path.insert(0, "src")

import jax                                                  # noqa: E402

from repro import configs                                   # noqa: E402
from repro.core.power_model import TPU_V5E_HOST             # noqa: E402
from repro.core.redistribute import \
    redistribute_after_power_off                            # noqa: E402
from repro.drs.snapshot import (ClusterSnapshot, Host,      # noqa: E402
                                VirtualMachine)
from repro.models import transformer as tfm                 # noqa: E402
from repro.runtime.serve_loop import (CapacityAwareRouter,  # noqa: E402
                                      Replica, greedy_generate)


def main():
    cfg = configs.get_smoke("granite_8b")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    hosts = [Host("h0", TPU_V5E_HOST, power_cap=0.8 *
                  TPU_V5E_HOST.power_peak),
             Host("h1", TPU_V5E_HOST, power_cap=0.7 *
                  TPU_V5E_HOST.power_peak)]
    vms = [VirtualMachine(vm_id=f"rep{i}", host_id=f"h{i}", demand=1e14)
           for i in range(2)]
    snap = ClusterSnapshot(hosts, vms,
                           power_budget=1.5 * TPU_V5E_HOST.power_peak)
    router = CapacityAwareRouter([Replica("rep0", "h0"),
                                  Replica("rep1", "h1")])
    router.sync_capacities(snap)

    print("phase 1: both replicas, h1 capped at 70%")
    assigned = router.route(20)
    print("  routed:", {r: assigned.count(r) for r in set(assigned)})

    # Serve a batch on the busiest replica (model math is real).
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    tokens = greedy_generate(cfg, params, prompt, steps=8, max_len=32)
    print("  generated:", tokens.tolist())

    print("phase 2: low demand -> DPM powers h1 off; Watts flow to h0")
    for r in assigned:
        router.complete(r)
    snap2 = redistribute_after_power_off(snap, "h1")
    router.sync_capacities(snap2)
    print(f"  h0 cap {snap.hosts['h0'].power_cap:.0f} W -> "
          f"{snap2.hosts['h0'].power_cap:.0f} W")
    assigned = router.route(10)
    assert set(assigned) == {"rep0"}
    print("  all traffic on rep0, at a higher power cap "
          f"(capacity {snap2.hosts['h0'].managed_capacity:.2e} FLOP/s)")


if __name__ == "__main__":
    main()
