"""Quickstart: train a small LM end-to-end with CloudPowerCap in the loop.

Runs the full stack on CPU in a few minutes: synthetic data -> model ->
AdamW -> checkpoints, with a CloudPowerCap power plane driving per-pod batch
shares.  Mid-run, an operator power-budget cut hits one pod; the manager
redistributes caps and the batch scheduler replans -- training never stops
and never recompiles.

  PYTHONPATH=src python examples/quickstart.py [--steps 200]

For a larger run (~100M params), pass --preset 100m (slower on CPU).
"""

import argparse
import dataclasses
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro import configs                                  # noqa: E402
from repro.core.manager import CloudPowerCapManager, ManagerConfig  # noqa
from repro.core.power_model import TPU_V5E_HOST            # noqa: E402
from repro.data.pipeline import SyntheticTokens            # noqa: E402
from repro.drs.snapshot import (ClusterSnapshot, Host,     # noqa: E402
                                VirtualMachine)
from repro.optim.adamw import AdamW                        # noqa: E402
from repro.optim.schedule import cosine_schedule           # noqa: E402
from repro.runtime.power_integration import \
    PowerAwareBatchScheduler                               # noqa: E402
from repro.runtime.train_loop import (init_train_state,   # noqa: E402
                                      make_train_step)


def model_config(preset: str):
    base = configs.get_smoke("granite_8b")
    if preset == "100m":
        return dataclasses.replace(
            base, name="quickstart-100m", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32000)
    return dataclasses.replace(base, name="quickstart-small", n_layers=4,
                               d_model=256, n_heads=8, n_kv_heads=4,
                               head_dim=32, d_ff=512, vocab_size=4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["small", "100m"], default="small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = model_config(args.preset)
    print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.1f}M")

    # Power plane: 2 pods, full caps.
    hosts = [Host(f"pod{i}", TPU_V5E_HOST,
                  power_cap=TPU_V5E_HOST.power_peak) for i in range(2)]
    vms = [VirtualMachine(vm_id=f"shard{i}", host_id=f"pod{i}",
                          demand=TPU_V5E_HOST.capacity_peak * 0.9)
           for i in range(2)]
    snap = ClusterSnapshot(hosts, vms,
                           power_budget=2 * TPU_V5E_HOST.power_peak)
    manager = CloudPowerCapManager(ManagerConfig(dpm_enabled=False))
    scheduler = PowerAwareBatchScheduler(args.batch, [["pod0"], ["pod1"]],
                                         hysteresis=0.0)

    opt = AdamW(learning_rate=cosine_schedule(3e-3, 10, args.steps))
    data = SyntheticTokens(cfg.vocab_size, args.seq, args.batch, seed=0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step_fn = jax.jit(make_train_step(cfg, opt))
    plan = scheduler.plan(snap)
    print(f"batch plan: {plan.examples_per_pod.tolist()}")

    for step in range(args.steps):
        if step == args.steps // 2:
            # Operator event: pod0 loses 40% of its power cap.
            snap.hosts["pod0"].power_cap *= 0.6
            snap.power_budget = sum(h.power_cap for h in
                                    snap.powered_on_hosts())
            result = manager.run_invocation(snap)
            snap = result.snapshot
            plan = scheduler.plan(snap)
            print(f"[step {step}] power cut on pod0 -> caps "
                  f"{[round(h.power_cap) for h in snap.hosts.values()]} "
                  f"-> plan {plan.examples_per_pod.tolist()}")
        b = data.next_batch()
        batch = scheduler.apply({"tokens": b.tokens, "labels": b.labels,
                                 "weights": b.weights}, plan)
        state, metrics = step_fn(state, batch)
        if step % 20 == 0:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"tokens/step {int(metrics['tokens'])}")
    print("done. loss should be well below ln(vocab) =",
          f"{np.log(cfg.vocab_size):.2f}")


if __name__ == "__main__":
    main()
