"""Paper Sec. V-B as a runnable scenario: watch CloudPowerCap rebalance
Watts instead of migrating VMs.

Prints the per-host power caps / utilizations over time for CloudPowerCap
vs the Static baseline (the data behind paper Fig. 6), then the Table III
style summary.

  PYTHONPATH=src python examples/powercap_rebalancing.py
"""

import sys

sys.path.insert(0, "src")

from repro.sim.experiments import run_policy            # noqa: E402
from repro.sim.metrics import ratio_table               # noqa: E402


def main():
    results = {}
    for policy in ("cpc", "static", "statichigh"):
        results[policy] = run_policy("headroom", policy)

    print("=== timeline (CloudPowerCap) ===")
    last = None
    for t, per_host in results["cpc"].timeline:
        caps = tuple(round(v[0]) for v in per_host.values())
        if caps != last and t % 50 == 0 or caps != last:
            utils = [round(v[1], 2) for v in per_host.values()]
            print(f"t={t:6.0f}s caps={caps} util={utils}")
            last = caps

    print("\n=== events ===")
    for policy in ("cpc", "static"):
        print(f"[{policy}]")
        for t, e in results[policy].events:
            print(f"  t={t:6.0f}s {e}")

    print("\n=== Table III reproduction ===")
    table = ratio_table({k: v.acc for k, v in results.items()},
                        "statichigh")
    print(f"{'policy':12s} {'cpu_payload':>12s} {'vmotions':>9s}")
    for p in ("cpc", "static", "statichigh"):
        print(f"{p:12s} {table[p]['cpu_payload_ratio']:12.3f} "
              f"{table[p]['vmotions']:9d}")
    print("\npaper: CPC 0.99/0, Static 0.89/7, StaticHigh 1.00/0")


if __name__ == "__main__":
    main()
