"""Migration-layer parity: the batched engine must reproduce the object
plane's constraint corrections and hill-climb balancing move for move.

All three engines route migration decisions through the same kernels
(``repro.core.kernels.correct_constraints_slots`` / ``balance_migrations``
via ``repro.core.migration_core.MigrationCore`` on the object plane, and
inside the ``lax.scan`` program on the batched plane), so parity here is
exact: identical move counts, final placements, and float-tight energy for
affinity, anti-affinity, VM-host, and the fundable-capacity fit case
(paper Fig. 1a / Fig. 3: a move admitted only because the fit check sees
the capacity a host could reach if its cap were raised from the unreserved
budget).  Also covers the dense rule encoding (``RulesPack``) and the
per-host-sum cache behind the O(1) fit check.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.core.power_model import PAPER_HOST
from repro.drs import balancer as balancer_mod
from repro.drs import dpm as dpm_mod
from repro.drs import placement, rules as rules_mod
from repro.drs.arrays import RulesPack
from repro.drs.rules import AffinityRule, AntiAffinityRule, VMHostRule
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.sim import workloads
from repro.sim.batch import BatchCell, BatchedSimulator, BatchUnsupported
from repro.sim.cluster import SimConfig
from repro.sim.engine import VectorSimulator

FLOAT_FIELDS = ("cpu_payload_mhz_s", "cpu_demand_mhz_s", "mem_payload_mb_s",
                "mem_demand_mb_s", "energy_j")
INT_FIELDS = ("cap_changes", "vmotions", "power_ons", "power_offs")
POLICIES = ("cpc", "static")


def _manager(policy, max_moves=8, dpm_enabled=False):
    cfg = ManagerConfig(powercap_enabled=(policy == "cpc"),
                        dpm_enabled=dpm_enabled)
    cfg.balancer = balancer_mod.BalancerConfig(max_moves=max_moves)
    if dpm_enabled:
        cfg.dpm = dpm_mod.DPMConfig(stable_window_s=150.0)
    return CloudPowerCapManager(cfg)


def _pair(build, max_moves=8, dpm_enabled=False, slot_slack=3.0):
    """(vector refs by policy, batched results) for one scenario builder."""
    refs, cells = {}, []
    for policy in POLICIES:
        snap, traces, cfg = build()
        sim = VectorSimulator(snap, _manager(policy, max_moves, dpm_enabled),
                              traces, cfg)
        refs[policy] = sim.run()
        snap2, traces2, cfg2 = build()
        cells.append(BatchCell(
            name=policy, snapshot=snap2, traces=traces2, config=cfg2,
            powercap_enabled=(policy == "cpc"), dpm_enabled=dpm_enabled,
            balancer_enabled=max_moves > 0))
    bal = balancer_mod.BalancerConfig(max_moves=max_moves).params()
    from repro.core.kernels import DPMParams
    bsim = BatchedSimulator(
        cells, balancer=bal, slot_slack=slot_slack,
        dpm=DPMParams(stable_window_s=150.0) if dpm_enabled else None)
    return refs, bsim.run()


def _assert_parity(refs, res, rtol=1e-9):
    for i, policy in enumerate(POLICIES):
        ref, acc = refs[policy], res.accumulators(i)
        for f in INT_FIELDS:
            assert getattr(acc, f) == getattr(ref.acc, f), (policy, f)
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(getattr(acc, f), getattr(ref.acc, f),
                                       rtol=rtol, err_msg=(policy, f))


# ------------------------------------------------------------- scenarios
def _rules_build():
    """All three rule kinds violated at t=0 on a 4-host cluster."""
    hosts = [Host(f"host{i}", PAPER_HOST, power_cap=250.0)
             for i in range(4)]
    vms, traces, rng = [], {}, np.random.RandomState(0)
    for i in range(24):
        vm = VirtualMachine(vm_id=f"vm{i}", vcpus=1, memory_mb=8 * 1024,
                            host_id=f"host{i % 4}", reservation=500.0)
        vms.append(vm)
        base = rng.uniform(800, 1500)
        traces[vm.vm_id] = workloads.burst(
            base_cpu=base, burst_cpu=2.2 * base + 2000, mem_mb=2048.0,
            t_start=600.0, t_end=1500.0)
    rules = [AffinityRule(("vm0", "vm1")),
             AntiAffinityRule(("vm4", "vm8")),
             VMHostRule("vm2", frozenset({"host0", "host1"}))]
    snap = ClusterSnapshot(hosts, vms, power_budget=4 * 250.0, rules=rules)
    cfg = SimConfig(duration_s=2100.0, drs_first_at_s=300.0,
                    record_timeline=False, instant_migrations=True)
    return snap, traces, cfg


def _contended_build():
    """Everything piled on host0: the hill-climb balancer must spread it."""
    hosts = [Host(f"host{i}", PAPER_HOST, power_cap=250.0)
             for i in range(3)]
    vms, traces, rng = [], {}, np.random.RandomState(3)
    for i in range(18):
        vm = VirtualMachine(vm_id=f"vm{i}", vcpus=1, memory_mb=8 * 1024,
                            host_id="host0")
        vms.append(vm)
        traces[vm.vm_id] = workloads.constant(rng.uniform(1500, 2500),
                                              2048.0)
    snap = ClusterSnapshot(hosts, vms, power_budget=3 * 250.0)
    cfg = SimConfig(duration_s=1200.0, drs_first_at_s=300.0,
                    record_timeline=False, instant_migrations=True)
    return snap, traces, cfg


def _cap_blocked_build():
    """Paper Fig. 1a: the affinity correction fits only under the fundable
    capacity view, so CloudPowerCap corrects and Static cannot."""
    hosts = [Host("hA", PAPER_HOST, power_cap=250.0),
             Host("hB", PAPER_HOST, power_cap=250.0)]
    vms = [VirtualMachine(vm_id="vm1", reservation=12000.0, demand=12000.0,
                          host_id="hA", mem_demand=1024.0),
           VirtualMachine(vm_id="vm2", reservation=6000.0, demand=6000.0,
                          host_id="hA", mem_demand=1024.0),
           VirtualMachine(vm_id="vm3", reservation=14000.0, demand=14000.0,
                          host_id="hB", mem_demand=1024.0)]
    traces = {v.vm_id: workloads.constant(v.demand, v.mem_demand)
              for v in vms}
    snap = ClusterSnapshot(hosts, vms, power_budget=640.0,
                           rules=[AffinityRule(("vm2", "vm3"))])
    cfg = SimConfig(duration_s=900.0, drs_first_at_s=300.0,
                    record_timeline=False, instant_migrations=True)
    return snap, traces, cfg


def _churn_rules_build():
    """Valley->burst DPM churn with rules constraining evacuations."""
    hosts = [Host(f"host{i}", PAPER_HOST, power_cap=250.0)
             for i in range(3)]
    vms, traces = [], {}
    for i in range(30):
        vm = VirtualMachine(vm_id=f"vm{i}", vcpus=1, memory_mb=8 * 1024,
                            host_id=f"host{i // 10}")
        vms.append(vm)
        traces[vm.vm_id] = workloads.step_trace([
            (0.0, 1200.0, 2 * 1024),
            (700.0, 300.0, 2 * 1024),
            (1400.0, 2400.0, 2 * 1024)])
    rules = [AntiAffinityRule(("vm0", "vm10")),
             VMHostRule("vm1", frozenset({"host0", "host2"}))]
    snap = ClusterSnapshot(hosts, vms, power_budget=900.0, rules=rules)
    cfg = SimConfig(duration_s=2100.0, drs_first_at_s=300.0,
                    record_timeline=False, instant_migrations=True)
    return snap, traces, cfg


# ----------------------------------------------------------------- tests
def test_rule_correction_parity():
    """Affinity + anti-affinity + VM-host corrections: exact parity, and
    the violations are actually fixed in both planes."""
    refs, res = _pair(_rules_build)
    _assert_parity(refs, res)
    for policy in POLICIES:
        assert refs[policy].acc.vmotions >= 3        # all three corrections
        assert not rules_mod.all_violations(refs[policy].final)


def test_balancer_parity_under_contention():
    """The hill-climb balancer picks identical moves in both planes; CPC
    moves fewer VMs because BalancePowerCap shifts Watts first."""
    refs, res = _pair(_contended_build)
    _assert_parity(refs, res)
    assert refs["static"].acc.vmotions > 0
    assert refs["cpc"].acc.vmotions < refs["static"].acc.vmotions
    # Final placements agree: per-host occupancy from the batched engine's
    # accounting equals the vector engine's final snapshot.
    for policy, i in (("cpc", 0), ("static", 1)):
        final = refs[policy].final
        assert sum(len(final.vms_on(h)) for h in final.hosts) == 18


def test_fundable_capacity_fit_parity():
    """Fig. 3: the correction move is admitted only when the fit check sees
    fundable capacity -- CPC corrects (with the cap changes that fund it),
    Static leaves the violation -- identically in both planes."""
    refs, res = _pair(_cap_blocked_build)
    _assert_parity(refs, res)
    assert refs["cpc"].acc.vmotions == 1
    assert refs["cpc"].acc.cap_changes > 0
    assert not rules_mod.all_violations(refs["cpc"].final)
    assert refs["static"].acc.vmotions == 0
    assert rules_mod.all_violations(refs["static"].final)


def test_rule_aware_dpm_evacuation_parity():
    """DPM power-off with placement rules (previously BatchUnsupported):
    evacuation targets respect anti-affinity and VM-host rules, with exact
    lifecycle-count parity."""
    refs, res = _pair(_churn_rules_build, max_moves=0, dpm_enabled=True)
    _assert_parity(refs, res)
    assert refs["cpc"].acc.power_offs == 1
    assert refs["cpc"].acc.vmotions == 10
    # vm0 evacuated off host0 but never onto vm10's host1; vm1 only to its
    # allowed hosts.
    final = refs["cpc"].final
    assert not rules_mod.all_violations(final)


def test_final_placement_parity_via_object_adapter():
    """MigrationCore drives the object snapshot to the same final placement
    the kernels compute (replay fidelity, not just counts)."""
    snap, _, _ = _rules_build()
    work = snap.clone()
    moves = placement.correct_constraints(work)
    assert moves
    for vm_id, dest in moves:
        assert work.vms[vm_id].host_id == dest
    assert not rules_mod.all_violations(work)


def _timed(build, slots=2, bw=None):
    """Wrap a scenario builder in the gated timed-vMotion regime."""
    def b():
        snap, traces, cfg = build()
        cfg = dataclasses.replace(cfg, instant_migrations=False,
                                  migration_slots_per_host=slots,
                                  migration_bandwidth=bw)
        return snap, traces, cfg
    return b


def test_timed_rule_correction_parity():
    """Gated timed vMotion (copy window >= 2 ticks, per-host launch
    slots): corrections launch at the invocation, burn endpoint overhead,
    and commit FIFO -- bit-identical counts and float-tight energy across
    the vector and batched planes."""
    refs, res = _pair(_timed(_rules_build, slots=2))
    _assert_parity(refs, res)
    for policy in POLICIES:
        assert refs[policy].acc.vmotions >= 3
        assert not rules_mod.all_violations(refs[policy].final)


def test_timed_balancer_parity_under_bandwidth_gate():
    """A cluster bandwidth budget of 2 launches per invocation: deferred
    balancer moves are re-scored next round (cascading churn), identically
    in both planes."""
    refs, res = _pair(_timed(_contended_build, slots=None, bw=2))
    _assert_parity(refs, res)
    assert refs["static"].acc.vmotions > 0


def test_timed_churn_rules_parity():
    """The acceptance grid: DPM churn + placement rules + timed gated
    migrations (duration 16 s = 2 ticks, 2 launch slots per host) runs on
    the compiled path with zero fallback cells and exact lifecycle
    parity."""
    build = _timed(_churn_rules_build, slots=2)
    snap, traces, cfg = build()
    assert BatchedSimulator.unsupported_cells(
        [BatchCell("probe", snap, traces, cfg, dpm_enabled=True)]) == {}
    refs, res = _pair(build, max_moves=0, dpm_enabled=True)
    _assert_parity(refs, res)
    assert refs["cpc"].acc.power_offs == 1
    assert refs["cpc"].acc.vmotions == 10


def test_timed_zero_slots_blocks_all_launches():
    """migration_slots_per_host=0 means the manager may launch nothing:
    violations persist, zero vMotions, and both planes agree (None would
    mean *ungated*, so the zero edge must stay expressible)."""
    refs, res = _pair(_timed(_rules_build, slots=0))
    _assert_parity(refs, res)
    for policy in POLICIES:
        assert refs[policy].acc.vmotions == 0
        assert rules_mod.all_violations(refs[policy].final)


def test_timed_evacuation_exempt_from_slot_limits():
    """Power-off is all-or-nothing: a DPM evacuation launches every
    evacuee at once even under a 1-slot-per-host gate, so the in-flight
    count legitimately exceeds the per-host limit while the table
    drains."""
    refs, res = _pair(_timed(_churn_rules_build, slots=1), max_moves=0,
                      dpm_enabled=True)
    _assert_parity(refs, res)
    assert refs["cpc"].acc.power_offs == 1
    assert refs["cpc"].acc.vmotions == 10    # all 10 evacuees moved


def _endpoint_failure_build():
    """Affinity correction whose only admissible move is big -> h1, with
    h1 scripted to fail at t=310 -- mid-copy for a 16 s vMotion launched
    at the t=300 invocation."""
    hosts = [Host("h0", PAPER_HOST, power_cap=320.0),
             Host("h1", PAPER_HOST, power_cap=320.0)]
    vms = [
        VirtualMachine(vm_id="big", reservation=10_000.0, demand=10_000.0,
                       host_id="h0", mem_demand=2048.0),
        VirtualMachine(vm_id="filler", reservation=23_000.0,
                       demand=23_000.0, host_id="h0", mem_demand=512.0),
        VirtualMachine(vm_id="small", reservation=2_000.0, demand=2_000.0,
                       host_id="h1", mem_demand=512.0),
    ]
    traces = {v.vm_id: workloads.constant(v.demand, v.mem_demand)
              for v in vms}
    snap = ClusterSnapshot(hosts, vms, power_budget=640.0,
                           rules=[AffinityRule(("big", "small"))])
    cfg = SimConfig(duration_s=600.0, drs_first_at_s=300.0,
                    record_timeline=False, instant_migrations=False,
                    migration_slots_per_host=2,
                    power_events=((310.0, "h1", False),))
    return snap, traces, cfg


def test_timed_destination_powers_off_mid_flight():
    """Transfers are oblivious to endpoint power flips: the destination
    fails mid-copy, the migration still commits on schedule, and the VM
    lands on the powered-off host -- identically in both planes."""
    snap, traces, cfg = _endpoint_failure_build()
    mgr = _manager("static", max_moves=0)
    ref = VectorSimulator(snap, mgr, traces, cfg).run()
    assert ref.acc.vmotions == 1
    assert ref.final.vms["big"].host_id == "h1"
    assert not ref.final.hosts["h1"].powered_on

    snap2, traces2, cfg2 = _endpoint_failure_build()
    cell = BatchCell("fail", snap2, traces2, cfg2,
                     powercap_enabled=False, balancer_enabled=False)
    res = BatchedSimulator([cell], slot_slack=3.0).run()
    acc = res.accumulators(0)
    for f in INT_FIELDS:
        assert getattr(acc, f) == getattr(ref.acc, f), f
    for f in FLOAT_FIELDS:
        np.testing.assert_allclose(getattr(acc, f), getattr(ref.acc, f),
                                   rtol=1e-9, err_msg=f)
    h1 = list(snap2.hosts).index("h1")
    assert not res.final_on[0, h1]
    assert res.final_occ[0, h1].sum() == 2   # small + the landed big


def test_ungated_timed_migration_rejected():
    """Timed migrations without launch gating (the data-dependent runtime
    concurrency gate) stay on the vector engine, loudly."""
    snap, traces, cfg = _rules_build()
    cfg.instant_migrations = False
    with pytest.raises(BatchUnsupported, match="launch gating"):
        BatchedSimulator([BatchCell("a", snap, traces, cfg)])


def test_unsupported_cells_partition():
    """The per-cell reason map names exactly the offending cells: ungated
    timed cells, and cells disagreeing with the batch's migration-model
    anchor."""
    snap1, traces1, cfg1 = _rules_build()
    snap2, traces2, cfg2 = _rules_build()
    cfg2 = dataclasses.replace(cfg2, instant_migrations=False)
    cells = [BatchCell("good", snap1, traces1, cfg1),
             BatchCell("bad", snap2, traces2, cfg2)]
    reasons = BatchedSimulator.unsupported_cells(cells)
    assert set(reasons) == {"bad"}
    assert "launch gating" in reasons["bad"]
    # A gated timed cell is fine alone but cannot share a program with an
    # instant-model cell: the execution model is compiled in.
    snap3, traces3, cfg3 = _rules_build()
    cfg3 = dataclasses.replace(cfg3, instant_migrations=False,
                               migration_slots_per_host=2)
    assert BatchedSimulator.unsupported_cells(
        [BatchCell("timed", snap3, traces3, cfg3)]) == {}
    mixed = BatchedSimulator.unsupported_cells(
        [BatchCell("good", snap1, traces1, cfg1),
         BatchCell("timed", snap3, traces3, cfg3)])
    assert set(mixed) == {"timed"}
    assert "migration execution model" in mixed["timed"]


# ------------------------------------------------------- rule encoding
def test_rules_pack_encoding():
    vm_index = {f"vm{i}": i for i in range(6)}
    host_index = {f"h{i}": i for i in range(3)}
    pack = RulesPack.from_rules(
        [AffinityRule(("vm0", "vm1")), AffinityRule(("vm1", "vm2")),
         AntiAffinityRule(("vm3", "vm4")),
         VMHostRule("vm5", frozenset({"h0", "h2"}))],
        vm_index, host_index)
    # Overlapping affinity rules merge into one group.
    assert pack.n_groups == 1
    assert pack.max_group_members == 3
    g = pack.affinity_group
    assert g[0] == g[1] == g[2] >= 0 and g[3] == g[4] == g[5] == -1
    assert pack.n_anti == 1
    assert list(pack.anti_member[0]) == [False, False, False, True, True,
                                         False]
    assert pack.n_vmhost == 1
    assert list(pack.allowed[5]) == [True, False, True]
    assert all(pack.allowed[i].all() for i in range(5))


# --------------------------------------------- fit-check sum cache (perf)
def test_fit_check_uses_cached_host_sums():
    """The reservation/memory fit check must not rescan the VM inventory
    per candidate (the old O(V^2 H) balancer pass)."""
    snap, _, _ = _rules_build()
    calls = {"n": 0}
    orig = ClusterSnapshot.vms_on

    def counting_vms_on(self, host_id):
        calls["n"] += 1
        return orig(self, host_id)

    ClusterSnapshot.vms_on = counting_vms_on
    try:
        snap.mem_demand_on("host0")          # build the cache
        calls["n"] = 0
        for _ in range(50):
            placement.fits(snap, "vm0", "host1")
        assert calls["n"] == 0
    finally:
        ClusterSnapshot.vms_on = orig


def test_host_sum_cache_tracks_moves():
    """move_vm keeps the cached per-host sums exact through a long random
    move sequence (regression for the incremental-update path)."""
    snap, _, _ = _rules_build()
    rng = np.random.RandomState(7)
    hosts = list(snap.hosts)
    snap.mem_demand_on(hosts[0])             # build the cache
    vm_ids = list(snap.vms)
    for _ in range(200):
        snap.move_vm(vm_ids[rng.randint(len(vm_ids))],
                     hosts[rng.randint(len(hosts))])
    for h in hosts:
        brute_mem = sum(v.mem_demand for v in snap.vms_on(h))
        brute_cpu = sum(v.reservation for v in snap.vms_on(h))
        np.testing.assert_allclose(snap.mem_demand_on(h), brute_mem)
        np.testing.assert_allclose(snap.cached_cpu_reserved(h), brute_cpu)


def test_multiple_affinity_groups_anchoring_same_host():
    """Two affinity groups both anchoring on the fullest host must BOTH
    gather there (regression: undersized slot headroom silently dropped
    the second group's correction on the object plane)."""
    hosts = [Host(f"host{i}", PAPER_HOST, power_cap=320.0)
             for i in range(4)]
    vms = []
    for g, res in (("a", 100.0), ("b", 90.0)):
        for i in range(4):
            vms.append(VirtualMachine(
                vm_id=f"{g}{i}", reservation=res if i == 0 else 10.0,
                demand=200.0, mem_demand=256.0, host_id=f"host{i}"))
    rules = [AffinityRule(("a0", "a1", "a2", "a3")),
             AffinityRule(("b0", "b1", "b2", "b3"))]
    snap = ClusterSnapshot(hosts, vms, power_budget=4 * 320.0, rules=rules)
    moves = placement.correct_constraints(snap)
    assert len(moves) == 6                       # 3 movers per group
    assert not rules_mod.all_violations(snap)
    assert all(v.host_id == "host0" for v in snap.vms.values())


def test_affinity_retries_other_member_hosts():
    """When the anchor's host cannot admit the group, correction gathers
    it on another member host instead (regression: the multi-home retry
    of the pre-kernel object plane)."""
    hosts = [Host("h0", PAPER_HOST, power_cap=320.0),
             Host("h1", PAPER_HOST, power_cap=320.0)]
    vms = [
        VirtualMachine(vm_id="big", reservation=10_000.0, demand=10_000.0,
                       host_id="h0", mem_demand=512.0),
        VirtualMachine(vm_id="filler", reservation=23_000.0,
                       demand=23_000.0, host_id="h0", mem_demand=512.0),
        VirtualMachine(vm_id="small", reservation=2_000.0, demand=2_000.0,
                       host_id="h1", mem_demand=512.0),
    ]
    # managed(320 W) = 34,800 MHz: h0 cannot take small (35,000), but h1
    # can take big (12,000) -- only the non-anchor home works.
    snap = ClusterSnapshot(hosts, vms, power_budget=640.0,
                           rules=[AffinityRule(("big", "small"))])
    moves = placement.correct_constraints(snap)
    assert moves == [("big", "h1")]
    assert not rules_mod.all_violations(snap)
