"""Heterogeneous clusters: the paper assumes homogeneous racks and sketches
normalization as future work -- our algorithms operate in capacity space
with per-spec Watts<->capacity maps, so mixed fleets work.  Property-test
the safety invariants under heterogeneity."""

import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.balance import BalanceConfig, balance_power_cap
from repro.core.power_model import HostPowerSpec
from repro.core.redistribute import redistribute_for_power_on
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine

SPECS = [
    HostPowerSpec(capacity_peak=34_800.0, power_idle=160.0,
                  power_peak=320.0, memory_mb=96 * 1024),
    HostPowerSpec(capacity_peak=52_000.0, power_idle=210.0,
                  power_peak=450.0, memory_mb=192 * 1024),   # newer gen
    HostPowerSpec(capacity_peak=20_000.0, power_idle=90.0,
                  power_peak=200.0, memory_mb=64 * 1024),    # low-power
]


@st.composite
def hetero_clusters(draw):
    n = draw(st.integers(2, 6))
    hosts = []
    for i in range(n):
        spec = SPECS[draw(st.integers(0, len(SPECS) - 1))]
        frac = draw(st.floats(0.3, 1.0))
        cap = spec.power_idle + frac * (spec.power_peak - spec.power_idle)
        hosts.append(Host(f"h{i}", spec, power_cap=cap))
    vms = []
    for j in range(draw(st.integers(2, 12))):
        host = hosts[draw(st.integers(0, n - 1))]
        demand = draw(st.floats(0.0, 0.9)) * host.managed_capacity
        vms.append(VirtualMachine(vm_id=f"v{j}", demand=demand,
                                  mem_demand=1024.0, host_id=host.host_id))
    budget = sum(h.power_cap for h in hosts)
    return ClusterSnapshot(hosts, vms, power_budget=budget)


@settings(max_examples=60, deadline=None)
@given(hetero_clusters())
def test_hetero_balance_safety(snap):
    before_watts = snap.total_allocated_power()
    before_imb = snap.imbalance()
    balanced, did = balance_power_cap(snap, BalanceConfig())
    assert balanced.total_allocated_power() <= before_watts + 1e-6, \
        "heterogeneous Watts<->capacity maps must not mint power"
    assert balanced.imbalance() <= before_imb + 1e-9
    for h in balanced.powered_on_hosts():
        assert balanced.reservations_respected(h.host_id)
        spec = h.spec
        assert spec.power_idle - 1e-9 <= h.power_cap <= \
            spec.power_peak + 1e-9


@settings(max_examples=40, deadline=None)
@given(hetero_clusters())
def test_hetero_power_on_funding(snap):
    standby = Host("standby", SPECS[1], power_cap=0.0, powered_on=False)
    snap.hosts["standby"] = standby
    funded, granted = redistribute_for_power_on(snap, "standby")
    total = sum(h.power_cap for h in funded.hosts.values()
                if h.powered_on or h.host_id == "standby")
    assert total <= funded.power_budget + 1e-6
    for h in funded.powered_on_hosts():
        assert funded.reservations_respected(h.host_id)


def test_hetero_balance_prefers_efficient_watts():
    """Watts flow where they buy the most capacity: the efficient host can
    serve the same demand at fewer Watts, so a saturated efficient host
    pulls budget from an idle inefficient one."""
    eff = SPECS[1]    # 52 GHz / (450-210) W  -> 217 MHz/W
    ineff = SPECS[0]  # 34.8 GHz / 160 W      -> 217 MHz/W... use low-power
    hosts = [Host("eff", eff, power_cap=eff.power_idle + 60.0),
             Host("idle", ineff, power_cap=320.0)]
    vms = [VirtualMachine(vm_id="hot", demand=30_000.0, mem_demand=1024,
                          host_id="eff"),
           VirtualMachine(vm_id="cold", demand=1_000.0, mem_demand=1024,
                          host_id="idle")]
    snap = ClusterSnapshot(hosts, vms,
                           power_budget=sum(h.power_cap for h in hosts))
    balanced, did = balance_power_cap(snap, BalanceConfig())
    assert did
    assert balanced.hosts["eff"].power_cap > hosts[0].power_cap
    assert balanced.total_allocated_power() <= snap.power_budget + 1e-6
