"""Sharded sweep engine == single device, bit for bit.

Cells are embarrassingly parallel: sharding the S-cells axis over a
``("cells",)`` mesh runs the identical compiled per-cell arithmetic on a
smaller leading dimension, so every per-cell result -- cap-change counts,
migrations, power events, energy, payload, final placements -- must be
*bit-identical* to the single-device run.  The multi-device tests run in a
subprocess so the 8 fake host devices don't leak into other tests' jax
runtime (same pattern as ``test_moe_shardmap.py``); the in-process tests
cover the pad-bucket partitioner and the padding arithmetic on however
many devices the plain runtime has.
"""

import subprocess
import sys
import textwrap

import pytest

from repro.sim import sweep as sw

POLICIES = ("cpc", "static")


def _hetero_specs():
    """Two pad buckets: (4, 16) and (16, 16), with migrations live."""
    return [
        sw.SweepSpec(name="s4", n_hosts=4, spike="burst",
                     duration_s=600.0, tick_s=30.0),
        sw.SweepSpec(name="s4r", n_hosts=4, spike="prime",
                     rules="violation_burst", duration_s=600.0,
                     tick_s=30.0),
        sw.SweepSpec(name="s12", n_hosts=12, spike="step",
                     heterogeneous=True, duration_s=600.0, tick_s=30.0),
        sw.SweepSpec(name="s10", n_hosts=10, spike="burst",
                     duration_s=600.0, tick_s=30.0),
    ]


def test_bucketed_run_sweep_matches_exact_pack():
    """The pow2 pad-bucket path reproduces the exact-pack engine: padding
    hosts/slots only adds inert rows to independent cells, so protocol
    counts are identical; float payload/energy may drift in the last ulp
    because a different slot-axis width changes XLA's reduction tree."""
    import numpy as np

    specs = _hetero_specs()
    res_b = sw.run_sweep(specs, policies=POLICIES, engine="batch",
                         n_devices=1)
    buckets = {tuple(b["bucket"]) for b in sw.LAST_BATCH_INFO}
    assert len(buckets) >= 2, buckets
    res_e = sw.run_sweep_batched(specs, policies=POLICIES, n_devices=1)
    for name in res_e:
        for p in POLICIES:
            a, b = res_b[name][p], res_e[name][p]
            assert (a.cap_changes, a.vmotions, a.power_ons, a.power_offs) \
                == (b.cap_changes, b.vmotions, b.power_ons, b.power_offs)
            np.testing.assert_allclose(a.energy_j, b.energy_j, rtol=1e-12)
            np.testing.assert_allclose(a.cpu_payload_mhz_s,
                                       b.cpu_payload_mhz_s, rtol=1e-12)


def test_run_sweep_batch_preserves_grid_order():
    specs = _hetero_specs()[::-1]          # big bucket first in the input
    res = sw.run_sweep(specs, policies=POLICIES, engine="batch",
                       n_devices=1)
    assert list(res) == [s.name for s in specs]
    assert all(list(by_p) == list(POLICIES) for by_p in res.values())


SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.sim import sweep as sw
    from repro.sim.batch import BatchedSimulator

    assert len(jax.devices()) == 8
    specs = [
        sw.SweepSpec(name="s4", n_hosts=4, spike="burst",
                     duration_s=600.0, tick_s=30.0),
        sw.SweepSpec(name="s4r", n_hosts=4, spike="prime",
                     rules="violation_burst", duration_s=600.0,
                     tick_s=30.0),
        sw.SweepSpec(name="s12", n_hosts=12, spike="step",
                     heterogeneous=True, duration_s=600.0, tick_s=30.0),
        sw.SweepSpec(name="s10", n_hosts=10, spike="burst",
                     duration_s=600.0, tick_s=30.0),
    ]
    policies = ("cpc", "static")

    res1 = sw.run_sweep(specs, policies=policies, engine="batch",
                        n_devices=1)
    res8 = sw.run_sweep(specs, policies=policies, engine="batch")
    buckets = [(tuple(b["bucket"]), b["n_devices"])
               for b in sw.LAST_BATCH_INFO]
    assert len({b for b, _ in buckets}) >= 2, buckets
    assert any(n > 1 for _, n in buckets), buckets

    migrated = False
    for name in res1:
        for p in policies:
            a, b = res1[name][p], res8[name][p]
            assert a.cap_changes == b.cap_changes, (name, p)
            assert a.vmotions == b.vmotions, (name, p)
            assert a.power_ons == b.power_ons, (name, p)
            assert a.power_offs == b.power_offs, (name, p)
            assert a.energy_j == b.energy_j, (name, p)
            assert a.cpu_payload_mhz_s == b.cpu_payload_mhz_s, (name, p)
            migrated |= a.vmotions > 0
    assert migrated          # the grid exercised the migration layer

    # Final placements, straight off the batched engine: one bucket's
    # cells on 1 device vs sharded over 8.
    cells, _ = sw._build_batch_cells(
        [s for s in specs if s.n_hosts > 8], policies)
    r1 = BatchedSimulator(cells, n_devices=1).run()
    r4 = BatchedSimulator(cells, n_devices=4).run()
    assert r4.n_devices == 4
    assert np.array_equal(r1.final_occ, r4.final_occ)
    assert np.array_equal(r1.final_caps, r4.final_caps)
    assert np.array_equal(r1.final_on, r4.final_on)
    print("SHARDED_PARITY_OK")
""")


@pytest.mark.slow
def test_sharded_vs_single_device_bit_identical_subprocess():
    import os
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT], capture_output=True,
        text=True, timeout=900,
        env=os.environ.copy() | {"PYTHONPATH": "src"})
    assert "SHARDED_PARITY_OK" in out.stdout, out.stderr[-2000:]


ROW_CONTENTION_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.sim import sweep as sw

    assert len(jax.devices()) == 8
    specs = sw.row_contention_specs(sizes=(10,), duration_s=600.0)
    policies = ("cpc", "static")
    res1 = sw.run_sweep(specs, policies=policies, engine="batch",
                        n_devices=1)
    res8 = sw.run_sweep(specs, policies=policies, engine="batch")
    assert any(n_dev > 1 for _, n_dev in
               [(tuple(b["bucket"]), b["n_devices"])
                for b in sw.LAST_BATCH_INFO])
    for name in res1:
        for p in policies:
            a, b = res1[name][p], res8[name][p]
            assert a.cap_changes == b.cap_changes, (name, p)
            assert a.energy_j == b.energy_j, (name, p)
            assert a.cpu_payload_mhz_s == b.cpu_payload_mhz_s, (name, p)
    assert any(res1[name]["cpc"].cap_changes > 0 for name in res1)
    print("ROW_CONTENTION_SHARDED_OK")
""")


@pytest.mark.slow
def test_row_contention_sharded_bit_identical_subprocess():
    """The budget-tree columns shard with the cells axis: the two_row grid
    on 8 forced virtual devices is bit-identical to the single-device run,
    with the cpc cell really redistributing under its binding row."""
    import os
    out = subprocess.run(
        [sys.executable, "-c", ROW_CONTENTION_SCRIPT], capture_output=True,
        text=True, timeout=900,
        env=os.environ.copy() | {"PYTHONPATH": "src"})
    assert "ROW_CONTENTION_SHARDED_OK" in out.stdout, out.stderr[-2000:]
