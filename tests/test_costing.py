"""Roofline costing: jaxpr FLOP/byte counter and HLO collective parser."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.costing import (cost_of, hlo_collective_bytes,
                                  jaxpr_cost)


def test_dot_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = cost_of(f, a, b)
    assert c["flops"] == 2 * 64 * 128 * 32
    assert c["bytes"] == (64 * 128 + 128 * 32 + 64 * 32) * 4


def test_scan_trip_count_multiplies():
    def f(x):
        def body(carry, _):
            return carry @ carry, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    c = cost_of(f, x)
    assert c["flops"] == 7 * 2 * 16 * 16 * 16


def test_grad_includes_remat_recompute():
    def layer(x, w):
        return jnp.tanh(x @ w)

    def loss_plain(x, w):
        return jnp.sum(layer(x, w))

    def loss_remat(x, w):
        return jnp.sum(jax.checkpoint(layer)(x, w))

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    plain = cost_of(jax.grad(loss_plain, argnums=1), x, w)
    remat = cost_of(jax.grad(loss_remat, argnums=1), x, w)
    assert remat["flops"] > plain["flops"], \
        "remat recompute must be visible to the counter"


def test_hlo_collective_parser_with_while_trips():
    hlo = """
body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

cond.1 (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY main.1 (a: f32[8]) -> f32[8] {
  %ag = f32[16]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""
    out = hlo_collective_bytes(hlo)
    # all-gather once: 16*4 = 64 B; all-reduce 5 trips x 8*4 x2 (ring) = 320.
    assert out["all-gather"] == 64
    assert out["all-reduce"] == 5 * 32 * 2
    assert out["total"] == 64 + 320


def test_f32_as_bf16_equivalence_mode():
    hlo = """
ENTRY main.1 (a: f32[8]) -> f32[8] {
  %ag = f32[16]{0} all-gather(%a), dimensions={0}
  ROOT %r = f32[8] slice(%ag)
}
"""
    raw = hlo_collective_bytes(hlo)
    eq = hlo_collective_bytes(hlo, f32_as_bf16=True)
    assert raw["all-gather"] == 64 and eq["all-gather"] == 32


def test_shard_map_scaled_by_mesh():
    import os
    if len(jax.devices()) < 1:
        return
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import AxisType, make_mesh_compat
    mesh = make_mesh_compat((1,), ("m",), axis_types=(AxisType.Auto,))

    def f(x):
        return shard_map(lambda v: v @ v, mesh=mesh, in_specs=P(None, None),
                         out_specs=P(None, None), check_rep=False)(x)
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    c = cost_of(f, x)
    assert c["flops"] == 2 * 8 * 8 * 8 * mesh.size
