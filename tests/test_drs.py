"""DRS substrate: rules, constraint correction, balancer, DPM."""

import pytest

from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.core.power_model import PAPER_HOST
from repro.drs import balancer, dpm, placement, rules
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine


def _cluster(caps, budget=None, rule_list=None):
    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=c)
             for i, c in enumerate(caps)]
    return ClusterSnapshot(hosts, [], budget or sum(caps),
                           rules=rule_list or [])


def test_affinity_violation_and_correction():
    snap = _cluster([320.0, 320.0])
    snap.vms["a"] = VirtualMachine(vm_id="a", host_id="h0", demand=1000,
                                   mem_demand=1024)
    snap.vms["b"] = VirtualMachine(vm_id="b", host_id="h1", demand=1000,
                                   mem_demand=1024)
    rule = rules.AffinityRule(("a", "b"))
    snap.rules.append(rule)
    assert rule.violations(snap)
    moves = placement.correct_constraints(snap)
    assert len(moves) == 1
    assert not rule.violations(snap)


def test_anti_affinity_correction():
    snap = _cluster([320.0, 320.0])
    for vid in ("a", "b"):
        snap.vms[vid] = VirtualMachine(vm_id=vid, host_id="h0", demand=1000,
                                       mem_demand=1024)
    rule = rules.AntiAffinityRule(("a", "b"))
    snap.rules.append(rule)
    assert rule.violations(snap)
    placement.correct_constraints(snap)
    assert not rule.violations(snap)


def test_paper_fig1a_requires_cap_redistribution():
    """Fig. 1a: combined reservations need a cap raise on the target host.

    With static caps the affinity correction is infeasible; with the
    CloudPowerCap manager (flexible power) it succeeds.
    """
    hosts = [Host("hA", PAPER_HOST, power_cap=250.0),
             Host("hB", PAPER_HOST, power_cap=250.0)]
    # Capacity at 250 W = 19.575 GHz.  VM1 12 GHz + VM2 6 GHz on A;
    # VM3 14 GHz on B.  Affinity(VM2, VM3): B would need 20 GHz > 19.575.
    vms = [
        VirtualMachine(vm_id="vm1", reservation=12000.0, demand=12000.0,
                       host_id="hA", mem_demand=1024),
        VirtualMachine(vm_id="vm2", reservation=6000.0, demand=6000.0,
                       host_id="hA", mem_demand=1024),
        VirtualMachine(vm_id="vm3", reservation=14000.0, demand=14000.0,
                       host_id="hB", mem_demand=1024),
    ]
    rule = rules.AffinityRule(("vm2", "vm3"))
    snap = ClusterSnapshot(hosts, vms, power_budget=500.0, rules=[rule])

    static = snap.clone()
    moves = placement.correct_constraints(static)
    assert rule.violations(static), "static caps cannot correct this"

    mgr = CloudPowerCapManager(ManagerConfig(dpm_enabled=False))
    result = mgr.run_invocation(snap.clone())
    assert not rules.all_violations(result.snapshot)
    assert result.migrations >= 1
    assert result.cap_changes >= 1
    result.snapshot.validate()


def test_balancer_contention_gate():
    snap = _cluster([320.0, 320.0])
    for i in range(4):
        snap.vms[f"v{i}"] = VirtualMachine(
            vm_id=f"v{i}", host_id="h0" if i < 3 else "h1",
            demand=3000.0, mem_demand=1024)
    # Imbalanced but uncontended: no moves.
    assert balancer.balance(snap.clone()) == []


def test_balancer_moves_under_contention():
    snap = _cluster([250.0, 250.0])
    for i in range(8):
        snap.vms[f"v{i}"] = VirtualMachine(
            vm_id=f"v{i}", host_id="h0", demand=3000.0, mem_demand=1024)
    moves = balancer.balance(snap)
    assert len(moves) >= 3
    assert snap.imbalance() < 0.3


def test_dpm_power_on_trigger():
    snap = _cluster([250.0, 250.0, 250.0])
    snap.hosts["h2"].powered_on = False
    for i in range(10):
        snap.vms[f"v{i}"] = VirtualMachine(
            vm_id=f"v{i}", host_id=f"h{i % 2}", demand=9000.0,
            mem_demand=1024)
    rec = dpm.run_dpm(snap, dpm.DPMConfig())
    assert rec.power_on == "h2"


def test_dpm_power_off_requires_sustained_low():
    snap = _cluster([250.0, 250.0])
    snap.vms["v0"] = VirtualMachine(vm_id="v0", host_id="h0", demand=500.0,
                                    mem_demand=512)
    cfg = dpm.DPMConfig(stable_window_s=300.0)
    rec = dpm.run_dpm(snap, cfg, low_since={"h0": 100.0, "h1": 100.0},
                      now=200.0)
    assert rec.power_off is None          # only low for 100 s
    rec = dpm.run_dpm(snap, cfg, low_since={"h0": 100.0, "h1": 100.0},
                      now=500.0)
    assert rec.power_off is not None      # sustained


def test_dpm_power_off_respects_nonmigratable():
    snap = _cluster([250.0, 250.0])
    snap.vms["pinned"] = VirtualMachine(vm_id="pinned", host_id="h1",
                                        demand=200.0, mem_demand=512,
                                        migratable=False)
    cfg = dpm.DPMConfig(stable_window_s=0.0)
    rec = dpm.run_dpm(snap, cfg, low_since={"h0": 0.0, "h1": 0.0}, now=1e5)
    # h1's pinned VM cannot move; h1 (least utilized may be h0) -- whichever
    # host is chosen, no recommendation may strand the pinned VM.
    if rec.power_off == "h1":
        pytest.fail("power-off recommended for host with pinned VM")
