"""CloudPowerCap Algorithms 1-3: safety + fairness properties."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.balance import BalanceConfig, balance_power_cap
from repro.core.power_model import PAPER_HOST
from repro.core.redistribute import (redistribute_after_power_off,
                                     redistribute_for_power_on)
from repro.core.redivvy import get_flexible_power, redivvy_power_cap
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine


@st.composite
def clusters(draw):
    n_hosts = draw(st.integers(2, 6))
    cap = draw(st.floats(200.0, 320.0))
    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=cap)
             for i in range(n_hosts)]
    vms = []
    for i in range(draw(st.integers(1, 14))):
        host = f"h{draw(st.integers(0, n_hosts - 1))}"
        res = draw(st.floats(0.0, 3000.0))
        demand = draw(st.floats(0.0, 12000.0))
        vms.append(VirtualMachine(
            vm_id=f"vm{i}", reservation=res, demand=demand,
            memory_mb=8 * 1024, mem_demand=2 * 1024, host_id=host))
    snap = ClusterSnapshot(hosts, vms, power_budget=n_hosts * cap)
    # Admission control: drop VMs whose reservations overflow their host.
    for h in hosts:
        while snap.cpu_reserved(h.host_id) > h.managed_capacity:
            victim = max(snap.vms_on(h.host_id), key=lambda v: v.reservation)
            del snap.vms[victim.vm_id]
    return snap


@settings(max_examples=60, deadline=None)
@given(clusters())
def test_balance_safety(snap):
    before_total = snap.total_allocated_power()
    balanced, did = balance_power_cap(snap, BalanceConfig())
    # Budget conserved (never grows), reservations respected.
    assert balanced.total_allocated_power() <= before_total + 1e-6
    for h in balanced.powered_on_hosts():
        assert balanced.reservations_respected(h.host_id)
    # Imbalance never increases.
    assert balanced.imbalance() <= snap.imbalance() + 1e-9


@settings(max_examples=60, deadline=None)
@given(clusters())
def test_redivvy_conservation(snap):
    flex = get_flexible_power(snap)
    new_caps = redivvy_power_cap(snap, flex)
    total = sum(new_caps.values())
    assert total <= snap.power_budget + 1e-6
    for host_id, cap in new_caps.items():
        # Reservations still supported at the new cap.
        host = flex.hosts[host_id]
        assert host.spec.managed_capacity(cap) >= \
            flex.cpu_reserved(host_id) - 1e-6


@settings(max_examples=60, deadline=None)
@given(clusters())
def test_power_on_funding(snap):
    # Add a standby host, then fund it.
    standby = Host("standby", PAPER_HOST, power_cap=0.0, powered_on=False)
    snap.hosts["standby"] = standby
    snap.power_budget += 0.0  # budget unchanged: funding must come from peers
    funded, granted = redistribute_for_power_on(snap, "standby")
    total = sum(h.power_cap for h in funded.hosts.values()
                if h.powered_on or h.host_id == "standby")
    assert total <= funded.power_budget + 1e-6
    assert granted <= PAPER_HOST.power_peak + 1e-9
    for h in funded.powered_on_hosts():
        assert funded.reservations_respected(h.host_id)


@settings(max_examples=60, deadline=None)
@given(clusters())
def test_power_off_reabsorption(snap):
    victim = snap.powered_on_hosts()[0]
    # Evacuate it first (reservations must not be stranded).
    others = [h.host_id for h in snap.powered_on_hosts()[1:]]
    if not others:
        return
    for vm in snap.vms_on(victim.host_id):
        vm.host_id = others[0]
    for h in snap.powered_on_hosts():
        if not snap.reservations_respected(h.host_id):
            return  # inadmissible scenario after forced evacuation
    out = redistribute_after_power_off(snap, victim.host_id)
    assert not out.hosts[victim.host_id].powered_on
    assert out.hosts[victim.host_id].power_cap == 0.0
    assert out.total_allocated_power() <= out.power_budget + 1e-6
    # Freed Watts flow to hosts below peak.
    before = {h.host_id: snap.hosts[h.host_id].power_cap
              for h in out.powered_on_hosts()}
    assert all(out.hosts[k].power_cap >= v - 1e-9
               for k, v in before.items())


def test_balance_paper_headroom_example():
    """Fig. 1b-style: 24 GHz demand against a 19.575 GHz capped host."""
    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=250.0) for i in range(3)]
    vms = []
    for i in range(30):
        demand = 2400.0 if i < 10 else 1000.0
        vms.append(VirtualMachine(vm_id=f"vm{i}", demand=demand,
                                  host_id=f"h{i // 10}"))
    snap = ClusterSnapshot(hosts, vms, power_budget=750.0)
    balanced, did = balance_power_cap(snap, BalanceConfig())
    assert did
    # The hot host's capacity now covers its demand; donors still cover
    # theirs; Watts conserved.
    assert balanced.hosts["h0"].managed_capacity >= 24000.0 - 50.0
    for h in ("h1", "h2"):
        assert balanced.hosts[h].managed_capacity >= 10000.0 - 50.0
    assert balanced.total_allocated_power() <= 750.0 + 1e-6
