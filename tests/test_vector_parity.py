"""Vectorized engine parity: VectorSimulator must reproduce Simulator.

The array-based engine replays the paper's evaluation scenarios and must
match the per-object reference engine's Table III/IV/V metrics -- exactly
for the integer action counts, to float tolerance for the payload/energy
integrals.  Also covers the two primitives the engine is built on: the
batched waterfill against the scalar one, and TraceBank against the
callable traces.
"""

import numpy as np
import pytest

from repro.drs.entitlement import batched_waterfill, waterfill
from repro.sim import workloads
from repro.sim.experiments import POLICIES, run_policy
from repro.sim.workloads import TraceBank

INT_FIELDS = ("vmotions", "cap_changes", "power_ons", "power_offs")
FLOAT_FIELDS = ("cpu_payload_mhz_s", "cpu_demand_mhz_s", "mem_payload_mb_s",
                "mem_demand_mb_s", "energy_j")


def _assert_acc_parity(legacy, vector, rtol=1e-9):
    for f in INT_FIELDS:
        assert getattr(legacy, f) == getattr(vector, f), f
    for f in FLOAT_FIELDS:
        np.testing.assert_allclose(getattr(vector, f), getattr(legacy, f),
                                   rtol=rtol, err_msg=f)
    assert set(legacy.tag_payload) == set(vector.tag_payload)
    for tag in legacy.tag_payload:
        np.testing.assert_allclose(vector.tag_payload[tag],
                                   legacy.tag_payload[tag], rtol=rtol)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("scenario", ("headroom", "standby"))
def test_paper_scenario_parity(scenario, policy):
    legacy = run_policy(scenario, policy, engine="legacy")
    vector = run_policy(scenario, policy, engine="vector")
    _assert_acc_parity(legacy.acc, vector.acc)
    if legacy.window_acc is not None:
        _assert_acc_parity(legacy.window_acc, vector.window_acc)
    # Event streams (cap changes, power ops, DRS notes) must line up too.
    assert [e for _, e in legacy.events] == [e for _, e in vector.events]


@pytest.mark.slow
@pytest.mark.parametrize("policy", POLICIES)
def test_flexible_scenario_parity(policy):
    legacy = run_policy("flexible", policy, engine="legacy")
    vector = run_policy("flexible", policy, engine="vector")
    _assert_acc_parity(legacy.acc, vector.acc)


def test_batched_waterfill_matches_scalar():
    rng = np.random.RandomState(42)
    for _ in range(50):
        n_segs = rng.randint(1, 6)
        caps = rng.uniform(0.0, 30000.0, n_segs)
        floors, ceils, weights, seg = [], [], [], []
        for s in range(n_segs):
            k = rng.randint(0, 10)
            f = rng.uniform(0.0, 3000.0, k)
            floors.append(f)
            ceils.append(f + rng.uniform(0.0, 9000.0, k))
            weights.append(rng.uniform(1.0, 4000.0, k))
            seg.append(np.full(k, s, dtype=np.int64))
        floors, ceils, weights, seg = map(
            np.concatenate, (floors, ceils, weights, seg))
        out = batched_waterfill(caps, floors, ceils, weights, seg, n_segs)
        for s in range(n_segs):
            m = seg == s
            ref = waterfill(caps[s], floors[m], ceils[m], weights[m])
            np.testing.assert_allclose(out[m], ref, rtol=1e-7, atol=1e-6)


def test_batched_waterfill_conserves_capacity():
    caps = np.array([10000.0, 0.0, 500.0])
    floors = np.array([0.0, 100.0, 0.0, 200.0, 300.0])
    ceils = np.array([8000.0, 9000.0, 50.0, 400.0, 600.0])
    weights = np.ones(5)
    seg = np.array([0, 0, 1, 2, 2])
    out = batched_waterfill(caps, floors, ceils, weights, seg, 3)
    # Segment sums never exceed capacity (except floor-degenerate pro-rata).
    assert np.bincount(seg, weights=out, minlength=3)[0] <= 10000.0 + 1e-6
    assert out[2] == pytest.approx(0.0)      # capacity 0, floor 0


def test_trace_bank_matches_callables():
    traces = {
        "a": workloads.constant(1000.0, 2048.0),
        "b": workloads.step_trace([(0.0, 500.0, 1024.0),
                                   (300.0, 900.0, 2048.0),
                                   (900.0, 100.0, 512.0)]),
        "c": workloads.burst(800.0, 2400.0, 4096.0, 750.0, 1400.0),
        "d": workloads.prime_time(200.0, 5200.0, 1024.0, 7168.0,
                                  period_s=21600.0, prime_start_frac=0.25,
                                  prime_frac=0.5),
        "e": workloads.prime_time(100.0, 900.0, 64.0, 128.0,
                                  period_s=1000.0, prime_start_frac=0.0,
                                  prime_frac=0.4),
        # No-spec callable exercises the fallback path.
        "f": lambda t: (42.0 + t, 7.0),
    }
    order = ["a", "b", "c", "d", "e", "f"]
    bank = TraceBank.from_traces(traces, order)
    for t in np.arange(0.0, 43200.0, 150.0):
        rows, cpu, mem = bank.eval(float(t))
        got = {order[r]: (c, m) for r, c, m in zip(rows, cpu, mem)}
        for vid, trace in traces.items():
            assert got[vid] == trace(float(t)), (vid, t)


def test_prime_time_wrap_spec():
    """Prime window wrapping past the period boundary still matches."""
    tr = workloads.prime_time(100.0, 900.0, 1.0, 2.0, period_s=1000.0,
                              prime_start_frac=0.8, prime_frac=0.4)
    bank = TraceBank.from_traces({"x": tr}, ["x"])
    for t in np.arange(0.0, 3000.0, 25.0):
        _, cpu, mem = bank.eval(float(t))
        assert (cpu[0], mem[0]) == tr(float(t)), t
