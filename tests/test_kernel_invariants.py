"""Property-based invariants of the hot allocation kernels, per executor.

Where ``test_kernel_parity`` pins the executors to each *other*, this file
pins them to the *math*: every invariant below must hold on the ``numpy``,
``jax``, and ``jax-pallas`` executors alike, exercised through the
production dispatchers (``waterfill_dense`` / ``balance_caps``) under
``executor_scope`` so each run takes the same code path the simulator
takes.

Waterfill (weighted max-min):
  * allocations never drop below reserved floors (outside the degenerate
    floors-exceed-capacity regime, where floors are granted pro-rata),
  * never exceed ceilings, and inactive slots allocate exactly nothing,
  * per-host totals never exceed host capacity,
  * totals are monotone in capacity (more budget never shrinks anyone).

BalancePowerCap -- on *any* specs:
  * the cap-spread (population stddev of normalized entitlements over
    powered-on hosts) never increases -- the loop's ``worse`` guard reverts
    any non-improving round,
  * ``did == False`` cells pass through bit-identical.

BalancePowerCap -- on *homogeneous* host specs (identical power/capacity
maps within a cell, the paper's cluster setting; heterogeneous maps make
Watts conservation approximate by design -- the kernel's over-budget trim
is documented as a safety net, not an exact bound):
  * hosts that shrank keep ``managed >= cpu_reserved`` (their VMs'
    reservations stay admissible),
  * the powered-on cap total never grows past the cluster budget -- or,
    when the budget starts out violated (``budget_below_floor``), past the
    total it started with.

Like the parity harness, fuzzing runs as an always-on seed sweep plus
hypothesis-driven generation when hypothesis is available.
"""

import numpy as np
import pytest

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro import backend as backend_mod
from repro.backend import NUMPY
from repro.core import kernels
from repro.drs.entitlement import waterfill_dense, waterfill_dense_math

from test_kernel_parity import SCENARIOS, balance_problem, dense_problem

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis-driven fuzzing needs hypothesis (requirements.txt)")

EXECUTORS = ("numpy", "jax", "jax-pallas")
SEEDS = tuple(range(4))


# ------------------------------------------------------- executor runners
def run_waterfill(executor, capacity, floors, ceils, weights, active):
    """The production ``waterfill_dense`` dispatcher on the named executor,
    result on the NumPy plane."""
    if executor == "numpy":
        with backend_mod.executor_scope(executor):
            return waterfill_dense(np, NUMPY.fori, capacity, floors, ceils,
                                   weights, active=active)
    be = backend_mod.jax_backend()
    with enable_x64(), backend_mod.executor_scope(executor):
        out = waterfill_dense(jnp, be.fori, jnp.asarray(capacity),
                              jnp.asarray(floors), jnp.asarray(ceils),
                              jnp.asarray(weights),
                              active=jnp.asarray(active))
        return np.asarray(out)


def run_balance(executor, problem):
    """The production ``balance_caps`` driver on the named executor, with
    the dense-slot ``ents_at`` that executor would use in the simulator."""
    hosts, caps0, dense, cpu_res, budget, enabled = problem
    params = kernels.BalanceParams()
    if executor == "numpy":
        def ents_at(c):
            managed = kernels.managed_capacity(np, hosts, c)
            alloc = waterfill_dense(np, NUMPY.fori, managed, dense.floors,
                                    dense.ceils, dense.weights,
                                    active=dense.active)
            return np.sum(alloc, axis=-1)

        with backend_mod.executor_scope(executor):
            caps, did = kernels.balance_caps(
                NUMPY, hosts, caps0.copy(), ents_at, cpu_res, budget,
                enabled, params)
        return np.asarray(caps), np.asarray(did)
    be = backend_mod.jax_backend()
    with enable_x64(), backend_mod.executor_scope(executor):
        hosts_j = kernels.HostCols(*(jnp.asarray(c) for c in hosts))
        dense_j = kernels.DenseCols(
            jnp.asarray(dense.floors), jnp.asarray(dense.ceils),
            jnp.asarray(dense.weights), jnp.asarray(dense.active))

        def ents_at(c):
            managed = kernels.managed_capacity(jnp, hosts_j, c)
            alloc = waterfill_dense(jnp, be.fori, managed, dense_j.floors,
                                    dense_j.ceils, dense_j.weights,
                                    active=dense_j.active)
            return jnp.sum(alloc, axis=-1)

        caps, did = kernels.balance_caps(
            be, hosts_j, jnp.asarray(caps0), ents_at, jnp.asarray(cpu_res),
            jnp.asarray(budget), jnp.asarray(enabled), params,
            dense=dense_j)
        return np.asarray(caps), np.asarray(did)


def homogeneous_balance_problem(seed, scenario, s=2, h=5, j=6):
    """``balance_problem`` with per-cell *uniform* host specs, so the
    Watts<->capacity maps are identical within a cell and transfers conserve
    Watts exactly (the regime where the reserved-floor and budget bounds
    are exact kernel guarantees, not safety nets)."""
    hosts, _, dense, _, _, enabled = balance_problem(seed, scenario, s, h, j)

    def col(a):
        return np.broadcast_to(np.asarray(a)[..., :1], (s, h)).copy()

    hosts = kernels.HostCols(hosts.on, col(hosts.power_idle),
                             col(hosts.power_peak),
                             col(hosts.capacity_peak),
                             col(hosts.hyp_overhead))
    rng = np.random.default_rng(seed ^ 0x40)
    caps0 = rng.uniform(hosts.power_idle, hosts.power_peak)
    managed0 = kernels.managed_capacity(np, hosts, caps0)
    cpu_res = managed0 * rng.uniform(0.0, 0.8, (s, h))
    budget = np.sum(np.where(hosts.on, caps0, 0.0), axis=-1)
    if scenario == "budget_below_floor":
        budget = budget * 0.5
    return hosts, caps0, dense, cpu_res, budget, enabled


def _spread(hosts, caps, dense):
    """Cap-spread on the NumPy plane: masked stddev of normalized
    entitlements over powered-on hosts (what the loop's ``worse`` guard
    measures, recomputed in float64)."""
    managed = kernels.managed_capacity(np, hosts, caps)
    alloc = waterfill_dense_math(np, NUMPY.fori, managed, dense.floors,
                                 dense.ceils, dense.weights,
                                 active=dense.active)
    ents = np.sum(alloc, axis=-1)
    ns = np.where(managed > 0.0, ents / np.maximum(managed, 1e-300), 0.0)
    n_on = np.sum(hosts.on, axis=-1)
    return kernels._masked_std(np, ns, hosts.on, n_on)


# ------------------------------------------------------------ core checks
def check_waterfill_invariants(executor, seed, scenario):
    capacity, floors, ceils, weights, active = dense_problem(seed, scenario)
    out = run_waterfill(executor, capacity, floors, ceils, weights, active)
    assert out.shape == floors.shape

    # Inactive slots allocate exactly nothing; nothing is ever negative.
    assert np.all(out[~active] == 0.0)
    assert np.all(out >= 0.0)

    # Floors honored wherever the capacity can cover them; the degenerate
    # regime grants floors pro-rata (so allocations sit *below* floors).
    total_floor = floors.sum(axis=-1)
    degenerate = total_floor >= capacity
    assert np.all(out[~degenerate] >= floors[~degenerate] - 1e-9)
    assert np.all(out[degenerate] <= floors[degenerate] + 1e-9)

    # Ceilings (lifted to floors) honored everywhere.
    assert np.all(out <= np.maximum(ceils, floors) + 1e-9)

    # Per-host totals never exceed the host's capacity.
    sums = out.sum(axis=-1)
    assert np.all(sums <= capacity + 1e-6)

    # Monotone in capacity: more budget never shrinks a host's total.
    bigger = capacity * 1.25 + 1.0
    sums2 = run_waterfill(executor, bigger, floors, ceils, weights,
                          active).sum(axis=-1)
    assert np.all(sums2 >= sums - 1e-6)


def check_balance_robust_invariants(executor, seed, scenario):
    """Invariants that hold on arbitrary (heterogeneous) host specs."""
    problem = balance_problem(seed, scenario)
    hosts, caps0, dense, cpu_res, budget, enabled = problem
    caps, did = run_balance(executor, problem)
    assert caps.shape == caps0.shape and did.shape == enabled.shape

    # Cells that did nothing pass through bit-identical.
    for s in range(caps.shape[0]):
        if not did[s]:
            assert np.array_equal(caps[s], caps0[s])

    # The cap-spread never increases: the loop's ``worse`` guard reverts
    # any round that would widen it.
    assert np.all(_spread(hosts, caps, dense)
                  <= _spread(hosts, caps0, dense) + 1e-7)


def check_balance_exact_invariants(executor, seed, scenario):
    """Watts-conservation invariants, exact on homogeneous host specs."""
    problem = homogeneous_balance_problem(seed, scenario)
    hosts, caps0, dense, cpu_res, budget, enabled = problem
    caps, did = run_balance(executor, problem)
    on = hosts.on

    # Spread still never increases, same as the heterogeneous case.
    assert np.all(_spread(hosts, caps, dense)
                  <= _spread(hosts, caps0, dense) + 1e-7)

    total0 = np.sum(np.where(on, caps0, 0.0), axis=-1)
    total = np.sum(np.where(on, caps, 0.0), axis=-1)
    if scenario == "budget_below_floor":
        # Budget starts out violated: transfers conserve and the over-budget
        # trim only takes, so the total never grows past where it started.
        assert np.all(total <= total0 + 1e-6 * (1.0 + total0))
        return

    # Conserving transfers keep the powered-on total within the budget.
    assert np.all(total <= budget + 1e-6 * (1.0 + budget))

    # Shrunk hosts are donors, and donors never drop below their VMs'
    # reservations: managed capacity stays >= cpu_reserved.
    managed = kernels.managed_capacity(np, hosts, caps)
    shrunk = on & (caps < caps0 - 1e-6)
    assert np.all(~shrunk | (managed >= cpu_res - 1e-6))


# -------------------------------------------------- seed-parametrized fuzz
@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("seed", SEEDS)
def test_waterfill_invariants(executor, seed, scenario):
    check_waterfill_invariants(executor, seed, scenario)


@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_balance_robust_invariants(executor, seed, scenario):
    check_balance_robust_invariants(executor, seed, scenario)


@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_balance_exact_invariants(executor, seed, scenario):
    check_balance_exact_invariants(executor, seed, scenario)


# ------------------------------------------------- hypothesis-driven fuzz
if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1),
           scenario=st.sampled_from(SCENARIOS),
           executor=st.sampled_from(EXECUTORS))
    def test_waterfill_invariants_hypothesis(seed, scenario, executor):
        check_waterfill_invariants(executor, seed, scenario)

    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1),
           scenario=st.sampled_from(SCENARIOS),
           executor=st.sampled_from(EXECUTORS))
    def test_balance_robust_invariants_hypothesis(seed, scenario, executor):
        check_balance_robust_invariants(executor, seed, scenario)

    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1),
           scenario=st.sampled_from(SCENARIOS),
           executor=st.sampled_from(EXECUTORS))
    def test_balance_exact_invariants_hypothesis(seed, scenario, executor):
        check_balance_exact_invariants(executor, seed, scenario)
