"""shard_map MoE dispatch == dense oracle path, on a real multi-device mesh.

Runs in a subprocess so the 8 fake host devices don't leak into other
tests' jax runtime.
"""

import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, dataclasses
    from repro import configs
    from repro.models import moe
    from repro.runtime.sharding import sharding_context, Rules

    cfg = dataclasses.replace(configs.get_smoke('olmoe_1b_7b'),
                              moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    params = {k2: jax.random.normal(jax.random.fold_in(key, i), s) * 0.05
              for i, (k2, s) in enumerate([
                  ('router', (d, e)), ('w_gate', (e, d, f)),
                  ('w_up', (e, d, f)), ('w_down', (e, f, d))])}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d)) * 0.5
    y_dense, _ = moe._moe_ffn_dense(params, x, cfg)
    from repro.launch.mesh import AxisType, make_mesh_compat
    mesh = make_mesh_compat((2, 4), ("data", "model"),
                            axis_types=(AxisType.Auto,) * 2)
    with sharding_context(mesh, Rules(batch=("data",), expert=("model",))):
        y_sm, _ = jax.jit(lambda p, xx: moe.moe_ffn(p, xx, cfg))(params, x)
    err = float(jnp.max(jnp.abs(y_dense - y_sm)))
    assert err < 1e-6, err
    # Gradients flow through the shard_map dispatch.
    def loss(p):
        with sharding_context(mesh, Rules(batch=("data",),
                                          expert=("model",))):
            y, aux = moe.moe_ffn(p, x, cfg)
        return jnp.sum(jnp.square(y)) + aux
    g = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in g.values())
    print("MOE_SHARDMAP_OK", err)
""")


@pytest.mark.slow
def test_moe_shardmap_equivalence_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
        | __import__("os").environ.copy() | {"PYTHONPATH": "src"})
    assert "MOE_SHARDMAP_OK" in out.stdout, out.stderr[-2000:]
