"""DPM edge cases: triggers, evacuation search, and capacity projection.

Deterministic companions to the trigger tests in ``test_drs.py``: the
power-on/power-off priority when both candidates exist, evacuations with no
viable target, the stability window against recent configuration changes,
and the ``capacity_at_util`` guards (powered-off hosts, zero demand).
"""

import pytest

from repro.core.power_model import PAPER_HOST
from repro.drs import dpm
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine


def _cluster(demands_per_host, cap=250.0, standby=0, mem_demand=1024.0,
             migratable=True, memory_mb=8 * 1024):
    """One host per entry in ``demands_per_host`` (list of per-VM demands),
    plus ``standby`` powered-off hosts with a zero cap."""
    hosts, vms = [], []
    for i, dems in enumerate(demands_per_host):
        hosts.append(Host(f"h{i}", PAPER_HOST, power_cap=cap))
        for k, d in enumerate(dems):
            vms.append(VirtualMachine(
                vm_id=f"vm{i}_{k}", demand=d, mem_demand=mem_demand,
                memory_mb=memory_mb, host_id=f"h{i}",
                migratable=migratable))
    for s in range(standby):
        hosts.append(Host(f"standby{s}", PAPER_HOST, power_cap=0.0,
                          powered_on=False))
    budget = cap * len(demands_per_host)
    return ClusterSnapshot(hosts, vms, power_budget=budget)


def _util_demand(cap, util, n_vms):
    return util * PAPER_HOST.managed_capacity(cap) / n_vms


# ------------------------------------------------------- trigger priority
def test_simultaneous_candidates_power_on_wins():
    """One hot host and every *other* host idle: the power-on trigger takes
    priority over consolidation (run_dpm returns early)."""
    hot = [_util_demand(250.0, 0.95, 2)] * 2
    idle = [_util_demand(250.0, 0.05, 2)] * 2
    snap = _cluster([hot, idle, idle], standby=1)
    cfg = dpm.DPMConfig(stable_window_s=0.0)
    rec = dpm.run_dpm(snap, cfg, low_since={"h1": 0.0, "h2": 0.0}, now=1e5)
    assert rec.power_on == "standby0"
    assert rec.power_off is None
    assert rec.evacuations == []


def test_hot_cluster_without_standby_recommends_nothing():
    hot = [_util_demand(250.0, 0.95, 2)] * 2
    snap = _cluster([hot, hot], standby=0)
    rec = dpm.run_dpm(snap, dpm.DPMConfig())
    assert rec.power_on is None and rec.power_off is None


# ------------------------------------------------------ stability window
def test_stability_window_not_elapsed_blocks_power_off():
    idle = [_util_demand(250.0, 0.05, 2)] * 2
    snap = _cluster([idle, idle])
    cfg = dpm.DPMConfig(stable_window_s=300.0)
    low = {"h0": 0.0, "h1": 0.0}
    assert dpm.run_dpm(snap, cfg, low_since=low, now=299.0).power_off is None
    assert dpm.run_dpm(snap, cfg, low_since=low,
                       now=300.0).power_off is not None


def test_recent_config_change_restarts_the_window():
    """A power action inside the window resets stability even when every
    host has been low for longer."""
    idle = [_util_demand(250.0, 0.05, 2)] * 2
    snap = _cluster([idle, idle])
    cfg = dpm.DPMConfig(stable_window_s=300.0)
    low = {"h0": 0.0, "h1": 0.0}
    rec = dpm.run_dpm(snap, cfg, low_since=low, now=1000.0,
                      last_config_change=900.0)
    assert rec.power_off is None
    rec = dpm.run_dpm(snap, cfg, low_since=low, now=1000.0,
                      last_config_change=700.0)
    assert rec.power_off is not None


# ---------------------------------------------------- evacuation failures
def test_no_viable_evacuation_target_cancels_power_off():
    """Targets sit just under the low band but above target_util headroom:
    any evacuee would push them past the ceiling, so nothing is emitted."""
    near = [_util_demand(250.0, 0.44, 4)] * 4
    tiny = [_util_demand(250.0, 0.10, 2)] * 2
    snap = _cluster([near, near, tiny])
    cfg = dpm.DPMConfig(stable_window_s=0.0, target_util=0.45)
    rec = dpm.run_dpm(snap, cfg, low_since={f"h{i}": 0.0 for i in range(3)},
                      now=1e5)
    assert rec.power_off is None
    assert rec.evacuations == []


def test_unmigratable_vm_cancels_power_off():
    idle = [_util_demand(250.0, 0.05, 2)] * 2
    snap = _cluster([idle, idle], migratable=False)
    cfg = dpm.DPMConfig(stable_window_s=0.0)
    rec = dpm.run_dpm(snap, cfg, low_since={"h0": 0.0, "h1": 0.0}, now=1e5)
    assert rec.power_off is None


def test_successful_power_off_evacuates_least_utilized_host():
    light = [_util_demand(250.0, 0.04, 2)] * 2
    heavy = [_util_demand(250.0, 0.20, 2)] * 2
    snap = _cluster([heavy, light, heavy])
    cfg = dpm.DPMConfig(stable_window_s=0.0)
    rec = dpm.run_dpm(snap, cfg, low_since={f"h{i}": 0.0 for i in range(3)},
                      now=1e5)
    assert rec.power_off == "h1"
    assert sorted(vm for vm, _ in rec.evacuations) == ["vm1_0", "vm1_1"]
    assert all(dest in ("h0", "h2") for _, dest in rec.evacuations)


# ------------------------------------------------------- capacity_at_util
def test_capacity_at_util_excludes_powered_off_hosts():
    """VMs parked on a powered-off host must not project phantom capacity."""
    snap = _cluster([[1000.0, 1000.0]])
    snap.hosts["h0"].powered_on = False
    assert dpm.capacity_at_util(snap, "h0", 0.5) == 0.0


def test_capacity_at_util_zero_demand_is_zero():
    snap = _cluster([[0.0, 0.0]])
    assert dpm.capacity_at_util(snap, "h0", 0.5) == 0.0


def test_capacity_at_util_projects_demand():
    snap = _cluster([[600.0, 400.0]])
    assert dpm.capacity_at_util(snap, "h0", 0.5) == pytest.approx(2000.0)
