"""Batched-engine parity: BatchedSimulator must reproduce VectorSimulator.

The jit-compiled grid engine replays the paper's three evaluation scenarios
(all three policies packed as one batch per scenario) in the cap-only
management regime the sweeps isolate (no DPM, no migration search) and must
match the NumPy vector engine cell by cell: exact cap-change counts, float
tolerance for the payload/energy integrals.  Also covers the JAX waterfill
primitive against the NumPy one and the engine's packing constraints.
"""

import numpy as np
import pytest

from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.drs import balancer as balancer_mod
from repro.sim.batch import BatchCell, BatchedSimulator
from repro.sim.engine import VectorSimulator
from repro.sim.experiments import POLICIES, SCENARIOS

FLOAT_FIELDS = ("cpu_payload_mhz_s", "cpu_demand_mhz_s", "mem_payload_mb_s",
                "mem_demand_mb_s", "energy_j")


def _cap_only_manager(policy: str) -> CloudPowerCapManager:
    """The sweep regime: powercap policy only, no DPM, no migration search."""
    cfg = ManagerConfig(powercap_enabled=(policy == "cpc"),
                        dpm_enabled=False)
    cfg.balancer = balancer_mod.BalancerConfig(max_moves=0)
    return CloudPowerCapManager(cfg)


def _scenario_pair(scenario: str):
    """(vector results by policy, one BatchedSimulator over all policies)."""
    refs, cells = {}, []
    for policy in POLICIES:
        snap, traces, cfg, window = SCENARIOS[scenario].build(policy)
        cfg.record_timeline = False
        sim = VectorSimulator(snap, _cap_only_manager(policy), traces, cfg,
                              window=window)
        refs[policy] = sim.run()
        snap2, traces2, cfg2, window2 = SCENARIOS[scenario].build(policy)
        cfg2.record_timeline = False
        cells.append(BatchCell(
            name=f"{scenario}/{policy}", snapshot=snap2, traces=traces2,
            config=cfg2, powercap_enabled=(policy == "cpc"), window=window2))
    return refs, BatchedSimulator(cells)


def _assert_cell_parity(ref, batch, i, rtol=1e-9):
    acc = batch.accumulators(i)
    assert acc.cap_changes == ref.acc.cap_changes
    for f in FLOAT_FIELDS:
        np.testing.assert_allclose(getattr(acc, f), getattr(ref.acc, f),
                                   rtol=rtol, err_msg=f)
    assert set(acc.tag_payload) == set(ref.acc.tag_payload)
    for tag in ref.acc.tag_payload:
        np.testing.assert_allclose(acc.tag_payload[tag],
                                   ref.acc.tag_payload[tag], rtol=rtol)
        np.testing.assert_allclose(acc.tag_demand[tag],
                                   ref.acc.tag_demand[tag], rtol=rtol)
    wacc = batch.window_accumulators(i)
    assert (wacc is None) == (ref.window_acc is None)
    if wacc is not None:
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(getattr(wacc, f),
                                       getattr(ref.window_acc, f),
                                       rtol=rtol, err_msg=f"window {f}")


@pytest.mark.parametrize("scenario", ("headroom", "standby"))
def test_paper_scenario_parity(scenario):
    refs, bsim = _scenario_pair(scenario)
    res = bsim.run()
    for i, policy in enumerate(POLICIES):
        _assert_cell_parity(refs[policy], res, i)
    if scenario == "headroom":
        # The spike must actually exercise the jitted cap pipeline (standby's
        # uniform step stays balanced, so zero cap changes is correct there).
        assert res.accumulators(POLICIES.index("cpc")).cap_changes > 0


@pytest.mark.slow
def test_flexible_scenario_parity():
    refs, bsim = _scenario_pair("flexible")
    res = bsim.run()
    for i, policy in enumerate(POLICIES):
        _assert_cell_parity(refs[policy], res, i)


def test_batch_requires_uniform_time_grid():
    snap, traces, cfg, window = SCENARIOS["headroom"].build("cpc")
    snap2, traces2, cfg2, _ = SCENARIOS["headroom"].build("static")
    cfg2.tick_s = cfg.tick_s * 2
    cells = [BatchCell("a", snap, traces, cfg, window=window),
             BatchCell("b", snap2, traces2, cfg2)]
    with pytest.raises(ValueError, match="time grid"):
        BatchedSimulator(cells)


def test_batch_rejects_spec_less_traces():
    snap, traces, cfg, _ = SCENARIOS["headroom"].build("cpc")
    traces["vm0"] = lambda t: (1000.0, 2048.0)   # no declarative spec
    with pytest.raises(ValueError, match="declarative spec"):
        BatchedSimulator([BatchCell("a", snap, traces, cfg)])


def test_jax_waterfill_matches_numpy():
    from jax.experimental import enable_x64

    from repro.drs.entitlement import batched_waterfill, jax_batched_waterfill
    rng = np.random.RandomState(7)
    n_segs = 5
    caps = rng.uniform(0.0, 30000.0, n_segs)
    floors, ceils, weights, seg = [], [], [], []
    for s in range(n_segs):
        k = rng.randint(1, 12)
        f = rng.uniform(0.0, 3000.0, k)
        floors.append(f)
        ceils.append(f + rng.uniform(0.0, 9000.0, k))
        weights.append(rng.uniform(1.0, 4000.0, k))
        seg.append(np.full(k, s, dtype=np.int64))
    floors, ceils, weights, seg = map(
        np.concatenate, (floors, ceils, weights, seg))
    ref = batched_waterfill(caps, floors, ceils, weights, seg, n_segs)
    with enable_x64():
        got = np.asarray(jax_batched_waterfill(caps, floors, ceils, weights,
                                               seg, n_segs))
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-9)
