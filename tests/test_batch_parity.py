"""Batched-engine parity: BatchedSimulator must reproduce VectorSimulator.

The jit-compiled grid engine replays the paper's three evaluation scenarios
(all three policies packed as one batch per scenario) in the cap-only
management regime the sweeps isolate (no DPM, no migration search) and must
match the NumPy vector engine cell by cell: exact cap-change counts, float
tolerance for the payload/energy integrals.  Capacity-churn parity pins the
full host-lifecycle protocol -- DPM power-off with evacuation, Powercap
Redistribution funding a burst-driven power-on, scripted power events --
with exact cap-change / power-on / power-off / vmotion counts.  Also covers
the JAX waterfill primitive against the NumPy one and the engine's packing
constraints.
"""

import numpy as np
import pytest

from repro.core.kernels import DPMParams
from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.core.power_model import PAPER_HOST
from repro.drs import balancer as balancer_mod
from repro.drs import dpm as dpm_mod
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.sim import workloads
from repro.sim.batch import BatchCell, BatchedSimulator, BatchUnsupported
from repro.sim.cluster import SimConfig
from repro.sim.engine import VectorSimulator
from repro.sim.experiments import POLICIES, SCENARIOS

FLOAT_FIELDS = ("cpu_payload_mhz_s", "cpu_demand_mhz_s", "mem_payload_mb_s",
                "mem_demand_mb_s", "energy_j")
INT_FIELDS = ("cap_changes", "vmotions", "power_ons", "power_offs")


def _cap_only_manager(policy: str) -> CloudPowerCapManager:
    """The sweep regime: powercap policy only, no DPM, no migration search."""
    cfg = ManagerConfig(powercap_enabled=(policy == "cpc"),
                        dpm_enabled=False)
    cfg.balancer = balancer_mod.BalancerConfig(max_moves=0)
    return CloudPowerCapManager(cfg)


def _scenario_pair(scenario: str):
    """(vector results by policy, one BatchedSimulator over all policies)."""
    refs, cells = {}, []
    for policy in POLICIES:
        snap, traces, cfg, window = SCENARIOS[scenario].build(policy)
        cfg.record_timeline = False
        sim = VectorSimulator(snap, _cap_only_manager(policy), traces, cfg,
                              window=window)
        refs[policy] = sim.run()
        snap2, traces2, cfg2, window2 = SCENARIOS[scenario].build(policy)
        cfg2.record_timeline = False
        cells.append(BatchCell(
            name=f"{scenario}/{policy}", snapshot=snap2, traces=traces2,
            config=cfg2, powercap_enabled=(policy == "cpc"), window=window2))
    return refs, BatchedSimulator(cells)


def _assert_cell_parity(ref, batch, i, rtol=1e-9):
    acc = batch.accumulators(i)
    assert acc.cap_changes == ref.acc.cap_changes
    for f in FLOAT_FIELDS:
        np.testing.assert_allclose(getattr(acc, f), getattr(ref.acc, f),
                                   rtol=rtol, err_msg=f)
    assert set(acc.tag_payload) == set(ref.acc.tag_payload)
    for tag in ref.acc.tag_payload:
        np.testing.assert_allclose(acc.tag_payload[tag],
                                   ref.acc.tag_payload[tag], rtol=rtol)
        np.testing.assert_allclose(acc.tag_demand[tag],
                                   ref.acc.tag_demand[tag], rtol=rtol)
    wacc = batch.window_accumulators(i)
    assert (wacc is None) == (ref.window_acc is None)
    if wacc is not None:
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(getattr(wacc, f),
                                       getattr(ref.window_acc, f),
                                       rtol=rtol, err_msg=f"window {f}")


@pytest.mark.parametrize("scenario", ("headroom", "standby"))
def test_paper_scenario_parity(scenario):
    refs, bsim = _scenario_pair(scenario)
    res = bsim.run()
    for i, policy in enumerate(POLICIES):
        _assert_cell_parity(refs[policy], res, i)
    if scenario == "headroom":
        # The spike must actually exercise the jitted cap pipeline (standby's
        # uniform step stays balanced, so zero cap changes is correct there).
        assert res.accumulators(POLICIES.index("cpc")).cap_changes > 0


@pytest.mark.slow
def test_flexible_scenario_parity():
    refs, bsim = _scenario_pair("flexible")
    res = bsim.run()
    for i, policy in enumerate(POLICIES):
        _assert_cell_parity(refs[policy], res, i)


# ------------------------------------------------------ capacity churn
def _churn_build(budget_per_host=300.0):
    """Paper-Sec.-V-C-style valley-then-burst on 3 hosts / 30 VMs with
    budget headroom: DPM consolidates and powers host0 off mid-run, the
    burst trips the power-on trigger, and Powercap Redistribution funds
    host0's return from the unallocated pool plus donors."""
    hosts = [Host(f"host{i}", PAPER_HOST, power_cap=250.0)
             for i in range(3)]
    vms, traces = [], {}
    for i in range(30):
        vm = VirtualMachine(vm_id=f"vm{i}", vcpus=1, memory_mb=8 * 1024,
                            host_id=f"host{i // 10}")
        vms.append(vm)
        traces[vm.vm_id] = workloads.step_trace([
            (0.0, 1200.0, 2 * 1024),
            (700.0, 300.0, 2 * 1024),
            (1400.0, 2400.0, 2 * 1024),
        ])
    snap = ClusterSnapshot(hosts, vms, power_budget=3 * budget_per_host)
    cfg = SimConfig(duration_s=2100.0, drs_first_at_s=300.0,
                    record_timeline=False, instant_migrations=True)
    return snap, traces, cfg


def _churn_manager(policy: str) -> CloudPowerCapManager:
    cfg = ManagerConfig(powercap_enabled=(policy == "cpc"),
                        dpm_enabled=True)
    cfg.dpm = dpm_mod.DPMConfig(stable_window_s=150.0)
    cfg.balancer = balancer_mod.BalancerConfig(max_moves=0)
    return CloudPowerCapManager(cfg)


def _churn_pair(policies=("cpc", "static")):
    refs, cells = {}, []
    for policy in policies:
        snap, traces, cfg = _churn_build()
        sim = VectorSimulator(snap, _churn_manager(policy), traces, cfg)
        refs[policy] = sim.run()
        snap2, traces2, cfg2 = _churn_build()
        cells.append(BatchCell(
            name=policy, snapshot=snap2, traces=traces2, config=cfg2,
            powercap_enabled=(policy == "cpc"), dpm_enabled=True))
    bsim = BatchedSimulator(cells, dpm=DPMParams(stable_window_s=150.0),
                            slot_slack=3.0)
    return refs, bsim


def test_churn_power_off_then_on_parity():
    """Acceptance: the power-off -> burst -> funded power-on lifecycle runs
    end-to-end in one jitted program with exact action-count and
    float-tolerance energy parity against VectorSimulator."""
    policies = ("cpc", "static")
    refs, bsim = _churn_pair(policies)
    res = bsim.run()
    for i, policy in enumerate(policies):
        ref, acc = refs[policy], res.accumulators(i)
        for f in INT_FIELDS:
            assert getattr(acc, f) == getattr(ref.acc, f), (policy, f)
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(getattr(acc, f),
                                       getattr(ref.acc, f),
                                       rtol=1e-9, err_msg=(policy, f))
    # The scenario must actually churn: a power-off AND a power-on, with
    # the cpc cell's power-on funded by emitted cap changes.
    cpc = res.accumulators(policies.index("cpc"))
    assert cpc.power_offs == 1 and cpc.power_ons == 1
    assert cpc.vmotions == 10           # host0's evacuation
    assert cpc.cap_changes > 0
    # host0 ends powered back on in both planes.
    assert bool(res.final_on[policies.index("cpc"), 0])
    assert refs["cpc"].final.hosts["host0"].powered_on


def test_churn_scripted_events_parity():
    """Scripted maintenance window (off at 700 s, back at 1400 s) replayed
    identically by both engines, without DPM."""
    refs, cells = {}, []
    for policy in ("cpc", "static"):
        snap, traces, cfg = _churn_build()
        cfg.power_events = ((700.0, "host1", False), (1400.0, "host1", True))
        sim = VectorSimulator(snap, _cap_only_manager(policy), traces, cfg)
        refs[policy] = sim.run()
        snap2, traces2, cfg2 = _churn_build()
        cfg2.power_events = cfg.power_events
        cells.append(BatchCell(
            name=policy, snapshot=snap2, traces=traces2, config=cfg2,
            powercap_enabled=(policy == "cpc")))
    res = BatchedSimulator(cells).run()
    for i, policy in enumerate(("cpc", "static")):
        ref, acc = refs[policy], res.accumulators(i)
        for f in INT_FIELDS:
            assert getattr(acc, f) == getattr(ref.acc, f), (policy, f)
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(getattr(acc, f),
                                       getattr(ref.acc, f),
                                       rtol=1e-9, err_msg=(policy, f))
        assert bool(res.final_on[i, 1])      # host1 came back


def test_churn_event_boot_during_pending_power_off_parity():
    """A scripted power-on that fires while a DPM power-off's deferred cap
    actions are pending: the booted host's (clamped) cap must survive the
    deferred application -- only hosts with emitted actions change."""
    refs, cells = {}, []
    for policy in ("cpc", "static"):
        snaps = []
        for _ in range(2):
            snap, traces, cfg = _churn_build()
            # A 4th standby host that a scripted event boots at 920 s --
            # inside the [900, 930) pending window of the DPM power-off
            # the valley triggers at the 900 s DRS tick.
            snap.hosts["spare"] = Host("spare", PAPER_HOST,
                                       power_cap=120.0, powered_on=False)
            cfg.power_events = ((920.0, "spare", True),)
            snaps.append((snap, traces, cfg))
        snap, traces, cfg = snaps[0]
        sim = VectorSimulator(snap, _churn_manager(policy), traces, cfg)
        refs[policy] = sim.run()
        snap2, traces2, cfg2 = snaps[1]
        cells.append(BatchCell(
            name=policy, snapshot=snap2, traces=traces2, config=cfg2,
            powercap_enabled=(policy == "cpc"), dpm_enabled=True))
    res = BatchedSimulator(cells, dpm=DPMParams(stable_window_s=150.0),
                           slot_slack=3.0).run()
    for i, policy in enumerate(("cpc", "static")):
        ref, acc = refs[policy], res.accumulators(i)
        assert ref.acc.power_offs >= 1          # the window was live
        for f in INT_FIELDS:
            assert getattr(acc, f) == getattr(ref.acc, f), (policy, f)
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(getattr(acc, f),
                                       getattr(ref.acc, f),
                                       rtol=1e-9, err_msg=(policy, f))
        np.testing.assert_allclose(
            res.final_caps[i, 3],
            refs[policy].final.hosts["spare"].power_cap, rtol=1e-9)


def test_dpm_cell_timed_requires_launch_gating():
    """Timed migrations batch fine, but only under the gated launch
    protocol -- an ungated timed cell (no slot or bandwidth limits) is
    rejected loudly so it falls back to the vector engine."""
    snap, traces, cfg = _churn_build()
    cfg.instant_migrations = False
    with pytest.raises(BatchUnsupported, match="launch gating"):
        BatchedSimulator([BatchCell("a", snap, traces, cfg,
                                    dpm_enabled=True)])


def test_slot_pressure_raises_instead_of_diverging():
    """A slot axis too tight for the consolidation the scenario performs
    must fail loudly, not silently diverge from the object plane."""
    snap, traces, cfg = _churn_build()
    cells = [BatchCell("a", snap, traces, cfg, powercap_enabled=True,
                       dpm_enabled=True)]
    bsim = BatchedSimulator(cells, dpm=DPMParams(stable_window_s=150.0),
                            slot_slack=1.0)
    with pytest.raises(RuntimeError, match="slot_slack"):
        bsim.run()


def test_batch_requires_uniform_time_grid():
    snap, traces, cfg, window = SCENARIOS["headroom"].build("cpc")
    snap2, traces2, cfg2, _ = SCENARIOS["headroom"].build("static")
    cfg2.tick_s = cfg.tick_s * 2
    cells = [BatchCell("a", snap, traces, cfg, window=window),
             BatchCell("b", snap2, traces2, cfg2)]
    with pytest.raises(ValueError, match="time grid"):
        BatchedSimulator(cells)


def test_batch_rejects_spec_less_traces():
    snap, traces, cfg, _ = SCENARIOS["headroom"].build("cpc")
    traces["vm0"] = lambda t: (1000.0, 2048.0)   # no declarative spec
    with pytest.raises(ValueError, match="declarative spec"):
        BatchedSimulator([BatchCell("a", snap, traces, cfg)])


def test_jax_waterfill_matches_numpy():
    from jax.experimental import enable_x64

    from repro.drs.entitlement import batched_waterfill, jax_batched_waterfill
    rng = np.random.RandomState(7)
    n_segs = 5
    caps = rng.uniform(0.0, 30000.0, n_segs)
    floors, ceils, weights, seg = [], [], [], []
    for s in range(n_segs):
        k = rng.randint(1, 12)
        f = rng.uniform(0.0, 3000.0, k)
        floors.append(f)
        ceils.append(f + rng.uniform(0.0, 9000.0, k))
        weights.append(rng.uniform(1.0, 4000.0, k))
        seg.append(np.full(k, s, dtype=np.int64))
    floors, ceils, weights, seg = map(
        np.concatenate, (floors, ceils, weights, seg))
    ref = batched_waterfill(caps, floors, ceils, weights, seg, n_segs)
    with enable_x64():
        got = np.asarray(jax_batched_waterfill(caps, floors, ceils, weights,
                                               seg, n_segs))
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-9)
