"""Checkpointer: roundtrip, async, atomicity, GC, elastic recover."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import Checkpointer
from repro.optim.adamw import AdamW
from repro.runtime.elastic import ElasticController
from repro.runtime.train_loop import init_train_state


def _state():
    cfg = configs.get_smoke("granite_8b")
    opt = AdamW()
    return init_train_state(jax.random.PRNGKey(0), cfg, opt)


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(3, state)
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = ck.restore(3, target)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = _state()
    for step in (1, 2, 3):
        ck.save_async(step, state, {"data_step": step * 10})
    ck.wait()
    assert ck.latest_step() == 3
    assert ck.metadata(3)["data_step"] == 30
    # GC kept only the last two.
    assert ck.all_steps() == [2, 3]


def test_restore_dtype_cast(tmp_path):
    """Restoring onto a different optimizer-state dtype (elastic config
    change) casts instead of failing."""
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(1, state)
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, jnp.bfloat16 if x.dtype == jnp.float32 and x.ndim > 0
            else x.dtype),
        state)
    restored = ck.restore(1, target)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.dtype in (jnp.bfloat16, jnp.int32, jnp.uint32, jnp.float32)


def test_elastic_recover(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(7, state)

    def make_mesh(n_pods):
        return f"mesh-{n_pods}"         # placeholder: CPU test

    def make_shardings(mesh, target):
        return None                      # replicated on 1 device

    ctl = ElasticController(ck, make_mesh, make_shardings)
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    mesh, restored, step = ctl.recover(target, to_pods=1)
    assert step == 7 and mesh == "mesh-1"
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(restored)[0]),
        np.asarray(jax.tree_util.tree_leaves(state)[0]))
    assert ctl.history[-1].reason == "failure"


def test_atomic_marker(tmp_path):
    """A checkpoint without its .json marker is invisible (torn write)."""
    ck = Checkpointer(str(tmp_path))
    state = _state()
    path = ck.save(5, state)
    os.remove(path.replace(".npz", ".json"))
    assert ck.latest_step() is None
