"""Serving-path correctness: token-by-token decode reproduces the full
forward for every stateful family (KV caches, SSM states, hybrid, cross
attention)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as tfm
from repro.runtime.serve_loop import greedy_generate, make_prefill_step

STATEFUL = ["granite_8b", "granite_20b", "minicpm_2b", "nemotron_4_340b",
            "mamba2_2p7b", "zamba2_7b", "whisper_tiny", "internvl2_26b"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", STATEFUL)
def test_decode_matches_full_forward(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = tfm.init_params(key, cfg)
    b, s = 2, 16
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model)) * 0.1
    full = tfm.forward(params, cfg, tokens=tokens, **kwargs).hidden

    state = tfm.init_decode_state(cfg, b, s)
    outs = []
    for t in range(s):
        pos = jnp.full((b, 1), t)
        r = tfm.forward(params, cfg, tokens=tokens[:, t:t + 1], cache=state,
                        positions=pos, **kwargs)
        state = r.cache
        outs.append(r.hidden)
    dec = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - dec))) < 5e-5


@pytest.mark.slow
def test_moe_decode_matches_when_dropless():
    cfg = dataclasses.replace(configs.get_smoke("olmoe_1b_7b"),
                              moe_capacity_factor=64.0)
    key = jax.random.PRNGKey(1)
    params = tfm.init_params(key, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full = tfm.forward(params, cfg, tokens=tokens).hidden
    state = tfm.init_decode_state(cfg, b, s)
    outs = []
    for t in range(s):
        r = tfm.forward(params, cfg, tokens=tokens[:, t:t + 1], cache=state,
                        positions=jnp.full((b, 1), t))
        state = r.cache
        outs.append(r.hidden)
    dec = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - dec))) < 5e-5


def test_prefill_then_decode_greedy():
    cfg = configs.get_smoke("granite_8b")
    key = jax.random.PRNGKey(3)
    params = tfm.init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    out = greedy_generate(cfg, params, prompt, steps=6, max_len=32)
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_prefill_cache_matches_incremental():
    """Multi-token prefill into the cache == token-by-token filling."""
    cfg = configs.get_smoke("granite_8b")
    key = jax.random.PRNGKey(4)
    params = tfm.init_params(key, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    prefill = make_prefill_step(cfg, max_len=24)
    logits_a, state_a = prefill(params, tokens)

    state = tfm.init_decode_state(cfg, b, 24)
    for t in range(s):
        r = tfm.forward(params, cfg, tokens=tokens[:, t:t + 1], cache=state,
                        positions=jnp.full((b, 1), t))
        state = r.cache
    w_out = tfm.unembed_weight(params, cfg)
    logits_b = (r.hidden[:, -1] @ w_out).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(logits_a - logits_b))) < 5e-4
