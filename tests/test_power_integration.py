"""Power-cap <-> data-plane integration: batch scheduler, straggler
mitigation, serving router."""

import numpy as np

from repro.core.power_model import PAPER_HOST
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.runtime.power_integration import (PowerAwareBatchScheduler,
                                             StragglerMitigator,
                                             StragglerReport)
from repro.runtime.serve_loop import CapacityAwareRouter, Replica


def _snapshot(caps):
    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=c)
             for i, c in enumerate(caps)]
    vms = [VirtualMachine(vm_id=f"job{i}", demand=8000.0, host_id=f"h{i}")
           for i in range(len(caps))]
    return ClusterSnapshot(hosts, vms, power_budget=sum(caps))


def test_batch_plan_proportional_to_caps():
    snap = _snapshot([320.0, 250.0])
    sched = PowerAwareBatchScheduler(global_batch=64,
                                     pod_hosts=[["h0"], ["h1"]],
                                     hysteresis=0.0)
    plan = sched.plan(snap)
    cap0 = PAPER_HOST.capped_capacity(320.0)
    cap1 = PAPER_HOST.capped_capacity(250.0)
    # Pod 0's fair share (0.64 * 64 = 41) exceeds its 32 slots: clamped.
    assert plan.examples_per_pod[0] == 32
    # Pod 1 gets its proportional share of the batch.
    expect1 = 64 * cap1 / (cap0 + cap1)
    assert abs(plan.examples_per_pod[1] - expect1) <= 1.0
    assert plan.examples_per_pod.sum() <= 64
    # Weight mask: pod 0's slots [0:32), pod 1's [32:64).
    assert plan.weights[:plan.examples_per_pod[0]].all()
    assert plan.weights[32 + plan.examples_per_pod[1]:].sum() == 0


def test_batch_plan_equal_caps_full_batch():
    snap = _snapshot([320.0, 320.0])
    sched = PowerAwareBatchScheduler(64, [["h0"], ["h1"]], hysteresis=0.0)
    plan = sched.plan(snap)
    assert list(plan.examples_per_pod) == [32, 32]
    assert plan.weights.sum() == 64


def test_hysteresis_suppresses_small_changes():
    snap = _snapshot([320.0, 320.0])
    sched = PowerAwareBatchScheduler(64, [["h0"], ["h1"]], hysteresis=0.05)
    p1 = sched.plan(snap)
    snap.hosts["h0"].power_cap = 316.0      # ~1% capacity change
    p2 = sched.plan(snap)
    assert np.array_equal(p1.examples_per_pod, p2.examples_per_pod)


def test_apply_masks_batch():
    import jax.numpy as jnp
    snap = _snapshot([320.0, 250.0])
    sched = PowerAwareBatchScheduler(8, [["h0"], ["h1"]], hysteresis=0.0)
    plan = sched.plan(snap)
    batch = {"weights": jnp.ones((8, 4))}
    out = sched.apply(batch, plan)
    assert float(out["weights"].sum()) == plan.weights.sum() * 4


def test_straggler_detect_and_mitigate():
    snap = _snapshot([250.0, 250.0, 250.0])
    mit = StragglerMitigator(threshold=0.15, patience=2)
    report = StragglerReport(step_times={"h0": 1.4, "h1": 1.0, "h2": 1.0})
    assert mit.detect(report) == []            # first strike
    assert mit.detect(report) == ["h0"]        # patience reached
    balanced = mit.mitigate(snap.clone(), report)
    assert balanced is not None
    # Watts moved toward the straggler.
    assert balanced.hosts["h0"].power_cap > 250.0
    assert balanced.total_allocated_power() <= snap.power_budget + 1e-6


def test_router_weights_by_capacity():
    snap = _snapshot([320.0, 250.0])
    router = CapacityAwareRouter([Replica("r0", "h0"), Replica("r1", "h1")])
    router.sync_capacities(snap)
    assigned = router.route(13)
    n0 = assigned.count("r0")
    cap0 = PAPER_HOST.capped_capacity(320.0)
    cap1 = PAPER_HOST.capped_capacity(250.0)
    # Weighted least-loaded: shares track capacity ratio.
    assert abs(n0 / 13 - cap0 / (cap0 + cap1)) < 0.15


def test_router_skips_powered_off_replica():
    snap = _snapshot([320.0, 250.0])
    snap.hosts["h1"].powered_on = False
    router = CapacityAwareRouter([Replica("r0", "h0"), Replica("r1", "h1")])
    router.sync_capacities(snap)
    assert set(router.route(5)) == {"r0"}
