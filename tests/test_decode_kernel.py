"""Flash-decoding Pallas kernel vs ragged-cache oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize(
    "b,s,hq,hkv,d,bk",
    [
        (2, 256, 4, 2, 64, 64),
        (1, 384, 8, 1, 128, 128),    # MQA, long cache
        (3, 100, 4, 4, 32, 64),      # ragged block tail
        (1, 64, 2, 2, 16, 64),       # single block
    ])
def test_decode_matches_ref(b, s, hq, hkv, d, bk):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, k, v, kv_len, block_k=bk)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fully_masked_blocks_do_not_pollute():
    """kv_len=1 with many blocks: every block except the first is fully
    masked; the combine must ignore their junk partials."""
    ks = jax.random.split(KEY, 3)
    b, s, h, d = 2, 512, 2, 32
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    kv_len = jnp.array([1, 3])
    out = decode_attention(q, k, v, kv_len, block_k=64)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([96, 160]),
       hkv=st.sampled_from([1, 2]), g=st.sampled_from([1, 4]),
       d=st.sampled_from([16, 32]))
def test_decode_hypothesis(b, s, hkv, g, d):
    ks = jax.random.split(jax.random.PRNGKey(s * 3 + d), 4)
    q = jax.random.normal(ks[0], (b, hkv * g, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, k, v, kv_len, block_k=32)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
