"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape sweeps
and hypothesis-driven randomized shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.moe_gmm.ref import grouped_matmul_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.ssd import ssd_chunked

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,d,causal,qoff",
    [
        (2, 128, 128, 4, 2, 64, True, 0),
        (1, 256, 256, 8, 1, 128, True, 0),     # MQA
        (2, 100, 100, 4, 4, 32, True, 0),      # non-multiple of block
        (1, 1, 384, 4, 2, 64, True, 383),      # decode
        (2, 64, 64, 4, 2, 64, False, 0),       # bidirectional
        (1, 96, 160, 2, 2, 16, True, 64),      # continuation prefill
    ])
def test_flash_attention_matches_ref(b, sq, skv, hq, hkv, d, causal, qoff,
                                     dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, q_offset=qoff,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 2), sq=st.integers(1, 96), hkv=st.sampled_from([1, 2]),
       groups=st.sampled_from([1, 3]), d=st.sampled_from([16, 32]))
def test_flash_attention_hypothesis(b, sq, hkv, groups, d):
    ks = jax.random.split(jax.random.PRNGKey(sq * 7 + d), 3)
    hq = hkv * groups
    q = jax.random.normal(ks[0], (b, sq, hq, d))
    k = jax.random.normal(ks[1], (b, sq, hkv, d))
    v = jax.random.normal(ks[2], (b, sq, hkv, d))
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- SSD scan
@pytest.mark.parametrize(
    "b,l,h,p,n,chunk",
    [(2, 64, 4, 16, 32, 16), (1, 128, 8, 32, 16, 32), (2, 48, 2, 8, 8, 16),
     (1, 40, 4, 16, 16, 16)])  # ragged tail
def test_ssd_kernel_matches_sequential_ref(b, l, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, l, h, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, h, n)) * 0.3
    y, st_ = ssd_scan(x, dt, a_log, bm, cm, chunk=chunk)
    yr, str_ = ssd_ref(x, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(str_),
                               rtol=1e-4, atol=1e-4)


def test_model_ssd_chunked_matches_sequential_ref():
    """The model's own chunked SSD (XLA path) against the same oracle."""
    ks = jax.random.split(KEY, 5)
    b, l, h, p, n = 2, 96, 4, 16, 24
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, l, h, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, h, n)) * 0.3
    y, st_ = ssd_chunked(x, dt, a_log, bm, cm, chunk=32)
    yr, str_ = ssd_ref(x, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(str_),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_with_initial_state():
    ks = jax.random.split(KEY, 6)
    b, l, h, p, n = 1, 32, 2, 8, 8
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, l, h, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, h, n)) * 0.3
    init = jax.random.normal(ks[5], (b, h, p, n)) * 0.2
    y, st_ = ssd_scan(x, dt, a_log, bm, cm, chunk=16, init_state=init)
    yr, str_ = ssd_ref(x, dt, a_log, bm, cm, init_state=init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- grouped GEMM
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", [(4, 64, 128, 96), (8, 100, 60, 70),
                                     (2, 16, 512, 256), (1, 8, 8, 8)])
def test_grouped_matmul_matches_ref(e, c, d, f, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (e, c, d), dtype)
    w = jax.random.normal(k2, (e, d, f), dtype)
    out = grouped_matmul(x, w, block_c=32, block_d=64, block_f=32)
    ref = grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-3)
