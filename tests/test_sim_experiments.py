"""End-to-end reproduction of the paper's three experiments (Tables III-V).

Assertions target the paper's *claims* (orderings and zero/non-zero
structure); exact payload percentages depend on unpublished simulator
internals and are recorded in EXPERIMENTS.md instead.
"""

import pytest

from repro.sim.experiments import run_all, run_policy
from repro.sim.metrics import ratio_table


@pytest.fixture(scope="module")
def headroom():
    return run_all("headroom")


@pytest.fixture(scope="module")
def standby():
    return run_all("standby")


class TestHeadroomRebalancing:  # paper Sec. V-B, Table III
    def test_cpc_avoids_all_migrations(self, headroom):
        assert headroom["cpc"].acc.vmotions == 0
        assert headroom["cpc"].acc.cap_changes > 0

    def test_static_migrates(self, headroom):
        assert headroom["static"].acc.vmotions >= 3

    def test_statichigh_no_action_needed(self, headroom):
        assert headroom["statichigh"].acc.vmotions == 0

    def test_payload_ordering(self, headroom):
        t = ratio_table({k: v.acc for k, v in headroom.items()},
                        "statichigh")
        assert t["cpc"]["cpu_payload_ratio"] >= \
            t["static"]["cpu_payload_ratio"] - 1e-6
        assert t["cpc"]["cpu_payload_ratio"] >= 0.97   # paper: 0.99

    def test_caps_track_burst(self, headroom):
        events = [e for _, e in headroom["cpc"].events if e.startswith("cap")]
        # Raised for the burst, restored after.
        assert any("host0" in e for e in events)


class TestStandbyReallocation:  # paper Sec. V-C, Table IV
    def test_consolidation_happens_everywhere(self, standby):
        for policy in ("cpc", "static", "statichigh"):
            assert standby[policy].acc.power_offs == 1

    def test_cpc_absorbs_spike_without_poweron(self, standby):
        assert standby["cpc"].acc.power_ons == 0
        assert standby["cpc"].acc.vmotions == 10   # evacuation only

    def test_static_needs_poweron(self, standby):
        assert standby["static"].acc.power_ons == 1
        assert standby["static"].acc.vmotions > 10

    def test_power_ratio(self, standby):
        t = ratio_table({k: v.acc for k, v in standby.items()}, "statichigh")
        assert t["static"]["power_ratio"] > 1.02    # paper: 1.36
        assert abs(t["cpc"]["power_ratio"] - 1.0) < 0.02

    def test_cpc_caps_raised_after_poweroff(self, standby):
        events = [e for _, e in standby["cpc"].events if "cap" in e]
        assert any("=320W" in e for e in events)


@pytest.mark.slow
class TestFlexibleCapacity:  # paper Sec. V-D, Table V
    @pytest.fixture(scope="class")
    def flexible(self):
        return run_all("flexible")

    def test_trading_fully_served_under_cpc(self, flexible):
        assert flexible["cpc"].acc.tag_satisfaction("trading") >= 0.97

    def test_trading_starved_under_static(self, flexible):
        sat = flexible["static"].acc.tag_satisfaction("trading")
        assert 0.55 <= sat <= 0.72                  # paper: 0.62

    def test_memory_ratio(self, flexible):
        t = ratio_table({k: v.acc for k, v in flexible.items()},
                        "statichigh")
        assert t["cpc"]["mem_payload_ratio"] > 1.2  # paper: 1.28
        assert t["static"]["mem_payload_ratio"] > 1.2

    def test_cpu_payload_ordering(self, flexible):
        t = ratio_table({k: v.acc for k, v in flexible.items()},
                        "statichigh")
        assert t["cpc"]["cpu_payload_ratio"] > \
            t["static"]["cpu_payload_ratio"]
        assert t["cpc"]["cpu_payload_ratio"] > 1.2  # paper: 1.24
