"""Algorithm 3 edge cases: power-on funding under a strained budget.

Deterministic companions to the hypothesis property tests in
``test_algorithms.py`` (which are skipped when hypothesis is absent):
what happens when the unallocated pool is empty and every donor is pinned
at (or near) its power-on-threshold floor, and what happens when the
power-on candidate is already powered on.
"""

import pytest

from repro.core.power_model import PAPER_HOST
from repro.core.redistribute import redistribute_for_power_on
from repro.drs.dpm import DPMConfig
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine


def _cluster(util: float, n_hosts: int = 3, cap: float = 250.0,
             vms_per_host: int = 5):
    """Fully-allocated budget (no unallocated pool), every host's VMs
    pinned at ``util`` of its capped capacity."""
    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=cap)
             for i in range(n_hosts)]
    hosts.append(Host("standby", PAPER_HOST, power_cap=0.0,
                      powered_on=False))
    vms = []
    for i in range(n_hosts):
        per_vm = util * PAPER_HOST.managed_capacity(cap) / vms_per_host
        for k in range(vms_per_host):
            vms.append(VirtualMachine(
                vm_id=f"vm{i}_{k}", demand=per_vm, memory_mb=8 * 1024,
                mem_demand=1024.0, host_id=f"h{i}"))
    return ClusterSnapshot(hosts, vms, power_budget=n_hosts * cap)


def test_insufficient_budget_drains_donors_only_to_their_floor():
    """Donors surrender Watts down to the power-on-threshold floor and no
    further; the grant falls short of peak and the budget is conserved."""
    dpm = DPMConfig()
    util = 0.6                        # below high_util: hosts can donate
    snap = _cluster(util)
    assert snap.unallocated_power_budget() == pytest.approx(0.0)

    funded, granted = redistribute_for_power_on(snap, "standby", dpm)

    assert 0.0 < granted < PAPER_HOST.power_peak  # short of the target
    assert funded.hosts["standby"].power_cap == pytest.approx(granted)
    total = sum(h.power_cap for h in funded.hosts.values()
                if h.powered_on or h.host_id == "standby")
    assert total <= funded.power_budget + 1e-6
    for i in range(3):
        donor = funded.hosts[f"h{i}"]
        demand = sum(v.effective_demand for v in funded.vms_on(donor.host_id))
        # Post-drain utilization stays at or below the power-on trigger:
        # draining must never itself re-trigger a power-on (oscillation).
        post_util = demand / donor.spec.managed_capacity(donor.power_cap)
        assert post_util <= dpm.high_util + 1e-6
        # Drained exactly to the floor: the donors gave everything allowed.
        floor_cap = donor.spec.cap_for_managed_capacity(
            demand / dpm.high_util)
        assert donor.power_cap == pytest.approx(max(floor_cap,
                                                    donor.spec.power_idle))


def test_insufficient_budget_all_donors_pinned_grants_nothing():
    """Hot donors (>= high_util) cannot be drained at all: the grant is zero
    and the caller's feasibility check (managed capacity == 0) trips."""
    dpm = DPMConfig()
    snap = _cluster(util=0.95)        # every host above the power-on trigger
    funded, granted = redistribute_for_power_on(snap, "standby", dpm)
    assert granted == pytest.approx(0.0)
    assert PAPER_HOST.managed_capacity(granted) <= 0.0  # infeasible signal
    for i in range(3):
        assert funded.hosts[f"h{i}"].power_cap == pytest.approx(250.0)


def test_candidate_already_powered_on_keeps_its_cap():
    """An already-on candidate's allocation counts toward the target and is
    never reduced; spare budget tops it up toward peak."""
    hosts = [Host("h0", PAPER_HOST, power_cap=250.0),
             Host("h1", PAPER_HOST, power_cap=200.0)]
    vms = [VirtualMachine(vm_id="v0", demand=20000.0, host_id="h0"),
           VirtualMachine(vm_id="v1", demand=20000.0, host_id="h1")]
    # 90 W of unallocated budget available for the top-up.
    snap = ClusterSnapshot(hosts, vms, power_budget=540.0)

    funded, granted = redistribute_for_power_on(snap, "h1")

    assert granted == pytest.approx(290.0)    # 200 held + 90 unallocated
    assert funded.hosts["h1"].power_cap == pytest.approx(290.0)
    assert funded.hosts["h1"].power_cap >= snap.hosts["h1"].power_cap
    total = sum(h.power_cap for h in funded.powered_on_hosts())
    assert total <= funded.power_budget + 1e-6


def test_candidate_already_on_at_peak_is_a_noop():
    hosts = [Host("h0", PAPER_HOST, power_cap=PAPER_HOST.power_peak),
             Host("h1", PAPER_HOST, power_cap=250.0)]
    vms = [VirtualMachine(vm_id="v0", demand=1000.0, host_id="h0")]
    snap = ClusterSnapshot(hosts, vms, power_budget=1000.0)
    funded, granted = redistribute_for_power_on(snap, "h0")
    assert granted == pytest.approx(PAPER_HOST.power_peak)
    assert funded.hosts["h0"].power_cap == pytest.approx(
        PAPER_HOST.power_peak)
    # The peer keeps its cap: nothing needed, nothing drained.
    assert funded.hosts["h1"].power_cap == pytest.approx(250.0)
