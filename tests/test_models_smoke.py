"""Per-architecture smoke tests: reduced same-family config, one forward and
one train step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as tfm
from repro.optim.adamw import AdamW
from repro.runtime.train_loop import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    s_text = S
    batch = {
        "tokens": jax.random.randint(key, (B, s_text), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, s_text), 0, cfg.vocab_size),
        "weights": jnp.ones((B, s_text), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model),
                                   jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    batch = _batch(cfg, key)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["vision_embeds"] = batch["vision_embeds"]
    if cfg.family == "encdec":
        kwargs["frames"] = batch["frames"]
    res = tfm.forward(params, cfg, tokens=batch["tokens"], **kwargs)
    expect_s = S + (cfg.n_prefix_embeds if cfg.family == "vlm" else 0)
    assert res.hidden.shape == (B, expect_s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(res.hidden)))


@pytest.mark.slow
@pytest.mark.parametrize("arch", configs.ARCHS)
def test_one_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    opt = AdamW(learning_rate=1e-3, state_dtype=cfg.optimizer_state_dtype)
    state = init_train_state(key, cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg, key)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # Parameters actually moved.
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite_8b", "olmoe_1b_7b",
                                  "mamba2_2p7b"])
def test_microbatched_grads_match_single_shot(arch):
    import dataclasses
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(2)
    opt = AdamW(learning_rate=1e-3)
    batch = _batch(cfg, key)

    cfg1 = dataclasses.replace(cfg, microbatches=1)
    cfg2 = dataclasses.replace(cfg, microbatches=2)
    s1 = init_train_state(key, cfg1, opt)
    s2 = init_train_state(key, cfg2, opt)
    n1, m1 = jax.jit(make_train_step(cfg1, opt))(s1, batch)
    n2, m2 = jax.jit(make_train_step(cfg2, opt))(s2, batch)
    # MoE capacity drops differ between T and T/2 token pools; dense/ssm
    # must match tightly.
    tol = 5e-2 if cfg.family == "moe" else 2e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < tol
    if cfg.family != "moe":
        diff = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            n1.params, n2.params)
        assert max(jax.tree_util.tree_leaves(diff)) < 1e-4
