"""Waterfill / divvy properties (hypothesis)."""

import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.drs.entitlement import waterfill, divvy
from repro.drs.snapshot import VirtualMachine


@st.composite
def fill_problem(draw):
    n = draw(st.integers(1, 12))
    floors = np.array(draw(st.lists(st.floats(0, 50), min_size=n,
                                    max_size=n)))
    extra = np.array(draw(st.lists(st.floats(0, 100), min_size=n,
                                   max_size=n)))
    ceilings = floors + extra
    weights = np.array(draw(st.lists(st.floats(0.1, 10), min_size=n,
                                     max_size=n)))
    capacity = draw(st.floats(float(floors.sum()), float(ceilings.sum())
                              + 100.0))
    return capacity, floors, ceilings, weights


@settings(max_examples=300, deadline=None)
@given(fill_problem())
def test_waterfill_invariants(problem):
    capacity, floors, ceilings, weights = problem
    x = waterfill(capacity, floors, ceilings, weights)
    assert np.all(x >= floors - 1e-6), "floors are guaranteed"
    assert np.all(x <= ceilings + 1e-6), "ceilings are hard limits"
    target = min(capacity, ceilings.sum())
    assert np.isclose(x.sum(), target, rtol=1e-6, atol=1e-5), \
        "capacity fully used (up to total demand)"


@settings(max_examples=200, deadline=None)
@given(fill_problem())
def test_waterfill_weighted_fairness(problem):
    """Max-min: among VMs strictly inside (floor, ceiling), allocation is
    proportional to weight (same water level)."""
    capacity, floors, ceilings, weights = problem
    x = waterfill(capacity, floors, ceilings, weights)
    inside = (x > floors + 1e-4) & (x < ceilings - 1e-4)
    levels = x[inside] / weights[inside]
    if levels.size >= 2:
        assert np.ptp(levels) <= 1e-2 * max(levels.max(), 1.0)


def test_divvy_reservation_priority():
    vms = [
        VirtualMachine(vm_id="a", reservation=2000.0, demand=500.0,
                       shares=1000),
        VirtualMachine(vm_id="b", demand=5000.0, shares=1000),
    ]
    ents = divvy(3000.0, vms)
    # Reserved-but-idle VM keeps its full reservation as entitlement.
    assert ents["a"] >= 2000.0 - 1e-6
    assert ents["b"] <= 1000.0 + 1e-6


def test_divvy_shares_split_contention():
    vms = [
        VirtualMachine(vm_id="a", demand=4000.0, shares=3000),
        VirtualMachine(vm_id="b", demand=4000.0, shares=1000),
    ]
    ents = divvy(4000.0, vms)
    assert np.isclose(ents["a"], 3000.0, atol=1.0)
    assert np.isclose(ents["b"], 1000.0, atol=1.0)
