"""Scenario-sweep harness: grid generation, deployment math, smoke runs."""

import numpy as np
import pytest

from repro.core.power_model import PAPER_HOST
from repro.sim.sweep import (SMALL_HOST, TWO_ROW_LIMIT_FRAC, SweepSpec,
                             build_sweep, row_contention_specs, run_cell,
                             run_sweep, run_sweep_batched, scale_ladder,
                             scenario_families)


def test_scenario_families_grid():
    specs = scenario_families(sizes=(4, 8), budgets_per_host_w=(250.0,),
                             spikes=("burst", "prime"),
                             heterogeneous=(False, True))
    assert len(specs) == 2 * 1 * 2 * 2
    names = {s.name for s in specs}
    assert len(names) == len(specs)          # unique cell names
    assert any(s.heterogeneous for s in specs)


def test_build_sweep_static_deployment():
    spec = SweepSpec(name="t", n_hosts=6, vms_per_host=4, spike="flat")
    snap, traces, cfg = build_sweep(spec, "static")
    assert len(snap.hosts) == 6
    assert len(snap.vms) == 24
    assert len(traces) == 24
    assert snap.budget_respected()
    # Budget spread evenly across homogeneous hosts.
    caps = {h.power_cap for h in snap.hosts.values()}
    assert len(caps) == 1
    assert cfg.record_timeline is False


def test_build_sweep_statichigh_standby_hosts():
    spec = SweepSpec(name="t", n_hosts=8, spike="flat")  # 2000 W budget
    snap, _, _ = build_sweep(spec, "statichigh")
    on = snap.powered_on_hosts()
    # 2000 W / 320 W peak -> 6 hosts at peak, 2 in standby.
    assert len(on) == 6
    assert all(h.power_cap == PAPER_HOST.power_peak for h in on)
    assert snap.budget_respected()
    # All VMs land on powered-on hosts.
    assert all(snap.vms[v].host_id in {h.host_id for h in on}
               for v in snap.vms)


def test_build_sweep_heterogeneous_mixes_specs():
    spec = SweepSpec(name="t", n_hosts=4, heterogeneous=True, spike="flat")
    snap, _, _ = build_sweep(spec, "cpc")
    specs = {h.spec for h in snap.hosts.values()}
    assert specs == {PAPER_HOST, SMALL_HOST}
    assert snap.budget_respected()


def test_build_sweep_deterministic_by_seed():
    spec = SweepSpec(name="t", n_hosts=4, spike="burst", seed=7)
    a, ta, _ = build_sweep(spec, "cpc")
    b, tb, _ = build_sweep(spec, "cpc")
    assert [v.vm_id for v in a.vms.values()] == \
        [v.vm_id for v in b.vms.values()]
    for vid in ta:
        assert ta[vid](100.0) == tb[vid](100.0)
        assert ta[vid](500.0) == tb[vid](500.0)


def test_unknown_spike_rejected():
    with pytest.raises(ValueError):
        build_sweep(SweepSpec(name="t", spike="nope"), "cpc")


@pytest.mark.parametrize("spike", ("flat", "burst", "step", "prime"))
def test_run_cell_smoke(spike):
    spec = SweepSpec(name=f"s_{spike}", n_hosts=6, vms_per_host=4,
                     spike=spike, duration_s=600.0, tick_s=30.0,
                     drs_period_s=300.0)
    r = run_cell(spec, "cpc")
    assert r.ticks == 20
    assert r.ticks_per_s > 0
    assert 0.0 < r.cpu_satisfaction <= 1.0 + 1e-9
    assert r.energy_j > 0.0
    assert r.vmotions == 0               # migration search disabled in sweeps


def test_sweep_policies_separate_under_burst():
    """Host-correlated bursts strand static caps; CPC recovers the payload."""
    spec = SweepSpec(name="sep", n_hosts=12, vms_per_host=8, spike="burst",
                     duration_s=1200.0, tick_s=20.0, seed=3)
    res = run_sweep([spec], policies=("cpc", "static"))
    cpc, static = res["sep"]["cpc"], res["sep"]["static"]
    assert cpc.cap_changes > 0
    assert static.cap_changes == 0
    assert cpc.cpu_satisfaction >= static.cpu_satisfaction - 1e-9
    assert cpc.cpu_payload_mhz_s >= static.cpu_payload_mhz_s - 1e-6


def test_scale_ladder_shapes():
    ladder = scale_ladder(sizes=(10, 100), spike="burst")
    assert [s.n_hosts for s in ladder] == [10, 100]
    assert all(s.n_vms == 10 * s.n_hosts for s in ladder)


def test_run_sweep_batched_matches_sequential():
    """The jitted grid engine reproduces the sequential sweep cell by cell."""
    specs = scenario_families(sizes=(4,), budgets_per_host_w=(250.0,),
                              spikes=("burst", "prime"),
                              heterogeneous=(False, True),
                              duration_s=600.0, tick_s=30.0)
    policies = ("cpc", "static")
    seq = run_sweep(specs, policies=policies, engine="vector")
    bat = run_sweep(specs, policies=policies, engine="batch")
    assert set(bat) == set(seq)
    for name in seq:
        for p in policies:
            a, b = seq[name][p], bat[name][p]
            assert b.cap_changes == a.cap_changes, (name, p)
            assert b.vmotions == 0
            assert b.ticks == a.ticks
            np.testing.assert_allclose(b.cpu_payload_mhz_s,
                                       a.cpu_payload_mhz_s, rtol=1e-9)
            np.testing.assert_allclose(b.energy_j, a.energy_j, rtol=1e-9)
            np.testing.assert_allclose(b.cpu_satisfaction,
                                       a.cpu_satisfaction, rtol=1e-9)


def test_run_sweep_batched_matches_sequential_churn():
    """Capacity-churn cells (DPM lifecycle, scripted events) reproduce the
    sequential sweep exactly, including the power action counts."""
    specs = scenario_families(sizes=(6,), budgets_per_host_w=(250.0,),
                              spikes=("burst",), heterogeneous=(False,),
                              churns=("none", "dpm", "maintenance",
                                      "failure"),
                              duration_s=1500.0, tick_s=30.0)
    policies = ("cpc", "static")
    seq = run_sweep(specs, policies=policies, engine="vector")
    bat = run_sweep(specs, policies=policies, engine="batch")
    churned = False
    for name in seq:
        for p in policies:
            a, b = seq[name][p], bat[name][p]
            assert (b.cap_changes, b.vmotions, b.power_ons, b.power_offs) \
                == (a.cap_changes, a.vmotions, a.power_ons,
                    a.power_offs), (name, p)
            np.testing.assert_allclose(b.cpu_payload_mhz_s,
                                       a.cpu_payload_mhz_s, rtol=1e-9)
            np.testing.assert_allclose(b.energy_j, a.energy_j, rtol=1e-9)
            churned |= a.power_ons + a.power_offs > 0
    assert churned                       # the grid exercised the lifecycle


def test_run_sweep_batched_matches_sequential_rules():
    """Rule-family cells (constraint corrections, fundable-capacity fits,
    hill-climb balancing) reproduce the sequential sweep exactly."""
    specs = scenario_families(sizes=(8,), budgets_per_host_w=(250.0,),
                              spikes=("burst",), heterogeneous=(False,),
                              rules=("violation_burst", "cap_blocked"),
                              duration_s=600.0, tick_s=10.0)
    policies = ("cpc", "static")
    seq = run_sweep(specs, policies=policies, engine="vector")
    bat = run_sweep(specs, policies=policies, engine="batch")
    migrated = False
    for name in seq:
        for p in policies:
            a, b = seq[name][p], bat[name][p]
            assert (b.cap_changes, b.vmotions) \
                == (a.cap_changes, a.vmotions), (name, p)
            np.testing.assert_allclose(b.cpu_payload_mhz_s,
                                       a.cpu_payload_mhz_s, rtol=1e-9)
            np.testing.assert_allclose(b.energy_j, a.energy_j, rtol=1e-9)
            migrated |= a.vmotions > 0
    assert migrated                 # the grid exercised the migration layer


def test_run_sweep_batched_matches_sequential_timed():
    """Timed-migration families (gated vMotions with copy windows, slot
    limits, and a cluster bandwidth budget) run batched with zero fallback
    cells and reproduce the sequential sweep's action counts and energy
    bit for bit.  Payload accumulates per-VM delivery in a different
    reduction order than the object plane's bincount, so it is compared
    at tight tolerance rather than exactly."""
    from repro.sim.batch import BatchedSimulator
    from repro.sim.sweep import _build_batch_cells, _grid_balancer

    specs = scenario_families(sizes=(6,), budgets_per_host_w=(250.0,),
                              spikes=("burst",), heterogeneous=(False,),
                              churns=("timed_churn", "failure_cascade"),
                              rules=("none", "violation_burst"),
                              duration_s=1200.0, tick_s=10.0)
    policies = ("cpc", "static")
    cells, _ = _build_batch_cells(specs, policies)
    assert BatchedSimulator.unsupported_cells(
        cells, _grid_balancer(specs)) == {}     # no vector-fallback cliff
    seq = run_sweep(specs, policies=policies, engine="vector")
    bat = run_sweep(specs, policies=policies, engine="batch")
    migrated = churned = False
    for name in seq:
        for p in policies:
            a, b = seq[name][p], bat[name][p]
            assert (b.cap_changes, b.vmotions, b.power_ons, b.power_offs) \
                == (a.cap_changes, a.vmotions, a.power_ons,
                    a.power_offs), (name, p)
            assert b.energy_j == a.energy_j, (name, p)
            np.testing.assert_allclose(b.cpu_payload_mhz_s,
                                       a.cpu_payload_mhz_s, rtol=1e-9)
            migrated |= a.vmotions > 0
            churned |= a.power_ons + a.power_offs > 0
    assert migrated                # timed launches committed via the table
    assert churned                 # and the DPM lifecycle fired around them


def test_run_sweep_batch_fallback_partitions_grid():
    """A grid with cells the batched engine cannot replay exactly raises by
    default; with on_unsupported="fallback" it is *partitioned* -- only the
    offending cells run on the sequential vector engine."""
    from repro.sim.batch import BatchUnsupported

    specs = [SweepSpec(name="a", n_hosts=4, spike="flat", duration_s=300.0,
                       tick_s=30.0),
             SweepSpec(name="b", n_hosts=4, spike="flat", duration_s=600.0,
                       tick_s=30.0)]         # mixed time grids
    with pytest.raises(BatchUnsupported, match="time grid"):
        run_sweep(specs, policies=("cpc",), engine="batch")
    with pytest.warns(RuntimeWarning, match="sequential vector engine"):
        res = run_sweep(specs, policies=("cpc",), engine="batch",
                        on_unsupported="fallback")
    assert set(res) == {"a", "b"}
    # Parity for both halves of the partition against the pure-vector run.
    for specs_one in ([specs[0]], [specs[1]]):
        ref = run_sweep(specs_one, policies=("cpc",), engine="vector")
        name = specs_one[0].name
        assert res[name]["cpc"].cap_changes == ref[name]["cpc"].cap_changes
        np.testing.assert_allclose(res[name]["cpc"].energy_j,
                                   ref[name]["cpc"].energy_j, rtol=1e-9)


@pytest.mark.parametrize("order", ("reversed", "shuffled"))
def test_run_sweep_async_completion_order_independent(monkeypatch, order):
    """The overlapped pipeline dispatches every bucket before harvesting
    any; out-of-order bucket completion (injected by shuffling the harvest
    order) must still return the merged grid in exact specs x policies
    order, with per-cell values matching the vector engine -- including the
    vector-fallback cells interleaved into the assembly."""
    import repro.sim.sweep as sw

    specs = [SweepSpec(name="small", n_hosts=4, spike="burst",
                       duration_s=600.0, tick_s=30.0),
             SweepSpec(name="big", n_hosts=8, spike="burst",
                       duration_s=600.0, tick_s=30.0),
             SweepSpec(name="odd", n_hosts=4, spike="flat",
                       duration_s=300.0, tick_s=30.0)]  # mixed time grid
    policies = ("cpc", "static")
    ref = run_sweep(specs, policies=policies, engine="vector")

    orders: list = []

    def scrambled(n):
        idx = list(range(n))
        if order == "reversed":
            idx.reverse()
        else:
            rng = np.random.RandomState(0)
            rng.shuffle(idx)
        orders.append(list(idx))
        return idx

    monkeypatch.setattr(sw, "_harvest_order", scrambled)
    with pytest.warns(RuntimeWarning, match="sequential vector engine"):
        res = run_sweep(specs, policies=policies, engine="batch",
                        on_unsupported="fallback")
    # The hetero grid really produced >= 2 concurrently dispatched buckets
    # (pow2 classes (4, 16) and (8, 16)) whose harvest we scrambled.
    assert orders and max(len(o) for o in orders) >= 2
    # Exact specs x policies iteration order, fallback cell included.
    assert list(res) == [s.name for s in specs]
    for name in res:
        assert list(res[name]) == list(policies)
    for s in specs:
        for p in policies:
            a, b = ref[s.name][p], res[s.name][p]
            assert b.cap_changes == a.cap_changes, (s.name, p)
            np.testing.assert_allclose(b.energy_j, a.energy_j, rtol=1e-9)
            np.testing.assert_allclose(b.cpu_payload_mhz_s,
                                       a.cpu_payload_mhz_s, rtol=1e-9)


_TS_FIELDS = ("cpu_payload_mhz_s", "cpu_demand_mhz_s", "mem_payload_mb_s",
              "mem_demand_mb_s", "energy_j")
_TS_COUNTERS = ("cap_changes", "vmotions", "power_ons", "power_offs")


@pytest.mark.parametrize("regime", ("cap", "dpm", "rules", "timed"))
def test_reduced_metrics_bit_identical_to_timeseries(regime):
    """The device-side reduced path (default) and the full per-tick
    timeseries path agree bit for bit: ``keep_timeseries=False`` summaries
    equal the ``keep_timeseries=True`` run's summaries *and* the
    ``fold_timeseries`` reduction of its per-tick series, across every
    batched regime (cap-only scan, DPM churn, rules + balancer, timed
    migrations)."""
    from repro.sim.batch import BatchedSimulator
    from repro.sim.sweep import _build_batch_cells, _grid_balancer

    grids = {
        "cap": dict(sizes=(4,), spikes=("burst",), heterogeneous=(False,),
                    duration_s=600.0, tick_s=30.0),
        "dpm": dict(sizes=(6,), spikes=("burst",), heterogeneous=(False,),
                    churns=("dpm",), duration_s=1500.0, tick_s=30.0),
        "rules": dict(sizes=(8,), spikes=("burst",), heterogeneous=(False,),
                      rules=("violation_burst",), duration_s=600.0,
                      tick_s=10.0),
        "timed": dict(sizes=(6,), spikes=("burst",), heterogeneous=(False,),
                      churns=("timed_churn",), rules=("violation_burst",),
                      duration_s=1200.0, tick_s=10.0),
    }
    specs = scenario_families(budgets_per_host_w=(250.0,), **grids[regime])
    cells, _ = _build_batch_cells(specs, ("cpc", "static"))
    bal = _grid_balancer(specs)
    r0 = BatchedSimulator(cells, balancer=bal, slot_slack=3.0).run()
    r1 = BatchedSimulator(cells, balancer=bal, slot_slack=3.0,
                          keep_timeseries=True).run()
    assert r0.timeseries is None
    assert set(r1.timeseries) == set(_TS_FIELDS) | set(_TS_COUNTERS)
    red = r1.reduced_timeseries()
    for f in _TS_FIELDS:
        assert np.array_equal(getattr(r1, f), getattr(r0, f)), f
        assert np.array_equal(red[f], getattr(r0, f)), f
    for f in _TS_COUNTERS:
        assert np.array_equal(getattr(r1, f), getattr(r0, f)), f
        assert np.array_equal(red[f], getattr(r0, f)), f
    # The satisfaction summary derives from the folded fields exactly too.
    with np.errstate(invalid="ignore"):
        np.testing.assert_array_equal(
            red["cpu_payload_mhz_s"] / red["cpu_demand_mhz_s"],
            r0.cpu_payload_mhz_s / r0.cpu_demand_mhz_s)
    # Each regime exercised the machinery whose counters it folds.
    if regime == "dpm":
        assert int(r0.power_offs.sum()) > 0
    if regime in ("rules", "timed"):
        assert int(r0.vmotions.sum()) > 0
    assert int(r0.cap_changes.sum()) > 0


# --------------------------------------------- budget-tree (row) families
def test_row_contention_specs_shapes():
    specs = row_contention_specs(sizes=(10, 100))
    assert [s.n_hosts for s in specs] == [10, 100]
    assert all(s.tree == "two_row" for s in specs)
    assert len({s.name for s in specs}) == len(specs)


def test_unknown_tree_rejected():
    with pytest.raises(ValueError, match="tree"):
        build_sweep(SweepSpec(name="t", tree="nope"), "cpc")


def test_build_sweep_two_row_deployment_respects_tree():
    """Deployment projects the initial caps under the row limits, so every
    engine starts from a tree-respecting state."""
    spec = row_contention_specs(sizes=(10,))[0]
    for policy in ("cpc", "static", "statichigh"):
        snap, _, _ = build_sweep(spec, policy)
        tree = snap.effective_tree()
        assert tree is not None
        caps = np.array([h.power_cap for h in snap.hosts.values()])
        on = np.array([h.powered_on for h in snap.hosts.values()])
        assert tree.max_overshoot(caps, on) <= 1e-6
        # Row 0's limit really undercuts its pro-rata share.
        assert tree.limit[1] == pytest.approx(
            TWO_ROW_LIMIT_FRAC * snap.power_budget)


def test_build_sweep_tree_preserves_rng_stream():
    """Adding the tree must not disturb the random draws: the tree-less
    spec with the same seed deploys the identical VM set and traces."""
    base = SweepSpec(name="t", n_hosts=10, spike="burst", seed=5)
    treed = SweepSpec(name="t", n_hosts=10, spike="burst", seed=5,
                      tree="two_row")
    a, ta, _ = build_sweep(base, "cpc")
    b, tb, _ = build_sweep(treed, "cpc")
    assert [v.vm_id for v in a.vms.values()] == \
        [v.vm_id for v in b.vms.values()]
    for vid in ta:
        assert ta[vid](50.0) == tb[vid](50.0)


def test_row_contention_batch_matches_vector():
    """Differential acceptance: the two_row grid is bit-identical between
    the batched scan (tree columns carried through lax.scan) and the
    sequential vector engine -- exact cap-change counts, tight-tolerance
    payload/energy."""
    specs = row_contention_specs(sizes=(10,), duration_s=600.0)
    policies = ("cpc", "static")
    seq = run_sweep(specs, policies=policies, engine="vector")
    bat = run_sweep(specs, policies=policies, engine="batch")
    for name in seq:
        for p in policies:
            a, b = seq[name][p], bat[name][p]
            assert b.cap_changes == a.cap_changes, (name, p)
            assert b.vmotions == 0
            np.testing.assert_allclose(b.cpu_payload_mhz_s,
                                       a.cpu_payload_mhz_s, rtol=1e-9)
            np.testing.assert_allclose(b.energy_j, a.energy_j, rtol=1e-9)
    assert seq[specs[0].name]["cpc"].cap_changes > 0


def test_row_contention_policy_separation():
    """The burst is concentrated under the binding row, so CPC's tree-aware
    redistribution recovers payload Static strands against the row limit."""
    specs = row_contention_specs(sizes=(10,), duration_s=600.0)
    res = run_sweep(specs, policies=("cpc", "static"), engine="batch")
    name = specs[0].name
    cpc, static = res[name]["cpc"], res[name]["static"]
    assert cpc.cap_changes > 0 and static.cap_changes == 0
    assert cpc.cpu_payload_mhz_s > static.cpu_payload_mhz_s * 1.001


def test_run_sweep_batched_policy_separation():
    """CPC beats Static under host-correlated bursts on the batch engine."""
    spec = SweepSpec(name="sep", n_hosts=12, vms_per_host=8, spike="burst",
                     duration_s=1200.0, tick_s=20.0, seed=3)
    res = run_sweep_batched([spec], policies=("cpc", "static"))
    cpc, static = res["sep"]["cpc"], res["sep"]["static"]
    assert cpc.cap_changes > 0
    assert static.cap_changes == 0
    assert cpc.cpu_satisfaction >= static.cpu_satisfaction - 1e-9


@pytest.mark.slow
def test_sweep_scale_thousand_hosts():
    """Acceptance: a 1,000-host / 10,000-VM cell runs end-to-end."""
    spec = SweepSpec(name="xl", n_hosts=1000, vms_per_host=10,
                     spike="burst", duration_s=600.0)
    r = run_cell(spec, "cpc")
    assert r.spec.n_vms == 10_000
    assert r.ticks == 60
    assert r.cpu_satisfaction > 0.5
    assert np.isfinite(r.energy_j)
