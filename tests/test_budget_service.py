"""Fault-injection suite for the headroom/admission service.

Malformed queries, powered-off leaves, and limit changes racing a pending
power-on must either raise a structured :class:`BudgetServiceError` or
return a consistent answer -- and the service must *never* expose a cap
set that violates an ancestor limit mid-transition (the invariant is
re-checked after every event, including failed ones).  The error taxonomy
(``code`` strings) is pinned here so callers can branch on it.
"""

import numpy as np
import pytest

from repro.core.budget_tree import BudgetTree
from repro.core.power_model import PAPER_HOST
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.runtime.budget_service import (AdmissionQuery, BudgetService,
                                          BudgetServiceError, CapDecision,
                                          DemandUpdate, HeadroomQuery,
                                          NodeLimitChange, PowerOff,
                                          PowerOnComplete, PowerOnRequest,
                                          service_from_snapshot,
                                          sync_router_capacities,
                                          synthetic_feed)
from repro.runtime.serve_loop import CapacityAwareRouter, Replica


def two_row_service(row0=700.0, row1=400.0, budget=1100.0):
    """Rows of two hosts each; h3 starts in standby."""
    tree = BudgetTree.two_rows(budget, 4, row0_limit=row0, row1_limit=row1)
    caps = np.array([250.0, 250.0, 320.0, 0.0])
    on = np.array([True, True, True, False])
    return BudgetService(tree, [f"h{i}" for i in range(4)], caps, on)


# -------------------------------------------------------- malformed input
@pytest.mark.parametrize("event,code", [
    (HeadroomQuery("nope"), "unknown-host"),
    (AdmissionQuery("nope", 10.0), "unknown-host"),
    (AdmissionQuery("h0", -1.0), "bad-watts"),
    (AdmissionQuery("h0", float("nan")), "bad-watts"),
    (DemandUpdate("h0", float("inf")), "bad-watts"),
    (DemandUpdate("h3", 100.0), "host-off"),
    (PowerOnRequest("h0", 100.0), "already-on"),
    (PowerOnComplete("h0"), "not-pending"),
    (PowerOff("h3"), "host-off"),
    (NodeLimitChange(99, 100.0), "unknown-node"),
    (NodeLimitChange(1, -10.0), "bad-watts"),
    (NodeLimitChange(1, float("nan")), "bad-watts"),
])
def test_malformed_events_raise_structured_codes(event, code):
    svc = two_row_service()
    caps0, on0 = svc.caps.copy(), svc.on.copy()
    with pytest.raises(BudgetServiceError) as exc:
        svc.handle(event)
    assert exc.value.code == code
    # Failed events leave no partial state behind.
    np.testing.assert_array_equal(svc.caps, caps0)
    np.testing.assert_array_equal(svc.on, on0)
    assert not svc.pending.any()


def test_unknown_event_type_rejected():
    svc = two_row_service()
    with pytest.raises(BudgetServiceError) as exc:
        svc.handle(object())
    assert exc.value.code == "unknown-event"


def test_topology_mismatch_rejected():
    tree = BudgetTree.two_rows(1000.0, 4, row0_limit=500.0)
    with pytest.raises(BudgetServiceError) as exc:
        BudgetService(tree, ["h0", "h1"], np.zeros(2), np.ones(2, bool))
    assert exc.value.code == "bad-topology"


def test_initially_violating_caps_rejected():
    tree = BudgetTree.two_rows(1000.0, 4, row0_limit=300.0)
    with pytest.raises(BudgetServiceError) as exc:
        BudgetService(tree, [f"h{i}" for i in range(4)],
                      np.array([250.0, 250.0, 100.0, 100.0]),
                      np.ones(4, bool))
    assert exc.value.code == "invariant"


# ------------------------------------------------------ powered-off leaves
def test_powered_off_leaf_consistent_answers():
    svc = two_row_service()
    # A standby host still answers queries (its stale cap counts nothing).
    assert svc.headroom("h3") == pytest.approx(80.0)
    fits, grantable = svc.admissible("h3", 60.0)
    assert fits and grantable == pytest.approx(60.0)
    fits, grantable = svc.admissible("h3", 200.0)
    assert not fits and grantable == pytest.approx(80.0)
    # ...but mutating it requires an explicit power-on request.
    with pytest.raises(BudgetServiceError) as exc:
        svc.handle(DemandUpdate("h3", 100.0))
    assert exc.value.code == "host-off"


def test_double_power_on_rejected_grant_preserved():
    svc = two_row_service()
    granted, decisions = svc.handle(PowerOnRequest("h3", 200.0))
    assert granted == pytest.approx(80.0)     # clipped to row-1 headroom
    assert [d.reason for d in decisions] == ["power-on-grant"]
    with pytest.raises(BudgetServiceError) as exc:
        svc.handle(PowerOnRequest("h3", 50.0))
    assert exc.value.code == "already-pending"
    assert svc.caps[3] == pytest.approx(80.0)  # first grant untouched
    svc.handle(PowerOnComplete("h3"))
    assert svc.on[3] and not svc.pending[3]


# ------------------------------- limit change racing a pending power-on
def test_limit_change_racing_pending_power_on():
    """Tighten row 1 while h3's 80 W grant is still in flight: the service
    must scale the *pending* grant too (it counts as allocated) and stream
    the forced decreases -- the invariant holds at every step."""
    svc = two_row_service()
    svc.handle(PowerOnRequest("h3", 200.0))
    assert svc.pending[3] and svc.caps[3] == pytest.approx(80.0)
    # Row 1 now sits exactly at its 400 W limit (320 + 80 pending).
    _, decisions = svc.handle(NodeLimitChange(2, 200.0))
    touched = {d.host_id: d.cap_w for d in decisions}
    assert set(touched) == {"h2", "h3"}       # both row-1 residents shrink
    assert sum(touched.values()) == pytest.approx(200.0)
    assert svc.caps[3] < 80.0                 # the pending grant was cut
    # Completion lands inside the tightened row.
    svc.handle(PowerOnComplete("h3"))
    assert svc.tree.max_overshoot(svc.caps, svc.on) <= 1e-6
    # Row 0 was never touched by the race.
    assert "h0" not in touched and "h1" not in touched


def test_limit_change_never_exposes_violation_midstream():
    """Every event handler re-checks the invariant before returning, so a
    replayed feed full of races and malformed events can never leave a
    node over its limit (handle() would assert, failing the test)."""
    svc = two_row_service()
    feed = synthetic_feed(svc.tree, n_events=500, seed=3)
    # synthetic_feed names hosts host{i}; remap onto this service's ids.
    remap = {f"host{i}": f"h{i}" for i in range(4)}
    events = [dataclass_replace(ev, remap) for ev in feed]
    report = svc.replay(events)
    assert report.n_events == len(events)
    assert report.n_errors > 0                # the feed includes races
    assert svc.tree.max_overshoot(svc.caps, svc.on | svc.pending) <= 1e-6
    # Latency percentiles are well-formed (the benchmark gates them).
    assert 0.0 < report.p50_us <= report.p99_us


def dataclass_replace(ev, remap):
    if hasattr(ev, "host_id"):
        import dataclasses
        return dataclasses.replace(ev, host_id=remap[ev.host_id])
    return ev


def test_replay_strict_raises_collecting_does_not():
    svc = two_row_service()
    events = [HeadroomQuery("h0"), DemandUpdate("nope", 10.0),
              HeadroomQuery("h1")]
    report = svc.replay(events)
    assert report.n_errors == 1
    assert report.errors[0][0] == "unknown-host"
    assert report.answers[0] is not None and report.answers[2] is not None
    with pytest.raises(BudgetServiceError):
        two_row_service().replay(events, strict=True)


# ------------------------------------------------------- demand semantics
def test_demand_update_clips_raise_to_headroom():
    svc = two_row_service()
    # h2 asks for more than row 1 allows: clipped at 320 + 80 = 400.
    new, decisions = svc.handle(DemandUpdate("h2", 500.0))
    assert new == pytest.approx(400.0)
    assert decisions == [CapDecision("h2", 400.0, "demand-update")]
    # Decreases always pass through exactly.
    new, _ = svc.handle(DemandUpdate("h2", 100.0))
    assert new == 100.0
    # A no-op update streams no decision.
    _, decisions = svc.handle(DemandUpdate("h2", 100.0))
    assert decisions == []


def test_power_off_frees_row_headroom():
    svc = two_row_service()
    assert svc.headroom("h3") == pytest.approx(80.0)
    svc.handle(PowerOff("h2"))
    assert svc.headroom("h3") == pytest.approx(400.0)


# ----------------------------------------------------- runtime integration
def test_service_from_snapshot_and_router_sync():
    tree = BudgetTree.two_rows(1100.0, 4, row0_limit=700.0,
                               row1_limit=400.0)
    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=c, powered_on=onf)
             for i, (c, onf) in enumerate(
                 [(250.0, True), (250.0, True), (320.0, True),
                  (0.0, False)])]
    vms = [VirtualMachine(vm_id="vm0", host_id="h0")]
    snap = ClusterSnapshot(hosts, vms, power_budget=1100.0,
                           budget_tree=tree)
    svc = service_from_snapshot(snap)
    assert svc.headroom("h3") == pytest.approx(80.0)

    router = CapacityAwareRouter([Replica(f"r{i}", f"h{i}")
                                  for i in range(4)])
    replica_hosts = {f"r{i}": f"h{i}" for i in range(4)}
    sync_router_capacities(svc, router, replica_hosts)
    assert router.capacity["r0"] == pytest.approx(250.0)
    assert router.capacity["r3"] == 0.0       # off host weights zero
    svc.handle(PowerOnRequest("h3", 200.0))
    sync_router_capacities(svc, router, replica_hosts)
    assert router.capacity["r3"] == 0.0       # pending: still zero
    svc.handle(PowerOnComplete("h3"))
    sync_router_capacities(svc, router, replica_hosts)
    assert router.capacity["r3"] == pytest.approx(80.0)


def test_service_from_snapshot_without_tree_uses_flat():
    hosts = [Host("h0", PAPER_HOST, power_cap=200.0)]
    snap = ClusterSnapshot(hosts, [], power_budget=300.0)
    svc = service_from_snapshot(snap)
    assert svc.tree.n_nodes == 1
    assert svc.headroom("h0") == pytest.approx(100.0)
