"""Flash-attention backward Pallas kernels vs autodiff of the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,d,causal",
    [
        (2, 96, 96, 4, 2, 32, True),
        (1, 128, 128, 4, 1, 64, True),    # MQA
        (2, 64, 64, 2, 2, 16, False),     # bidirectional
        (1, 100, 100, 4, 2, 32, True),    # non-multiple of block
    ])
def test_flash_attention_grads_match_ref(b, sq, skv, hq, hkv, d, causal):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, sq, hq, d))
    k = jax.random.normal(ks[1], (b, skv, hkv, d))
    v = jax.random.normal(ks[2], (b, skv, hkv, d))
    ct = jax.random.normal(ks[3], (b, sq, hq, d))

    def loss_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=causal,
                                       block_q=32, block_k=32) * ct)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal) * ct)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, r, name in zip(gk, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_forward_lse_matches_direct_logsumexp():
    ks = jax.random.split(KEY, 3)
    b, s, hq, hkv, d = 1, 64, 2, 2, 16
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    _, lse = flash_attention_kernel(q, k, v, causal=True, block_q=32,
                                    block_k=32, interpret=True)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref_lse = jax.nn.logsumexp(scores, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(ref_lse),
                               rtol=1e-5, atol=1e-5)


def test_value_and_grad_through_jit():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))

    @jax.jit
    def loss(q, k, v):
        return jnp.sum(jnp.square(
            flash_attention(q, k, v, block_q=16, block_k=16)))

    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
    assert jnp.isfinite(val)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in grads)
