"""Elastic resize on a real multi-device mesh (subprocess: 8 fake devices).

Exercises the full DPM-driven path: train on 2 pods -> checkpoint ->
rebuild 1-pod mesh -> restore resharded -> continue -> scale back up,
asserting loss continuity (the examples/elastic_training.py flow)."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_elastic_training_example():
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "examples/elastic_training.py"],
        capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK: loss continuous across both elastic transitions" in out.stdout
