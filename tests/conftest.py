import os

# Smoke tests and benches must see the real (single) CPU device; only the
# dry-run sets xla_force_host_platform_device_count (and only in its own
# process).
assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", "")

# Hypothesis profiles: CI runs the differential/property harness with a
# fixed, derandomized profile (HYPOTHESIS_PROFILE=ci) so the kernel-parity
# gate is reproducible run-to-run; locally the default profile keeps the
# suite fast.  Tests that set @settings(...) explicitly keep their own
# example counts.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", derandomize=True, max_examples=50,
                                   deadline=None)
    _hyp_settings.register_profile("dev", max_examples=20, deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # pragma: no cover - hypothesis-less environments
    pass
