import os

# Smoke tests and benches must see the real (single) CPU device; only the
# dry-run sets xla_force_host_platform_device_count (and only in its own
# process).
assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", "")
