"""Training loop end-to-end: loss decreases, schedules, compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticTokens
from repro.optim.adamw import AdamW
from repro.optim.compress import (ErrorFeedbackCompressor, dequantize_int8,
                                  quantize_int8)
from repro.optim.schedule import cosine_schedule, wsd_schedule
from repro.runtime.train_loop import init_train_state, make_train_step


def test_loss_decreases_dense():
    cfg = configs.get_smoke("granite_8b")
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=8, seed=7)
    opt = AdamW(learning_rate=3e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for _ in range(30):
        b = data.next_batch()
        batch = {"tokens": b.tokens, "labels": b.labels,
                 "weights": b.weights}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_weight_mask_excludes_examples():
    """Power-aware masking: zero-weight examples do not affect the loss."""
    cfg = configs.get_smoke("granite_8b")
    opt = AdamW(learning_rate=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    key = jax.random.PRNGKey(5)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    w_mask = jnp.ones((4, 32)).at[2:].set(0.0)
    _, m1 = step(state, {"tokens": tokens, "labels": labels,
                         "weights": w_mask})
    # Replacing the masked-out rows with junk must not change the loss.
    junk_tokens = tokens.at[2:].set((tokens[2:] + 17) % cfg.vocab_size)
    junk_labels = labels.at[2:].set((labels[2:] + 5) % cfg.vocab_size)
    _, m2 = step(state, {"tokens": junk_tokens, "labels": junk_labels,
                         "weights": w_mask})
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    assert float(m1["tokens"]) == 64.0


def test_schedules():
    cos = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(cos(0)) == 0.0
    assert np.isclose(float(cos(10)), 1e-3, rtol=1e-3)
    assert float(cos(100)) < float(cos(50))
    wsd = wsd_schedule(1e-3, warmup_steps=10, stable_steps=50,
                       decay_steps=20)
    assert np.isclose(float(wsd(30)), 1e-3)       # stable plateau
    assert np.isclose(float(wsd(59)), 1e-3)
    assert float(wsd(80)) < 2e-5                  # decayed


def test_int8_quantization_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 3.0
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.51 + 1e-6


def test_error_feedback_compensates():
    """Sum of compressed grads converges to sum of true grads."""
    comp = ErrorFeedbackCompressor()
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    residual = comp.init(g)
    total_true = jnp.zeros(64)
    total_sent = jnp.zeros(64)
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(i + 2), (64,))}
        sent, residual = comp.compress(gi, residual)
        total_true += gi["w"]
        total_sent += sent["w"]
    # Residual bounds the cumulative error.
    gap = float(jnp.max(jnp.abs(total_true - total_sent)))
    assert gap <= float(jnp.max(jnp.abs(residual["w"]))) + 1e-4


def test_data_pipeline_determinism_and_state():
    d1 = SyntheticTokens(vocab_size=1000, seq_len=16, global_batch=4,
                         seed=3)
    b1 = d1.next_batch()
    b2 = d1.next_batch()
    # Restore from checkpointed cursor -> identical stream.
    d2 = SyntheticTokens(vocab_size=1000, seq_len=16, global_batch=4,
                         seed=3)
    d2.load_state_dict({"seed": 3, "step": 1})
    b2r = d2.next_batch()
    assert jnp.array_equal(b2.tokens, b2r.tokens)
    assert not jnp.array_equal(b1.tokens, b2.tokens)
