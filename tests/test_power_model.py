"""Power model (paper Eqs. 1-4) unit + property tests, and Table II."""

import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.power_model import (HostPowerSpec, PAPER_HOST,
                                    deployment_table)


def test_paper_host_capped_capacity():
    # 250 W on the Table I server -> 19.575 GHz (Sec. II-B numbers).
    assert np.isclose(PAPER_HOST.capped_capacity(250.0), 19575.0)
    assert np.isclose(PAPER_HOST.capped_capacity(320.0), 34800.0)
    assert np.isclose(PAPER_HOST.capped_capacity(160.0), 0.0)


def test_cap_clipping():
    assert PAPER_HOST.capped_capacity(500.0) == 34800.0   # above peak
    assert PAPER_HOST.capped_capacity(100.0) == 0.0       # below idle


def test_table2_deployments():
    rows = deployment_table(PAPER_HOST, 8000.0, [400, 320, 285, 250])
    expect = [  # (count, capacity GHz, cpu ratio, mem ratio) -- paper Table II
        (20, 696.0, 1.00, 1.00),
        (25, 870.0, 1.25, 1.25),
        (28, 761.25, 1.09, 1.40),
        (32, 626.4, 0.90, 1.60),
    ]
    for row, (count, ghz, cr, mr) in zip(rows, expect):
        assert row["host_count"] == count
        assert np.isclose(row["capacity"] / 1000.0, ghz, atol=0.3)
        assert np.isclose(row["capacity_ratio"], cr, atol=0.01)
        assert np.isclose(row["memory_ratio"], mr, atol=0.01)


host_specs = st.builds(
    HostPowerSpec,
    capacity_peak=st.floats(1e3, 1e6),
    power_idle=st.floats(10.0, 300.0),
    power_peak=st.floats(301.0, 1000.0),
)


@settings(max_examples=200, deadline=None)
@given(spec=host_specs, cap=st.floats(0.0, 1200.0))
def test_roundtrip_and_monotonicity(spec, cap):
    c = spec.capped_capacity(cap)
    assert 0.0 <= c <= spec.capacity_peak
    # Inverting capacity must give back a clipped cap.
    cap_back = spec.cap_for_capacity(c)
    assert np.isclose(spec.capped_capacity(cap_back), c, rtol=1e-9,
                      atol=1e-6)
    # Monotone: more Watts never less capacity.
    assert spec.capped_capacity(cap + 10.0) >= c - 1e-9


@settings(max_examples=100, deadline=None)
@given(spec=host_specs, u=st.floats(0.0, 1.0))
def test_power_consumed_bounds(spec, u):
    p = spec.power_consumed(u)
    assert spec.power_idle - 1e-9 <= p <= spec.power_peak + 1e-9
    # Consuming at capped utilization never exceeds the cap (Eq. 2).
    cap = spec.power_idle + u * (spec.power_peak - spec.power_idle)
    c = spec.capped_capacity(cap)
    assert spec.power_consumed(c / spec.capacity_peak) <= cap + 1e-6


@settings(max_examples=100, deadline=None)
@given(spec=host_specs, overhead=st.floats(0.0, 500.0), cap=st.floats(0, 1e4))
def test_managed_capacity_never_negative(spec, overhead, cap):
    import dataclasses
    spec = dataclasses.replace(spec, hypervisor_overhead=overhead)
    assert spec.managed_capacity(cap) >= 0.0
