"""Property suite locking down hierarchical budget trees.

Four pinned properties (plus regressions) over random hierarchies:

  * **Invariant** -- after any manager invocation, every tree node's
    powered-on subtree cap-sum stays within its limit (checked by
    brute-force Python sums, independent of the engines' own asserts).
  * **Flat bit-identity** -- a single-level tree that adds no constraint
    (root at the scalar budget, one unlimited leaf per host) produces
    *bit-identical* actions to the scalar-budget protocol on all three
    engines: object, vector, and batched.
  * **Monotonicity** -- tightening any node's limit never increases any
    host's projected cap (and a live service's ``NodeLimitChange`` never
    raises a cap).
  * **Headroom parity** -- the admission service's ``headroom`` answers
    equal brute-force recomputation from first principles, before and
    after replaying a mixed event feed.

Regressions: power-on funding's donor/pool scope stops at the requester's
tightest binding ancestor (a saturated row cannot be over-funded from
another row's watts), and DPM evacuation scope collapses to the binding
subtree.  Like the kernel-invariant harness, fuzzing runs as an always-on
seed sweep plus hypothesis-driven generation when hypothesis is available.
"""

import numpy as np
import pytest

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import kernels
from repro.core.budget_tree import BudgetTree
from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.core.power_model import PAPER_HOST
from repro.core.redistribute import redistribute_for_power_on
from repro.drs import balancer as balancer_mod
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.runtime.budget_service import (BudgetService, NodeLimitChange,
                                          synthetic_feed)
from repro.sim import workloads
from repro.sim.batch import BatchCell, BatchedSimulator
from repro.sim.cluster import SimConfig, Simulator
from repro.sim.engine import VectorSimulator

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis-driven fuzzing needs hypothesis (requirements.txt)")

SEEDS = tuple(range(5))


# ------------------------------------------------------------- generators
def random_tree(rng, n_hosts, budget):
    """A random feasible hierarchy: parents precede children, hosts hang
    off arbitrary nodes, and every non-root limit grants its subtree at
    least ~idle power per host (so reservation floors always fit) while
    often undercutting the pro-rata share (so limits actually bind)."""
    n_nodes = 1 + rng.randint(0, 4)
    parent = [-1] + [int(rng.randint(0, m)) for m in range(1, n_nodes)]
    host_node = rng.randint(0, n_nodes, size=n_hosts)
    probe = BudgetTree(parent, [budget] * n_nodes, host_node)
    limit = [float(budget)]
    for m in range(1, n_nodes):
        k = max(int(probe.subtree_hosts(m).sum()), 1)
        limit.append(k * float(rng.uniform(185.0, 330.0)))
    return BudgetTree(parent, limit, host_node)


def random_cluster(rng, tree, budget, n_hosts):
    hosts = [Host(f"h{i}", PAPER_HOST,
                  power_cap=float(rng.uniform(170.0, 320.0)),
                  powered_on=bool(rng.rand() > 0.15))
             for i in range(n_hosts)]
    if not any(h.powered_on for h in hosts):
        hosts[0].powered_on = True
    vms = []
    for i in range(2 * n_hosts):
        owner = hosts[i % n_hosts]
        if not owner.powered_on:
            continue
        vms.append(VirtualMachine(
            vm_id=f"vm{i}", vcpus=2, memory_mb=4096.0,
            demand=float(rng.uniform(0.0, 6000.0)),
            mem_demand=float(rng.uniform(256.0, 2048.0)),
            host_id=owner.host_id))
    return ClusterSnapshot(hosts, vms, power_budget=budget, budget_tree=tree)


def _cap_only_manager() -> CloudPowerCapManager:
    cfg = ManagerConfig(powercap_enabled=True, dpm_enabled=False)
    cfg.balancer = balancer_mod.BalancerConfig(max_moves=0)
    return CloudPowerCapManager(cfg)


def brute_force_overshoot(tree, caps, on):
    """Worst per-node limit violation, recomputed with Python sums."""
    worst = -np.inf
    for m in range(tree.n_nodes):
        members = np.nonzero(tree.subtree_hosts(m))[0]
        used = sum(float(caps[j]) for j in members if on[j])
        worst = max(worst, used - float(tree.limit[m]))
    return worst


# --------------------------------------------- property 1: tree invariant
def check_manager_tree_invariant(seed):
    rng = np.random.RandomState(seed)
    n_hosts = int(rng.randint(3, 7))
    budget = 300.0 * n_hosts
    tree = random_tree(rng, n_hosts, budget)
    snap = random_cluster(rng, tree, budget, n_hosts)
    res = _cap_only_manager().run_invocation(snap)
    final = list(res.snapshot.hosts.values())
    caps = np.array([h.power_cap for h in final])
    on = np.array([h.powered_on for h in final])
    assert brute_force_overshoot(tree, caps, on) <= 1e-6
    assert caps[on].sum() <= budget + 1e-6


@pytest.mark.parametrize("seed", SEEDS)
def test_manager_respects_tree_invariant(seed):
    check_manager_tree_invariant(seed)


# ------------------------------------------ property 2: flat bit-identity
def star_flat_tree(budget, n_hosts):
    """An ``n_hosts + 1``-node tree that adds no constraint: root at the
    scalar budget, one unlimited leaf per host.  Non-trivial (so the tree
    code path actually runs in every engine) but non-binding, so the
    protocol must behave bit-identically to the scalar budget."""
    parent = [-1] + [0] * n_hosts
    limit = [float(budget)] + [np.inf] * n_hosts
    return BudgetTree(parent, limit, np.arange(1, n_hosts + 1))


def _burst_build(tree_builder):
    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=250.0) for i in range(4)]
    vms, traces = [], {}
    for i in range(8):
        vm = VirtualMachine(vm_id=f"vm{i}", vcpus=2, memory_mb=4096.0,
                            host_id=f"h{i % 4}")
        vms.append(vm)
        if i % 4 == 0:        # hosts 0's VMs burst at 400 s -> cap churn
            traces[vm.vm_id] = workloads.step_trace(
                [(0.0, 800.0, 1024.0), (400.0, 6000.0, 1024.0)])
        else:
            traces[vm.vm_id] = workloads.step_trace([(0.0, 800.0, 1024.0)])
    budget = 4 * 250.0
    tree = tree_builder(budget, 4) if tree_builder else None
    snap = ClusterSnapshot(hosts, vms, power_budget=budget, budget_tree=tree)
    cfg = SimConfig(duration_s=900.0, drs_first_at_s=300.0,
                    record_timeline=False)
    return snap, traces, cfg


def _run_burst(engine, tree_builder):
    """(accumulators, final caps) for the burst scenario on one engine."""
    snap, traces, cfg = _burst_build(tree_builder)
    if engine == "batch":
        cell = BatchCell("cell", snap, traces, cfg, powercap_enabled=True)
        res = BatchedSimulator([cell]).run()
        return res.accumulators(0), np.asarray(res.final_caps[0])
    cls = Simulator if engine == "legacy" else VectorSimulator
    res = cls(snap, _cap_only_manager(), traces, cfg).run()
    caps = np.array([h.power_cap for h in res.final.hosts.values()])
    return res.acc, caps


@pytest.mark.parametrize("engine", ("legacy", "vector", "batch"))
def test_flat_tree_bit_identical_to_scalar(engine):
    acc0, caps0 = _run_burst(engine, None)
    acc1, caps1 = _run_burst(engine, star_flat_tree)
    assert acc0.cap_changes > 0          # the scenario exercises the caps
    for f in ("cap_changes", "vmotions", "power_ons", "power_offs",
              "cpu_payload_mhz_s", "mem_payload_mb_s", "energy_j"):
        assert getattr(acc1, f) == getattr(acc0, f), f
    np.testing.assert_array_equal(caps1, caps0)


def test_trivial_flat_tree_skips_tree_path():
    """``BudgetTree.flat`` encodes exactly the scalar budget; engines skip
    the tree code entirely for it."""
    snap, _, _ = _burst_build(lambda b, h: BudgetTree.flat(b, h))
    assert snap.budget_tree is not None
    assert snap.effective_tree() is None


# --------------------------------------------- property 3: monotonicity
def check_tightening_monotone(seed):
    rng = np.random.RandomState(seed)
    n_hosts = int(rng.randint(3, 9))
    budget = 300.0 * n_hosts
    tree = random_tree(rng, n_hosts, budget)
    caps = rng.uniform(0.0, 320.0, n_hosts)
    floors = caps * rng.uniform(0.0, 0.6, n_hosts)
    on = rng.rand(n_hosts) > 0.2
    base = tree.project(caps, on, floors=floors)
    # Projection sanity: never above the input, never below the floors.
    assert np.all(base[on] <= caps[on] + 1e-9)
    assert np.all(base[on] >= floors[on] - 1e-9)
    # Tightening any single node's limit never increases any host's cap.
    node = int(rng.randint(0, tree.n_nodes))
    lam = float(rng.uniform(0.3, 1.0))
    tight = tree.with_limit(node, float(tree.limit[node]) * lam)
    assert np.all(tight.project(caps, on, floors=floors)
                  <= base + 1e-9)


@pytest.mark.parametrize("seed", SEEDS)
def test_tightening_never_raises_caps(seed):
    check_tightening_monotone(seed)


def check_service_limit_change_monotone(seed):
    rng = np.random.RandomState(seed)
    n_hosts = int(rng.randint(3, 9))
    budget = 300.0 * n_hosts
    tree = random_tree(rng, n_hosts, budget)
    on = rng.rand(n_hosts) > 0.25
    caps = tree.project(rng.uniform(100.0, 300.0, n_hosts), on)
    svc = BudgetService(tree, [f"host{i}" for i in range(n_hosts)], caps, on)
    before = svc.caps.copy()
    node = int(rng.randint(0, tree.n_nodes))
    new_limit = float(tree.limit[node]) * float(rng.uniform(0.3, 1.0))
    if not np.isfinite(new_limit):
        new_limit = budget * 0.5
    _, decisions = svc.handle(NodeLimitChange(node, new_limit))
    assert np.all(svc.caps[svc.on] <= before[svc.on] + 1e-9)
    for d in decisions:                  # streamed decisions only decrease
        assert d.cap_w <= before[svc._host(d.host_id)] + 1e-9


@pytest.mark.parametrize("seed", SEEDS)
def test_service_limit_change_never_raises_caps(seed):
    check_service_limit_change_monotone(seed)


# ------------------------------------------ property 4: headroom parity
def check_service_headroom_brute_force(seed):
    rng = np.random.RandomState(seed)
    n_hosts = int(rng.randint(3, 9))
    budget = 300.0 * n_hosts
    tree = random_tree(rng, n_hosts, budget)
    on = rng.rand(n_hosts) > 0.25
    caps = tree.project(rng.uniform(100.0, 300.0, n_hosts), on)
    ids = [f"host{i}" for i in range(n_hosts)]
    svc = BudgetService(tree, ids, caps, on)
    for h in ids:
        assert svc.headroom(h) == pytest.approx(
            svc.brute_force_headroom(h), abs=1e-9)
    # Still in lockstep after churning through a mixed event feed.
    svc.replay(synthetic_feed(tree, n_events=300, seed=seed))
    for h in ids:
        assert svc.headroom(h) == pytest.approx(
            svc.brute_force_headroom(h), abs=1e-9)


@pytest.mark.parametrize("seed", SEEDS)
def test_service_headroom_matches_brute_force(seed):
    check_service_headroom_brute_force(seed)


# ------------------------------------------------- hypothesis-driven fuzz
if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1))
    def test_manager_tree_invariant_hypothesis(seed):
        check_manager_tree_invariant(seed)

    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1))
    def test_tightening_monotone_hypothesis(seed):
        check_tightening_monotone(seed)

    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1))
    def test_service_headroom_hypothesis(seed):
        check_service_headroom_brute_force(seed)

    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1))
    def test_service_limit_change_monotone_hypothesis(seed):
        check_service_limit_change_monotone(seed)


# ------------------------------------------------------------ regressions
def test_power_on_funding_respects_binding_row():
    """Satellite fix: the funding pool and donor set stop at the
    requester's tightest binding ancestor.  Row 1 (limit 400 W) holds one
    busy host at 320 W; funding its standby neighbor may grant at most the
    row's 80 W of headroom even though the rack has 280 W unallocated --
    the scalar protocol (no tree) would grant far more and blow the row
    limit by ~200 W."""
    budget = 1100.0
    tree = BudgetTree.two_rows(budget, 4, row0_limit=700.0,
                               row1_limit=400.0)

    def build(with_tree):
        hosts = [Host("h0", PAPER_HOST, power_cap=250.0),
                 Host("h1", PAPER_HOST, power_cap=250.0),
                 Host("h2", PAPER_HOST, power_cap=320.0),
                 Host("h3", PAPER_HOST, power_cap=160.0, powered_on=False)]
        vms = [VirtualMachine(vm_id="busy0", vcpus=8, memory_mb=8192.0,
                              demand=33000.0, host_id="h2"),
               VirtualMachine(vm_id="idle0", vcpus=1, memory_mb=2048.0,
                              demand=500.0, host_id="h0"),
               VirtualMachine(vm_id="idle1", vcpus=1, memory_mb=2048.0,
                              demand=500.0, host_id="h1")]
        return ClusterSnapshot(hosts, vms, power_budget=budget,
                               budget_tree=tree if with_tree else None)

    whatif, granted = redistribute_for_power_on(build(True), "h3")
    assert granted == pytest.approx(80.0, abs=1e-6)
    # Donors outside the binding row are untouched.
    assert whatif.hosts["h0"].power_cap == 250.0
    assert whatif.hosts["h1"].power_cap == 250.0
    # The row limit holds with the pending grant counted as allocated.
    caps = np.array([whatif.hosts[f"h{i}"].power_cap for i in range(4)])
    on_or_pending = np.array([True, True, True, True])
    assert brute_force_overshoot(tree, caps, on_or_pending) <= 1e-6

    # Control: without the tree the same request drains the rack pool.
    _, flat_granted = redistribute_for_power_on(build(False), "h3")
    assert flat_granted >= 250.0


def test_evac_scope_collapses_to_binding_row():
    """Evacuating a host under a saturated row keeps the freed watts and
    displaced demand inside that row; with slack everywhere the scope is
    the whole cluster (the scalar-protocol behavior)."""
    tree = BudgetTree.two_rows(1000.0, 4, row0_limit=500.0)
    tc = tree.cols()
    on = np.ones((1, 4), dtype=bool)
    victim = np.array([0])
    saturated = np.array([[250.0, 250.0, 100.0, 100.0]])
    scope = kernels.tree_evac_scope(np, tc, on, saturated, victim)
    np.testing.assert_array_equal(scope,
                                  [[True, True, False, False]])
    relaxed = np.array([[200.0, 250.0, 100.0, 100.0]])
    scope = kernels.tree_evac_scope(np, tc, on, relaxed, victim)
    np.testing.assert_array_equal(scope, [[True, True, True, True]])


@pytest.mark.parametrize("seed", SEEDS[:3])
def test_tree_kernels_numpy_jax_parity(seed):
    """The segment ops behind every tree decision agree across executors
    (the batched engine must pick the same actions as the NumPy planes)."""
    rng = np.random.RandomState(seed)
    n_hosts = int(rng.randint(3, 9))
    budget = 300.0 * n_hosts
    tree = random_tree(rng, n_hosts, budget)
    tc = tree.cols()
    on = (rng.rand(1, n_hosts) > 0.2)
    caps = rng.uniform(0.0, 320.0, (1, n_hosts))
    floors = caps * rng.uniform(0.0, 0.6, (1, n_hosts))
    victim = np.array([int(rng.randint(0, n_hosts))])

    ref_sums = kernels.tree_node_sums(np, tc, on, caps)
    ref_slack = kernels.tree_host_slack(
        np, tc, kernels.tree_headroom(np, tc, on, caps))
    ref_proj = kernels.tree_project_caps(np, tc, on, caps, floors)
    ref_scope = kernels.tree_evac_scope(np, tc, on, caps, victim)

    with enable_x64():
        tcj = kernels.TreeCols(jnp.asarray(tc.anc), jnp.asarray(tc.limit),
                               jnp.asarray(tc.depth))
        onj, capsj = jnp.asarray(on), jnp.asarray(caps)
        got_sums = np.asarray(kernels.tree_node_sums(jnp, tcj, onj, capsj))
        got_slack = np.asarray(kernels.tree_host_slack(
            jnp, tcj, kernels.tree_headroom(jnp, tcj, onj, capsj)))
        got_proj = np.asarray(kernels.tree_project_caps(
            jnp, tcj, onj, capsj, jnp.asarray(floors)))
        got_scope = np.asarray(kernels.tree_evac_scope(
            jnp, tcj, onj, capsj, jnp.asarray(victim)))

    np.testing.assert_allclose(got_sums, ref_sums, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got_slack, ref_slack, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got_proj, ref_proj, rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(got_scope, ref_scope)


# ------------------------------------------------------- constructor edges
def test_tree_constructor_validation():
    with pytest.raises(ValueError, match="at least a root"):
        BudgetTree([], [], [])
    with pytest.raises(ValueError, match="root"):
        BudgetTree([0, -1], [100.0, 100.0], [1])
    with pytest.raises(ValueError, match="precede"):
        BudgetTree([-1, 2, 1], [100.0] * 3, [0])
    with pytest.raises(ValueError, match="non-negative"):
        BudgetTree([-1], [-5.0], [0])
    with pytest.raises(ValueError, match="unknown node"):
        BudgetTree([-1, 0], [100.0, 50.0], [2])
    with pytest.raises(ValueError, match="length mismatch"):
        BudgetTree([-1, 0], [100.0], [0])


def test_with_limit_is_copy_on_write():
    tree = BudgetTree.two_rows(1000.0, 4, row0_limit=400.0)
    tight = tree.with_limit(1, 300.0)
    assert tree.limit[1] == 400.0 and tight.limit[1] == 300.0
    assert tight.parent is not tree.limit
    np.testing.assert_array_equal(tight.host_node, tree.host_node)
