"""Differential harness: the Pallas executor against the lax and NumPy ones.

The contract under test (`repro.kernels.powercap`): off-TPU the Pallas
kernels run in interpret mode, where they execute the same float64 op
sequence as the lax executor and must be **bit-identical** to it -- caps,
entitlements, and did-anything flags, across random (reservation, limit,
shares, demand, budget) tuples and every degenerate regime (zero-demand
hosts, all-reserved budgets, single-VM hosts, empty hosts, budget below
the reserved floor).  The NumPy executor differs from the JAX planes only
by reduction order, so it is compared at ~1 ulp-per-reduction tolerance
(1e-9 relative), not bitwise.

Fuzzing runs twice: a seed-parametrized sweep that always runs (no extra
dependencies), and hypothesis-driven fuzzing over the same problem builder
when hypothesis is installed (CI pins ``HYPOTHESIS_PROFILE=ci``:
derandomized, fixed example counts -- see ``conftest.py``).

Also locks the ``waterfill_dense`` padded-slot leak fix: poisoned padding
values in inactive slots must not absorb entitlement once the ``active``
mask is passed (regression for the pre-mask-only era, where stale demand
in recycled slots could widen the bisection bracket).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro import backend as backend_mod
from repro.backend import NUMPY
from repro.core import kernels
from repro.drs.entitlement import (batched_waterfill, waterfill_core,
                                   waterfill_dense, waterfill_dense_math)
from repro.kernels.powercap import ops, ref

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis-driven fuzzing needs hypothesis (requirements.txt)")

SCENARIOS = ("plain", "zero_demand", "all_reserved", "single_vm",
             "empty_host", "budget_below_floor")
SEEDS = tuple(range(5))


# ------------------------------------------------------ problem builders
def dense_problem(seed: int, scenario: str, s: int = 2, h: int = 5,
                  j: int = 6):
    """One (capacity, floors, ceils, weights, active) tuple in the dense
    slot layout, with the named degenerate regime injected."""
    rng = np.random.default_rng(seed)
    floors = rng.uniform(0.0, 300.0, (s, h, j))
    ceils = floors + rng.uniform(0.0, 500.0, (s, h, j))
    weights = rng.uniform(0.1, 10.0, (s, h, j))
    active = rng.random((s, h, j)) < 0.8
    if scenario == "zero_demand":
        # Entire hosts with zero demand (and zero reservations).
        floors[:, 0, :] = 0.0
        ceils[:, 0, :] = 0.0
    elif scenario == "all_reserved":
        # Budget fully reserved: every ceiling pinned at its floor.
        ceils = floors.copy()
    elif scenario == "single_vm":
        active[:] = False
        active[:, :, 0] = True
    elif scenario == "empty_host":
        active[:, 1, :] = False
    floors = np.where(active, floors, 0.0)
    ceils = np.where(active, ceils, 0.0)
    total_floor = floors.sum(axis=-1)
    if scenario == "budget_below_floor":
        capacity = total_floor * rng.uniform(0.1, 0.9, (s, h))
    else:
        capacity = rng.uniform(0.0, 1.2, (s, h)) * np.maximum(
            ceils.sum(axis=-1), 1.0)
    return capacity, floors, ceils, weights, active


def balance_problem(seed: int, scenario: str, s: int = 2, h: int = 5,
                    j: int = 6):
    """A BalancePowerCap cell batch around a dense entitlement problem."""
    rng = np.random.default_rng(seed ^ 0x5EED)
    _, floors, ceils, weights, active = dense_problem(seed, scenario, s, h,
                                                      j)
    on = rng.random((s, h)) < 0.85
    if scenario == "empty_host":
        on[:, 1] = True      # keep the empty host powered on
    idle = rng.uniform(80.0, 120.0, (s, h))
    peak = idle + rng.uniform(100.0, 200.0, (s, h))
    cap_peak = rng.uniform(2000.0, 4000.0, (s, h))
    hyp = rng.uniform(0.0, 50.0, (s, h))
    hosts = kernels.HostCols(on, idle, peak, cap_peak, hyp)
    caps0 = rng.uniform(idle, peak)
    managed0 = kernels.managed_capacity(np, hosts, caps0)
    cpu_res = managed0 * rng.uniform(0.0, 0.8, (s, h))
    budget = np.sum(np.where(on, caps0, 0.0), axis=-1)
    if scenario == "budget_below_floor":
        budget = budget * 0.5
    enabled = rng.random(s) < 0.9
    dense = kernels.DenseCols(floors, ceils, weights, active)
    return hosts, caps0, dense, cpu_res, budget, enabled


def segmented_problem(seed: int, scenario: str, n: int = 40,
                      n_segs: int = 7):
    rng = np.random.default_rng(seed ^ 0xCAFE)
    seg = rng.integers(0, n_segs, n)
    floors = rng.uniform(0.0, 100.0, n)
    ceils = floors + rng.uniform(0.0, 300.0, n)
    weights = rng.uniform(0.1, 5.0, n)
    if scenario == "zero_demand":
        floors[seg == 0] = 0.0
        ceils[seg == 0] = 0.0
    elif scenario == "all_reserved":
        ceils = floors.copy()
    elif scenario == "single_vm":
        keep = np.zeros(n, dtype=bool)
        keep[np.unique(seg, return_index=True)[1]] = True
        floors, ceils, weights, seg = (floors[keep], ceils[keep],
                                       weights[keep], seg[keep])
    elif scenario == "empty_host":
        seg = np.where(seg == 1, 2, seg)     # host 1 has no VMs
    total_floor = np.bincount(seg, weights=floors, minlength=n_segs)
    if scenario == "budget_below_floor":
        capacity = total_floor * rng.uniform(0.1, 0.9, n_segs)
    else:
        capacity = rng.uniform(0.0, 3000.0, n_segs)
    return capacity, floors, ceils, weights, seg, n_segs


# ------------------------------------------------------------ core checks
def check_dense_parity(seed: int, scenario: str):
    capacity, floors, ceils, weights, active = dense_problem(seed, scenario)
    with enable_x64():
        got = np.asarray(ops.pallas_waterfill_dense(
            capacity, floors, ceils, weights, active=active))
        want = np.asarray(ref.lax_waterfill_dense(
            capacity, floors, ceils, weights, active=active))
    np_res = waterfill_dense_math(np, NUMPY.fori, capacity, floors, ceils,
                                  weights, active=active)
    assert got.dtype == np.float64
    assert np.array_equal(got, want), (
        f"pallas != lax (bitwise), max diff {np.abs(got - want).max()}")
    np.testing.assert_allclose(np_res, want, rtol=1e-9, atol=1e-9)


def check_balance_parity(seed: int, scenario: str):
    hosts, caps0, dense, cpu_res, budget, enabled = balance_problem(
        seed, scenario)
    params = kernels.BalanceParams()
    with enable_x64():
        hosts_j = kernels.HostCols(*(jnp.asarray(c) for c in hosts))
        caps_p, did_p = ops.pallas_balance_caps(
            hosts_j, jnp.asarray(caps0), dense, jnp.asarray(cpu_res),
            jnp.asarray(budget), jnp.asarray(enabled), params)
        caps_l, did_l = ref.lax_balance_caps(
            hosts, caps0, dense, cpu_res, budget, enabled, params)
        caps_p, did_p = np.asarray(caps_p), np.asarray(did_p)
        caps_l, did_l = np.asarray(caps_l), np.asarray(did_l)
    assert np.array_equal(caps_p, caps_l), (
        f"pallas != lax caps (bitwise), max diff "
        f"{np.abs(caps_p - caps_l).max()}")
    assert np.array_equal(did_p, did_l)


def check_segmented_parity(seed: int, scenario: str):
    capacity, floors, ceils, weights, seg, n_segs = segmented_problem(
        seed, scenario)
    got = np.asarray(ops.pallas_waterfill_segmented(
        capacity, floors, ceils, weights, seg, n_segs))
    mirror = np.asarray(ref.lax_waterfill_segmented(
        capacity, floors, ceils, weights, seg, n_segs))
    core = waterfill_core(NUMPY, capacity, floors, ceils,
                          np.maximum(weights, 1e-12), seg, n_segs)
    assert np.array_equal(got, mirror), (
        f"pallas segmented != lax mirror (bitwise), max diff "
        f"{np.abs(got - mirror).max()}")
    np.testing.assert_allclose(got, core, rtol=1e-9, atol=1e-9)


# -------------------------------------------------- seed-parametrized fuzz
@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("seed", SEEDS)
def test_dense_waterfill_parity(seed, scenario):
    check_dense_parity(seed, scenario)


@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("seed", SEEDS[:3])
def test_balance_caps_parity(seed, scenario):
    check_balance_parity(seed, scenario)


@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("seed", SEEDS)
def test_segmented_waterfill_parity(seed, scenario):
    check_segmented_parity(seed, scenario)


# ------------------------------------------------- hypothesis-driven fuzz
if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1),
           scenario=st.sampled_from(SCENARIOS))
    def test_dense_waterfill_parity_hypothesis(seed, scenario):
        check_dense_parity(seed, scenario)

    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1),
           scenario=st.sampled_from(SCENARIOS))
    def test_balance_caps_parity_hypothesis(seed, scenario):
        check_balance_parity(seed, scenario)

    @needs_hypothesis
    @given(seed=st.integers(0, 2**32 - 1),
           scenario=st.sampled_from(SCENARIOS))
    def test_segmented_waterfill_parity_hypothesis(seed, scenario):
        check_segmented_parity(seed, scenario)


# ------------------------------------------------- executor registry/wiring
def test_executor_registry_validates():
    with pytest.raises(ValueError):
        backend_mod.set_executor("cuda")
    with backend_mod.executor_scope("jax-pallas"):
        assert backend_mod.executor_name() == "jax-pallas"
        assert backend_mod.pallas_enabled()
    assert backend_mod.executor_name() == "jax"
    assert not backend_mod.pallas_enabled()


def test_executor_env_validation(monkeypatch):
    monkeypatch.setenv("REPRO_EXECUTOR", "tpu-magic")
    with pytest.raises(ValueError):
        backend_mod.executor_name()
    monkeypatch.setenv("REPRO_EXECUTOR", "jax-pallas")
    assert backend_mod.pallas_enabled()


def test_numpy_entry_lifts_to_segmented_kernel():
    """``batched_waterfill`` (the VectorSimulator delivery primitive)
    reaches the segmented Pallas kernel under the jax-pallas executor and
    matches its NumPy result to reduction-order rounding."""
    capacity, floors, ceils, weights, seg, n_segs = segmented_problem(
        0, "plain")
    want = batched_waterfill(capacity, floors, ceils, weights, seg, n_segs)
    with backend_mod.executor_scope("jax-pallas"):
        got = batched_waterfill(capacity, floors, ceils, weights, seg,
                                n_segs)
    assert isinstance(got, np.ndarray)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_dense_dispatcher_routes_to_pallas():
    """``waterfill_dense`` on the JAX plane must give bitwise-equal results
    whether the executor dispatches to Pallas or stays on lax."""
    capacity, floors, ceils, weights, active = dense_problem(1, "plain")
    be = backend_mod.jax_backend()
    with enable_x64():
        args = (jnp.asarray(capacity), jnp.asarray(floors),
                jnp.asarray(ceils), jnp.asarray(weights))
        act = jnp.asarray(active)
        with backend_mod.executor_scope("jax"):
            want = np.asarray(waterfill_dense(jnp, be.fori, *args,
                                              active=act))
        with backend_mod.executor_scope("jax-pallas"):
            got = np.asarray(waterfill_dense(jnp, be.fori, *args,
                                             active=act))
    assert np.array_equal(got, want)


def test_object_plane_balance_under_pallas_executor():
    """``balance_power_cap`` (ManagerCore's phase 2) runs through the fused
    kernel under the jax-pallas executor, with the same protocol outcome as
    the NumPy executor (entitlements differ only by reduction order)."""
    from repro.core.balance import balance_power_cap
    from repro.core.power_model import PAPER_HOST
    from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine

    hosts = [Host(f"h{i}", PAPER_HOST, power_cap=250.0) for i in range(3)]
    vms = []
    for i in range(9):
        vms.append(VirtualMachine(
            vm_id=f"vm{i}", host_id=f"h{i % 3}",
            demand=[400.0, 2200.0, 900.0][i % 3],
            reservation=100.0, shares=1000))
    snap = ClusterSnapshot(hosts, vms, power_budget=750.0)
    want, did_want = balance_power_cap(snap)
    with backend_mod.executor_scope("jax-pallas"):
        got, did_got = balance_power_cap(snap)
    assert did_got == did_want
    want_caps = [h.power_cap for h in want.hosts.values()]
    got_caps = [h.power_cap for h in got.hosts.values()]
    np.testing.assert_allclose(got_caps, want_caps, rtol=1e-6, atol=1e-6)


# --------------------------------------------------- padded-slot leak fix
def test_padded_slot_leak_regression():
    """Poisoned padding: stale demand left in inactive slots must not
    absorb entitlement when the ``active`` mask is passed.  (Without the
    mask the poison visibly corrupts the allocation -- that is the leak
    this guards against.)"""
    capacity, floors, ceils, weights, active = dense_problem(3, "plain")
    poison_f = np.where(active, floors, 7e5)
    poison_c = np.where(active, ceils, 9e5)
    poison_w = np.where(active, weights, 50.0)
    clean = waterfill_dense_math(np, NUMPY.fori, capacity, floors, ceils,
                                 np.where(active, weights, 1e-12))

    # The leak exists without the mask: poisoned slots soak up capacity.
    leaked = waterfill_dense_math(np, NUMPY.fori, capacity, poison_f,
                                  poison_c, poison_w)
    assert not np.allclose(np.where(active, leaked, 0.0),
                           np.where(active, clean, 0.0))

    # With the mask, every executor neutralizes the poison bit-for-bit.
    masked_np = waterfill_dense_math(np, NUMPY.fori, capacity, poison_f,
                                     poison_c, poison_w, active=active)
    assert np.array_equal(masked_np, clean)
    with enable_x64():
        masked_lax = np.asarray(ref.lax_waterfill_dense(
            capacity, poison_f, poison_c, poison_w, active=active))
        masked_pl = np.asarray(ops.pallas_waterfill_dense(
            capacity, poison_f, poison_c, poison_w, active=active))
    np.testing.assert_allclose(masked_lax, clean, rtol=1e-9, atol=1e-9)
    assert np.array_equal(masked_pl, masked_lax)


def test_inactive_slots_allocate_nothing():
    capacity, floors, ceils, weights, active = dense_problem(4, "plain")
    poison_c = np.where(active, ceils, 9e5)
    out = waterfill_dense_math(np, NUMPY.fori, capacity, floors, poison_c,
                               weights, active=active)
    assert np.all(out[~active] == 0.0)
