"""Benchmark harness: one entry per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark), where
``derived`` packs the table's headline numbers.  Paper-number comparisons
live in EXPERIMENTS.md.

  table2_deployments   -- paper Table II   (rack deployment trade-offs)
  table3_rebalancing   -- paper Table III  (headroom rebalancing, Sec. V-B)
  table4_standby       -- paper Table IV   (standby reallocation, Sec. V-C)
  table5_flexible      -- paper Table V    (flexible capacity, Sec. V-D)
  powercap_latency     -- cap-change vs vMotion cost asymmetry (Sec. II-D)
  sweep_scale          -- vectorized-engine scenario sweep at 10/100/1000
                          hosts (ticks/sec + CPC-vs-Static satisfaction delta)
  roofline_summary     -- per-(arch x shape) roofline terms from the dry-run

Run: PYTHONPATH=src python -m benchmarks.run [--skip-slow]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


def table2_deployments():
    from repro.core.power_model import PAPER_HOST, deployment_table
    rows = deployment_table(PAPER_HOST, 8000.0, [400, 320, 285, 250])
    derived = ";".join(
        f"{int(r['power_cap_w'])}W:{r['host_count']}hosts"
        f"/cpu{r['capacity_ratio']:.2f}/mem{r['memory_ratio']:.2f}"
        for r in rows)
    return derived


def _sim_table(scenario):
    from repro.sim.experiments import run_all
    from repro.sim.metrics import ratio_table
    res = run_all(scenario)
    table = ratio_table({k: v.acc for k, v in res.items()}, "statichigh")
    return res, table


def table3_rebalancing():
    res, t = _sim_table("headroom")
    return ";".join(
        f"{p}:cpu{t[p]['cpu_payload_ratio']:.2f}/vmo{t[p]['vmotions']}"
        for p in ("cpc", "static", "statichigh"))


def table4_standby():
    res, t = _sim_table("standby")
    return ";".join(
        f"{p}:cpu{t[p]['cpu_payload_ratio']:.2f}/vmo{t[p]['vmotions']}"
        f"/pow{t[p]['power_ratio']:.2f}"
        for p in ("cpc", "static", "statichigh"))


def table5_flexible():
    res, t = _sim_table("flexible")
    return ";".join(
        f"{p}:cpu{t[p]['cpu_payload_ratio']:.2f}"
        f"/mem{t[p]['mem_payload_ratio']:.2f}"
        f"/trd{res[p].acc.tag_satisfaction('trading'):.2f}"
        for p in ("cpc", "static", "statichigh"))


def powercap_latency():
    """Sec. II-D asymmetry: cap write (<1 ms) vs vMotion (seconds).

    Reports our simulator's models of both actions for one 2 GB VM."""
    from repro.sim.cluster import SimConfig
    cfg = SimConfig()
    cap_ms = 1.0  # baseboard RPC, paper ref [4]
    vmotion_s = (2 * 1024) / cfg.vmotion_rate_mb_s
    return (f"cap:{cap_ms}ms;vmotion:{vmotion_s:.0f}s;"
            f"ratio:{vmotion_s * 1000 / cap_ms:.0f}x")


def sweep_scale():
    """Scenario sweep on the vectorized engine: 10/100/1000 hosts.

    Each cell is a host-correlated burst scenario (10 VMs per host) run
    under all three policies; reports the vector engine's throughput in
    ticks/sec, the CPC-vs-Static payload-satisfaction delta, and CPC's cap
    changes.  The 1,000-host cell simulates 10,000 VMs end-to-end."""
    from repro.sim.sweep import run_sweep, scale_ladder
    specs = scale_ladder(sizes=(10, 100, 1000), spike="burst",
                         duration_s=600.0)
    res = run_sweep(specs, policies=("cpc", "static"))
    parts = []
    for spec in specs:
        cpc = res[spec.name]["cpc"]
        static = res[spec.name]["static"]
        parts.append(
            f"{spec.n_hosts}h:{cpc.ticks_per_s:.0f}tps"
            f"/dsat{cpc.cpu_satisfaction - static.cpu_satisfaction:+.3f}"
            f"/caps{cpc.cap_changes}")
    return ";".join(parts)


def roofline_summary():
    pats = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun", "*.json")
    cells = []
    for p in sorted(glob.glob(pats)):
        with open(p) as f:
            d = json.load(f)
        if d.get("ok"):
            cells.append(d)
    if not cells:
        return "no-dryrun-results(run repro.launch.dryrun first)"
    by_dom = {}
    for c in cells:
        by_dom.setdefault(c["roofline"]["dominant"], []).append(c)
    return (f"{len(cells)}cells;" + ";".join(
        f"{k}:{len(v)}" for k, v in sorted(by_dom.items())))


def kernel_microbenches():
    from benchmarks.kernel_bench import BENCHES as KB
    parts = []
    for name, fn in KB:
        us, derived = fn()
        parts.append(f"{name.replace('kernel_', '')}:{us:.0f}us")
    return ";".join(parts) + ";(interpret-mode)"


BENCHES = [
    ("table2_deployments", table2_deployments, False),
    ("table3_rebalancing", table3_rebalancing, False),
    ("table4_standby", table4_standby, False),
    ("table5_flexible", table5_flexible, True),
    ("powercap_latency", powercap_latency, False),
    ("sweep_scale", sweep_scale, True),
    ("kernel_microbenches", kernel_microbenches, False),
    ("roofline_summary", roofline_summary, False),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-slow", action="store_true")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    for name, fn, slow in BENCHES:
        if slow and args.skip_slow:
            print(f"{name},skipped,--skip-slow")
            continue
        us, derived = _timed(fn)
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
