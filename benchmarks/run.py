"""Benchmark harness: one entry per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark), where
``derived`` packs the table's headline numbers.  Paper-number comparisons
live in EXPERIMENTS.md.

  table2_deployments   -- paper Table II   (rack deployment trade-offs)
  table3_rebalancing   -- paper Table III  (headroom rebalancing, Sec. V-B)
  table4_standby       -- paper Table IV   (standby reallocation, Sec. V-C)
  table5_flexible      -- paper Table V    (flexible capacity, Sec. V-D)
  powercap_latency     -- cap-change vs vMotion cost asymmetry (Sec. II-D)
  sweep_scale          -- vectorized-engine scenario sweep at 10/100/1000
                          hosts (ticks/sec + CPC-vs-Static satisfaction delta)
  sweep_grid           -- the jit-compiled batched engine running a 32-cell
                          scenario grid (100 hosts x budget x spike x mix) as
                          ONE program, vs the sequential run_sweep path
  sweep_grid_dpm       -- the batched engine with the host power-state
                          dimension live: a 32-cell capacity-churn grid (DPM
                          power-off/power-on, maintenance windows, host
                          failures) as ONE program, vs sequential
  sweep_grid_rules     -- the batched engine with the migration layer live:
                          a 32-cell rule-scenario grid (affinity /
                          anti-affinity / VM-host violation bursts,
                          Fig.-1a cap-blocked corrections, hill-climb
                          balancing) as ONE program, vs sequential
  sweep_e2e            -- end-to-end sweep throughput through the
                          overlapped pipeline: the sweep_grid 32-cell
                          grid measured from SweepSpec list to merged
                          results (scenario construction + vectorized
                          TraceBank packing + AOT dispatch + harvest),
                          with the compile/pack/run cost split and the
                          e2e-vs-steady ratio the smoke gate tracks
  sweep_scale_sharded  -- the sharded sweep engine: a 256-cell grid over a
                          1-device vs 8-virtual-device ("cells",) mesh
                          (subprocess with forced host device count), plus
                          a 10k-host / 100k-VM-slot datacenter cell, via
                          benchmarks/sweep_sharded.py
  budget_service       -- hierarchical-budget control plane: event-replay
                          latency percentiles (headroom/admission queries,
                          demand updates, node-limit changes over a two-row
                          budget tree) plus headroom and row_contention
                          sweep parity
  roofline_summary     -- per-(arch x shape) roofline terms from the dry-run

Run: PYTHONPATH=src python -m benchmarks.run [--skip-slow] [--json]

``--json`` additionally writes machine-readable sweep-throughput numbers to
``BENCH_sweep.json`` (ticks/s per grid size, cells/s batched vs sequential)
so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import time

#: Structured results populated by the sweep benches, dumped by ``--json``.
ARTIFACT: dict = {}


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


def table2_deployments():
    from repro.core.power_model import PAPER_HOST, deployment_table
    rows = deployment_table(PAPER_HOST, 8000.0, [400, 320, 285, 250])
    derived = ";".join(
        f"{int(r['power_cap_w'])}W:{r['host_count']}hosts"
        f"/cpu{r['capacity_ratio']:.2f}/mem{r['memory_ratio']:.2f}"
        for r in rows)
    return derived


def _sim_table(scenario):
    from repro.sim.experiments import run_all
    from repro.sim.metrics import ratio_table
    res = run_all(scenario)
    table = ratio_table({k: v.acc for k, v in res.items()}, "statichigh")
    return res, table


def table3_rebalancing():
    res, t = _sim_table("headroom")
    return ";".join(
        f"{p}:cpu{t[p]['cpu_payload_ratio']:.2f}/vmo{t[p]['vmotions']}"
        for p in ("cpc", "static", "statichigh"))


def table4_standby():
    res, t = _sim_table("standby")
    return ";".join(
        f"{p}:cpu{t[p]['cpu_payload_ratio']:.2f}/vmo{t[p]['vmotions']}"
        f"/pow{t[p]['power_ratio']:.2f}"
        for p in ("cpc", "static", "statichigh"))


def table5_flexible():
    res, t = _sim_table("flexible")
    return ";".join(
        f"{p}:cpu{t[p]['cpu_payload_ratio']:.2f}"
        f"/mem{t[p]['mem_payload_ratio']:.2f}"
        f"/trd{res[p].acc.tag_satisfaction('trading'):.2f}"
        for p in ("cpc", "static", "statichigh"))


def powercap_latency():
    """Sec. II-D asymmetry: cap write (<1 ms) vs vMotion (seconds).

    Reports our simulator's models of both actions for one 2 GB VM."""
    from repro.sim.cluster import SimConfig
    cfg = SimConfig()
    cap_ms = 1.0  # baseboard RPC, paper ref [4]
    vmotion_s = (2 * 1024) / cfg.vmotion_rate_mb_s
    return (f"cap:{cap_ms}ms;vmotion:{vmotion_s:.0f}s;"
            f"ratio:{vmotion_s * 1000 / cap_ms:.0f}x")


def sweep_scale():
    """Scenario sweep on the vectorized engine: 10/100/1000 hosts.

    Each cell is a host-correlated burst scenario (10 VMs per host) run
    under all three policies; reports the vector engine's throughput in
    ticks/sec, the CPC-vs-Static payload-satisfaction delta, and CPC's cap
    changes.  The 1,000-host cell simulates 10,000 VMs end-to-end."""
    from repro.sim.sweep import run_sweep, scale_ladder
    specs = scale_ladder(sizes=(10, 100, 1000), spike="burst",
                         duration_s=600.0)
    res = run_sweep(specs, policies=("cpc", "static"))
    parts = []
    ARTIFACT["sweep_scale"] = {}
    for spec in specs:
        cpc = res[spec.name]["cpc"]
        static = res[spec.name]["static"]
        ARTIFACT["sweep_scale"][str(spec.n_hosts)] = {
            "ticks_per_s": cpc.ticks_per_s,
            "dsat_cpc_vs_static":
                cpc.cpu_satisfaction - static.cpu_satisfaction,
            "cap_changes": cpc.cap_changes,
        }
        parts.append(
            f"{spec.n_hosts}h:{cpc.ticks_per_s:.0f}tps"
            f"/dsat{cpc.cpu_satisfaction - static.cpu_satisfaction:+.3f}"
            f"/caps{cpc.cap_changes}")
    return ";".join(parts)


def sweep_grid():
    """The batched engine's headline: a >=32-cell grid in one jitted program.

    Grid: 100 hosts x {230, 250} W/host x 4 spike families x {homogeneous,
    mixed} x {cpc, static} = 32 cells (32,000 VMs simulated end-to-end).
    The sequential baseline runs a 4-cell subset of the same grid through
    the per-cell ``run_sweep`` path.  Both sides report *engine* cells/s --
    simulation wall time on prepared clusters, matching ``run_cell``'s
    ``wall_s`` semantics which exclude scenario construction -- and the
    artifact also records end-to-end numbers (build + pack + run) plus the
    one-off jit compile."""
    from repro.sim.batch import BatchCell, BatchedSimulator
    from repro.sim.sweep import build_sweep, run_cell, scenario_families
    specs = scenario_families(sizes=(100,), budgets_per_host_w=(230.0, 250.0),
                              spikes=("flat", "burst", "step", "prime"),
                              heterogeneous=(False, True), duration_s=600.0)
    policies = ("cpc", "static")
    n_cells = len(specs) * len(policies)

    t0 = time.perf_counter()
    cells = []
    for spec in specs:
        for p in policies:
            snap, traces, cfg = build_sweep(spec, p)
            cells.append(BatchCell(
                name=f"{spec.name}/{p}", snapshot=snap, traces=traces,
                config=cfg, powercap_enabled=(p == "cpc")))
    sim = BatchedSimulator(cells)
    prep_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    sim.run()                                       # jit compile + first run
    first_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = sim.run()
    batch_wall = time.perf_counter() - t0
    batch_cps = n_cells / batch_wall
    # First call = compile + one execution; steady-state wall isolates the
    # execution, so the difference estimates the one-off compile cost.
    compile_wall = max(first_wall - batch_wall, 0.0)

    seq_wall, seq_cells = 0.0, 0
    t0 = time.perf_counter()
    for spec in specs[:2]:
        for p in policies:
            seq_wall += run_cell(spec, p, engine="vector").wall_s
            seq_cells += 1
    seq_e2e = time.perf_counter() - t0
    seq_cps = seq_cells / seq_wall

    i_of = {c.name: i for i, c in enumerate(cells)}
    sat = []
    for s in specs:
        cpc = res.accumulators(i_of[f"{s.name}/cpc"])
        static = res.accumulators(i_of[f"{s.name}/static"])
        sat.append(cpc.cpu_satisfaction() - static.cpu_satisfaction())
    ARTIFACT["sweep_grid"] = {
        "n_cells": n_cells,
        "n_hosts": 100,
        "cells_per_s_batched": batch_cps,
        "cells_per_s_sequential": seq_cps,
        "speedup": batch_cps / seq_cps,
        "cells_per_s_batched_e2e": n_cells / (prep_wall + batch_wall),
        "cells_per_s_sequential_e2e": seq_cells / seq_e2e,
        "compile_s": compile_wall,
        "mean_dsat_cpc_vs_static": sum(sat) / len(sat),
    }
    return (f"{n_cells}cells@100h:{batch_cps:.1f}cells/s"
            f";seq:{seq_cps:.1f}cells/s"
            f";speedup:{batch_cps / seq_cps:.1f}x"
            f";compile:{compile_wall:.1f}s")


def _pipeline_timing():
    """Summed per-bucket cost split of the most recent batched sweep call
    (see ``repro.sim.sweep.LAST_BATCH_INFO``)."""
    from repro.sim.sweep import LAST_BATCH_INFO
    return {
        "n_buckets": len(LAST_BATCH_INFO),
        "compile_s": sum(b["compile_s"] for b in LAST_BATCH_INFO),
        "pack_s": sum(b["pack_s"] for b in LAST_BATCH_INFO),
        "run_s": sum(b["run_s"] for b in LAST_BATCH_INFO),
    }


def sweep_e2e():
    """End-to-end sweep throughput: the overlapped pipeline, whole path.

    Same 32-cell grid as ``sweep_grid``, but the measured wall starts from
    the ``SweepSpec`` list: scenario construction (table-vectorized trace
    factories), ``TraceBank`` packing, AOT dispatch, and harvest all
    inside the clock -- the number a sweep user actually experiences.  A
    first call warms the AOT executables so the measured pass isolates the
    pipeline (compile cost is reported separately by ``sweep_grid``).
    Reports e2e cells/s, steady-state cells/s (device wall only), their
    ratio -- the machine-portable pipeline-efficiency metric the smoke
    gate tracks -- and the compile/pack/run split."""
    from repro.sim.sweep import run_sweep_batched, scenario_families
    specs = scenario_families(sizes=(100,), budgets_per_host_w=(230.0, 250.0),
                              spikes=("flat", "burst", "step", "prime"),
                              heterogeneous=(False, True), duration_s=600.0)
    policies = ("cpc", "static")
    n_cells = len(specs) * len(policies)

    run_sweep_batched(specs, policies=policies)     # warm AOT executables
    t0 = time.perf_counter()
    run_sweep_batched(specs, policies=policies)
    e2e_wall = time.perf_counter() - t0
    timing = _pipeline_timing()
    e2e_cps = n_cells / e2e_wall
    steady_cps = n_cells / timing["run_s"]
    ratio = e2e_cps / steady_cps
    ARTIFACT["sweep_e2e"] = {
        "n_cells": n_cells,
        "n_hosts": 100,
        "cells_per_s_e2e": e2e_cps,
        "cells_per_s_steady": steady_cps,
        "e2e_ratio": ratio,
        "e2e_wall_s": e2e_wall,
        "timing": timing,
    }
    return (f"{n_cells}cells@100h:e2e:{e2e_cps:.1f}cells/s"
            f";steady:{steady_cps:.1f}cells/s"
            f";ratio:{ratio:.2f}"
            f";pack:{timing['pack_s']:.2f}s"
            f";run:{timing['run_s']:.2f}s")


def sweep_grid_dpm():
    """Capacity churn at grid scale: the host-lifecycle dimension batched.

    Grid: 100 hosts x 4 churn families (cap-only, DPM valley/burst,
    maintenance window, host failure) x 2 spike families x {homogeneous,
    mixed} x {cpc, static} = 32 cells (32,000 VMs), every cell's DPM
    triggers, evacuations, scripted events, and powercap redistribution
    running inside ONE jitted program.  The sequential baseline runs the
    four pure-churn cells of the same grid through the per-cell vector
    path.  Cells/s semantics match ``sweep_grid`` (engine wall time on
    prepared clusters)."""
    from repro.sim.sweep import run_cell, run_sweep_batched, \
        scenario_families
    # 1500 s so the DPM valley [500, 1000) spans a full stability window
    # before a DRS tick lands in it (power-off at 900 s) and the burst
    # third trips the power-on trigger (1200 s).
    specs = scenario_families(
        sizes=(100,), budgets_per_host_w=(250.0,),
        spikes=("burst", "prime"), heterogeneous=(False, True),
        churns=("none", "dpm", "maintenance", "failure"),
        duration_s=1500.0, tick_s=15.0)
    policies = ("cpc", "static")
    n_cells = len(specs) * len(policies)

    t0 = time.perf_counter()
    res = run_sweep_batched(specs, policies=policies, slot_slack=1.5)
    first_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_sweep_batched(specs, policies=policies, slot_slack=1.5)
    batch_wall = time.perf_counter() - t0
    batch_cps = n_cells / sum(r.wall_s for by_p in res.values()
                              for r in by_p.values())
    compile_wall = max(first_wall - batch_wall, 0.0)

    churn_specs = [s for s in specs if s.churn == "dpm"][:2]
    seq_wall, seq_cells = 0.0, 0
    for spec in churn_specs:
        for p in policies:
            seq_wall += run_cell(spec, p, engine="vector").wall_s
            seq_cells += 1
    seq_cps = seq_cells / seq_wall

    pons = sum(r.power_ons for by_p in res.values() for r in by_p.values())
    poffs = sum(r.power_offs for by_p in res.values()
                for r in by_p.values())
    vmo = sum(r.vmotions for by_p in res.values() for r in by_p.values())
    ARTIFACT["sweep_grid_dpm"] = {
        "timing": _pipeline_timing(),
        "n_cells": n_cells,
        "n_hosts": 100,
        "cells_per_s_batched": batch_cps,
        "cells_per_s_sequential": seq_cps,
        "speedup": batch_cps / seq_cps,
        "compile_s": compile_wall,
        "power_ons": int(pons),
        "power_offs": int(poffs),
        "evacuations": int(vmo),
    }
    return (f"{n_cells}cells@100h:{batch_cps:.1f}cells/s"
            f";seq:{seq_cps:.1f}cells/s"
            f";speedup:{batch_cps / seq_cps:.1f}x"
            f";poffs:{poffs};pons:{pons};evac:{vmo}"
            f";compile:{compile_wall:.1f}s")


def sweep_grid_rules():
    """Rule-aware placement and balancing at grid scale: the migration
    dimension batched.

    Grid: 100 hosts x 2 rule families (violation burst: split affinity
    groups + co-placed anti-affinity pairs + misplaced VM-host rules;
    cap-blocked: a Fig.-1a affinity correction only fundable capacity can
    admit) x 4 spike families x {homogeneous, mixed} x {cpc, static} = 32
    cells (32,000 VMs), every cell's constraint corrections, hill-climb
    balancer moves, and powercap pipeline running inside ONE jitted
    program.  The sequential baseline runs a 4-cell subset through the
    per-cell vector path.  Cells/s semantics match ``sweep_grid`` (engine
    wall time on prepared clusters)."""
    from repro.sim.sweep import run_cell, run_sweep_batched, \
        scenario_families
    specs = scenario_families(
        sizes=(100,), budgets_per_host_w=(250.0,),
        spikes=("flat", "burst", "step", "prime"),
        heterogeneous=(False, True),
        rules=("violation_burst", "cap_blocked"),
        duration_s=600.0, tick_s=10.0)
    policies = ("cpc", "static")
    n_cells = len(specs) * len(policies)

    t0 = time.perf_counter()
    res = run_sweep_batched(specs, policies=policies, slot_slack=1.5)
    first_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_sweep_batched(specs, policies=policies, slot_slack=1.5)
    batch_wall = time.perf_counter() - t0
    batch_cps = n_cells / sum(r.wall_s for by_p in res.values()
                              for r in by_p.values())
    compile_wall = max(first_wall - batch_wall, 0.0)

    seq_wall, seq_cells = 0.0, 0
    for spec in specs[:2]:
        for p in policies:
            seq_wall += run_cell(spec, p, engine="vector").wall_s
            seq_cells += 1
    seq_cps = seq_cells / seq_wall

    vmo = sum(r.vmotions for by_p in res.values() for r in by_p.values())
    caps = sum(r.cap_changes for by_p in res.values()
               for r in by_p.values())
    ARTIFACT["sweep_grid_rules"] = {
        "timing": _pipeline_timing(),
        "n_cells": n_cells,
        "n_hosts": 100,
        "cells_per_s_batched": batch_cps,
        "cells_per_s_sequential": seq_cps,
        "speedup": batch_cps / seq_cps,
        "compile_s": compile_wall,
        "migrations": int(vmo),
        "cap_changes": int(caps),
    }
    return (f"{n_cells}cells@100h:{batch_cps:.1f}cells/s"
            f";seq:{seq_cps:.1f}cells/s"
            f";speedup:{batch_cps / seq_cps:.1f}x"
            f";migr:{vmo};caps:{caps}"
            f";compile:{compile_wall:.1f}s")


def sweep_grid_timed():
    """Production-realistic churn at grid scale: timed migrations batched.

    Grid: 100 hosts x {timed_churn, failure_cascade} x {no rules,
    violation burst} x 2 spike families x {homogeneous, mixed} x {cpc,
    static} = 32 cells (32,000 VMs).  Every cell runs the gated vMotion
    execution model -- multi-tick copy windows carried in the scan-state
    in-flight table, both endpoints charged transfer overhead, per-host
    migration slots plus the cluster bandwidth budget gating launches,
    deferred moves re-scored next invocation -- inside ONE jitted
    program; before this model these cells fell off the batched engine
    onto the per-cell vector path.  The sequential baseline runs a
    4-cell subset through that vector path.  Cells/s semantics match
    ``sweep_grid`` (engine wall time on prepared clusters)."""
    from repro.sim.sweep import run_cell, run_sweep_batched, \
        scenario_families
    specs = scenario_families(
        sizes=(100,), budgets_per_host_w=(250.0,),
        spikes=("burst", "prime"), heterogeneous=(False, True),
        churns=("timed_churn", "failure_cascade"),
        rules=("none", "violation_burst"),
        duration_s=600.0, tick_s=10.0)
    policies = ("cpc", "static")
    n_cells = len(specs) * len(policies)

    t0 = time.perf_counter()
    res = run_sweep_batched(specs, policies=policies, slot_slack=1.5)
    first_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_sweep_batched(specs, policies=policies, slot_slack=1.5)
    batch_wall = time.perf_counter() - t0
    batch_cps = n_cells / sum(r.wall_s for by_p in res.values()
                              for r in by_p.values())
    compile_wall = max(first_wall - batch_wall, 0.0)

    seq_wall, seq_cells = 0.0, 0
    for spec in specs[:2]:
        for p in policies:
            seq_wall += run_cell(spec, p, engine="vector").wall_s
            seq_cells += 1
    seq_cps = seq_cells / seq_wall

    vmo = sum(r.vmotions for by_p in res.values() for r in by_p.values())
    pons = sum(r.power_ons for by_p in res.values() for r in by_p.values())
    poffs = sum(r.power_offs for by_p in res.values()
                for r in by_p.values())
    ARTIFACT["sweep_grid_timed"] = {
        "timing": _pipeline_timing(),
        "n_cells": n_cells,
        "n_hosts": 100,
        "cells_per_s_batched": batch_cps,
        "cells_per_s_sequential": seq_cps,
        "speedup": batch_cps / seq_cps,
        "compile_s": compile_wall,
        "migrations": int(vmo),
        "power_ons": int(pons),
        "power_offs": int(poffs),
    }
    return (f"{n_cells}cells@100h:{batch_cps:.1f}cells/s"
            f";seq:{seq_cps:.1f}cells/s"
            f";speedup:{batch_cps / seq_cps:.1f}x"
            f";migr:{vmo};pons:{pons};poffs:{poffs}"
            f";compile:{compile_wall:.1f}s")


def _sharded_probe(n_devices: int, *argv: str) -> dict:
    """Run ``benchmarks.sweep_sharded`` in a subprocess with ``n_devices``
    forced host devices (the cells mesh needs them to exist before jax
    initializes) and parse its JSON stdout."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{n_devices}")
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep_sharded", *argv],
        capture_output=True, text=True, env=env,
        cwd=os.path.normpath(os.path.join(os.path.dirname(__file__), "..")))
    if proc.returncode != 0:
        raise RuntimeError(f"sweep_sharded probe failed:\n{proc.stderr}")
    return json.loads(proc.stdout)


def sweep_scale_sharded():
    """The sharded sweep engine: device scaling + the datacenter cell.

    Grid half: a 256-cell grid (128 specs x {cpc, static} at 10 hosts, one
    pad bucket) through ``run_sweep(engine="batch")`` on a 1-device mesh
    and again sharded over 8 virtual CPU devices, in one subprocess --
    reporting steady-state cells/s both ways, the speedup, per-bucket
    ``compile_s``, and the bit-identity of per-cell results across meshes
    (parity is the hard invariant; the speedup is hardware-honest and
    reflects however many physical cores back the virtual devices).
    Scale half: one 10,000-host / 100,000-VM-slot cell under cpc+static,
    completing end-to-end through the same path."""
    grid = _sharded_probe(8, "--mode", "grid", "--cells", "256",
                          "--hosts", "10", "--duration", "600",
                          "--tick", "10")
    scale = _sharded_probe(8, "--mode", "scale", "--hosts", "10000",
                           "--duration", "600", "--tick", "30")
    ARTIFACT["sweep_scale_sharded"] = {
        "n_cells": grid["n_cells"],
        "n_hosts": grid["n_hosts"],
        "n_devices": grid["sharded"]["n_devices"],
        "cells_per_s_single": grid["single"]["cells_per_s"],
        "cells_per_s_sharded": grid["sharded"]["cells_per_s"],
        "speedup_vs_single_device": grid["speedup"],
        "parity_bit_identical": grid["parity"],
        "compile_s_single": grid["single"]["compile_s"],
        "compile_s_sharded": grid["sharded"]["compile_s"],
        "datacenter_cell": {
            "n_hosts": scale["n_hosts"],
            "n_vm_slots": scale["n_vm_slots"],
            "ticks": scale["ticks"],
            "steady_s": scale["steady_s"],
            "compile_s": scale["compile_s"],
        },
    }
    return (f"{grid['n_cells']}cells@{grid['n_hosts']}h:"
            f"1dev:{grid['single']['cells_per_s']:.1f}cells/s"
            f";8dev:{grid['sharded']['cells_per_s']:.1f}cells/s"
            f";speedup:{grid['speedup']:.2f}x"
            f";parity:{'exact' if grid['parity'] else 'FAIL'}"
            f";10k-host:{scale['steady_s']:.1f}s"
            f"/{scale['ticks']}ticks"
            f";compile:{grid['sharded']['compile_s']:.1f}s")


def roofline_summary():
    pats = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun", "*.json")
    cells = []
    for p in sorted(glob.glob(pats)):
        with open(p) as f:
            d = json.load(f)
        if d.get("ok"):
            cells.append(d)
    if not cells:
        return "no-dryrun-results(run repro.launch.dryrun first)"
    by_dom = {}
    for c in cells:
        by_dom.setdefault(c["roofline"]["dominant"], []).append(c)
    return (f"{len(cells)}cells;" + ";".join(
        f"{k}:{len(v)}" for k, v in sorted(by_dom.items())))


def budget_service():
    """Hierarchical-budget control plane: replay latency + parity.

    Replays a mixed synthetic event feed (headroom/admission queries,
    demand updates, power churn, node-limit changes) through
    ``repro.runtime.budget_service.BudgetService`` over a two-row budget
    tree, and runs the ``row_contention`` tree sweep slice batch vs
    vector.  Reports p50/p99 per-event latency and both parity checks;
    ``benchmarks.check_regression`` gates the same measurement in CI."""
    from benchmarks.check_regression import measure_budget_service
    m = measure_budget_service()
    ARTIFACT["budget_service"] = m
    return (f"{m['n_events']}events@{m['n_hosts']}h:"
            f"p50:{m['p50_us']:.0f}us;p99:{m['p99_us']:.0f}us;"
            f"decisions:{m['n_decisions']};"
            f"headroom_parity:{m['headroom_parity_max_w']:.1e};"
            f"row_contention:"
            f"{'exact' if m['row_contention_parity'] else 'FAIL'}")


def kernel_microbenches():
    from benchmarks.kernel_bench import BENCHES as KB
    parts = []
    for name, fn in KB:
        us, derived = fn()
        parts.append(f"{name.replace('kernel_', '')}:{us:.0f}us")
    return ";".join(parts) + ";(interpret-mode)"


BENCHES = [
    ("table2_deployments", table2_deployments, False),
    ("table3_rebalancing", table3_rebalancing, False),
    ("table4_standby", table4_standby, False),
    ("table5_flexible", table5_flexible, True),
    ("powercap_latency", powercap_latency, False),
    ("sweep_scale", sweep_scale, True),
    ("sweep_grid", sweep_grid, True),
    ("sweep_grid_dpm", sweep_grid_dpm, True),
    ("sweep_grid_rules", sweep_grid_rules, True),
    ("sweep_grid_timed", sweep_grid_timed, True),
    ("sweep_e2e", sweep_e2e, True),
    ("sweep_scale_sharded", sweep_scale_sharded, True),
    ("budget_service", budget_service, True),
    ("kernel_microbenches", kernel_microbenches, False),
    ("roofline_summary", roofline_summary, False),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-slow", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write sweep throughput to BENCH_sweep.json")
    ap.add_argument("--only", action="append", default=None, metavar="NAME",
                    help="run only the named bench (repeatable)")
    args, _ = ap.parse_known_args()
    if args.only:
        unknown = set(args.only) - {name for name, _, _ in BENCHES}
        if unknown:
            ap.error(f"unknown bench(es): {sorted(unknown)}")
    # Persistent XLA compile cache: re-running the harness on unchanged
    # grid shapes pays trace + load instead of full recompiles (the rules
    # grid alone costs ~14 s of XLA time per cold process).
    from repro.sim.sweep import enable_compilation_cache
    cache = enable_compilation_cache()
    if cache:
        print(f"# jax compilation cache: {cache}", flush=True)
    print("name,us_per_call,derived")
    for name, fn, slow in BENCHES:
        if args.only is not None and name not in args.only:
            continue
        if slow and args.skip_slow:
            print(f"{name},skipped,--skip-slow")
            continue
        us, derived = _timed(fn)
        print(f"{name},{us:.0f},{derived}", flush=True)
    if args.json:
        if not ARTIFACT:
            # The sweep benches populate ARTIFACT and are both slow: with
            # --skip-slow there is nothing to record, and clobbering the
            # committed perf trajectory with '{}' would erase it.
            print("BENCH_sweep.json not written: sweep benches were skipped",
                  flush=True)
            return
        path = os.path.normpath(os.path.join(os.path.dirname(__file__),
                                             "..", "BENCH_sweep.json"))
        # Merge over the committed file: the smoke baselines (and any
        # full-size entry a --skip-slow run didn't re-measure) survive, so
        # a nightly `git diff` shows real drift, not dropped sections.
        data = {}
        if os.path.exists(path):
            with open(path) as f:
                data = json.load(f)
        data.update(ARTIFACT)
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        print(f"wrote {path}", flush=True)


if __name__ == "__main__":
    main()
