"""Roofline report generator: results/dryrun/*.json -> markdown table.

Per (arch x shape x mesh): the three roofline terms (seconds/step/chip),
the dominant term, MODEL_FLOPS/HLO_FLOPS (useful-compute ratio), and a
one-line mitigation hint for whatever dominates.

Run: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HINTS = {
    "compute": ("raise arithmetic efficiency: cut remat recompute "
                "(remat=dots), fuse attention (Pallas kernel path)"),
    "memory": ("cut HBM traffic: keep flash-attention working set in VMEM "
               "(Pallas path), bf16 score accumulation, fewer reshards"),
    "collective": ("cut bytes on the wire: less TP (wider FSDP/DP), "
                   "int8 cross-pod grad compression, overlap via "
                   "microbatch pipelining"),
}


def load_cells(mesh=None):
    base = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun", "*.json")
    cells = []
    for p in sorted(glob.glob(base)):
        with open(p) as f:
            d = json.load(f)
        if d.get("ok") and (mesh is None or d["mesh"] == mesh):
            cells.append(d)
    return cells


def fmt_row(c):
    r = c["roofline"]
    ratio = c.get("useful_flops_ratio")
    return (f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {ratio:.2f} |" if ratio else
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** | n/a |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    cells = load_cells(args.mesh)
    print(f"# Roofline ({args.mesh}, {len(cells)} cells)\n")
    print("| arch | shape | mesh | t_compute | t_memory | t_collective "
          "| dominant | useful |")
    print("|---|---|---|---|---|---|---|---|")
    for c in cells:
        print(fmt_row(c))
    print("\n## Mitigation hints")
    doms = {c["roofline"]["dominant"] for c in cells}
    for d in sorted(doms):
        print(f"- **{d}**: {HINTS[d]}")


if __name__ == "__main__":
    main()
