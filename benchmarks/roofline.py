"""Roofline report generator: results/dryrun/*.json -> markdown table.

Per (arch x shape x mesh): the three roofline terms (seconds/step/chip),
the dominant term, MODEL_FLOPS/HLO_FLOPS (useful-compute ratio), and a
one-line mitigation hint for whatever dominates.

``--kernels`` switches to the *analytic* roofline for the powercap
allocation kernels (``repro.kernels.powercap``): FLOPs and HBM bytes per
call from the block shapes, arithmetic intensity, and which side of the
machine balance each kernel lands on.  No dryrun results needed -- the
numbers follow from the BlockSpecs (each grid cell streams its columns
from HBM once and runs the whole bisection out of VMEM).

``--sweep`` prints the analytic per-device cells/s model for the sharded
sweep engine: bytes moved and flops per tick per cell from the packed
(H, J) slot plane, the per-device throughput bound, and the projected
scaling curve over mesh sizes (linear: the cells axis needs no
collectives) -- the sanity check for ``sweep_scale_sharded``'s measured
numbers.

Run: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16]
     PYTHONPATH=src python -m benchmarks.roofline --kernels [--s 64 ...]
     PYTHONPATH=src python -m benchmarks.roofline --sweep [--hosts 100 ...]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HINTS = {
    "compute": ("raise arithmetic efficiency: cut remat recompute "
                "(remat=dots), fuse attention (Pallas kernel path)"),
    "memory": ("cut HBM traffic: keep flash-attention working set in VMEM "
               "(Pallas path), bf16 score accumulation, fewer reshards"),
    "collective": ("cut bytes on the wire: less TP (wider FSDP/DP), "
                   "int8 cross-pod grad compression, overlap via "
                   "microbatch pipelining"),
}


def load_cells(mesh=None):
    base = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun", "*.json")
    cells = []
    for p in sorted(glob.glob(base)):
        with open(p) as f:
            d = json.load(f)
        if d.get("ok") and (mesh is None or d["mesh"] == mesh):
            cells.append(d)
    return cells


def fmt_row(c):
    r = c["roofline"]
    ratio = c.get("useful_flops_ratio")
    return (f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {ratio:.2f} |" if ratio else
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** | n/a |")


def powercap_kernel_rows(s, h, j, iters=200, rounds=8):
    """Analytic (flops, hbm_bytes) per call for the powercap kernels.

    Every kernel streams its float64 columns from HBM exactly once (one
    grid trip over cells, BlockSpec-blocked) and iterates in VMEM, so
    bytes are shape-determined and flops scale with the bisection depth:
    ~6 flops per slot per trip (scale, two clips, add, compare, select)
    plus the pro-rata residual pass, and ~60 flops per host per balance
    round for the transfer math.
    """
    slot_flops = iters * 6 + 10
    # dense: capacity (s,h) + 4 slot columns in, 1 out; active is 1 byte.
    dense_bytes = (s * h + 5 * s * h * j) * 8 + s * h * j
    dense_flops = slot_flops * s * h * j
    # fused balance: dense columns stay resident across rounds; per round
    # the state (caps/managed/ents/ns, (s,h) each) makes a round trip.
    bal_flops = rounds * (slot_flops * s * h * j + 60 * s * h)
    bal_bytes = dense_bytes + 14 * s * h * 8 + rounds * 8 * s * h * 8
    # segmented: CSR columns (4 x n) + per-host capacity/starts/counts,
    # padded rows of width jb ~ j.
    n = s * h * j
    seg_bytes = (4 * n + 3 * s * h) * 8 + s * h * j * 8
    seg_flops = slot_flops * s * h * j
    return [
        ("waterfill_dense", dense_flops, dense_bytes),
        ("balance_fused", bal_flops, bal_bytes),
        ("waterfill_segmented", seg_flops, seg_bytes),
    ]


def print_kernel_roofline(args):
    rows = powercap_kernel_rows(args.s, args.hosts, args.slots)
    balance = args.peak_gflops * 1e9 / (args.hbm_gbs * 1e9)
    print(f"# Powercap kernel roofline (S={args.s} H={args.hosts} "
          f"J={args.slots}, machine balance {balance:.0f} flop/B)\n")
    print("| kernel | flops/call | HBM B/call | intensity | bound |")
    print("|---|---|---|---|---|")
    for name, flops, byts in rows:
        inten = flops / byts
        bound = "compute" if inten >= balance else "memory"
        print(f"| {name} | {flops:.2e} | {byts:.2e} | {inten:.0f} "
              f"| **{bound}** |")
    print("\nThe bisection re-reads nothing from HBM (the whole column "
          "block lives in VMEM for all "
          "200 trips), so intensity grows linearly with iteration depth -- "
          "the kernels sit on the compute side everywhere except "
          "degenerate tiny-J shapes.")


def sweep_cell_cost(h, j, ticks, iters=100):
    """Analytic (flops, bytes) one sweep cell moves over a full run.

    Per tick the batched step streams the cell's float64 slot plane --
    demand sampling, the waterfill allocation (``iters`` bisection trips
    over floors/ceils/weights/active resident in cache), cap writes, and
    the (H,)-shaped power/energy/accumulator updates.  ~7 (H, J) arrays
    plus the 1-byte active mask and ~6 (H,) columns make the tick's
    working set; flops are dominated by the bisection at ~6 per slot per
    trip.  Cells never touch each other, so device cost is
    cells-per-device * this, and mesh throughput scales linearly.
    """
    slots = h * j
    bytes_tick = 7 * slots * 8 + slots + 6 * h * 8
    flops_tick = (iters * 6 + 20) * slots + 200 * h
    return flops_tick * ticks, bytes_tick * ticks


def print_sweep_roofline(args):
    flops, byts = sweep_cell_cost(args.hosts, args.slots, args.ticks)
    t_c = flops / (args.peak_gflops * 1e9)
    t_m = byts / (args.hbm_gbs * 1e9)
    per_dev = 1.0 / max(t_c, t_m)
    bound = "compute" if t_c >= t_m else "memory"
    print(f"# Sharded sweep roofline (H={args.hosts} J={args.slots} "
          f"T={args.ticks}, {args.peak_gflops:.0f} GFLOP/s, "
          f"{args.hbm_gbs:.0f} GB/s per device)\n")
    print(f"per cell: {flops:.2e} flops, {byts:.2e} HBM bytes "
          f"({flops / byts:.0f} flop/B, **{bound}**-bound)\n")
    print("| devices | cells/s (model) |")
    print("|---|---|")
    for n in (1, 2, 4, 8, 16):
        print(f"| {n} | {per_dev * n:.1f} |")
    print("\nNo collectives cross the cells axis, so the model is linear "
          "in mesh size; a measured curve (sweep_scale_sharded) bending "
          "below it means the devices share memory bandwidth or cores -- "
          "e.g. virtual CPU devices on one socket -- not that the program "
          "resharded.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--kernels", action="store_true",
                    help="analytic roofline for the powercap kernels")
    ap.add_argument("--s", type=int, default=64,
                    help="--kernels: batched cells")
    ap.add_argument("--hosts", type=int, default=100,
                    help="--kernels: hosts per cell")
    ap.add_argument("--slots", type=int, default=16,
                    help="--kernels: VM slots per host")
    ap.add_argument("--peak-gflops", type=float, default=1.0e4,
                    help="--kernels: peak f64-ish GFLOP/s of the target")
    ap.add_argument("--hbm-gbs", type=float, default=800.0,
                    help="--kernels: HBM GB/s of the target")
    ap.add_argument("--sweep", action="store_true",
                    help="analytic per-device cells/s model for the "
                         "sharded sweep engine")
    ap.add_argument("--ticks", type=int, default=60,
                    help="--sweep: scan length (duration_s / tick_s)")
    args = ap.parse_args()
    if args.kernels:
        print_kernel_roofline(args)
        return
    if args.sweep:
        print_sweep_roofline(args)
        return
    cells = load_cells(args.mesh)
    print(f"# Roofline ({args.mesh}, {len(cells)} cells)\n")
    print("| arch | shape | mesh | t_compute | t_memory | t_collective "
          "| dominant | useful |")
    print("|---|---|---|---|---|---|---|---|")
    for c in cells:
        print(fmt_row(c))
    print("\n## Mitigation hints")
    doms = {c["roofline"]["dominant"] for c in cells}
    for d in sorted(doms):
        print(f"- **{d}**: {HINTS[d]}")


if __name__ == "__main__":
    main()
