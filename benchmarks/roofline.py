"""Roofline report generator: results/dryrun/*.json -> markdown table.

Per (arch x shape x mesh): the three roofline terms (seconds/step/chip),
the dominant term, MODEL_FLOPS/HLO_FLOPS (useful-compute ratio), and a
one-line mitigation hint for whatever dominates.

``--kernels`` switches to the *analytic* roofline for the powercap
allocation kernels (``repro.kernels.powercap``): FLOPs and HBM bytes per
call from the block shapes, arithmetic intensity, and which side of the
machine balance each kernel lands on.  No dryrun results needed -- the
numbers follow from the BlockSpecs (each grid cell streams its columns
from HBM once and runs the whole bisection out of VMEM).

Run: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16]
     PYTHONPATH=src python -m benchmarks.roofline --kernels [--s 64 ...]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HINTS = {
    "compute": ("raise arithmetic efficiency: cut remat recompute "
                "(remat=dots), fuse attention (Pallas kernel path)"),
    "memory": ("cut HBM traffic: keep flash-attention working set in VMEM "
               "(Pallas path), bf16 score accumulation, fewer reshards"),
    "collective": ("cut bytes on the wire: less TP (wider FSDP/DP), "
                   "int8 cross-pod grad compression, overlap via "
                   "microbatch pipelining"),
}


def load_cells(mesh=None):
    base = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun", "*.json")
    cells = []
    for p in sorted(glob.glob(base)):
        with open(p) as f:
            d = json.load(f)
        if d.get("ok") and (mesh is None or d["mesh"] == mesh):
            cells.append(d)
    return cells


def fmt_row(c):
    r = c["roofline"]
    ratio = c.get("useful_flops_ratio")
    return (f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {ratio:.2f} |" if ratio else
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** | n/a |")


def powercap_kernel_rows(s, h, j, iters=200, rounds=8):
    """Analytic (flops, hbm_bytes) per call for the powercap kernels.

    Every kernel streams its float64 columns from HBM exactly once (one
    grid trip over cells, BlockSpec-blocked) and iterates in VMEM, so
    bytes are shape-determined and flops scale with the bisection depth:
    ~6 flops per slot per trip (scale, two clips, add, compare, select)
    plus the pro-rata residual pass, and ~60 flops per host per balance
    round for the transfer math.
    """
    slot_flops = iters * 6 + 10
    # dense: capacity (s,h) + 4 slot columns in, 1 out; active is 1 byte.
    dense_bytes = (s * h + 5 * s * h * j) * 8 + s * h * j
    dense_flops = slot_flops * s * h * j
    # fused balance: dense columns stay resident across rounds; per round
    # the state (caps/managed/ents/ns, (s,h) each) makes a round trip.
    bal_flops = rounds * (slot_flops * s * h * j + 60 * s * h)
    bal_bytes = dense_bytes + 14 * s * h * 8 + rounds * 8 * s * h * 8
    # segmented: CSR columns (4 x n) + per-host capacity/starts/counts,
    # padded rows of width jb ~ j.
    n = s * h * j
    seg_bytes = (4 * n + 3 * s * h) * 8 + s * h * j * 8
    seg_flops = slot_flops * s * h * j
    return [
        ("waterfill_dense", dense_flops, dense_bytes),
        ("balance_fused", bal_flops, bal_bytes),
        ("waterfill_segmented", seg_flops, seg_bytes),
    ]


def print_kernel_roofline(args):
    rows = powercap_kernel_rows(args.s, args.hosts, args.slots)
    balance = args.peak_gflops * 1e9 / (args.hbm_gbs * 1e9)
    print(f"# Powercap kernel roofline (S={args.s} H={args.hosts} "
          f"J={args.slots}, machine balance {balance:.0f} flop/B)\n")
    print("| kernel | flops/call | HBM B/call | intensity | bound |")
    print("|---|---|---|---|---|")
    for name, flops, byts in rows:
        inten = flops / byts
        bound = "compute" if inten >= balance else "memory"
        print(f"| {name} | {flops:.2e} | {byts:.2e} | {inten:.0f} "
              f"| **{bound}** |")
    print("\nThe bisection re-reads nothing from HBM (the whole column "
          "block lives in VMEM for all "
          "200 trips), so intensity grows linearly with iteration depth -- "
          "the kernels sit on the compute side everywhere except "
          "degenerate tiny-J shapes.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--kernels", action="store_true",
                    help="analytic roofline for the powercap kernels")
    ap.add_argument("--s", type=int, default=64,
                    help="--kernels: batched cells")
    ap.add_argument("--hosts", type=int, default=100,
                    help="--kernels: hosts per cell")
    ap.add_argument("--slots", type=int, default=16,
                    help="--kernels: VM slots per host")
    ap.add_argument("--peak-gflops", type=float, default=1.0e4,
                    help="--kernels: peak f64-ish GFLOP/s of the target")
    ap.add_argument("--hbm-gbs", type=float, default=800.0,
                    help="--kernels: HBM GB/s of the target")
    args = ap.parse_args()
    if args.kernels:
        print_kernel_roofline(args)
        return
    cells = load_cells(args.mesh)
    print(f"# Roofline ({args.mesh}, {len(cells)} cells)\n")
    print("| arch | shape | mesh | t_compute | t_memory | t_collective "
          "| dominant | useful |")
    print("|---|---|---|---|---|---|---|---|")
    for c in cells:
        print(fmt_row(c))
    print("\n## Mitigation hints")
    doms = {c["roofline"]["dominant"] for c in cells}
    for d in sorted(doms):
        print(f"- **{d}**: {HINTS[d]}")


if __name__ == "__main__":
    main()
