"""Kernel micro-benchmarks: interpret-mode correctness-grade timings plus
the *analytic* TPU-side work per call (FLOPs, VMEM working set).

Wall-clock here is CPU interpret mode (correctness harness, not perf);
the derived column carries what matters for the TPU target: FLOPs/call and
the VMEM footprint per grid cell implied by the BlockSpecs.

Run: PYTHONPATH=src python -m benchmarks.kernel_bench
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def bench_flash_attention():
    from repro.kernels.flash_attention import flash_attention
    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    us = _time(lambda q, k, v: flash_attention(q, k, v, block_q=128,
                                               block_k=128), q, k, v)
    flops = 2 * 2 * b * hq * s * s * d * 0.5          # qk + av, causal
    vmem_kb = (128 * d * 2 * 3 + 128 * 128 * 4 + 128 * d * 4) / 1024
    return us, f"flops={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


def bench_ssd_scan():
    from repro.kernels.ssd_scan import ssd_scan
    b, l, h, p, n, q = 1, 256, 4, 32, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, l, h, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, h, n)) * 0.3
    us = _time(lambda *a: ssd_scan(*a, chunk=q), x, dt, a_log, bm, cm)
    nc = l // q
    flops = nc * (2 * b * h * q * q * n + 2 * b * h * q * q * p
                  + 2 * b * h * q * p * n)
    vmem_kb = (q * 4 * (p + 2 * n) * 4 + 4 * q * q * 4 * 2) / 1024
    return us, f"flops={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


def bench_moe_gmm():
    from repro.kernels.moe_gmm import grouped_matmul
    e, c, d, f = 8, 128, 256, 128
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (e, c, d), jnp.float32)
    w = jax.random.normal(k2, (e, d, f), jnp.float32)
    us = _time(lambda x, w: grouped_matmul(x, w, block_c=128, block_d=128,
                                           block_f=128), x, w)
    flops = 2 * e * c * d * f
    vmem_kb = (128 * 128 * 2 * 2 + 128 * 128 * 4) / 1024
    return us, f"flops={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


def bench_kernel_waterfill():
    from repro.kernels.powercap.ops import pallas_waterfill_dense
    s, h, j, iters = 8, 32, 16, 200
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    floors = jax.random.uniform(ks[0], (s, h, j), maxval=300.0)
    ceils = floors + jax.random.uniform(ks[1], (s, h, j), maxval=500.0)
    weights = jax.random.uniform(ks[2], (s, h, j), minval=0.1, maxval=10.0)
    capacity = jax.random.uniform(ks[3], (s, h), maxval=5000.0)
    us = _time(lambda c, f, ce, w: pallas_waterfill_dense(c, f, ce, w),
               capacity, floors, ceils, weights)
    # Bisection: ~6 flops per slot per trip (scale, 2x clip, add, compare,
    # select), plus the residual pro-rata pass.
    flops = (iters * 6 + 10) * s * h * j
    # Per grid cell: capacity (1,h) + four (1,h,j) f64 columns in, out.
    vmem_kb = (h + 5 * h * j) * 8 / 1024
    return us, f"flops={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


def bench_kernel_cap_balance():
    from repro.core import kernels
    from repro.kernels.powercap.ops import pallas_balance_caps
    import numpy as np
    s, h, j = 4, 16, 8
    rng = np.random.default_rng(4)
    floors = jnp.asarray(rng.uniform(0.0, 300.0, (s, h, j)))
    ceils = floors + jnp.asarray(rng.uniform(0.0, 500.0, (s, h, j)))
    weights = jnp.asarray(rng.uniform(0.1, 10.0, (s, h, j)))
    active = jnp.asarray(rng.random((s, h, j)) < 0.8)
    idle = rng.uniform(80.0, 120.0, (s, h))
    peak = idle + rng.uniform(100.0, 200.0, (s, h))
    hosts = kernels.HostCols(
        jnp.ones((s, h), bool), jnp.asarray(idle), jnp.asarray(peak),
        jnp.asarray(rng.uniform(2000.0, 4000.0, (s, h))),
        jnp.asarray(rng.uniform(0.0, 50.0, (s, h))))
    caps0 = jnp.asarray(rng.uniform(idle, peak))
    cpu_res = jnp.zeros((s, h))
    budget = jnp.sum(caps0, axis=-1)
    enabled = jnp.ones((s,), bool)
    dense = kernels.DenseCols(floors, ceils, weights, active)
    us = _time(lambda c: pallas_balance_caps(hosts, c, dense, cpu_res,
                                             budget, enabled,
                                             kernels.BalanceParams()), caps0)
    # Per round: one fused waterfill over every slot + O(H) balance math.
    flops = (200 * 6 + 10) * s * h * j + 60 * s * h
    vmem_kb = (5 * h * j + 16 * h) * 8 / 1024
    return us, f"flops_round={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


BENCHES = [
    ("kernel_flash_attention", bench_flash_attention),
    ("kernel_ssd_scan", bench_ssd_scan),
    ("kernel_moe_gmm", bench_moe_gmm),
    ("kernel_waterfill", bench_kernel_waterfill),
    ("kernel_cap_balance", bench_kernel_cap_balance),
]


def main():
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        us, derived = fn()
        print(f"{name},{us:.0f},{derived}  (interpret-mode timing)")


if __name__ == "__main__":
    main()
