"""Kernel micro-benchmarks: interpret-mode correctness-grade timings plus
the *analytic* TPU-side work per call (FLOPs, VMEM working set).

Wall-clock here is CPU interpret mode (correctness harness, not perf);
the derived column carries what matters for the TPU target: FLOPs/call and
the VMEM footprint per grid cell implied by the BlockSpecs.

Run: PYTHONPATH=src python -m benchmarks.kernel_bench
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def bench_flash_attention():
    from repro.kernels.flash_attention import flash_attention
    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    us = _time(lambda q, k, v: flash_attention(q, k, v, block_q=128,
                                               block_k=128), q, k, v)
    flops = 2 * 2 * b * hq * s * s * d * 0.5          # qk + av, causal
    vmem_kb = (128 * d * 2 * 3 + 128 * 128 * 4 + 128 * d * 4) / 1024
    return us, f"flops={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


def bench_ssd_scan():
    from repro.kernels.ssd_scan import ssd_scan
    b, l, h, p, n, q = 1, 256, 4, 32, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, l, h, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, h, n)) * 0.3
    us = _time(lambda *a: ssd_scan(*a, chunk=q), x, dt, a_log, bm, cm)
    nc = l // q
    flops = nc * (2 * b * h * q * q * n + 2 * b * h * q * q * p
                  + 2 * b * h * q * p * n)
    vmem_kb = (q * 4 * (p + 2 * n) * 4 + 4 * q * q * 4 * 2) / 1024
    return us, f"flops={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


def bench_moe_gmm():
    from repro.kernels.moe_gmm import grouped_matmul
    e, c, d, f = 8, 128, 256, 128
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (e, c, d), jnp.float32)
    w = jax.random.normal(k2, (e, d, f), jnp.float32)
    us = _time(lambda x, w: grouped_matmul(x, w, block_c=128, block_d=128,
                                           block_f=128), x, w)
    flops = 2 * e * c * d * f
    vmem_kb = (128 * 128 * 2 * 2 + 128 * 128 * 4) / 1024
    return us, f"flops={flops:.2e};vmem_cell={vmem_kb:.0f}KB"


BENCHES = [
    ("kernel_flash_attention", bench_flash_attention),
    ("kernel_ssd_scan", bench_ssd_scan),
    ("kernel_moe_gmm", bench_moe_gmm),
]


def main():
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        us, derived = fn()
        print(f"{name},{us:.0f},{derived}  (interpret-mode timing)")


if __name__ == "__main__":
    main()
