"""Benchmark smoke: sweep-throughput regression gate for CI.

Runs a fixed *tiny* scenario grid -- a cap-only slice and a capacity-churn
slice -- through both the batched (jitted) and sequential (vector) sweep
engines, and gates on the batched/sequential **speedup**.  Speedup is the
machine-portable throughput metric: both sides execute in the same process
on the same hardware, so a CI runner's absolute cells/s cancels out, while
a regression in the compiled program (an accidental host-sync, a carry that
stopped aliasing, a kernel falling off the fused path) shows up directly.

Also gates the overlapped sweep pipeline (``sweep_e2e``): the cap-only
smoke grid clocked end-to-end -- scenario construction, TraceBank packing,
AOT dispatch, harvest -- against its steady-state device wall.  The gated
``e2e_ratio`` (e2e / steady cells/s) is machine-portable for the same
reason speedup is, and drops when host-side work creeps back onto the
critical path.

Also gates the sharded sweep engine (``sweep_scale_sharded``): a tiny grid
runs on a 1-device and an 8-virtual-device ``("cells",)`` mesh in a
subprocess; per-cell results must be bit-identical across the two meshes
(hard gate), and the sharded/single speedup must hold its committed floor
whenever the runner has at least as many cores as forced virtual devices
(oversubscribed runners skip the floor -- their throughput is scheduler
noise, not a property of the compiled program).

Also gates the fused Pallas allocation kernel (``kernel_waterfill``): the
CI runner has no TPU, so interpret-mode wall time is correctness-grade
noise and is recorded informationally only -- the gate is *parity*, the
kernel's actual contract: bitwise-identical float64 output against the lax
executor on a fixed problem.  Any drift in the fused kernel (a masking
change, a reduction reorder, an accidental f32 cast) fails the gate even
when every timing looks fine.

The committed baseline lives in ``BENCH_sweep.json`` under ``"smoke"``;
the gate fails when a grid's speedup drops more than ``--tolerance``
(default 30%) below it.  The baseline should be refreshed with
``--update-baseline`` on low-core hardware: extra cores help the jitted
batched side more than the single-threaded NumPy side, so a baseline
from a small machine is a conservative floor on bigger CI runners.  The
full-size headline numbers (``sweep_grid`` / ``sweep_grid_dpm``) are
tracked separately by ``benchmarks/run.py --json``.

Usage:
  PYTHONPATH=src python -m benchmarks.check_regression              # gate
  PYTHONPATH=src python -m benchmarks.check_regression --update-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_PATH = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_sweep.json"))


def _grids():
    from repro.sim.sweep import scenario_families
    return {
        "sweep_grid": scenario_families(
            sizes=(20,), budgets_per_host_w=(250.0,),
            spikes=("burst", "prime"), heterogeneous=(False, True),
            churns=("none",), duration_s=600.0, tick_s=10.0),
        # 1500 s so the DPM valley spans the stability window and the
        # cells actually power hosts off/on (see sweep_grid_dpm).
        "sweep_grid_dpm": scenario_families(
            sizes=(20,), budgets_per_host_w=(250.0,),
            spikes=("burst",), heterogeneous=(False, True),
            churns=("dpm", "failure"), duration_s=1500.0, tick_s=30.0),
        # Migration layer live: constraint-correction bursts and
        # cap-blocked (Fig. 1a) corrections with the hill-climb balancer
        # (see sweep_grid_rules).
        "sweep_grid_rules": scenario_families(
            sizes=(20,), budgets_per_host_w=(250.0,),
            spikes=("burst",), heterogeneous=(False, True),
            rules=("violation_burst", "cap_blocked"),
            duration_s=600.0, tick_s=10.0),
        # Timed-migration execution model: multi-tick copy windows in the
        # scan-state in-flight table, slot/bandwidth-gated launches, both
        # endpoints charged -- cells that used to fall off the batched
        # engine (see sweep_grid_timed).  10 s ticks keep transfers
        # multi-tick; 900 s spans three DRS invocations.
        "sweep_grid_timed": scenario_families(
            sizes=(20,), budgets_per_host_w=(250.0,),
            spikes=("burst",), heterogeneous=(False, True),
            churns=("timed_churn", "failure_cascade"),
            duration_s=900.0, tick_s=10.0),
    }


def measure() -> dict:
    from repro.sim.sweep import run_cell, run_sweep_batched
    policies = ("cpc", "static")
    out = {}
    for name, specs in _grids().items():
        run_sweep_batched(specs, policies=policies)      # jit compile
        res = run_sweep_batched(specs, policies=policies)
        batch_wall = sum(r.wall_s for by_p in res.values()
                         for r in by_p.values())
        n_cells = len(specs) * len(policies)
        seq_wall, seq_cells = 0.0, 0
        for spec in specs[:2]:
            for p in policies:
                seq_wall += run_cell(spec, p, engine="vector").wall_s
                seq_cells += 1
        out[name] = {
            "n_cells": n_cells,
            "n_hosts": specs[0].n_hosts,
            "cells_per_s_batched": n_cells / batch_wall,
            "cells_per_s_sequential": seq_cells / seq_wall,
            "speedup": (n_cells / batch_wall) / (seq_cells / seq_wall),
        }
    return out


def measure_e2e() -> dict:
    """``sweep_e2e`` smoke: pipeline efficiency end-to-end.

    Runs the cap-only smoke grid through ``run_sweep_batched`` twice (the
    first call warms the AOT executables) and clocks the second from the
    ``SweepSpec`` list to merged results.  The gated metric is the
    **e2e ratio** -- e2e cells/s over steady-state (device-wall) cells/s.
    Like speedup it is machine-portable: both walls come from the same
    process on the same hardware, so a regression in the overlapped
    pipeline (packing back on the critical path, a host sync between
    dispatch and harvest, scenario construction reverting to per-VM
    factories) lowers the ratio on any runner.
    """
    import time

    from repro.sim.sweep import LAST_BATCH_INFO, run_sweep_batched
    specs = _grids()["sweep_grid"]
    policies = ("cpc", "static")
    n_cells = len(specs) * len(policies)
    run_sweep_batched(specs, policies=policies)      # warm AOT executables
    t0 = time.perf_counter()
    run_sweep_batched(specs, policies=policies)
    e2e_wall = time.perf_counter() - t0
    run_s = sum(b["run_s"] for b in LAST_BATCH_INFO)
    return {
        "n_cells": n_cells,
        "n_hosts": specs[0].n_hosts,
        "cells_per_s_e2e": n_cells / e2e_wall,
        "cells_per_s_steady": n_cells / run_s,
        "e2e_ratio": run_s / e2e_wall,
    }


def measure_sharded() -> dict:
    """``sweep_scale_sharded`` smoke: the sharded sweep engine on 8 virtual
    CPU devices, in a subprocess (the cells mesh needs the forced device
    count set before jax initializes).

    Two gates ride on this entry: per-cell results across the 1-device and
    8-device meshes must be **bit-identical** (the sharding contract --
    cells are embarrassingly parallel, so the compiled arithmetic is the
    same program either way), and the sharded/single **speedup** must stay
    within tolerance of the committed baseline.  A baseline measured on
    low-core hardware is a conservative floor: real cores only help the
    sharded side.
    """
    import subprocess

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep_sharded", "--mode", "grid",
         "--cells", "16", "--hosts", "6", "--duration", "300",
         "--tick", "30"],
        capture_output=True, text=True, env=env,
        cwd=os.path.normpath(os.path.join(os.path.dirname(__file__), "..")))
    if proc.returncode != 0:
        raise RuntimeError(f"sweep_sharded probe failed:\n{proc.stderr}")
    g = json.loads(proc.stdout)
    n_devices = g["sharded"]["n_devices"]
    return {
        "n_cells": g["n_cells"],
        "n_hosts": g["n_hosts"],
        "n_devices": n_devices,
        "cells_per_s_single": g["single"]["cells_per_s"],
        "cells_per_s_sharded": g["sharded"]["cells_per_s"],
        "speedup": g["speedup"],
        "parity_bit_identical": bool(g["parity"]),
        # Whether the speedup floor is meaningful on THIS runner: with
        # fewer cores than forced virtual devices the sharded side is pure
        # oversubscription, so the floor is waived (parity still gates).
        "enforced": n_devices <= (os.cpu_count() or 1),
    }


def measure_kernel() -> dict:
    """``kernel_waterfill``: parity-gated, timing-informational.

    Runs the fused Pallas dense waterfill and the dispatch-free lax
    reference on the same fixed float64 problem (interpret mode off-TPU)
    and records the max absolute difference -- the gate requires exactly
    0.0, the bit-identity the differential test harness locks down.
    """
    import time

    import numpy as np
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels.powercap import ops, ref

    rng = np.random.default_rng(0)
    s, h, j = 4, 16, 8
    floors = rng.uniform(0.0, 300.0, (s, h, j))
    ceils = floors + rng.uniform(0.0, 500.0, (s, h, j))
    weights = rng.uniform(0.1, 10.0, (s, h, j))
    active = rng.random((s, h, j)) < 0.8
    floors = np.where(active, floors, 0.0)
    ceils = np.where(active, ceils, 0.0)
    capacity = rng.uniform(0.0, 1.2, (s, h)) * np.maximum(
        ceils.sum(axis=-1), 1.0)
    with enable_x64():
        args = tuple(jnp.asarray(a) for a in (capacity, floors, ceils,
                                              weights))
        act = jnp.asarray(active)
        got = ops.pallas_waterfill_dense(*args, active=act)
        want = ref.lax_waterfill_dense(*args, active=act)
        got.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            ops.pallas_waterfill_dense(*args,
                                       active=act).block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        return {
            "bit_identical": bool(jnp.all(got == want)),
            "max_abs_diff_vs_lax": float(jnp.abs(got - want).max()),
            "us_per_call_interpret": us,
        }


def measure_budget_service() -> dict:
    """``budget_service``: the live headroom/admission service and the
    hierarchical-budget sweep family, parity-gated with a generous
    latency bound.

    Three things ride on this entry: (1) the service's headroom answers
    must equal brute-force recomputation exactly on the post-replay state
    (the control plane's core contract); (2) the ``row_contention``
    budget-tree sweep slice must replay identically batch vs vector
    (exact cap-change counts, 1e-9 payload/energy); (3) replay latency
    percentiles are recorded, gated only against a 10x-the-baseline
    ceiling -- absolute microseconds are runner noise, an order of
    magnitude is an accidental O(n^2) or a jit on the hot path.
    """
    import numpy as np

    from repro.core.budget_tree import BudgetTree
    from repro.runtime import budget_service as bsvc
    from repro.sim.sweep import row_contention_specs, run_sweep

    n_hosts, n_events = 50, 4000
    budget = 250.0 * n_hosts
    tree = BudgetTree.two_rows(budget, n_hosts, row0_limit=0.45 * budget)
    hosts = [f"host{i}" for i in range(n_hosts)]
    on = np.ones(n_hosts, dtype=bool)
    caps0 = tree.project(np.full(n_hosts, 250.0), on,
                         floors=np.zeros(n_hosts))
    svc = bsvc.BudgetService(tree, hosts, caps0, on)
    rep = svc.replay(bsvc.synthetic_feed(tree, n_events=n_events, seed=0))
    parity = max(abs(svc.headroom(h) - svc.brute_force_headroom(h))
                 for h in hosts)

    # 600 s reaches past the burst onset, so the cpc cell really changes
    # caps under the binding row and the parity bit is non-trivial.
    specs = row_contention_specs(sizes=(10,), duration_s=600.0)
    policies = ("cpc", "static")
    vec = run_sweep(specs, policies=policies, engine="vector")
    bat = run_sweep(specs, policies=policies, engine="batch")
    sweep_active = any(vec[s]["cpc"].cap_changes > 0 for s in vec)
    sweep_exact = sweep_active and all(
        vec[s][p].cap_changes == bat[s][p].cap_changes
        and abs(vec[s][p].cpu_payload_mhz_s - bat[s][p].cpu_payload_mhz_s)
        <= 1e-9 * abs(vec[s][p].cpu_payload_mhz_s)
        and abs(vec[s][p].energy_j - bat[s][p].energy_j)
        <= 1e-9 * abs(vec[s][p].energy_j)
        for s in vec for p in vec[s])
    return {
        "n_hosts": n_hosts,
        "n_events": rep.n_events,
        "n_decisions": rep.n_decisions,
        "n_errors": rep.n_errors,
        "p50_us": rep.p50_us,
        "p99_us": rep.p99_us,
        "headroom_parity_max_w": float(parity),
        "row_contention_parity": bool(sweep_exact),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the measured smoke speedups into "
                         "BENCH_sweep.json instead of gating")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional speedup regression")
    args = ap.parse_args()

    measured = measure()
    for name, m in measured.items():
        print(f"{name}: {m['n_cells']}cells@{m['n_hosts']}h "
              f"batched {m['cells_per_s_batched']:.1f} cells/s, "
              f"sequential {m['cells_per_s_sequential']:.1f} cells/s, "
              f"speedup {m['speedup']:.2f}x", flush=True)
    measured["sweep_e2e"] = me = measure_e2e()
    print(f"sweep_e2e: {me['n_cells']}cells@{me['n_hosts']}h "
          f"e2e {me['cells_per_s_e2e']:.1f} cells/s, "
          f"steady {me['cells_per_s_steady']:.1f} cells/s, "
          f"ratio {me['e2e_ratio']:.2f}", flush=True)
    measured["sweep_scale_sharded"] = ms = measure_sharded()
    print(f"sweep_scale_sharded: {ms['n_cells']}cells@{ms['n_hosts']}h "
          f"on {ms['n_devices']} virtual devices, "
          f"sharded {ms['cells_per_s_sharded']:.1f} cells/s vs single "
          f"{ms['cells_per_s_single']:.1f} cells/s "
          f"({ms['speedup']:.2f}x), parity "
          f"{'exact' if ms['parity_bit_identical'] else 'BROKEN'}",
          flush=True)
    measured["kernel_waterfill"] = mk = measure_kernel()
    print(f"kernel_waterfill: max_abs_diff vs lax "
          f"{mk['max_abs_diff_vs_lax']:.1e}, "
          f"{mk['us_per_call_interpret']:.0f}us/call (interpret mode, "
          f"informational)", flush=True)
    measured["budget_service"] = mb = measure_budget_service()
    print(f"budget_service: {mb['n_events']}events@{mb['n_hosts']}h "
          f"p50 {mb['p50_us']:.0f}us p99 {mb['p99_us']:.0f}us, "
          f"headroom parity {mb['headroom_parity_max_w']:.1e}, "
          f"row_contention parity "
          f"{'exact' if mb['row_contention_parity'] else 'BROKEN'}",
          flush=True)

    with open(BASELINE_PATH) as f:
        bench = json.load(f)

    if args.update_baseline:
        bench["smoke"] = measured
        with open(BASELINE_PATH, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
        print(f"baseline updated in {BASELINE_PATH}")
        return 0

    baseline = bench.get("smoke")
    if not baseline:
        print("no committed smoke baseline in BENCH_sweep.json; run with "
              "--update-baseline and commit the result", file=sys.stderr)
        return 1
    failed = False
    for name, base in baseline.items():
        got = measured.get(name)
        if got is None:
            print(f"FAIL {name}: grid missing from this run",
                  file=sys.stderr)
            failed = True
            continue
        if "parity_bit_identical" in base:
            # Sharded engine: parity is the hard gate (bit-identical
            # per-cell results across mesh sizes).  The sharded/single
            # speedup floor catches collectives or resharding creeping
            # into the compiled program -- but it is only meaningful when
            # the virtual devices map onto real cores: on a runner with
            # fewer cores than forced devices the "sharded" side is pure
            # oversubscription and its throughput is scheduler noise, so
            # the floor is skipped (parity still gates).
            floor = base["speedup"] * (1.0 - args.tolerance)
            gate_speedup = got.get(
                "enforced", got["n_devices"] <= (os.cpu_count() or 1))
            ok = (got["parity_bit_identical"]
                  and (got["speedup"] >= floor or not gate_speedup))
            status = "ok" if ok else "FAIL"
            print(f"{status} {name}: parity "
                  f"{'exact' if got['parity_bit_identical'] else 'BROKEN'}"
                  f", speedup {got['speedup']:.2f}x vs baseline "
                  f"{base['speedup']:.2f}x (floor {floor:.2f}x, "
                  f"{'enforced' if gate_speedup else 'waived'})",
                  flush=True)
            if not gate_speedup:
                print(f"  floor waived: {got['n_devices']} forced virtual "
                      f"devices oversubscribe {os.cpu_count() or 1} "
                      f"physical core(s), so sharded throughput here is "
                      f"scheduler noise, not a property of the compiled "
                      f"program; the bit-identity parity gate still "
                      f"applies", flush=True)
            failed |= not ok
            continue
        if "headroom_parity_max_w" in base:
            # Budget service: parity is the hard gate (headroom answers
            # exactly equal brute force; the row_contention tree sweep
            # bit-stable batch vs vector).  Latency only fails at 10x the
            # committed baseline -- absolute microseconds are runner
            # noise, an order of magnitude is an algorithmic regression.
            ceil = max(base["p99_us"] * 10.0, 1000.0)
            ok = (got["headroom_parity_max_w"] == 0.0
                  and got["row_contention_parity"]
                  and got["p99_us"] <= ceil)
            status = "ok" if ok else "FAIL"
            print(f"{status} {name}: headroom parity "
                  f"{got['headroom_parity_max_w']:.1e} (gate: exactly 0), "
                  f"row_contention "
                  f"{'exact' if got['row_contention_parity'] else 'BROKEN'}"
                  f", p99 {got['p99_us']:.0f}us (ceiling {ceil:.0f}us)",
                  flush=True)
            failed |= not ok
            continue
        if "bit_identical" in base:
            # Parity gate: the fused kernel must stay bit-identical to the
            # lax executor; interpret-mode timing is never gated.
            ok = got["bit_identical"] and got["max_abs_diff_vs_lax"] == 0.0
            status = "ok" if ok else "FAIL"
            print(f"{status} {name}: pallas vs lax max_abs_diff "
                  f"{got['max_abs_diff_vs_lax']:.1e} (gate: exactly 0)",
                  flush=True)
            failed |= not ok
            continue
        if "e2e_ratio" in base:
            # Pipeline-efficiency gate: e2e over steady-state throughput.
            floor = base["e2e_ratio"] * (1.0 - args.tolerance)
            status = "ok" if got["e2e_ratio"] >= floor else "FAIL"
            print(f"{status} {name}: e2e ratio {got['e2e_ratio']:.2f} vs "
                  f"baseline {base['e2e_ratio']:.2f} (floor {floor:.2f})",
                  flush=True)
            failed |= got["e2e_ratio"] < floor
            continue
        floor = base["speedup"] * (1.0 - args.tolerance)
        status = "ok" if got["speedup"] >= floor else "FAIL"
        print(f"{status} {name}: speedup {got['speedup']:.2f}x vs baseline "
              f"{base['speedup']:.2f}x (floor {floor:.2f}x)",
              flush=True)
        failed |= got["speedup"] < floor
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
