"""Benchmark smoke: sweep-throughput regression gate for CI.

Runs a fixed *tiny* scenario grid -- a cap-only slice and a capacity-churn
slice -- through both the batched (jitted) and sequential (vector) sweep
engines, and gates on the batched/sequential **speedup**.  Speedup is the
machine-portable throughput metric: both sides execute in the same process
on the same hardware, so a CI runner's absolute cells/s cancels out, while
a regression in the compiled program (an accidental host-sync, a carry that
stopped aliasing, a kernel falling off the fused path) shows up directly.

The committed baseline lives in ``BENCH_sweep.json`` under ``"smoke"``;
the gate fails when a grid's speedup drops more than ``--tolerance``
(default 30%) below it.  The baseline should be refreshed with
``--update-baseline`` on low-core hardware: extra cores help the jitted
batched side more than the single-threaded NumPy side, so a baseline
from a small machine is a conservative floor on bigger CI runners.  The
full-size headline numbers (``sweep_grid`` / ``sweep_grid_dpm``) are
tracked separately by ``benchmarks/run.py --json``.

Usage:
  PYTHONPATH=src python -m benchmarks.check_regression              # gate
  PYTHONPATH=src python -m benchmarks.check_regression --update-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_PATH = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_sweep.json"))


def _grids():
    from repro.sim.sweep import scenario_families
    return {
        "sweep_grid": scenario_families(
            sizes=(20,), budgets_per_host_w=(250.0,),
            spikes=("burst", "prime"), heterogeneous=(False, True),
            churns=("none",), duration_s=600.0, tick_s=10.0),
        # 1500 s so the DPM valley spans the stability window and the
        # cells actually power hosts off/on (see sweep_grid_dpm).
        "sweep_grid_dpm": scenario_families(
            sizes=(20,), budgets_per_host_w=(250.0,),
            spikes=("burst",), heterogeneous=(False, True),
            churns=("dpm", "failure"), duration_s=1500.0, tick_s=30.0),
        # Migration layer live: constraint-correction bursts and
        # cap-blocked (Fig. 1a) corrections with the hill-climb balancer
        # (see sweep_grid_rules).
        "sweep_grid_rules": scenario_families(
            sizes=(20,), budgets_per_host_w=(250.0,),
            spikes=("burst",), heterogeneous=(False, True),
            rules=("violation_burst", "cap_blocked"),
            duration_s=600.0, tick_s=10.0),
    }


def measure() -> dict:
    from repro.sim.sweep import run_cell, run_sweep_batched
    policies = ("cpc", "static")
    out = {}
    for name, specs in _grids().items():
        run_sweep_batched(specs, policies=policies)      # jit compile
        res = run_sweep_batched(specs, policies=policies)
        batch_wall = sum(r.wall_s for by_p in res.values()
                         for r in by_p.values())
        n_cells = len(specs) * len(policies)
        seq_wall, seq_cells = 0.0, 0
        for spec in specs[:2]:
            for p in policies:
                seq_wall += run_cell(spec, p, engine="vector").wall_s
                seq_cells += 1
        out[name] = {
            "n_cells": n_cells,
            "n_hosts": specs[0].n_hosts,
            "cells_per_s_batched": n_cells / batch_wall,
            "cells_per_s_sequential": seq_cells / seq_wall,
            "speedup": (n_cells / batch_wall) / (seq_cells / seq_wall),
        }
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the measured smoke speedups into "
                         "BENCH_sweep.json instead of gating")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional speedup regression")
    args = ap.parse_args()

    measured = measure()
    for name, m in measured.items():
        print(f"{name}: {m['n_cells']}cells@{m['n_hosts']}h "
              f"batched {m['cells_per_s_batched']:.1f} cells/s, "
              f"sequential {m['cells_per_s_sequential']:.1f} cells/s, "
              f"speedup {m['speedup']:.2f}x", flush=True)

    with open(BASELINE_PATH) as f:
        bench = json.load(f)

    if args.update_baseline:
        bench["smoke"] = measured
        with open(BASELINE_PATH, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
        print(f"baseline updated in {BASELINE_PATH}")
        return 0

    baseline = bench.get("smoke")
    if not baseline:
        print("no committed smoke baseline in BENCH_sweep.json; run with "
              "--update-baseline and commit the result", file=sys.stderr)
        return 1
    failed = False
    for name, base in baseline.items():
        got = measured.get(name)
        if got is None:
            print(f"FAIL {name}: grid missing from this run",
                  file=sys.stderr)
            failed = True
            continue
        floor = base["speedup"] * (1.0 - args.tolerance)
        status = "ok" if got["speedup"] >= floor else "FAIL"
        print(f"{status} {name}: speedup {got['speedup']:.2f}x vs baseline "
              f"{base['speedup']:.2f}x (floor {floor:.2f}x)",
              flush=True)
        failed |= got["speedup"] < floor
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
