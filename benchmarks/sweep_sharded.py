"""Sharded sweep scaling probe: one JSON object on stdout.

The ``("cells",)`` mesh can only span devices that exist when jax first
initializes, so multi-device CPU runs need
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set *before* the
first jax import.  The benchmark harness (``benchmarks/run.py``) and the
``sweep-sharded-smoke`` CI job therefore launch this module as a
subprocess with that flag and parse its stdout; it is equally runnable by
hand:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.sweep_sharded --mode grid

Modes:
  grid   -- an N-cell single-bucket grid run twice through
            ``run_sweep(engine="batch")``: once on 1 device, once sharded
            over every visible device.  Reports cells/s both ways, the
            speedup, per-bucket compile_s, and whether the per-cell
            results are bit-identical across the two meshes (they must
            be: cells are embarrassingly parallel, the compiled per-cell
            arithmetic is the same program either way).
  scale  -- the datacenter cell: ``--hosts`` hosts x 10 VMs/host (10k
            hosts => 100k VM slots) under cpc+static, sharded over (at
            most) 2 devices since the grid is 2 cells.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _fingerprint(res) -> list:
    """Exact per-cell results in spec x policy order, JSON-stable."""
    out = []
    for name in res:
        for p, r in res[name].items():
            out.append([name, p, int(r.cap_changes), int(r.vmotions),
                        int(r.power_ons), int(r.power_offs),
                        float(r.energy_j).hex(),
                        float(r.cpu_payload_mhz_s).hex()])
    return out


def _grid_specs(n_cells: int, n_hosts: int, duration_s: float,
                tick_s: float):
    from repro.sim.sweep import scenario_families
    n_specs = n_cells // 2
    # 8 specs per budget point: 4 spike families x 2 host mixes.
    budgets = [200.0 + 10.0 * i for i in range(max(1, -(-n_specs // 8)))]
    specs = scenario_families(
        sizes=(n_hosts,), budgets_per_host_w=budgets,
        spikes=("flat", "burst", "step", "prime"),
        heterogeneous=(False, True), duration_s=duration_s, tick_s=tick_s)
    if len(specs) < n_specs:
        raise SystemExit(f"grid tops out at {2 * len(specs)} cells")
    return specs[:n_specs]


def _run(specs, policies, n_devices):
    from repro.sim import sweep as sw
    t0 = time.perf_counter()
    res = sw.run_sweep(specs, policies=policies, engine="batch",
                       n_devices=n_devices)
    first_s = time.perf_counter() - t0
    buckets = [dict(b) for b in sw.LAST_BATCH_INFO]
    t0 = time.perf_counter()
    res = sw.run_sweep(specs, policies=policies, engine="batch",
                       n_devices=n_devices)
    steady_s = time.perf_counter() - t0
    n_cells = len(specs) * len(policies)
    return res, {
        "n_cells": n_cells,
        "n_devices": max(b["n_devices"] for b in buckets),
        "first_s": first_s,
        "steady_s": steady_s,
        "cells_per_s": n_cells / steady_s,
        "compile_s": sum(b["compile_s"] for b in buckets),
        "buckets": buckets,
    }


def measure_grid(n_cells: int, n_hosts: int, duration_s: float,
                 tick_s: float) -> dict:
    import jax
    specs = _grid_specs(n_cells, n_hosts, duration_s, tick_s)
    policies = ("cpc", "static")
    res1, single = _run(specs, policies, n_devices=1)
    resn, sharded = _run(specs, policies, n_devices=None)
    return {
        "n_cells": n_cells,
        "n_hosts": n_hosts,
        "visible_devices": len(jax.devices()),
        "single": single,
        "sharded": sharded,
        "speedup": sharded["cells_per_s"] / single["cells_per_s"],
        "parity": _fingerprint(res1) == _fingerprint(resn),
    }


def measure_scale(n_hosts: int, duration_s: float, tick_s: float) -> dict:
    from repro.sim.sweep import SweepSpec, run_sweep
    # 230 W/host is the paper's constrained-budget regime: DRS ticks must
    # actually redistribute caps, so the datacenter cell exercises the full
    # pipeline rather than coasting on headroom.
    spec = SweepSpec(name=f"h{n_hosts}_burst", n_hosts=n_hosts,
                     spike="burst", rack_budget_w=230.0 * n_hosts,
                     duration_s=duration_s, tick_s=tick_s)
    res, stats = _run([spec], ("cpc", "static"), n_devices=None)
    r = res[spec.name]["cpc"]
    stats.update(n_hosts=n_hosts, n_vm_slots=n_hosts * 10, ticks=r.ticks,
                 ticks_per_s=r.ticks_per_s,
                 cap_changes=int(r.cap_changes))
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("grid", "scale"), default="grid")
    ap.add_argument("--cells", type=int, default=256)
    ap.add_argument("--hosts", type=int, default=10)
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--tick", type=float, default=10.0)
    args = ap.parse_args()
    if args.mode == "grid":
        out = measure_grid(args.cells, args.hosts, args.duration, args.tick)
    else:
        out = measure_scale(args.hosts, args.duration, args.tick)
    json.dump(out, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
