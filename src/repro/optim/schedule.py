"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395).

MiniCPM is one of the assigned architectures; its WSD schedule is implemented
here for fidelity (warmup -> long stable plateau -> short exponential-ish
decay), alongside the standard cosine used by the other configs.
"""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps) /
                            max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (
            1 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def wsd_schedule(peak_lr: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay: plateau at peak, then fast decay."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        decay_start = warmup_steps + stable_steps
        progress = jnp.clip((step - decay_start) / max(decay_steps, 1),
                            0.0, 1.0)
        decayed = peak_lr * (final_frac ** progress)
        return jnp.where(step < warmup_steps, warm,
                         jnp.where(step < decay_start, peak_lr, decayed))
    return lr
