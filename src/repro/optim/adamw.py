"""AdamW, functional, with configurable state dtype.

Large configs (nemotron-4-340b) set ``optimizer_state_dtype=bfloat16`` so
m/v fit HBM on the single-pod mesh -- the memory/precision trade-off is
recorded in EXPERIMENTS.md.  Updates are always computed in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass
class OptState:
    m: PyTree
    v: PyTree
    count: jax.Array


jax.tree_util.register_pytree_node(
    OptState,
    lambda s: ((s.m, s.v, s.count), None),
    lambda aux, ch: OptState(*ch))


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"

    def init(self, params: PyTree) -> OptState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return OptState(m=jax.tree_util.tree_map(zeros, params),
                        v=jax.tree_util.tree_map(zeros, params),
                        count=jnp.zeros((), jnp.int32))

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return self.learning_rate

    def update(self, grads: PyTree, state: OptState, params: PyTree
               ) -> tuple[PyTree, OptState]:
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip_norm is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                 for g in jax.tree_util.tree_leaves(g32)))
            scale = jnp.minimum(1.0, self.grad_clip_norm
                                / jnp.maximum(gnorm, 1e-12))
            g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)
        count = state.count + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self._lr(count)
        dt = jnp.dtype(self.state_dtype)

        def upd(p, g, m, v):
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mhat = m32 / b1c
            vhat = v32 / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            decay = self.weight_decay * p.astype(jnp.float32) \
                if p.ndim >= 2 else 0.0
            new_p = p.astype(jnp.float32) - lr * (step + decay)
            return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        out = jax.tree_util.tree_map(upd, params, g32, state.m, state.v)
        new_params = jax.tree_util.tree_map(
            lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(
            lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(
            lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(m=new_m, v=new_v, count=count)
