"""Gradient compression for cross-pod data parallelism.

Int8 quantization with per-tensor scale plus error feedback (the residual of
each round is added back the next round, preserving convergence).  On a
multi-pod mesh the cross-pod gradient reduction is the slowest collective
(DCN, not ICI); 4x fewer bytes directly scales that term down -- see
EXPERIMENTS.md SPerf.

``compressed_cross_pod_mean`` is the shard_map building block: quantize the
local (per-pod) partial gradient, all_gather the int8 payload over the "pod"
axis, dequantize and average locally.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class ErrorFeedbackCompressor:
    """Stateful wrapper: compress(grads) with residual carry."""

    def init(self, params: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads: PyTree, residual: PyTree
                 ) -> tuple[PyTree, PyTree]:
        def one(g, r):
            g = g.astype(jnp.float32) + r
            q, s = quantize_int8(g)
            deq = dequantize_int8(q, s)
            return deq, g - deq
        out = jax.tree_util.tree_map(one, grads, residual)
        deq = jax.tree_util.tree_map(lambda t: t[0], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return deq, res


def compressed_cross_pod_mean(g: jax.Array, axis_name: str = "pod"
                              ) -> jax.Array:
    """Inside shard_map: int8 all_gather over ``axis_name`` + local mean.

    Moves 1/4 the bytes of an fp32 psum (1/2 of bf16) across the cross-pod
    links at the cost of one quantization error per step (bounded by error
    feedback at the caller).
    """
    q, scale = quantize_int8(g)
    qs = jax.lax.all_gather(q, axis_name)            # (pods, ...)
    scales = jax.lax.all_gather(scale, axis_name)    # (pods,)
    deq = qs.astype(jnp.float32) * scales.reshape(
        (-1,) + (1,) * g.ndim)
    return deq.mean(axis=0)
