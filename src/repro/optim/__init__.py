"""Optimizer substrate: AdamW (configurable state dtype), LR schedules
(cosine, WSD), gradient clipping and compression."""

from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import cosine_schedule, wsd_schedule
from repro.optim.compress import (quantize_int8, dequantize_int8,
                                  ErrorFeedbackCompressor)

__all__ = ["AdamW", "OptState", "cosine_schedule", "wsd_schedule",
           "quantize_int8", "dequantize_int8", "ErrorFeedbackCompressor"]
