"""Struct-of-arrays view of a cluster: the vectorized hot-path layout.

``ClusterSnapshot`` keeps the per-object datamodel that the what-if
algorithms mutate freely; this module gives every scale-sensitive consumer
(powercap balancing, DPM triggers, the vectorized simulator engine) a flat
NumPy layout built in one O(hosts + VMs) pass, so per-host quantities --
reserved capacity, utilization, entitlements, Eq. 1 power -- come out of
single array expressions instead of Python loops over the inventory.

The view is a snapshot-in-time: it does not track later object mutations.
Callers either use it within one computation (build, compute, drop) or, for
cap-only loops like BalancePowerCap, carry the mutable ``power_cap`` column
themselves and write the result back with :func:`ArrayView.write_caps`.

See ``docs/ARCHITECTURE.md`` ("The array-based layout") for the full map of
which call sites use this view.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import backend as backend_mod
from repro.core import kernels


@dataclasses.dataclass
class RulesPack:
    """Placement rules as dense arrays (the kernel layer's rule encoding).

    * ``affinity_group``: per-VM affinity-group id (``-1`` = none).  VMs
      appearing in several :class:`repro.drs.rules.AffinityRule`\\ s are
      merged into one group (union semantics), ids numbered in first-rule
      order.
    * ``anti_member``: per-rule membership masks ``(R, V)`` -- rule ``r``
      forbids any two of its members from sharing a host (the pairwise
      expansion of :class:`AntiAffinityRule`).
    * ``allowed``: per-VM allowed-host bitmask ``(V, H)`` -- the AND over
      every :class:`VMHostRule` naming the VM (all-True without a rule).

    Scattered into the dense slot layout by the engine packers so the
    admission kernels read rules as pure array lookups.
    """

    n_groups: int
    n_anti: int
    n_vmhost: int
    max_group_members: int          # static loop bound for correction
    max_anti_members: int           # total anti-rule members (move bound)
    affinity_group: np.ndarray      # (V,) int64
    anti_member: np.ndarray         # (R, V) bool
    allowed: np.ndarray             # (V, H) bool

    def meta(self) -> "kernels.RulesMeta":
        """The kernel layer's static-shape view of this pack -- the single
        source of the compile-time loop/slack bounds for every engine."""
        return kernels.RulesMeta(
            n_groups=self.n_groups, n_anti=self.n_anti,
            n_vmhost=self.n_vmhost,
            max_group_members=self.max_group_members,
            max_anti_members=self.max_anti_members)

    @classmethod
    def from_rules(cls, rules, vm_index: dict, host_index: dict
                   ) -> "RulesPack":
        from repro.drs import rules as rules_mod  # local import, no cycle
        n_vms, n_hosts = len(vm_index), len(host_index)
        group = np.full(n_vms, -1, dtype=np.int64)
        anti_rows: list[np.ndarray] = []
        allowed = np.ones((n_vms, n_hosts), dtype=bool)
        n_vmhost = 0
        # Affinity: union-find over rule memberships, ids in rule order.
        parent: dict[int, int] = {}

        def find(x: int) -> int:
            while parent.setdefault(x, x) != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        aff_rules = [r for r in rules
                     if isinstance(r, rules_mod.AffinityRule)]
        for rule in aff_rules:
            rows = [vm_index[v] for v in rule.vm_ids if v in vm_index]
            for a, b in zip(rows, rows[1:]):
                parent[find(a)] = find(b)
        roots: dict[int, int] = {}
        for rule in aff_rules:
            for v in rule.vm_ids:
                if v not in vm_index:
                    continue
                root = find(vm_index[v])
                if root not in roots:
                    roots[root] = len(roots)
                group[vm_index[v]] = roots[root]
        for rule in rules:
            if isinstance(rule, rules_mod.AntiAffinityRule):
                row = np.zeros(n_vms, dtype=bool)
                for v in rule.vm_ids:
                    if v in vm_index:
                        row[vm_index[v]] = True
                anti_rows.append(row)
            elif isinstance(rule, rules_mod.VMHostRule):
                if rule.vm_id in vm_index:
                    n_vmhost += 1
                    mask = np.zeros(n_hosts, dtype=bool)
                    for h in rule.allowed_hosts:
                        if h in host_index:
                            mask[host_index[h]] = True
                    allowed[vm_index[rule.vm_id]] &= mask
        anti = (np.stack(anti_rows) if anti_rows
                else np.zeros((0, n_vms), dtype=bool))
        n_groups = len(roots)
        sizes = np.bincount(group[group >= 0], minlength=max(n_groups, 1))
        return cls(
            n_groups=n_groups, n_anti=len(anti_rows), n_vmhost=n_vmhost,
            max_group_members=int(sizes.max()) if n_groups else 0,
            max_anti_members=int(anti.sum()),
            affinity_group=group, anti_member=anti, allowed=allowed)


def dense_slot_assignment(snapshot, n_hosts: int):
    """Group placed, powered-on VMs under their resident host.

    Returns ``(vms, order, hj, slot, counts)``: ``vms`` is the snapshot's VM
    list, ``order`` the indices of active VMs sorted stably by host, ``hj``
    and ``slot`` each active VM's (host, slot) coordinate in the dense
    ``(H, J)`` layout, and ``counts`` the per-host occupancy.  Shared by the
    batched engine's packer and the object plane's migration adapter so both
    planes agree on slot coordinates (and therefore on every slot-ordered
    tie-break).
    """
    vms = list(snapshot.vms.values())
    host_idx = {hid: j for j, hid in enumerate(snapshot.hosts)}
    host_j = np.array([host_idx.get(v.host_id, -1) for v in vms],
                      dtype=np.int64)
    act = np.array([v.powered_on for v in vms], dtype=bool)
    act &= host_j >= 0
    order = np.nonzero(act)[0]
    hj = host_j[order]
    srt = np.argsort(hj, kind="stable")
    order, hj = order[srt], hj[srt]
    counts = np.bincount(hj, minlength=n_hosts)
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    slot = np.arange(hj.size) - np.repeat(starts, counts)
    return vms, order, hj, slot, counts


@dataclasses.dataclass
class ArrayView:
    """Flat arrays over all hosts (index ``h``) and all VMs (index ``v``)."""

    # Host columns.
    host_ids: list
    host_index: dict                    # host_id -> h
    power_cap: np.ndarray               # (H,) Watts
    host_on: np.ndarray                 # (H,) bool
    power_idle: np.ndarray              # (H,)
    power_peak: np.ndarray              # (H,)
    capacity_peak: np.ndarray           # (H,)
    hyp_overhead: np.ndarray            # (H,) Eq. 4's C_H
    host_memory_mb: np.ndarray          # (H,) spec memory (ignores power state)
    # VM columns.
    vm_ids: list
    vm_index: dict                      # vm_id -> v
    vm_host: np.ndarray                 # (H-index,) int; -1 when unplaced
    vm_on: np.ndarray                   # (V,) bool
    demand: np.ndarray                  # (V,) MHz
    mem_demand: np.ndarray              # (V,) MB
    reservation: np.ndarray             # (V,) MHz
    limit: np.ndarray                   # (V,) MHz (inf = unlimited)
    shares: np.ndarray                  # (V,)
    vm_memory_mb: np.ndarray            # (V,) configured memory
    mem_reservation: np.ndarray         # (V,) MB

    # ------------------------------------------------------------- build
    @classmethod
    def from_snapshot(cls, snapshot) -> "ArrayView":
        hosts = list(snapshot.hosts.values())
        vms = list(snapshot.vms.values())
        host_ids = [h.host_id for h in hosts]
        host_index = {hid: i for i, hid in enumerate(host_ids)}
        vm_ids = [v.vm_id for v in vms]
        vm_index = {vid: i for i, vid in enumerate(vm_ids)}
        f64 = np.float64
        return cls(
            host_ids=host_ids,
            host_index=host_index,
            power_cap=np.array([h.power_cap for h in hosts], dtype=f64),
            host_on=np.array([h.powered_on for h in hosts], dtype=bool),
            power_idle=np.array([h.spec.power_idle for h in hosts],
                                dtype=f64),
            power_peak=np.array([h.spec.power_peak for h in hosts],
                                dtype=f64),
            capacity_peak=np.array([h.spec.capacity_peak for h in hosts],
                                   dtype=f64),
            hyp_overhead=np.array([h.spec.hypervisor_overhead for h in hosts],
                                  dtype=f64),
            host_memory_mb=np.array([h.spec.memory_mb for h in hosts],
                                    dtype=f64),
            vm_ids=vm_ids,
            vm_index=vm_index,
            vm_host=np.array([host_index.get(v.host_id, -1) for v in vms],
                             dtype=np.int64),
            vm_on=np.array([v.powered_on for v in vms], dtype=bool),
            demand=np.array([v.demand for v in vms], dtype=f64),
            mem_demand=np.array([v.mem_demand for v in vms], dtype=f64),
            reservation=np.array([v.reservation for v in vms], dtype=f64),
            limit=np.array([v.limit for v in vms], dtype=f64),
            shares=np.array([v.shares for v in vms], dtype=f64),
            vm_memory_mb=np.array([v.memory_mb for v in vms], dtype=f64),
            mem_reservation=np.array([v.mem_reservation for v in vms],
                                     dtype=f64),
        )

    # ------------------------------------------------------ power model
    @property
    def n_hosts(self) -> int:
        return len(self.host_ids)

    @property
    def n_vms(self) -> int:
        return len(self.vm_ids)

    def host_cols(self) -> kernels.HostCols:
        """The static host columns as the kernel layer's ``(1, H)`` bundle."""
        return kernels.HostCols(
            on=self.host_on[None],
            power_idle=self.power_idle[None],
            power_peak=self.power_peak[None],
            capacity_peak=self.capacity_peak[None],
            hyp_overhead=self.hyp_overhead[None])

    def waterfill_cols(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                      np.ndarray]:
        """Masked per-VM entitlement columns ``(floors, ceils, weights, seg)``.

        Inactive VMs carry zero floor/ceiling (so they allocate nothing)
        with their segment pinned to host 0 -- the kernel layer's padding
        convention, numerically identical to dropping them.
        """
        active = self.active_vms()
        floors = np.where(active,
                          np.minimum(self.reservation, self.limit), 0.0)
        ceils = np.where(active, self.effective_demand(), 0.0)
        weights = np.maximum(self.shares, 1e-12)
        seg = np.where(active, self.vm_host, 0)
        return floors, ceils, weights, seg

    def capped_capacity(self, caps: np.ndarray | None = None) -> np.ndarray:
        """Eq. 3 per host; 0 for powered-off hosts."""
        caps = self.power_cap if caps is None else caps
        return kernels.capped_capacity(np, self.host_cols(), caps[None])[0]

    def managed_capacity(self, caps: np.ndarray | None = None) -> np.ndarray:
        """Eq. 4 per host; 0 for powered-off hosts."""
        caps = self.power_cap if caps is None else caps
        return kernels.managed_capacity(np, self.host_cols(), caps[None])[0]

    def peak_managed_capacity(self) -> np.ndarray:
        return kernels.peak_managed_capacity(np, self.host_cols())[0]

    def cap_for_managed_capacity(self, capacities: np.ndarray) -> np.ndarray:
        """Inverse of Eq. 4 (vectorized ``spec.cap_for_managed_capacity``)."""
        return kernels.cap_for_managed_capacity(
            np, self.host_cols(), capacities[None])[0]

    # -------------------------------------------------------- VM rollups
    def active_vms(self) -> np.ndarray:
        """Mask of VMs that are powered on and placed on a powered-on host."""
        placed = self.vm_host >= 0
        on_host = np.zeros(self.n_vms, dtype=bool)
        on_host[placed] = self.host_on[self.vm_host[placed]]
        return self.vm_on & placed & on_host

    def _host_sum(self, values: np.ndarray, mask: np.ndarray) -> np.ndarray:
        return np.bincount(self.vm_host[mask], weights=values[mask],
                           minlength=self.n_hosts)

    def effective_demand(self) -> np.ndarray:
        return np.clip(self.demand, self.reservation, self.limit)

    def cpu_reserved(self) -> np.ndarray:
        return self._host_sum(self.reservation, self.active_vms())

    def mem_reserved(self) -> np.ndarray:
        return self._host_sum(self.mem_reservation, self.active_vms())

    def mem_demand_sum(self) -> np.ndarray:
        return self._host_sum(self.mem_demand, self.active_vms())

    def reserved_power_cap(self) -> np.ndarray:
        """Per-host minimum cap honoring resident reservations (0 when off)."""
        caps = self.cap_for_managed_capacity(self.cpu_reserved())
        return np.where(self.host_on, caps, 0.0)

    def host_demand(self) -> np.ndarray:
        """Per-host sum of resident VMs' effective demand."""
        return self._host_sum(self.effective_demand(), self.active_vms())

    # ----------------------------------------------------- entitlements
    def host_cpu_utilization(self, caps: np.ndarray | None = None
                             ) -> np.ndarray:
        cap = self.managed_capacity(caps)
        return np.where(cap > 0.0,
                        self.host_demand() / np.maximum(cap, 1e-300), 0.0)

    def host_mem_utilization(self) -> np.ndarray:
        ok = self.host_on & (self.host_memory_mb > 0.0)
        return np.where(ok, self.mem_demand_sum()
                        / np.maximum(self.host_memory_mb, 1e-300), 0.0)

    def entitlement_sums(self, caps: np.ndarray | None = None) -> np.ndarray:
        """Per-host sum of VM entitlements (one batched waterfill pass)."""
        caps = self.power_cap if caps is None else caps
        if self.n_vms == 0:
            return np.zeros(self.n_hosts)
        floors, ceils, weights, seg = self.waterfill_cols()
        return kernels.entitlement_sums(
            backend_mod.NUMPY, self.host_cols(), caps[None], floors[None],
            ceils[None], weights[None], seg[None])[0]

    def normalized_entitlements(self, caps: np.ndarray | None = None
                                ) -> np.ndarray:
        """N_h per host (0 where capacity is 0 or the host is off)."""
        cap = self.managed_capacity(caps)
        ent = self.entitlement_sums(caps)
        return np.where(cap > 0.0, ent / np.maximum(cap, 1e-300), 0.0)

    def imbalance(self, caps: np.ndarray | None = None) -> float:
        """DRS imbalance metric over powered-on hosts."""
        on = self.host_on
        if int(on.sum()) <= 1:
            return 0.0
        return float(self.normalized_entitlements(caps)[on].std())

    # -------------------------------------------------------- writeback
    def write_caps(self, snapshot, caps: np.ndarray) -> None:
        """Write a power-cap column back into the per-object snapshot."""
        for i, hid in enumerate(self.host_ids):
            snapshot.hosts[hid].power_cap = float(caps[i])
