"""Resource-management substrate (the paper's DRS analogue).

CloudPowerCap (repro.core) is designed to coordinate with an existing cluster
resource manager.  The paper uses VMware DRS; we implement the equivalent
substrate here: cluster snapshot datamodel, entitlement divvy
(reservation/limit/shares water-filling), constraint rules + correction,
greedy hill-climbing entitlement balancing with a risk-cost-benefit filter,
and distributed power management (DPM).
"""

from repro.drs.snapshot import (ClusterSnapshot, Host, VirtualMachine)
from repro.drs.actions import Action
from repro.drs.entitlement import divvy, waterfill
from repro.drs import rules, balancer, dpm, placement

__all__ = [
    "ClusterSnapshot", "Host", "VirtualMachine", "Action", "divvy",
    "waterfill", "rules", "balancer", "dpm", "placement",
]
