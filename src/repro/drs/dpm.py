"""Distributed Power Management (paper Sec. II-C, IV-D).

DPM right-sizes powered-on capacity: consolidate VMs and power hosts off when
utilization is low for a sustained period; power hosts back on when any host
runs hot.  CloudPowerCap's Powercap Redistribution (repro.core.redistribute)
coordinates: it frees the budget of powered-off hosts and funds the caps of
powering-on hosts.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.drs import placement
from repro.drs.snapshot import ClusterSnapshot

if TYPE_CHECKING:  # annotation-only: avoids a repro.core import cycle
    from repro.core import kernels


@dataclasses.dataclass
class DPMConfig:
    high_util: float = 0.81        # power-on trigger
    low_util: float = 0.45         # power-off consideration band
    target_util: float = 0.45      # post-consolidation ceiling on targets
    stable_window_s: float = 300.0 # utilization must be low this long

    def params(self) -> "kernels.DPMParams":
        from repro.core import kernels  # local import, no cycle
        return kernels.DPMParams(
            high_util=self.high_util, low_util=self.low_util,
            target_util=self.target_util,
            stable_window_s=self.stable_window_s)


@dataclasses.dataclass
class DPMRecommendation:
    power_on: Optional[str] = None
    power_off: Optional[str] = None
    evacuations: list = dataclasses.field(default_factory=list)  # (vm, dest)


def capacity_at_util(snapshot: ClusterSnapshot, host_id: str,
                     util: float) -> float:
    """Managed capacity at which the host's current demand equals ``util``.

    Powered-off hosts contribute no managed capacity regardless of the
    demand parked on them (their resident VMs receive nothing), so they sit
    at zero rather than projecting a phantom capacity target; zero-demand
    hosts likewise resolve to zero rather than tracking the division floor.
    """
    if not snapshot.hosts[host_id].powered_on:
        return 0.0
    demand = sum(v.effective_demand for v in snapshot.vms_on(host_id))
    if demand <= 0.0:
        return 0.0
    return demand / max(util, 1e-9)


def run_dpm(snapshot: ClusterSnapshot, config: DPMConfig,
            low_since: Optional[dict[str, float]] = None,
            now: float = 0.0,
            last_config_change: float = -1e18) -> DPMRecommendation:
    """One DPM pass.  ``low_since[host]`` = sim time when the host's
    utilization last *entered* the low band (for the stability window)."""
    from repro.core import kernels  # local import, no cycle
    rec = DPMRecommendation()
    on = snapshot.powered_on_hosts()
    standby = [h for h in snapshot.hosts.values() if not h.powered_on]

    # Per-host utilizations in one vectorized pass (the hot/low triggers are
    # evaluated for every host on every DPM run); the trigger masks are the
    # shared kernels so the batched engine's DPM decisions cannot diverge.
    av = snapshot.as_arrays()
    cpu_util = av.host_cpu_utilization()
    mem_util = av.host_mem_utilization()
    on_mask = av.host_on

    # --- power-on path: any hot host? --------------------------------------
    hot = kernels.dpm_hot_mask(np, on_mask, cpu_util, mem_util,
                               config.high_util)
    if bool(hot.any()):
        if standby:
            rec.power_on = standby[0].host_id
        return rec

    # --- power-off path: sustained cluster-wide low utilization ------------
    if len(on) <= 1:
        return rec
    all_low = bool(kernels.dpm_all_low(np, on_mask[None], cpu_util[None],
                                       mem_util[None], config.low_util)[0])
    if not all_low:
        return rec
    if low_since is not None:
        oldest = max(max(low_since.get(h.host_id, now) for h in on),
                     last_config_change)
        if now - oldest < config.stable_window_s:
            return rec

    # Evacuate the least-utilized host if its VMs fit elsewhere without
    # pushing any target above target_util.
    on_idx = np.nonzero(on_mask)[0]
    victim_i = int(on_idx[np.argmin(cpu_util[on_idx])])
    victim = snapshot.hosts[av.host_ids[victim_i]]
    # Hierarchical budgets: keep evacuees inside the victim's tightest
    # saturated budget subtree (same mask as the batched engine's
    # ``kernels.tree_evac_scope``), so the displaced demand stays in the
    # power domain whose freed watts will feed it.
    tree = snapshot.effective_tree()
    evac_scope = None
    if tree is not None:
        evac_scope = kernels.tree_evac_scope(
            np, tree.cols(), on_mask[None], av.power_cap[None],
            np.asarray([victim_i]))[0]
    trial = snapshot.clone()
    evacuations: list[tuple[str, str]] = []
    ok = True
    for vm in sorted(trial.vms_on(victim.host_id),
                     key=lambda v: -v.mem_demand):
        if not vm.migratable:
            ok = False
            break
        best, best_util = None, 1e18
        for host in trial.powered_on_hosts():
            if host.host_id == victim.host_id:
                continue
            if evac_scope is not None and \
                    not bool(evac_scope[av.host_index[host.host_id]]):
                continue
            if not placement.fits(trial, vm.vm_id, host.host_id):
                continue
            cap = host.managed_capacity
            demand_after = sum(x.effective_demand
                               for x in trial.vms_on(host.host_id)
                               ) + vm.effective_demand
            util_after = demand_after / max(cap, 1e-9)
            mem_after = (sum(x.mem_demand for x in trial.vms_on(host.host_id))
                         + vm.mem_demand) / max(host.memory_mb, 1e-9)
            if util_after <= config.target_util and \
                    mem_after <= config.target_util and util_after < best_util:
                best, best_util = host.host_id, util_after
        if best is None:
            ok = False
            break
        trial.move_vm(vm.vm_id, best)
        evacuations.append((vm.vm_id, best))
    if ok:
        rec.power_off = victim.host_id
        rec.evacuations = evacuations
    return rec
