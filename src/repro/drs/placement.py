"""Initial placement and constraint correction.

Constraint correction is the first phase of every DRS invocation: generate
migrations that fix rule violations (affinity / anti-affinity / VM-host).
CloudPowerCap hooks in by letting the fit check see *fundable* capacity --
the capacity a host could reach if its power cap were raised using the
cluster's unreserved power budget -- instead of the capacity frozen at the
current cap (paper Fig. 3 / Sec. IV-B).

Since the migration layer moved into backend-neutral kernels
(``repro.core.kernels`` via :class:`repro.core.migration_core.MigrationCore`),
:func:`correct_constraints` is a thin adapter: it packs the snapshot into the
dense slot layout, runs the same correction kernel the batched sweep engine
compiles, and replays the emitted moves onto the object snapshot.  The
per-VM :func:`fits` / :func:`place` helpers remain the object-plane
primitives used by DPM's evacuation planning.
"""

from __future__ import annotations

from typing import Callable

from repro.drs import rules as rules_mod
from repro.drs.snapshot import ClusterSnapshot


CapacityFn = Callable[[ClusterSnapshot, str], float]


def current_capacity(snapshot: ClusterSnapshot, host_id: str) -> float:
    """Capacity at the host's current power cap (static-cap world view)."""
    return snapshot.hosts[host_id].managed_capacity


def fits(snapshot: ClusterSnapshot, vm_id: str, host_id: str,
         capacity_fn: CapacityFn = current_capacity) -> bool:
    """Reservation + memory + rule admission check for a what-if move.

    Per-host reservation/memory sums come from the snapshot's cached
    placement rollups (O(1) per candidate; kept coherent by
    ``ClusterSnapshot.move_vm``), so a full candidate scan is O(V * H), not
    O(V^2 * H).
    """
    vm = snapshot.vms[vm_id]
    host = snapshot.hosts[host_id]
    if not host.powered_on:
        return False
    if not rules_mod.placement_allowed(snapshot, vm_id, host_id):
        return False
    cpu_after = snapshot.cached_cpu_reserved(host_id) + vm.reservation
    if cpu_after > capacity_fn(snapshot, host_id) + 1e-9:
        return False
    mem_after = snapshot.mem_demand_on(host_id) + vm.mem_demand
    return mem_after <= host.memory_mb + 1e-9


def place(snapshot: ClusterSnapshot, vm_id: str,
          capacity_fn: CapacityFn = current_capacity):
    """Initial placement: pick the admissible host with most free capacity."""
    best, best_free = None, -1.0
    for host in snapshot.powered_on_hosts():
        if fits(snapshot, vm_id, host.host_id, capacity_fn):
            free = (capacity_fn(snapshot, host.host_id)
                    - snapshot.cached_cpu_reserved(host.host_id))
            if free > best_free:
                best, best_free = host.host_id, free
    return best


def correct_constraints(snapshot: ClusterSnapshot,
                        capacity_fn: CapacityFn = current_capacity,
                        budget=None) -> list[tuple[str, str]]:
    """Return (vm_id, dest_host) moves fixing rule violations, applied to
    ``snapshot`` in place (what-if semantics: callers pass a clone).

    Thin adapter over the shared correction kernel; the batched sweep engine
    runs the identical kernel inside its jitted program, so all three
    engines produce the same moves for the same snapshot.  ``budget`` is
    the invocation's shared ``LaunchBudget`` when migration launches are
    gated (``None`` = ungated).
    """
    if not snapshot.rules:
        return []
    from repro.core.migration_core import MigrationCore  # local: no cycle
    return MigrationCore().correct(snapshot, capacity_fn, budget)
