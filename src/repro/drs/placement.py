"""Initial placement and constraint correction.

Constraint correction is the first phase of every DRS invocation: generate
migrations that fix rule violations (affinity / anti-affinity / VM-host).
CloudPowerCap hooks in by letting the fit check see *fundable* capacity --
the capacity a host could reach if its power cap were raised using the
cluster's unreserved power budget -- instead of the capacity frozen at the
current cap (paper Fig. 3 / Sec. IV-B).
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.drs import rules as rules_mod
from repro.drs.snapshot import ClusterSnapshot


CapacityFn = Callable[[ClusterSnapshot, str], float]


def current_capacity(snapshot: ClusterSnapshot, host_id: str) -> float:
    """Capacity at the host's current power cap (static-cap world view)."""
    return snapshot.hosts[host_id].managed_capacity


def fits(snapshot: ClusterSnapshot, vm_id: str, host_id: str,
         capacity_fn: CapacityFn = current_capacity) -> bool:
    """Reservation + memory + rule admission check for a what-if move."""
    vm = snapshot.vms[vm_id]
    host = snapshot.hosts[host_id]
    if not host.powered_on:
        return False
    if not rules_mod.placement_allowed(snapshot, vm_id, host_id):
        return False
    cpu_after = snapshot.cpu_reserved(host_id) + vm.reservation
    if cpu_after > capacity_fn(snapshot, host_id) + 1e-9:
        return False
    mem_after = sum(v.mem_demand for v in snapshot.vms_on(host_id)) + vm.mem_demand
    return mem_after <= host.memory_mb + 1e-9


def place(snapshot: ClusterSnapshot, vm_id: str,
          capacity_fn: CapacityFn = current_capacity) -> Optional[str]:
    """Initial placement: pick the admissible host with most free capacity."""
    best, best_free = None, -1.0
    for host in snapshot.powered_on_hosts():
        if fits(snapshot, vm_id, host.host_id, capacity_fn):
            free = (capacity_fn(snapshot, host.host_id)
                    - snapshot.cpu_reserved(host.host_id))
            if free > best_free:
                best, best_free = host.host_id, free
    return best


def correct_constraints(snapshot: ClusterSnapshot,
                        capacity_fn: CapacityFn = current_capacity
                        ) -> list[tuple[str, str]]:
    """Return (vm_id, dest_host) moves fixing rule violations, applied to
    ``snapshot`` in place (what-if semantics: callers pass a clone)."""
    moves: list[tuple[str, str]] = []
    for rule in snapshot.rules:
        if isinstance(rule, rules_mod.AffinityRule):
            if not rule.violations(snapshot):
                continue
            # Anchor on the VM with the largest reservation (hardest to move).
            members = [snapshot.vms[v] for v in rule.vm_ids
                       if snapshot.vms[v].powered_on]
            anchor = max(members, key=lambda v: v.reservation)
            # Try anchoring on each member host in reservation order.
            candidates = sorted({m.host_id for m in members},
                                key=lambda h: -snapshot.vms[anchor.vm_id].reservation
                                if h == anchor.host_id else 0)
            fixed = False
            for home in candidates:
                trial = snapshot.clone()
                trial_moves = []
                ok = True
                for m in members:
                    if m.host_id == home:
                        continue
                    if not m.migratable or not fits(trial, m.vm_id, home,
                                                    capacity_fn):
                        ok = False
                        break
                    trial.vms[m.vm_id].host_id = home
                    trial_moves.append((m.vm_id, home))
                if ok:
                    for vm_id, dest in trial_moves:
                        snapshot.vms[vm_id].host_id = dest
                    moves.extend(trial_moves)
                    fixed = True
                    break
            _ = fixed  # unfixable violations simply remain (reported upstream)
        elif isinstance(rule, rules_mod.VMHostRule):
            vm = snapshot.vms[rule.vm_id]
            if not rule.violations(snapshot):
                continue
            for host_id in rule.allowed_hosts:
                if vm.migratable and fits(snapshot, vm.vm_id, host_id,
                                          capacity_fn):
                    snapshot.vms[vm.vm_id].host_id = host_id
                    moves.append((vm.vm_id, host_id))
                    break
        elif isinstance(rule, rules_mod.AntiAffinityRule):
            while rule.violations(snapshot):
                by_host: dict[str, list[str]] = {}
                for v in rule.vm_ids:
                    vm = snapshot.vms[v]
                    if vm.powered_on:
                        by_host.setdefault(vm.host_id, []).append(v)
                moved = False
                for host_id, residents in by_host.items():
                    if len(residents) <= 1:
                        continue
                    for vm_id in residents[1:]:
                        dest = place(snapshot, vm_id, capacity_fn)
                        if dest is not None and dest != host_id and \
                                snapshot.vms[vm_id].migratable:
                            snapshot.vms[vm_id].host_id = dest
                            moves.append((vm_id, dest))
                            moved = True
                            break
                    if moved:
                        break
                if not moved:
                    break  # uncorrectable with current capacities
    return moves
