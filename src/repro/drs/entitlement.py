"""Entitlement computation: reservation/limit/shares divvy.

A VM's entitlement is the capacity it *deserves* under contention: at least
its reservation, at most min(limit, demand), with slack divided in proportion
to shares (weighted max-min fairness / progressive filling, paper refs [23],
[24]).  The same water-filling primitive is used by the simulator's host
scheduler to decide what each VM actually receives each tick.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def waterfill(capacity: float, floors: np.ndarray, ceilings: np.ndarray,
              weights: np.ndarray) -> np.ndarray:
    """Weighted max-min allocation.

    Finds ``x_i = clip(weights_i * level, floors_i, ceilings_i)`` such that
    ``sum(x) == min(capacity, sum(ceilings))`` (assuming
    ``sum(floors) <= capacity``; otherwise floors are granted pro-rata, which
    only arises transiently since reservations are admission-controlled).

    ``x(level)`` is piecewise-linear and nondecreasing, so bisection on the
    water level converges globally; a final pro-rata correction removes the
    residual tolerance so the allocation is exact to ~1e-9.
    """
    floors = np.asarray(floors, dtype=np.float64)
    ceilings = np.asarray(ceilings, dtype=np.float64)
    weights = np.maximum(np.asarray(weights, dtype=np.float64), 1e-12)
    n = floors.shape[0]
    if n == 0:
        return np.zeros(0)
    ceilings = np.maximum(ceilings, floors)
    total_floor = floors.sum()
    if total_floor >= capacity:
        # Degenerate: grant reservations pro-rata (cannot happen post
        # admission control, but keep the primitive total).
        return floors * (capacity / max(total_floor, 1e-12))
    target = min(capacity, ceilings.sum())

    def alloc_at(level: float) -> np.ndarray:
        return np.clip(weights * level, floors, ceilings)

    lo, hi = 0.0, float(np.max(ceilings / weights)) + 1.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if alloc_at(mid).sum() < target:
            lo = mid
        else:
            hi = mid
    out = alloc_at(hi)
    # Distribute the (tiny) residual among VMs not pinned at their ceiling.
    gap = target - out.sum()
    slack = ceilings - out
    room = slack > 1e-12
    if gap > 1e-12 and room.any():
        w = weights * room
        out = np.clip(out + gap * w / w.sum(), floors, ceilings)
    return out


def batched_waterfill(capacity: np.ndarray, floors: np.ndarray,
                      ceilings: np.ndarray, weights: np.ndarray,
                      seg_ids: np.ndarray, n_segs: int,
                      iters: int = 200) -> np.ndarray:
    """Weighted max-min allocation over many independent hosts at once.

    Vectorized form of :func:`waterfill`: item ``i`` belongs to segment
    (host) ``seg_ids[i]`` with per-segment capacity ``capacity[s]``.  All
    segments bisect their water level in lockstep, with per-segment sums
    computed by ``np.bincount`` -- one array pass per iteration instead of a
    Python loop over hosts.  Segment-wise the math is identical to the
    scalar primitive (same bounds, same bisection, same pro-rata residual
    correction), so per-host results agree to the correction tolerance.
    """
    capacity = np.asarray(capacity, dtype=np.float64)
    floors = np.asarray(floors, dtype=np.float64)
    ceilings = np.asarray(ceilings, dtype=np.float64)
    weights = np.maximum(np.asarray(weights, dtype=np.float64), 1e-12)
    seg_ids = np.asarray(seg_ids)
    n = floors.shape[0]
    if n == 0:
        return np.zeros(0)
    ceilings = np.maximum(ceilings, floors)

    def seg_sum(values: np.ndarray) -> np.ndarray:
        return np.bincount(seg_ids, weights=values, minlength=n_segs)

    total_floor = seg_sum(floors)
    # Degenerate segments: floors alone exceed capacity -> pro-rata floors.
    degenerate = total_floor >= capacity
    target = np.minimum(capacity, seg_sum(ceilings))

    # Per-segment bisection bounds, advanced in lockstep.
    ratio = ceilings / weights
    hi = np.zeros(n_segs)
    np.maximum.at(hi, seg_ids, ratio)
    hi = hi + 1.0
    lo = np.zeros(n_segs)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        alloc = np.clip(weights * mid[seg_ids], floors, ceilings)
        under = seg_sum(alloc) < target
        lo = np.where(under, mid, lo)
        hi = np.where(under, hi, mid)
    out = np.clip(weights * hi[seg_ids], floors, ceilings)

    # Pro-rata residual correction among items not pinned at their ceiling.
    gap = target - seg_sum(out)
    room = (ceilings - out) > 1e-12
    w_room = weights * room
    w_room_sum = seg_sum(w_room)
    adjust = (gap > 1e-12) & (w_room_sum > 0.0)
    bump = np.where(adjust[seg_ids],
                    gap[seg_ids] * w_room / np.maximum(w_room_sum[seg_ids],
                                                       1e-300),
                    0.0)
    out = np.clip(out + bump, floors, ceilings)

    if degenerate.any():
        scale = capacity / np.maximum(total_floor, 1e-12)
        deg_items = degenerate[seg_ids]
        out = np.where(deg_items, floors * scale[seg_ids], out)
    return out


def divvy(capacity: float, vms: Sequence) -> dict[str, float]:
    """Compute per-VM entitlements on one host.

    floor   = min(reservation, limit)  (guaranteed even when idle)
    ceiling = clip(demand, reservation, limit)
    weight  = shares
    """
    if not vms:
        return {}
    floors = np.array([min(v.reservation, v.limit) for v in vms])
    ceilings = np.array([v.effective_demand for v in vms])
    weights = np.array([v.shares for v in vms])
    x = waterfill(capacity, floors, ceilings, weights)
    return {v.vm_id: float(xi) for v, xi in zip(vms, x)}


def deliver(capacity: float, vms: Sequence) -> dict[str, float]:
    """What each VM actually receives this tick (simulator host scheduler).

    Unlike entitlement, delivery never exceeds instantaneous demand: a
    reserved-but-idle VM does not burn cycles.
    """
    if not vms:
        return {}
    dem = np.array([min(v.demand, v.limit) for v in vms])
    floors = np.minimum(np.array([v.reservation for v in vms]), dem)
    weights = np.array([v.shares for v in vms])
    x = waterfill(capacity, floors, dem, weights)
    return {v.vm_id: float(xi) for v, xi in zip(vms, x)}
