"""Entitlement computation: reservation/limit/shares divvy.

A VM's entitlement is the capacity it *deserves* under contention: at least
its reservation, at most min(limit, demand), with slack divided in proportion
to shares (weighted max-min fairness / progressive filling, paper refs [23],
[24]).  The same water-filling primitive is used by the simulator's host
scheduler to decide what each VM actually receives each tick.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro import backend as backend_mod


def waterfill(capacity: float, floors: np.ndarray, ceilings: np.ndarray,
              weights: np.ndarray) -> np.ndarray:
    """Weighted max-min allocation.

    Finds ``x_i = clip(weights_i * level, floors_i, ceilings_i)`` such that
    ``sum(x) == min(capacity, sum(ceilings))`` (assuming
    ``sum(floors) <= capacity``; otherwise floors are granted pro-rata, which
    only arises transiently since reservations are admission-controlled).

    ``x(level)`` is piecewise-linear and nondecreasing, so bisection on the
    water level converges globally; a final pro-rata correction removes the
    residual tolerance so the allocation is exact to ~1e-9.
    """
    floors = np.asarray(floors, dtype=np.float64)
    ceilings = np.asarray(ceilings, dtype=np.float64)
    weights = np.maximum(np.asarray(weights, dtype=np.float64), 1e-12)
    n = floors.shape[0]
    if n == 0:
        return np.zeros(0)
    ceilings = np.maximum(ceilings, floors)
    total_floor = floors.sum()
    if total_floor >= capacity:
        # Degenerate: grant reservations pro-rata (cannot happen post
        # admission control, but keep the primitive total).
        return floors * (capacity / max(total_floor, 1e-12))
    target = min(capacity, ceilings.sum())

    def alloc_at(level: float) -> np.ndarray:
        return np.clip(weights * level, floors, ceilings)

    lo, hi = 0.0, float(np.max(ceilings / weights)) + 1.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if alloc_at(mid).sum() < target:
            lo = mid
        else:
            hi = mid
    out = alloc_at(hi)
    # Distribute the (tiny) residual among VMs not pinned at their ceiling.
    gap = target - out.sum()
    slack = ceilings - out
    room = slack > 1e-12
    if gap > 1e-12 and room.any():
        w = weights * room
        out = np.clip(out + gap * w / w.sum(), floors, ceilings)
    return out


def waterfill_core(be, capacity, floors, ceilings, weights, seg_ids,
                   n_segs: int, iters: int = 200):
    """Backend-neutral lockstep waterfill (the shape contract both the NumPy
    and JAX entry points share).

    Item ``i`` belongs to segment (host) ``seg_ids[i]`` with per-segment
    capacity ``capacity[s]``.  All segments bisect their water level in
    lockstep for a *fixed* ``iters`` trips (no data-dependent control flow,
    so the JAX backend can ``jit``/``vmap`` it), with per-segment sums via
    the backend's segment reduction.  Segment-wise the math is identical to
    the scalar :func:`waterfill` (same bounds, same bisection, same pro-rata
    residual correction), so per-host results agree to the correction
    tolerance.  Inputs must be pre-sanitized: float arrays, ``weights``
    bounded away from zero, ``seg_ids`` in ``[0, n_segs)``.
    """
    xp = be.xp
    ceilings = xp.maximum(ceilings, floors)

    total_floor = be.seg_sum(floors, seg_ids, n_segs)
    # Degenerate segments: floors alone exceed capacity -> pro-rata floors.
    degenerate = total_floor >= capacity
    target = xp.minimum(capacity, be.seg_sum(ceilings, seg_ids, n_segs))

    # Per-segment bisection bounds, advanced in lockstep.
    hi = be.seg_max(ceilings / weights, seg_ids, n_segs) + 1.0
    lo = xp.zeros_like(hi)

    def bisect(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        alloc = xp.clip(weights * mid[seg_ids], floors, ceilings)
        under = be.seg_sum(alloc, seg_ids, n_segs) < target
        return xp.where(under, mid, lo), xp.where(under, hi, mid)

    lo, hi = be.fori(iters, bisect, (lo, hi))
    out = xp.clip(weights * hi[seg_ids], floors, ceilings)

    # Pro-rata residual correction among items not pinned at their ceiling.
    gap = target - be.seg_sum(out, seg_ids, n_segs)
    room = (ceilings - out) > 1e-12
    w_room = weights * room
    w_room_sum = be.seg_sum(w_room, seg_ids, n_segs)
    adjust = (gap > 1e-12) & (w_room_sum > 0.0)
    bump = xp.where(adjust[seg_ids],
                    gap[seg_ids] * w_room / xp.maximum(w_room_sum[seg_ids],
                                                       1e-300),
                    0.0)
    out = xp.clip(out + bump, floors, ceilings)

    scale = capacity / xp.maximum(total_floor, 1e-12)
    return xp.where(degenerate[seg_ids], floors * scale[seg_ids], out)


def batched_waterfill(capacity: np.ndarray, floors: np.ndarray,
                      ceilings: np.ndarray, weights: np.ndarray,
                      seg_ids: np.ndarray, n_segs: int,
                      iters: int = 200) -> np.ndarray:
    """Weighted max-min allocation over many independent hosts at once.

    NumPy entry point of :func:`waterfill_core` (per-segment sums via
    ``np.bincount`` -- one array pass per iteration instead of a Python loop
    over hosts).
    """
    capacity = np.asarray(capacity, dtype=np.float64)
    floors = np.asarray(floors, dtype=np.float64)
    ceilings = np.asarray(ceilings, dtype=np.float64)
    weights = np.maximum(np.asarray(weights, dtype=np.float64), 1e-12)
    seg_ids = np.asarray(seg_ids)
    if floors.shape[0] == 0:
        return np.zeros(0)
    if backend_mod.pallas_enabled():
        # Executor lift: the NumPy caller (VectorSimulator delivery, the
        # object-plane balance adapter) reaches the segmented Pallas kernel
        # through the ragged CSR layout -- same item order per host, so the
        # per-host result matches the scalar primitive to the correction
        # tolerance.
        from repro.kernels.powercap.ops import pallas_waterfill_segmented
        return np.asarray(pallas_waterfill_segmented(
            capacity, floors, ceilings, weights, seg_ids, n_segs,
            iters=iters))
    return waterfill_core(backend_mod.NUMPY, capacity, floors, ceilings,
                          weights, seg_ids, n_segs, iters)


def waterfill_dense(xp, fori, capacity, floors, ceilings, weights,
                    iters: int = 200, active=None):
    """Dense-slot twin of :func:`waterfill_core`.

    Segments are the *leading* axes and items the trailing one: ``capacity``
    is ``(..., H)`` and the item columns ``(..., H, J)`` with ``J`` padded
    slots per segment (padding: zero floor/ceiling, tiny weight).  Per-
    segment sums become trailing-axis reductions, which avoids scatter-adds
    entirely -- on accelerators this is the fast path the batched sweep
    engine uses for both tick delivery and balance entitlements.  The math
    is identical to the segment form, so results agree to reduction-order
    rounding.

    ``active`` (same shape as ``floors``, optional) masks the live slots
    explicitly: inactive slots are forced to zero floor/ceiling and a tiny
    weight *inside* the primitive, so stale demand left in padded slots can
    never widen the bisection bracket or absorb entitlement -- callers that
    recycle slot storage (the batched engine's migration remaps) do not
    have to re-sanitize every column first.

    When the ``jax-pallas`` executor is active and ``xp`` is a JAX
    namespace, the math runs as the fused Pallas kernel
    (``repro.kernels.powercap``) instead of inline lax ops -- bit-identical
    off-TPU by construction (the kernel body calls
    :func:`waterfill_dense_math`).
    """
    if xp is not np and backend_mod.pallas_enabled():
        from repro.kernels.powercap.ops import pallas_waterfill_dense
        return pallas_waterfill_dense(capacity, floors, ceilings, weights,
                                      iters=iters, active=active)
    return waterfill_dense_math(xp, fori, capacity, floors, ceilings,
                                weights, iters, active)


def waterfill_dense_math(xp, fori, capacity, floors, ceilings, weights,
                         iters: int = 200, active=None):
    """The pure-array body of :func:`waterfill_dense` (no executor
    dispatch).  The Pallas kernel calls this exact function on its VMEM
    blocks, which is what makes the two executors bit-identical."""
    if active is not None:
        floors = xp.where(active, floors, 0.0)
        ceilings = xp.where(active, ceilings, 0.0)
        weights = xp.where(active, weights, 1e-12)
    ceilings = xp.maximum(ceilings, floors)
    total_floor = xp.sum(floors, axis=-1)
    degenerate = total_floor >= capacity
    target = xp.minimum(capacity, xp.sum(ceilings, axis=-1))

    hi = xp.max(ceilings / weights, axis=-1) + 1.0
    lo = xp.zeros_like(hi)

    def bisect(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        alloc = xp.clip(weights * mid[..., None], floors, ceilings)
        under = xp.sum(alloc, axis=-1) < target
        return xp.where(under, mid, lo), xp.where(under, hi, mid)

    lo, hi = fori(iters, bisect, (lo, hi))
    out = xp.clip(weights * hi[..., None], floors, ceilings)

    gap = target - xp.sum(out, axis=-1)
    room = (ceilings - out) > 1e-12
    w_room = weights * room
    w_room_sum = xp.sum(w_room, axis=-1)
    adjust = (gap > 1e-12) & (w_room_sum > 0.0)
    bump = xp.where(adjust[..., None],
                    gap[..., None] * w_room
                    / xp.maximum(w_room_sum, 1e-300)[..., None],
                    0.0)
    out = xp.clip(out + bump, floors, ceilings)

    scale = (capacity / xp.maximum(total_floor, 1e-12))[..., None]
    return xp.where(degenerate[..., None], floors * scale, out)


def jax_batched_waterfill(capacity, floors, ceilings, weights, seg_ids,
                          n_segs: int, iters: int = 200):
    """JAX twin of :func:`batched_waterfill` (same shape contract).

    Fixed-iteration bisection via ``lax.fori_loop`` and segment sums via
    ``jax.ops.segment_sum``, so the whole allocation is ``jit``-compilable
    and ``vmap``-batchable (``n_segs``/``iters`` must be static).  Used by
    the batched sweep engine (``repro.sim.batch``); numerically it tracks
    the NumPy primitive to reduction-order rounding (~1 ulp).
    """
    be = backend_mod.jax_backend()
    weights = be.xp.maximum(weights, 1e-12)
    if backend_mod.pallas_enabled():
        from repro.kernels.powercap.ops import pallas_waterfill_segmented
        return pallas_waterfill_segmented(capacity, floors, ceilings,
                                          weights, seg_ids, n_segs,
                                          iters=iters)
    return waterfill_core(be, capacity, floors, ceilings, weights, seg_ids,
                          n_segs, iters)


def divvy(capacity: float, vms: Sequence) -> dict[str, float]:
    """Compute per-VM entitlements on one host.

    floor   = min(reservation, limit)  (guaranteed even when idle)
    ceiling = clip(demand, reservation, limit)
    weight  = shares
    """
    if not vms:
        return {}
    floors = np.array([min(v.reservation, v.limit) for v in vms])
    ceilings = np.array([v.effective_demand for v in vms])
    weights = np.array([v.shares for v in vms])
    x = waterfill(capacity, floors, ceilings, weights)
    return {v.vm_id: float(xi) for v, xi in zip(vms, x)}


def deliver(capacity: float, vms: Sequence) -> dict[str, float]:
    """What each VM actually receives this tick (simulator host scheduler).

    Unlike entitlement, delivery never exceeds instantaneous demand: a
    reserved-but-idle VM does not burn cycles.
    """
    if not vms:
        return {}
    dem = np.array([min(v.demand, v.limit) for v in vms])
    floors = np.minimum(np.array([v.reservation for v in vms]), dem)
    weights = np.array([v.shares for v in vms])
    x = waterfill(capacity, floors, dem, weights)
    return {v.vm_id: float(xi) for v, xi in zip(vms, x)}
