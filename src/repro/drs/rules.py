"""Constraint rules: affinity / anti-affinity / VM-host placement rules.

The paper's motivating scenarios (Fig. 1a) hinge on business rules whose
correction requires migrations that static power caps can block.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class AffinityRule:
    """All listed VMs must share one host."""
    vm_ids: tuple

    def violations(self, snapshot) -> list[str]:
        hosts = {snapshot.vms[v].host_id for v in self.vm_ids
                 if snapshot.vms[v].powered_on}
        return [f"affinity{self.vm_ids}"] if len(hosts) > 1 else []


@dataclasses.dataclass(frozen=True)
class AntiAffinityRule:
    """No two listed VMs may share a host."""
    vm_ids: tuple

    def violations(self, snapshot) -> list[str]:
        placed = [snapshot.vms[v].host_id for v in self.vm_ids
                  if snapshot.vms[v].powered_on]
        return ([f"anti-affinity{self.vm_ids}"]
                if len(placed) != len(set(placed)) else [])


@dataclasses.dataclass(frozen=True)
class VMHostRule:
    """VM restricted to a set of hosts (e.g. storage visibility)."""
    vm_id: str
    allowed_hosts: frozenset

    def violations(self, snapshot) -> list[str]:
        vm = snapshot.vms[self.vm_id]
        if vm.powered_on and vm.host_id not in self.allowed_hosts:
            return [f"vm-host({self.vm_id})"]
        return []


def all_violations(snapshot) -> list[str]:
    out = []
    for rule in snapshot.rules:
        out.extend(rule.violations(snapshot))
    return out


def placement_allowed(snapshot, vm_id: str, host_id: str) -> bool:
    """Would placing ``vm_id`` on ``host_id`` respect every rule?"""
    for rule in snapshot.rules:
        if isinstance(rule, VMHostRule) and rule.vm_id == vm_id:
            if host_id not in rule.allowed_hosts:
                return False
        elif isinstance(rule, AntiAffinityRule) and vm_id in rule.vm_ids:
            for other in rule.vm_ids:
                if other != vm_id and snapshot.vms[other].host_id == host_id:
                    return False
        # Affinity rules are targets to *correct toward*; a move onto the
        # rule-mates' host is always allowed, a move away is checked by the
        # caller via all_violations on the what-if snapshot.
    return True
