"""Cluster snapshot datamodel.

DRS (and CloudPowerCap with it) operates on an internal snapshot of the
VM/host inventory, executes candidate actions in what-if mode on clones of the
snapshot, and finally emits recommendations.  This module is that datamodel.

Capacity unit is MHz throughout the simulator plane (paper convention).
"""

from __future__ import annotations

import copy
import dataclasses
import math
from typing import TYPE_CHECKING, Iterable, Optional

import numpy as np

if TYPE_CHECKING:  # annotation-only: avoids a repro.core import cycle
    from repro.core.power_model import HostPowerSpec


@dataclasses.dataclass
class VirtualMachine:
    """A VM (simulator plane) or job shard (data plane)."""

    vm_id: str
    vcpus: int = 1
    memory_mb: float = 8 * 1024
    # Resource controls (paper Sec. II-C).
    reservation: float = 0.0            # MHz, guaranteed
    limit: float = math.inf             # MHz, hard upper bound
    shares: Optional[float] = None      # default: 1000 per vCPU
    mem_reservation: float = 0.0        # MB
    # Current state.
    demand: float = 0.0                 # MHz the VM would consume uncontended
    mem_demand: float = 0.0             # MB
    host_id: Optional[str] = None
    powered_on: bool = True
    migratable: bool = True
    tags: frozenset = frozenset()

    def __post_init__(self) -> None:
        if self.shares is None:
            self.shares = 1000.0 * self.vcpus
        if self.limit < self.reservation:
            raise ValueError(f"{self.vm_id}: limit < reservation")

    @property
    def effective_demand(self) -> float:
        """Demand clamped into [reservation, limit].

        Entitlement never falls below the reservation (it is guaranteed even
        when idle, for admission-control purposes) and never exceeds the
        limit.
        """
        return float(np.clip(self.demand, self.reservation, self.limit))


@dataclasses.dataclass
class Host:
    host_id: str
    spec: "HostPowerSpec"
    power_cap: float                    # Watts; enforced by the baseboard
    powered_on: bool = True
    tags: frozenset = frozenset()

    @property
    def capped_capacity(self) -> float:
        """Eq. 3: raw capacity reachable at the current power cap."""
        if not self.powered_on:
            return 0.0
        return float(self.spec.capped_capacity(self.power_cap))

    @property
    def managed_capacity(self) -> float:
        """Eq. 4: capacity the resource manager may allocate."""
        if not self.powered_on:
            return 0.0
        return float(self.spec.managed_capacity(self.power_cap))

    @property
    def peak_managed_capacity(self) -> float:
        return float(self.spec.managed_capacity(self.spec.power_peak))

    @property
    def memory_mb(self) -> float:
        return self.spec.memory_mb if self.powered_on else 0.0


class ClusterSnapshot:
    """Hosts + VMs + the cluster power budget.

    All DRS/CPC algorithms treat the snapshot as mutable working state and
    clone it for what-if evaluation.
    """

    def __init__(self, hosts: Iterable[Host], vms: Iterable[VirtualMachine],
                 power_budget: float, rules: Optional[list] = None,
                 budget_tree=None):
        self.hosts: dict[str, Host] = {h.host_id: h for h in hosts}
        self.vms: dict[str, VirtualMachine] = {v.vm_id: v for v in vms}
        self.power_budget = float(power_budget)
        self.rules = list(rules or [])
        #: Optional ``repro.core.budget_tree.BudgetTree`` over the hosts in
        #: iteration order; ``None`` (or a trivial single-node tree) means
        #: the flat scalar budget.  Trees are immutable and shared across
        #: clones.
        self.budget_tree = budget_tree
        if budget_tree is not None and budget_tree.n_hosts != len(self.hosts):
            raise ValueError("budget tree host count != cluster host count")
        self._host_sums: Optional[dict] = None
        self._check_placements()

    # ------------------------------------------------------------------ util
    def _check_placements(self) -> None:
        for vm in self.vms.values():
            if vm.host_id is not None and vm.host_id not in self.hosts:
                raise ValueError(f"{vm.vm_id} placed on unknown host")

    def clone(self) -> "ClusterSnapshot":
        snap = ClusterSnapshot.__new__(ClusterSnapshot)
        snap.hosts = {k: copy.copy(h) for k, h in self.hosts.items()}
        snap.vms = {k: copy.copy(v) for k, v in self.vms.items()}
        snap.power_budget = self.power_budget
        snap.rules = list(self.rules)
        snap.budget_tree = self.budget_tree
        snap._host_sums = None
        return snap

    # ------------------------------------------------- per-host sum cache
    def _placement_sums(self) -> dict:
        """Cached per-host ``{cpu_reserved, mem_demand}`` rollups.

        Built lazily in one O(VMs) pass and maintained incrementally by
        :meth:`move_vm`, so the placement fit check costs O(1) per candidate
        instead of an O(VMs) rescan (which made a balancer pass O(V^2 * H)).
        Any mutation that bypasses ``move_vm`` (adding VMs, toggling VM power
        state, editing demands in place) must call
        :meth:`invalidate_host_sums`.
        """
        if self._host_sums is None:
            cpu = {hid: 0.0 for hid in self.hosts}
            mem = {hid: 0.0 for hid in self.hosts}
            for v in self.vms.values():
                if v.powered_on and v.host_id in cpu:
                    cpu[v.host_id] += v.reservation
                    mem[v.host_id] += v.mem_demand
            self._host_sums = {"cpu_reserved": cpu, "mem_demand": mem}
        return self._host_sums

    def invalidate_host_sums(self) -> None:
        self._host_sums = None

    def move_vm(self, vm_id: str, dest_host: Optional[str]) -> None:
        """Re-place a VM, keeping the per-host sum cache coherent.

        Every placement mutation in the manager/simulator plane goes through
        here; only scratch snapshots that never consult the cached sums may
        poke ``vm.host_id`` directly.
        """
        vm = self.vms[vm_id]
        if self._host_sums is not None and vm.powered_on:
            for key, val in (("cpu_reserved", vm.reservation),
                             ("mem_demand", vm.mem_demand)):
                col = self._host_sums[key]
                if vm.host_id in col:
                    col[vm.host_id] -= val
                if dest_host in col:
                    col[dest_host] += val
        vm.host_id = dest_host

    def as_arrays(self):
        """Struct-of-arrays view (``repro.drs.arrays.ArrayView``).

        Built fresh in one O(hosts + VMs) pass; it reflects the snapshot at
        call time and does not track later object mutations.  All
        scale-sensitive rollups (imbalance, bulk entitlements, DPM triggers)
        go through this view so they cost one vectorized pass instead of a
        Python loop per host.
        """
        from repro.drs.arrays import ArrayView  # local import, no cycle
        return ArrayView.from_snapshot(self)

    def powered_on_hosts(self) -> list[Host]:
        return [h for h in self.hosts.values() if h.powered_on]

    def vms_on(self, host_id: str) -> list[VirtualMachine]:
        return [v for v in self.vms.values()
                if v.host_id == host_id and v.powered_on]

    # ------------------------------------------------------- reservations
    def cpu_reserved(self, host_id: str) -> float:
        return sum(v.reservation for v in self.vms_on(host_id))

    def cached_cpu_reserved(self, host_id: str) -> float:
        """O(1) reserved-CPU sum for the placement fit check.

        Valid only while placement mutations go through :meth:`move_vm`
        (the manager's what-if flow); code that edits the inventory directly
        must use :meth:`cpu_reserved` or :meth:`invalidate_host_sums`.
        """
        return self._placement_sums()["cpu_reserved"].get(host_id, 0.0)

    def mem_demand_on(self, host_id: str) -> float:
        """O(1) sum of resident VMs' memory demand (the fit-check column)."""
        return self._placement_sums()["mem_demand"].get(host_id, 0.0)

    def mem_used(self, host_id: str) -> float:
        return sum(v.memory_mb for v in self.vms_on(host_id))

    def mem_reserved(self, host_id: str) -> float:
        return sum(v.mem_reservation for v in self.vms_on(host_id))

    def reserved_power_cap(self, host_id: str) -> float:
        """Minimum power cap supporting the reservations of resident VMs.

        This is the per-host floor below which a cap change would violate
        admission-controlled guarantees (paper Sec. IV-B: `GetFlexiblePower`
        clones the snapshot with every host at this floor).
        """
        host = self.hosts[host_id]
        if not host.powered_on:
            return 0.0
        return float(host.spec.cap_for_managed_capacity(
            self.cpu_reserved(host_id)))

    def total_allocated_power(self) -> float:
        return sum(h.power_cap for h in self.hosts.values() if h.powered_on)

    def unreserved_power_budget(self) -> float:
        """Budget minus the power needed for running VMs' reservations."""
        av = self.as_arrays()
        return self.power_budget - float(
            av.reserved_power_cap()[av.host_on].sum())

    def unallocated_power_budget(self) -> float:
        """Budget not currently assigned to any powered-on host's cap."""
        return self.power_budget - self.total_allocated_power()

    # ------------------------------------------------------- entitlements
    def host_entitlements(self, host_id: str) -> dict[str, float]:
        from repro.drs.entitlement import divvy  # local import, no cycle
        host = self.hosts[host_id]
        return divvy(host.managed_capacity, self.vms_on(host_id))

    def normalized_entitlement(self, host_id: str) -> float:
        """N_h = sum of VM entitlements / host managed capacity.

        Routed through the array view so the scalar and bulk definitions
        cannot diverge; bulk consumers should use ``as_arrays()`` directly.
        """
        av = self.as_arrays()
        return float(av.normalized_entitlements()[av.host_index[host_id]])

    def imbalance(self) -> float:
        """DRS imbalance metric: stddev of normalized entitlements.

        Computed through the array view: one batched waterfill over every
        host at once rather than a divvy call per host.
        """
        return self.as_arrays().imbalance()

    def host_cpu_utilization(self, host_id: str) -> float:
        host = self.hosts[host_id]
        cap = host.managed_capacity
        if cap <= 0:
            return 0.0
        demand = sum(v.effective_demand for v in self.vms_on(host_id))
        return demand / cap

    def host_mem_utilization(self, host_id: str) -> float:
        """Active-memory utilization (demand-based, ESX-style)."""
        host = self.hosts[host_id]
        if not host.powered_on or host.memory_mb <= 0:
            return 0.0
        demand = sum(v.mem_demand for v in self.vms_on(host_id))
        return demand / host.memory_mb

    # -------------------------------------------------------------- checks
    def reservations_respected(self, host_id: str) -> bool:
        """Admission-control invariant: CPU and *memory reservations* fit.

        Configured memory may be overcommitted (ESX semantics); demand-based
        memory pressure is handled by placement fit checks and DPM, not here.
        """
        host = self.hosts[host_id]
        return (self.cpu_reserved(host_id) <= host.managed_capacity + 1e-6
                and self.mem_reserved(host_id) <= host.memory_mb + 1e-6)

    def budget_respected(self) -> bool:
        return self.total_allocated_power() <= self.power_budget + 1e-6

    def effective_tree(self):
        """The budget tree when it actually constrains beyond the scalar
        budget; ``None`` for flat/trivial configurations (engines skip the
        tree code path entirely, keeping them bit-identical to the scalar
        protocol)."""
        tree = self.budget_tree
        if tree is None or tree.is_trivial(self.power_budget):
            return None
        return tree

    def tree_respected(self, atol: float = 1e-6) -> bool:
        """Every budget-tree node's subtree cap-sum within its limit."""
        tree = self.effective_tree()
        if tree is None:
            return True
        av = self.as_arrays()
        return tree.max_overshoot(av.power_cap, av.host_on) <= atol

    def validate(self) -> None:
        assert self.budget_respected(), (
            f"power budget violated: {self.total_allocated_power():.1f} W "
            f"allocated > {self.power_budget:.1f} W budget")
        assert self.tree_respected(), (
            "budget tree violated: a node's subtree caps exceed its limit")
        for h in self.powered_on_hosts():
            assert self.reservations_respected(h.host_id), (
                f"{h.host_id}: reservations exceed managed capacity")
