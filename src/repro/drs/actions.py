"""Recommendation/action model.

DRS invocations emit zero or more actions; CloudPowerCap's cap changes are
woven into the same list with explicit prerequisite edges so that execution
order preserves safety invariants (cap *decreases* precede the increases they
fund; cap increases that enable a migration precede that migration; host
power-on waits for its funding cap changes; etc.).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

_counter = itertools.count()


@dataclasses.dataclass
class Action:
    kind: str                       # set_power_cap | migrate | power_on | power_off
    target: str                     # host_id or vm_id
    value: Optional[float] = None   # Watts for set_power_cap
    dest: Optional[str] = None      # target host for migrate
    prereqs: tuple = ()             # action ids that must complete first
    action_id: int = dataclasses.field(default_factory=lambda: next(_counter))
    reason: str = ""

    def __repr__(self) -> str:  # compact, for logs
        extra = f"->{self.dest}" if self.dest else (
            f"={self.value:.1f}W" if self.value is not None else "")
        dep = f" after{list(self.prereqs)}" if self.prereqs else ""
        return f"<{self.action_id}:{self.kind} {self.target}{extra}{dep}>"


def set_power_cap(host_id: str, watts: float, prereqs=(), reason="") -> Action:
    return Action("set_power_cap", host_id, value=watts,
                  prereqs=tuple(prereqs), reason=reason)


def migrate(vm_id: str, dest_host: str, prereqs=(), reason="") -> Action:
    return Action("migrate", vm_id, dest=dest_host, prereqs=tuple(prereqs),
                  reason=reason)


def power_on(host_id: str, prereqs=(), reason="") -> Action:
    return Action("power_on", host_id, prereqs=tuple(prereqs), reason=reason)


def power_off(host_id: str, prereqs=(), reason="") -> Action:
    return Action("power_off", host_id, prereqs=tuple(prereqs), reason=reason)


def order_cap_changes(snapshot, new_caps: dict[str, float], reason: str = ""
                      ) -> list[Action]:
    """Emit SetPowerCap actions, decreases first, increases depending on them.

    This ordering keeps the instantaneous sum of caps within the budget at
    every point during execution (the paper's prerequisite discipline,
    Sec. III-B / IV-B).
    """
    decreases, increases = [], []
    for host_id, watts in new_caps.items():
        cur = snapshot.hosts[host_id].power_cap
        if watts < cur - 1e-9:
            decreases.append(set_power_cap(host_id, watts, reason=reason))
        elif watts > cur + 1e-9:
            increases.append((host_id, watts))
    dec_ids = tuple(a.action_id for a in decreases)
    inc_actions = [set_power_cap(h, w, prereqs=dec_ids, reason=reason)
                   for h, w in increases]
    return decreases + inc_actions
