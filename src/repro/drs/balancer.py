"""Greedy hill-climbing entitlement balancing (paper Sec. IV-A).

DRS minimizes the stddev of hosts' normalized entitlements by migrating VMs,
one greedy move at a time, each move passing a risk-cost-benefit filter.
CloudPowerCap's BalancePowerCap (repro.core.balance) runs *before* this and
removes as much imbalance as Watts can; whatever remains is fixed here by
actual migrations.

The decision procedure is the shared kernel
``repro.core.kernels.balance_migrations`` (argmax-scored candidate moves on
the dense slot layout, rule-aware admission, closed-form imbalance scoring);
this module is the object-plane adapter over
:class:`repro.core.migration_core.MigrationCore`, so the object, vector,
and batched engines pick identical moves.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class BalancerConfig:
    imbalance_threshold: float = 0.05   # target stddev of N_h
    max_moves: int = 16                 # per invocation (paper: 5-min budget)
    min_goodness: float = 1e-3          # minimum imbalance reduction per move
    # Risk-cost-benefit: a move must reduce imbalance by at least
    # cost_per_gb * mem_demand_gb (stddev units per GB moved) to be worth the
    # vMotion overhead.  Calibrated against the simulator's vMotion model.
    cost_per_gb: float = 2e-4
    # The benefit side of risk-cost-benefit: migrations only pay off when
    # some host is actually straining against its capacity (otherwise every
    # VM already receives its entitlement and the imbalance is cosmetic).
    contention_threshold: float = 0.9

    def params(self):
        """The kernel layer's static-config twin of this dataclass."""
        from repro.core import kernels  # local import, no cycle
        return kernels.MigrationParams(
            imbalance_threshold=self.imbalance_threshold,
            max_moves=self.max_moves,
            min_goodness=self.min_goodness,
            cost_per_gb=self.cost_per_gb,
            contention_threshold=self.contention_threshold)


def balance(snapshot: ClusterSnapshot,
            config: Optional[BalancerConfig] = None,
            budget=None) -> list[tuple[str, str]]:
    """Mutates ``snapshot`` (what-if) and returns the chosen moves.

    ``budget`` is the invocation's shared ``LaunchBudget`` when migration
    launches are gated (``None`` = ungated); correction launches earlier
    in the invocation count against the same ledger."""
    config = config or BalancerConfig()
    if config.max_moves <= 0:
        return []
    from repro.core.migration_core import MigrationCore  # local: no cycle
    return MigrationCore(config.params()).balance(snapshot, budget)
