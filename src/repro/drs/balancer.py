"""Greedy hill-climbing entitlement balancing (paper Sec. IV-A).

DRS minimizes the stddev of hosts' normalized entitlements by migrating VMs,
one greedy move at a time, each move passing a risk-cost-benefit filter.
CloudPowerCap's BalancePowerCap (repro.core.balance) runs *before* this and
removes as much imbalance as Watts can; whatever remains is fixed here by
actual migrations.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.drs import placement
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class BalancerConfig:
    imbalance_threshold: float = 0.05   # target stddev of N_h
    max_moves: int = 16                 # per invocation (paper: 5-min budget)
    min_goodness: float = 1e-3          # minimum imbalance reduction per move
    # Risk-cost-benefit: a move must reduce imbalance by at least
    # cost_per_gb * mem_demand_gb (stddev units per GB moved) to be worth the
    # vMotion overhead.  Calibrated against the simulator's vMotion model.
    cost_per_gb: float = 2e-4
    # The benefit side of risk-cost-benefit: migrations only pay off when
    # some host is actually straining against its capacity (otherwise every
    # VM already receives its entitlement and the imbalance is cosmetic).
    contention_threshold: float = 0.9


def _imbalance(snapshot: ClusterSnapshot) -> float:
    return snapshot.imbalance()


def _normalized_entitlement_map(snapshot: ClusterSnapshot) -> dict[str, float]:
    """N_h for every powered-on host in one batched-waterfill pass."""
    av = snapshot.as_arrays()
    ns = av.normalized_entitlements()
    return {hid: float(ns[i]) for i, hid in enumerate(av.host_ids)
            if av.host_on[i]}


def _candidate_moves(snapshot: ClusterSnapshot):
    """(vm, dest) pairs from above-average-N hosts to below-average hosts."""
    on = snapshot.powered_on_hosts()
    ns = _normalized_entitlement_map(snapshot)
    mean_n = float(np.mean(list(ns.values()))) if ns else 0.0
    donors = [h for h in on if ns[h.host_id] > mean_n]
    receivers = [h for h in on if ns[h.host_id] <= mean_n]
    for donor in donors:
        for vm in snapshot.vms_on(donor.host_id):
            if not vm.migratable:
                continue
            for recv in receivers:
                if recv.host_id == donor.host_id:
                    continue
                if placement.fits(snapshot, vm.vm_id, recv.host_id):
                    yield vm.vm_id, recv.host_id


def balance(snapshot: ClusterSnapshot,
            config: Optional[BalancerConfig] = None
            ) -> list[tuple[str, str]]:
    """Mutates ``snapshot`` (what-if) and returns the chosen moves."""
    config = config or BalancerConfig()
    moves: list[tuple[str, str]] = []
    ns = _normalized_entitlement_map(snapshot)
    if not ns or max(ns.values()) <= config.contention_threshold:
        return moves  # no host strained: migration cost outweighs benefit
    cur = _imbalance(snapshot)
    while cur > config.imbalance_threshold and len(moves) < config.max_moves:
        best: Optional[tuple[str, str]] = None
        best_after = cur
        for vm_id, dest in _candidate_moves(snapshot):
            src = snapshot.vms[vm_id].host_id
            snapshot.vms[vm_id].host_id = dest
            after = _imbalance(snapshot)
            snapshot.vms[vm_id].host_id = src
            # Risk-cost-benefit filter: improvement must beat the migration
            # cost proxy (scaled by the VM's in-memory state to move).
            gain = cur - after
            cost = config.min_goodness + config.cost_per_gb * (
                snapshot.vms[vm_id].mem_demand / 1024.0)
            if gain > cost and after < best_after:
                best, best_after = (vm_id, dest), after
        if best is None:
            break
        snapshot.vms[best[0]].host_id = best[1]
        moves.append(best)
        cur = best_after
    return moves
