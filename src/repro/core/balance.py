"""Algorithm 2: BalancePowerCap -- powercap-based entitlement balancing.

Progressive filling toward max-min fairness (paper ref [24]): repeatedly move
capacity (Watts) from the host with the lowest normalized entitlement to the
host with the highest, until the cluster imbalance metric (stddev of N_h)
drops below threshold or physical cap ranges bind.  A cap write costs <1 ms;
a vMotion costs seconds of copying plus CPU overhead on both hosts -- so this
runs *before* DRS's migration-based balancer and usually replaces it.

Safety invariants maintained per transfer:
  * donor capacity never drops below its VMs' reservations (admission),
  * recipient capacity never exceeds its physical peak,
  * the sum of caps never exceeds the cluster budget (transfers conserve it).

The loop itself is the pure-array kernel ``repro.core.kernels.balance_caps``,
shared with the jit-compiled batched sweep engine (``repro.sim.batch``);
this module is the object-plane adapter: snapshot -> columns -> kernel ->
snapshot, placements frozen for the loop's duration so the struct-of-arrays
view is built once and only the ``power_cap`` column evolves.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import backend as backend_mod
from repro.backend import NUMPY
from repro.core import kernels
from repro.drs import actions as act
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class BalanceConfig:
    # Cap writes cost <1 ms, so powercap balancing can afford a much tighter
    # target than migration balancing (saturated hosts pin N_h at 1.0, so a
    # loose threshold would strand them short of their demand).
    imbalance_threshold: float = 0.01
    max_iters: int = 64
    min_transfer: float = 1e-3      # capacity units; below this we stop

    def params(self) -> kernels.BalanceParams:
        return kernels.BalanceParams(
            imbalance_threshold=self.imbalance_threshold,
            max_iters=self.max_iters,
            min_transfer=self.min_transfer)


def balance_power_cap(snapshot: ClusterSnapshot,
                      config: BalanceConfig | None = None
                      ) -> tuple[ClusterSnapshot, bool]:
    """Returns (what-if snapshot with rebalanced caps, did-anything flag)."""
    config = config or BalanceConfig()
    f = snapshot.clone()
    av = f.as_arrays()
    if int(av.host_on.sum()) < 2:
        # Nothing to balance between: skip the kernel (and its initial
        # entitlement waterfill) entirely.
        return f, False
    hosts = av.host_cols()
    floors, ceils, weights, seg = av.waterfill_cols()

    if backend_mod.pallas_enabled():
        # Executor lift: rebuild the ragged VM lists as the dense slot
        # layout and run the fused Pallas loop on the JAX plane.  Same
        # protocol, same per-host waterfill math; entitlements differ from
        # the segment form only by reduction-order rounding.
        new_caps, did_balance = _balance_caps_pallas(
            f, av, hosts, floors, ceils, weights,
            snapshot.power_budget, config)
    else:
        def ents_at(caps):
            return kernels.entitlement_sums(NUMPY, hosts, caps,
                                            floors[None], ceils[None],
                                            weights[None], seg[None])

        caps, did = kernels.balance_caps(
            NUMPY, hosts, av.power_cap[None].copy(), ents_at,
            av.cpu_reserved()[None],
            np.asarray([snapshot.power_budget]),
            np.asarray([True]),
            config.params())
        new_caps, did_balance = caps[0], bool(did[0])
    tree = snapshot.effective_tree()
    if tree is not None:
        # Hierarchical budgets: transfers conserve the cluster total but
        # may still push a row past its limit; scale the balanced caps
        # back under every node, protecting the reserved floors.
        floor_caps = kernels.reserved_floor_caps(
            np, hosts, av.cpu_reserved()[None])[0]
        new_caps = kernels.tree_project_caps(
            np, tree.cols(), av.host_on[None], new_caps[None],
            floor_caps[None])[0]
    av.write_caps(f, new_caps)
    if did_balance:
        f.validate()
    return f, did_balance


def _balance_caps_pallas(snapshot, av, hosts, floors, ceils, weights,
                         budget: float, config: BalanceConfig):
    """Run the balance loop through the fused Pallas kernel (``S == 1``).

    Packs the active VMs into the dense ``(1, H, J)`` slot layout (the same
    assignment the batched engine uses, so slot-ordered tie-breaks agree)
    and hands ``kernels.balance_caps`` the ``DenseCols`` bundle; the
    ``jax-pallas`` dispatch takes it from there.  Returns
    ``(caps (H,), did)`` on the NumPy plane.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.drs.arrays import dense_slot_assignment
    from repro.drs.entitlement import waterfill_dense

    H = av.n_hosts
    _, order, hj, slot, counts = dense_slot_assignment(snapshot, H)
    J = max(int(counts.max()) if counts.size else 0, 1)
    fl = np.zeros((1, H, J))
    ce = np.zeros((1, H, J))
    w = np.full((1, H, J), 1e-12)
    act = np.zeros((1, H, J), dtype=bool)
    fl[0, hj, slot] = floors[order]
    ce[0, hj, slot] = ceils[order]
    w[0, hj, slot] = weights[order]
    act[0, hj, slot] = True

    be = backend_mod.jax_backend()
    with enable_x64():
        hosts_j = kernels.HostCols(*(jnp.asarray(c) for c in hosts))
        dense = kernels.DenseCols(jnp.asarray(fl), jnp.asarray(ce),
                                  jnp.asarray(w), jnp.asarray(act))

        def ents_at(c):
            managed = kernels.managed_capacity(jnp, hosts_j, c)
            alloc = waterfill_dense(jnp, be.fori, managed, dense.floors,
                                    dense.ceils, dense.weights,
                                    active=dense.active)
            return jnp.sum(alloc, axis=-1)

        caps, did = kernels.balance_caps(
            be, hosts_j, jnp.asarray(av.power_cap[None]), ents_at,
            jnp.asarray(av.cpu_reserved()[None]),
            jnp.asarray([budget]), jnp.asarray([True]),
            config.params(), dense=dense)
        return np.asarray(caps)[0], bool(np.asarray(did)[0])


def emit_actions(before: ClusterSnapshot, after: ClusterSnapshot
                 ) -> list[act.Action]:
    """Cap-decrease actions are prerequisites of the increases they fund."""
    new_caps = {h.host_id: h.power_cap for h in after.powered_on_hosts()}
    return act.order_cap_changes(before, new_caps, reason="powercap-balance")
