"""Algorithm 2: BalancePowerCap -- powercap-based entitlement balancing.

Progressive filling toward max-min fairness (paper ref [24]): repeatedly move
capacity (Watts) from the host with the lowest normalized entitlement to the
host with the highest, until the cluster imbalance metric (stddev of N_h)
drops below threshold or physical cap ranges bind.  A cap write costs <1 ms;
a vMotion costs seconds of copying plus CPU overhead on both hosts -- so this
runs *before* DRS's migration-based balancer and usually replaces it.

Safety invariants maintained per transfer:
  * donor capacity never drops below its VMs' reservations (admission),
  * recipient capacity never exceeds its physical peak,
  * the sum of caps never exceeds the cluster budget (transfers conserve it).

The loop itself is the pure-array kernel ``repro.core.kernels.balance_caps``,
shared with the jit-compiled batched sweep engine (``repro.sim.batch``);
this module is the object-plane adapter: snapshot -> columns -> kernel ->
snapshot, placements frozen for the loop's duration so the struct-of-arrays
view is built once and only the ``power_cap`` column evolves.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.backend import NUMPY
from repro.core import kernels
from repro.drs import actions as act
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class BalanceConfig:
    # Cap writes cost <1 ms, so powercap balancing can afford a much tighter
    # target than migration balancing (saturated hosts pin N_h at 1.0, so a
    # loose threshold would strand them short of their demand).
    imbalance_threshold: float = 0.01
    max_iters: int = 64
    min_transfer: float = 1e-3      # capacity units; below this we stop

    def params(self) -> kernels.BalanceParams:
        return kernels.BalanceParams(
            imbalance_threshold=self.imbalance_threshold,
            max_iters=self.max_iters,
            min_transfer=self.min_transfer)


def balance_power_cap(snapshot: ClusterSnapshot,
                      config: BalanceConfig | None = None
                      ) -> tuple[ClusterSnapshot, bool]:
    """Returns (what-if snapshot with rebalanced caps, did-anything flag)."""
    config = config or BalanceConfig()
    f = snapshot.clone()
    av = f.as_arrays()
    if int(av.host_on.sum()) < 2:
        # Nothing to balance between: skip the kernel (and its initial
        # entitlement waterfill) entirely.
        return f, False
    hosts = av.host_cols()
    floors, ceils, weights, seg = av.waterfill_cols()

    def ents_at(caps):
        return kernels.entitlement_sums(NUMPY, hosts, caps, floors[None],
                                        ceils[None], weights[None],
                                        seg[None])

    caps, did = kernels.balance_caps(
        NUMPY, hosts, av.power_cap[None].copy(), ents_at,
        av.cpu_reserved()[None],
        np.asarray([snapshot.power_budget]),
        np.asarray([True]),
        config.params())
    did_balance = bool(did[0])
    av.write_caps(f, caps[0])
    if did_balance:
        f.validate()
    return f, did_balance


def emit_actions(before: ClusterSnapshot, after: ClusterSnapshot
                 ) -> list[act.Action]:
    """Cap-decrease actions are prerequisites of the increases they fund."""
    new_caps = {h.host_id: h.power_cap for h in after.powered_on_hosts()}
    return act.order_cap_changes(before, new_caps, reason="powercap-balance")
