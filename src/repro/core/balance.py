"""Algorithm 2: BalancePowerCap -- powercap-based entitlement balancing.

Progressive filling toward max-min fairness (paper ref [24]): repeatedly move
capacity (Watts) from the host with the lowest normalized entitlement to the
host with the highest, until the cluster imbalance metric (stddev of N_h)
drops below threshold or physical cap ranges bind.  A cap write costs <1 ms;
a vMotion costs seconds of copying plus CPU overhead on both hosts -- so this
runs *before* DRS's migration-based balancer and usually replaces it.

Safety invariants maintained per transfer:
  * donor capacity never drops below its VMs' reservations (admission),
  * recipient capacity never exceeds its physical peak,
  * the sum of caps never exceeds the cluster budget (transfers conserve it).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.drs import actions as act
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class BalanceConfig:
    # Cap writes cost <1 ms, so powercap balancing can afford a much tighter
    # target than migration balancing (saturated hosts pin N_h at 1.0, so a
    # loose threshold would strand them short of their demand).
    imbalance_threshold: float = 0.01
    max_iters: int = 64
    min_transfer: float = 1e-3      # capacity units; below this we stop


def balance_power_cap(snapshot: ClusterSnapshot,
                      config: BalanceConfig | None = None
                      ) -> tuple[ClusterSnapshot, bool]:
    """Returns (what-if snapshot with rebalanced caps, did-anything flag).

    The whole loop runs in array space: placements are frozen for its
    duration, so the struct-of-arrays view is built once and only the
    ``power_cap`` column evolves.  Each round costs one batched-waterfill
    pass over every VM plus O(hosts) arithmetic, independent of cluster
    size in Python-interpreter terms.
    """
    config = config or BalanceConfig()
    f = snapshot.clone()
    did_balance = False

    av = f.as_arrays()
    on = av.host_on
    caps = av.power_cap.copy()
    if int(on.sum()) >= 2:
        cpu_res = av.cpu_reserved()
        peak_managed = av.peak_managed_capacity()
        managed = av.managed_capacity(caps)
        ents = av.entitlement_sums(caps)
        ns = np.where(managed > 0.0, ents / np.maximum(managed, 1e-300), 0.0)
        for _ in range(config.max_iters):
            imbalance = float(ns[on].std())
            if imbalance <= config.imbalance_threshold:
                break
            total_cap = float(managed[on].sum())
            if total_cap <= 0:
                break
            # Cluster-average normalized entitlement: the water level every
            # host would sit at if capacity were perfectly divisible.
            n_avg = float(ents[on].sum()) / total_cap
            if n_avg <= 1e-12:
                break

            # Batched progressive filling: every host above the average
            # level is a recipient (bounded by its physical peak), every
            # host below is a donor (bounded by the average level and by its
            # reservations).  One batch round moves the same total capacity
            # as many pairwise rounds of the paper's Algorithm 2 and
            # converges to the same max-min fixed point.
            cbar = ents / n_avg        # capacity at which N_h == n_avg
            recipients = on & (ns > n_avg)
            donors = on & (ns < n_avg)
            need = np.where(
                recipients,
                np.maximum(np.minimum(peak_managed, cbar) - managed, 0.0),
                0.0)
            avail = np.where(
                donors,
                np.maximum(managed - np.maximum(cbar, cpu_res), 0.0),
                0.0)
            total_need, total_avail = float(need.sum()), float(avail.sum())
            transfer = min(total_need, total_avail)
            if transfer <= config.min_transfer:
                break  # powercap range exhausted -> DRS migration handles it

            prev_caps = caps.copy()
            grow = recipients & (need > 0.0)
            caps = np.where(grow, av.cap_for_managed_capacity(
                managed + transfer * need / max(total_need, 1e-300)), caps)
            shrink = donors & (avail > 0.0)
            caps = np.where(shrink, av.cap_for_managed_capacity(
                managed - transfer * avail / max(total_avail, 1e-300)), caps)
            # Watts conservation under heterogeneous specs: trim recipients
            # if the budget would be exceeded (linear maps conserve exactly
            # for homogeneous specs; this is a safety net).
            over = float(caps[on].sum()) - snapshot.power_budget
            if over > 1e-6:
                caps = np.where(
                    recipients,
                    np.maximum(caps - over / int(recipients.sum()),
                               av.power_idle),
                    caps)
            managed = av.managed_capacity(caps)
            ents = av.entitlement_sums(caps)
            ns = np.where(managed > 0.0,
                          ents / np.maximum(managed, 1e-300), 0.0)
            # Heterogeneous Watts<->capacity maps (plus the trim above) can
            # make a round non-improving near convergence: revert it and
            # stop rather than oscillate.
            if float(ns[on].std()) > imbalance + 1e-12:
                caps = prev_caps
                break
            did_balance = True

    av.write_caps(f, caps)
    if did_balance:
        f.validate()
    return f, did_balance


def emit_actions(before: ClusterSnapshot, after: ClusterSnapshot
                 ) -> list[act.Action]:
    """Cap-decrease actions are prerequisites of the increases they fund."""
    new_caps = {h.host_id: h.power_cap for h in after.powered_on_hosts()}
    return act.order_cap_changes(before, new_caps, reason="powercap-balance")
