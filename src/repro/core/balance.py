"""Algorithm 2: BalancePowerCap -- powercap-based entitlement balancing.

Progressive filling toward max-min fairness (paper ref [24]): repeatedly move
capacity (Watts) from the host with the lowest normalized entitlement to the
host with the highest, until the cluster imbalance metric (stddev of N_h)
drops below threshold or physical cap ranges bind.  A cap write costs <1 ms;
a vMotion costs seconds of copying plus CPU overhead on both hosts -- so this
runs *before* DRS's migration-based balancer and usually replaces it.

Safety invariants maintained per transfer:
  * donor capacity never drops below its VMs' reservations (admission),
  * recipient capacity never exceeds its physical peak,
  * the sum of caps never exceeds the cluster budget (transfers conserve it).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.drs import actions as act
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class BalanceConfig:
    # Cap writes cost <1 ms, so powercap balancing can afford a much tighter
    # target than migration balancing (saturated hosts pin N_h at 1.0, so a
    # loose threshold would strand them short of their demand).
    imbalance_threshold: float = 0.01
    max_iters: int = 64
    min_transfer: float = 1e-3      # capacity units; below this we stop


def _normalized_entitlements(snapshot: ClusterSnapshot) -> dict[str, float]:
    return {h.host_id: snapshot.normalized_entitlement(h.host_id)
            for h in snapshot.powered_on_hosts()}


def balance_power_cap(snapshot: ClusterSnapshot,
                      config: BalanceConfig | None = None
                      ) -> tuple[ClusterSnapshot, bool]:
    """Returns (what-if snapshot with rebalanced caps, did-anything flag)."""
    config = config or BalanceConfig()
    f = snapshot.clone()
    did_balance = False

    for _ in range(config.max_iters):
        hosts_on = f.powered_on_hosts()
        ns = _normalized_entitlements(f)
        if len(ns) < 2:
            break
        imbalance = float(np.std(list(ns.values())))
        if imbalance <= config.imbalance_threshold:
            break
        # Cluster-average normalized entitlement: the water level every host
        # would sit at if capacity were perfectly divisible.
        ents = {h.host_id: sum(f.host_entitlements(h.host_id).values())
                for h in hosts_on}
        total_cap = sum(h.managed_capacity for h in hosts_on)
        if total_cap <= 0:
            break
        n_avg = sum(ents.values()) / total_cap
        if n_avg <= 1e-12:
            break

        # Batched progressive filling: every host above the average level is
        # a recipient (bounded by its physical peak), every host below is a
        # donor (bounded by the average level and by its reservations).  One
        # batch round moves the same total capacity as many pairwise rounds
        # of the paper's Algorithm 2 and converges to the same max-min fixed
        # point.
        need, avail = {}, {}
        for h in hosts_on:
            hid = h.host_id
            cbar = ents[hid] / n_avg   # capacity at which N_h == n_avg
            cur = h.managed_capacity
            if ns[hid] > n_avg:
                need[hid] = max(min(h.peak_managed_capacity, cbar) - cur, 0.0)
            elif ns[hid] < n_avg:
                donor_floor = max(cbar, f.cpu_reserved(hid))
                avail[hid] = max(cur - donor_floor, 0.0)
        total_need, total_avail = sum(need.values()), sum(avail.values())
        transfer = min(total_need, total_avail)
        if transfer <= config.min_transfer:
            break  # powercap range exhausted -> DRS migration handles rest

        prev_caps = {h.host_id: h.power_cap for h in f.powered_on_hosts()}
        for hid, n in need.items():
            if n <= 0.0:
                continue
            h = f.hosts[hid]
            h.power_cap = float(h.spec.cap_for_managed_capacity(
                h.managed_capacity + transfer * n / total_need))
        for hid, a in avail.items():
            if a <= 0.0:
                continue
            h = f.hosts[hid]
            h.power_cap = float(h.spec.cap_for_managed_capacity(
                h.managed_capacity - transfer * a / total_avail))
        # Watts conservation under heterogeneous specs: trim recipients if
        # the budget would be exceeded (linear maps conserve exactly for
        # homogeneous specs; this is a safety net).
        over = sum(h.power_cap for h in f.powered_on_hosts()
                   ) - snapshot.power_budget
        if over > 1e-6:
            for hid in need:
                h = f.hosts[hid]
                h.power_cap = max(h.power_cap - over / len(need),
                                  h.spec.power_idle)
        # Heterogeneous Watts<->capacity maps (plus the trim above) can make
        # a round non-improving near convergence: revert it and stop rather
        # than oscillate.
        if f.imbalance() > imbalance + 1e-12:
            for hid, cap in prev_caps.items():
                f.hosts[hid].power_cap = cap
            break
        did_balance = True

    if did_balance:
        f.validate()
    return f, did_balance


def emit_actions(before: ClusterSnapshot, after: ClusterSnapshot
                 ) -> list[act.Action]:
    """Cap-decrease actions are prerequisites of the increases they fund."""
    new_caps = {h.host_id: h.power_cap for h in after.powered_on_hosts()}
    return act.order_cap_changes(before, new_caps, reason="powercap-balance")
