"""Hierarchical power-budget trees.

CloudPowerCap's original protocol manages a single scalar rack budget.  A
datacenter deployment stacks budgets: host -> rack -> row -> room, each
level with its own breaker/contract limit, and every watt a host receives
must fit under *every* limit on its root path.  :class:`BudgetTree` is the
dense description of that hierarchy shared by all three engines:

  * ``parent``    -- ``(n_nodes,)`` int parent index, root at index 0 with
    parent ``-1``; parents always precede children (topological order), so
    depth-bounded up/down sweeps are simple prefix loops.
  * ``limit``     -- ``(n_nodes,)`` float per-node power limit in watts.
  * ``host_node`` -- ``(n_hosts,)`` int node each host hangs off (in
    snapshot/ArrayView host iteration order).

The engines never walk the tree pointer-by-pointer.  The constructor
flattens it into an ancestor incidence matrix (``host x node`` bool:
"node m is on host h's root path"), which turns every tree question into a
masked segment reduction (`repro.core.kernels` ``tree_*`` ops): subtree
cap-sums are a segment-sum up the tree, per-host effective slack is a
masked min gather down, and over-limit projection is a per-node
proportional scale applied through the same mask.  The batched engine
packs the incidence matrix per cell and carries it through its
``lax.scan`` unchanged.

A *trivial* tree (single node whose limit is at least the scalar budget)
encodes exactly today's flat behavior; engines skip the tree code path for
it entirely so flat configurations stay bit-identical to the scalar
protocol.
"""

from __future__ import annotations

from typing import Iterable

import numpy as np

from repro import backend
from repro.core import kernels

__all__ = ["BudgetTree"]


class BudgetTree:
    """Immutable budget hierarchy over the cluster's hosts.

    Instances are shared (never copied) across snapshot clones; to change a
    limit, build a new tree with :meth:`with_limit`.
    """

    def __init__(self, parent: Iterable[int], limit: Iterable[float],
                 host_node: Iterable[int]):
        self.parent = np.asarray(parent, dtype=np.int64)
        self.limit = np.asarray(limit, dtype=np.float64)
        self.host_node = np.asarray(host_node, dtype=np.int64)
        n = self.parent.shape[0]
        if n == 0:
            raise ValueError("budget tree needs at least a root node")
        if self.limit.shape != (n,):
            raise ValueError("parent/limit length mismatch")
        if self.parent[0] != -1:
            raise ValueError("node 0 must be the root (parent == -1)")
        if n > 1:
            kids = self.parent[1:]
            if np.any(kids < 0) or np.any(kids >= np.arange(1, n)):
                raise ValueError(
                    "parents must precede children (parent[i] in [0, i))")
        if np.any(self.limit < 0.0):
            raise ValueError("node limits must be non-negative")
        if self.host_node.size and (
                self.host_node.min() < 0 or self.host_node.max() >= n):
            raise ValueError("host_node references an unknown node")

        # Ancestor-or-self incidence: anc_nodes[m, k] == node k lies on
        # node m's root path.  Parents precede children, so one forward
        # pass closes the relation.
        anc = np.eye(n, dtype=bool)
        for m in range(1, n):
            anc[m] |= anc[self.parent[m]]
        self.anc_nodes = anc
        self.host_anc = anc[self.host_node]          # (H, N) bool
        self.depth = anc.sum(axis=1).astype(np.int64) - 1   # root depth 0

        # Flattened (host, ancestor) pair lists: the CSR-ish layout the
        # S=1 control plane feeds to the backend segment ops.
        ph, pn = np.nonzero(self.host_anc)
        self.pair_host = ph.astype(np.int64)
        self.pair_node = pn.astype(np.int64)

    # ------------------------------------------------------------ builders
    @classmethod
    def flat(cls, budget: float, n_hosts: int) -> "BudgetTree":
        """Single-node tree encoding today's scalar rack budget."""
        return cls([-1], [float(budget)], np.zeros(n_hosts, dtype=np.int64))

    @classmethod
    def two_rows(cls, budget: float, n_hosts: int, row0_limit: float,
                 row1_limit: float | None = None) -> "BudgetTree":
        """Root + two row nodes; first half of the hosts on row 0."""
        if row1_limit is None:
            row1_limit = float(budget)
        split = n_hosts // 2
        host_node = np.where(np.arange(n_hosts) < split, 1, 2)
        return cls([-1, 0, 0], [float(budget), float(row0_limit),
                                float(row1_limit)], host_node)

    def with_limit(self, node: int, limit: float) -> "BudgetTree":
        """A copy of this tree with one node limit replaced."""
        new_limit = self.limit.copy()
        new_limit[int(node)] = float(limit)
        return BudgetTree(self.parent, new_limit, self.host_node)

    # ------------------------------------------------------------- queries
    @property
    def n_nodes(self) -> int:
        return int(self.parent.shape[0])

    @property
    def n_hosts(self) -> int:
        return int(self.host_node.shape[0])

    def is_trivial(self, budget: float) -> bool:
        """True when the tree adds no constraint beyond the scalar budget
        (single root whose limit does not undercut it)."""
        return self.n_nodes == 1 and float(self.limit[0]) >= budget - 1e-9

    def cols(self) -> "kernels.TreeCols":
        """The ``(S=1, ...)`` kernel columns for this tree."""
        return kernels.TreeCols(anc=self.host_anc[None],
                                limit=self.limit[None],
                                depth=self.depth[None])

    def node_sums(self, caps: np.ndarray, on: np.ndarray) -> np.ndarray:
        """Per-node subtree cap-sum (powered-off hosts contribute 0)."""
        caps_on = np.where(on, caps, 0.0)
        return backend.NUMPY.seg_sum(
            caps_on[self.pair_host], self.pair_node, self.n_nodes)

    def headroom(self, caps: np.ndarray, on: np.ndarray) -> np.ndarray:
        """Per-node remaining watts under the node limit."""
        return self.limit - self.node_sums(caps, on)

    def host_slack(self, caps: np.ndarray, on: np.ndarray) -> np.ndarray:
        """Per-host tightest headroom along the root path (may be < 0)."""
        head = self.headroom(caps, on)
        return backend.NUMPY.seg_min(
            head[self.pair_node], self.pair_host, self.n_hosts)

    def max_overshoot(self, caps: np.ndarray, on: np.ndarray) -> float:
        """Largest per-node limit violation in watts (<= 0 when clean)."""
        return float(np.max(self.node_sums(caps, on) - self.limit))

    def subtree_hosts(self, node: int) -> np.ndarray:
        """Bool mask of hosts inside ``node``'s subtree."""
        return self.host_anc[:, int(node)]

    def project(self, caps: np.ndarray, on: np.ndarray,
                floors: np.ndarray | None = None) -> np.ndarray:
        """Scale caps down until every node limit holds (see
        :func:`repro.core.kernels.tree_project_caps`)."""
        if floors is None:
            floors = np.zeros_like(caps)
        return kernels.tree_project_caps(
            np, self.cols(), on[None], caps[None], floors[None])[0]

    def validate(self, caps: np.ndarray, on: np.ndarray,
                 atol: float = 1e-6) -> None:
        over = self.max_overshoot(caps, on)
        assert over <= atol, (
            f"budget tree violated: worst node over by {over:.6f} W")
