"""Backend-neutral pure-array kernels for the CloudPowerCap allocation math.

Every scale-sensitive decision in the manager pipeline -- the Eqs. 1/3/4
Watts<->capacity maps, reserved-floor computation, RedivvyPowerCap's
proportional-share cap redistribution, and BalancePowerCap's progressive
filling -- is expressed here as pure functions over plain column arrays
(caps, demands, reservations), parameterized by a ``repro.backend`` executor:

  * the object plane (``repro.core.balance`` / ``repro.core.redivvy`` via
    ``repro.drs.arrays``) runs them eagerly on NumPy with ``S == 1``;
  * the batched sweep engine (``repro.sim.batch``) runs the *same* functions
    under JAX ``jit``, batched over ``S`` scenario cells inside ``lax.scan``.

All kernels take a leading cell axis: host columns are ``(S, H)``, VM
columns ``(S, V)``, per-cell scalars ``(S,)``.  Padding convention: padded
hosts have ``on == False`` (and a nonzero ``power_peak - power_idle`` range
so the Eq. 3 division stays finite); padded/inactive VMs carry zero
floors/ceilings so they allocate nothing, with ``vm_seg`` pointing at host 0.
"""

from __future__ import annotations

from typing import NamedTuple

from repro import backend as backend_mod
from repro.drs.entitlement import waterfill_core, waterfill_dense

#: Minimum cap delta that counts as a change -- must match the emission
#: threshold in ``repro.drs.actions.order_cap_changes`` so the batched
#: engine's action counting agrees with the object plane's.
CAP_CHANGE_EPS = 1e-9


class HostCols(NamedTuple):
    """Static host columns, ``(S, H)`` each (a pytree, so jit-transparent)."""

    on: object             # bool: powered on
    power_idle: object     # Watts at 0% utilization
    power_peak: object     # Watts at 100% utilization
    capacity_peak: object  # capacity at 100% utilization, uncapped
    hyp_overhead: object   # Eq. 4's C_H


class BalanceParams(NamedTuple):
    """Static configuration of the balance loop (mirrors BalanceConfig)."""

    imbalance_threshold: float = 0.01
    max_iters: int = 64
    min_transfer: float = 1e-3


class DPMParams(NamedTuple):
    """Static DPM thresholds (mirrors ``repro.drs.dpm.DPMConfig``)."""

    high_util: float = 0.81        # power-on trigger
    low_util: float = 0.45         # power-off consideration band
    target_util: float = 0.45      # post-consolidation ceiling on targets
    stable_window_s: float = 300.0 # utilization must be low this long


class MigrationParams(NamedTuple):
    """Static configuration of the migration balancer (mirrors
    ``repro.drs.balancer.BalancerConfig``)."""

    imbalance_threshold: float = 0.05
    max_moves: int = 16
    min_goodness: float = 1e-3
    cost_per_gb: float = 2e-4
    contention_threshold: float = 0.9


class MigrationLimits(NamedTuple):
    """Per-invocation launch gates on manager migrations.

    ``slots_per_host``: a host may be an *endpoint* (source or destination)
    of at most this many migration launches per manager invocation;
    ``bandwidth``: cluster-wide cap on total launches per invocation.
    ``None`` means ungated, ``0`` means no launches at all.  Gated moves
    are simply not emitted -- the manager re-scores them at its next
    invocation, so corrections cascade across rounds instead of bursting.
    Evacuations (DPM consolidation) are exempt: power-off is all-or-nothing
    and already waits for its migrations to drain, so in-flight counts MAY
    exceed ``slots_per_host`` while a host evacuates.
    """

    slots_per_host: int | None = None
    bandwidth: int | None = None

    @property
    def gated(self) -> bool:
        return self.slots_per_host is not None or self.bandwidth is not None


class DenseCols(NamedTuple):
    """Dense-slot VM entitlement columns, ``(S, H, J)`` each.

    Callers that hold their VMs in the dense slot layout can hand these to
    :func:`balance_caps` alongside ``ents_at``; the ``jax-pallas`` executor
    then fuses the per-round waterfill with the balance math in a single
    kernel pass instead of materializing the ``(S, H, J)`` allocation
    between them.  ``active`` is the live-slot mask (stale values in padded
    slots are neutralized inside the primitive); ``iters`` the bisection
    trip count (static).
    """

    floors: object                 # (S, H, J)
    ceils: object                  # (S, H, J)
    weights: object                # (S, H, J)
    active: object                 # (S, H, J) bool
    iters: int = 200


class RulesMeta(NamedTuple):
    """Static shape of a grid's rule set (compile-time loop bounds)."""

    n_groups: int = 0              # merged affinity groups
    n_anti: int = 0                # anti-affinity rules
    n_vmhost: int = 0              # VM-host rules
    max_group_members: int = 0     # largest affinity group
    max_anti_members: int = 0      # total anti-rule members

    @property
    def move_bound(self) -> int:
        """Upper bound on constraint-correction moves per invocation."""
        return (self.n_groups * self.max_group_members + self.n_vmhost
                + self.max_anti_members)

    @property
    def any(self) -> bool:
        return (self.n_groups + self.n_anti + self.n_vmhost) > 0


#: Waterfill trips used by the migration kernels in *every* engine -- the
#: object-plane adapters and the jitted batch program must bisect the same
#: number of times so their entitlement scores (and therefore their greedy
#: argmax decisions) agree bit-for-bit.
MIGRATION_WATERFILL_ITERS = 100


# ------------------------------------------------------------ power model
def capped_capacity(xp, hosts: HostCols, caps):
    """Eq. 3 per host; 0 for powered-off hosts."""
    c = xp.clip(caps, hosts.power_idle, hosts.power_peak)
    frac = (c - hosts.power_idle) / (hosts.power_peak - hosts.power_idle)
    return xp.where(hosts.on, hosts.capacity_peak * frac, 0.0)


def managed_capacity(xp, hosts: HostCols, caps):
    """Eq. 4 per host; 0 for powered-off hosts."""
    return xp.where(
        hosts.on,
        xp.maximum(capped_capacity(xp, hosts, caps) - hosts.hyp_overhead,
                   0.0),
        0.0)


def peak_managed_capacity(xp, hosts: HostCols):
    return xp.maximum(hosts.capacity_peak - hosts.hyp_overhead, 0.0)


def cap_for_managed_capacity(xp, hosts: HostCols, capacities):
    """Inverse of Eq. 4 (vectorized ``HostPowerSpec.cap_for_managed_capacity``)."""
    c = xp.clip(capacities + hosts.hyp_overhead, 0.0, hosts.capacity_peak)
    return hosts.power_idle + (hosts.power_peak - hosts.power_idle) * (
        c / hosts.capacity_peak)


def power_consumed(xp, hosts: HostCols, utilization):
    """Eq. 1: utilization -> consumed Watts (0 when powered off)."""
    u = xp.clip(utilization, 0.0, 1.0)
    return xp.where(hosts.on,
                    hosts.power_idle
                    + (hosts.power_peak - hosts.power_idle) * u,
                    0.0)


def reserved_floor_caps(xp, hosts: HostCols, cpu_reserved):
    """Per-host minimum cap honoring resident reservations (paper Fig. 3
    step 1); never below idle, 0 for powered-off hosts."""
    floor = xp.maximum(cap_for_managed_capacity(xp, hosts, cpu_reserved),
                       hosts.power_idle)
    return xp.where(hosts.on, floor, 0.0)


# ---------------------------------------------------------------- redivvy
def redivvy_caps(xp, on, caps_start, caps_floor):
    """Algorithm 1 (RedivvyPowerCap), conserving form.

    ``caps_start`` are pre-correction caps C_{i,S}; ``caps_floor`` the
    post-correction reservation floors C_{i,F}.  Hosts whose floor grew keep
    it; hosts whose floor shrank surrender exactly the fraction ``r`` of
    their excess that funds the growth and keep the rest.  Powered-off hosts
    keep ``caps_start`` untouched.
    """
    delta = xp.where(on, caps_floor - caps_start, 0.0)
    needed = xp.sum(xp.where(delta > 0.0, delta, 0.0), axis=-1)
    excess = xp.sum(xp.where(delta > 0.0, 0.0, -delta), axis=-1)
    r = xp.minimum(needed / xp.maximum(excess, 1e-300), 1.0)[..., None]
    shrunk = caps_floor + (1.0 - r) * (caps_start - caps_floor)
    new = xp.where(delta > 0.0, caps_floor, shrunk)
    # Corner cases exactly as the object-plane algorithm resolves them:
    # nothing grew -> every host keeps its original cap; growth with no
    # excess -> every host sits at its floor.
    new = xp.where((excess > 0.0)[..., None], new, caps_floor)
    new = xp.where((needed > 0.0)[..., None], new, caps_start)
    return xp.where(on, new, caps_start)


def count_cap_changes(xp, on, before, after):
    """Per-cell count of hosts whose cap change would emit a SetPowerCap
    action (the ``order_cap_changes`` threshold)."""
    changed = on & (xp.abs(after - before) > CAP_CHANGE_EPS)
    return xp.sum(changed, axis=-1)


# ------------------------------------------------------------ budget tree
#
# Hierarchical budgets (host -> rack -> row -> room) arrive flattened as an
# ancestor incidence matrix (see ``repro.core.budget_tree.BudgetTree``), so
# every tree question is a masked segment reduction over the node axis:
# subtree cap-sums are a segment-sum up the tree, per-host slack a masked
# min gather down, and over-limit repair a per-node proportional scale.
# The ops are deliberately pure ``(S, H) x (S, H, N) -> (S, N)`` array math
# so the same source runs eagerly on NumPy (object plane, S == 1) and under
# jit inside the batched engine's ``lax.scan``.

#: A node counts as *binding* for projection only past this overshoot, so
#: conserving kernels whose totals drift by float-summation ULPs (well
#: below 1e-9 at rack scale) pass through bitwise untouched.
TREE_PROJECT_EPS = 1e-9

#: Headroom below this counts a node as *saturated* for evacuation scoping.
TREE_BIND_EPS = 1e-6


class TreeCols(NamedTuple):
    """Budget-tree columns (a pytree, so jit-transparent).

    ``anc[s, h, m]`` -- node ``m`` lies on host ``h``'s root path (ancestor
    incidence, self-inclusive via the host's leaf).  Padded hosts have an
    all-False row; padded nodes an all-False column with ``limit == +inf``
    and ``depth == -1``, so they never constrain anything.
    """

    anc: object      # (S, H, N) bool
    limit: object    # (S, N) Watts
    depth: object    # (S, N) int, root 0 (padding -1)


def tree_anc_at(xp, tree: TreeCols, host):
    """Ancestor row of per-cell host index ``host`` (``(S,) -> (S, N)``)."""
    return xp.take_along_axis(
        tree.anc, host[..., None, None], axis=-2)[..., 0, :]


def tree_node_sums(xp, tree: TreeCols, on, caps):
    """Per-node subtree cap-sum: segment-sum of powered-on caps up the
    tree through the ancestor incidence (``(S, H) -> (S, N)``)."""
    caps_on = xp.where(on, caps, 0.0)
    return xp.sum(xp.where(tree.anc, caps_on[..., None], 0.0), axis=-2)


def tree_headroom(xp, tree: TreeCols, on, caps):
    """Per-node remaining watts under the node limit (may be < 0)."""
    return tree.limit - tree_node_sums(xp, tree, on, caps)


def tree_host_slack(xp, tree: TreeCols, headroom):
    """Per-host effective slack: tightest headroom along the root path
    (gather down; ``+inf`` for hosts outside the tree, i.e. padding)."""
    return xp.min(xp.where(tree.anc, headroom[..., None, :], xp.inf),
                  axis=-1)


def tree_project_caps(xp, tree: TreeCols, on, caps, floors):
    """Scale caps down until every node limit holds, never below floors.

    Each host's cap splits into a protected floor and excess; every node
    whose subtree sum overshoots its limit by more than
    ``TREE_PROJECT_EPS`` computes the proportional excess scale that lands
    it exactly on the limit, and each host applies the tightest scale along
    its root path.  One pass suffices: a node's post-projection sum is at
    most ``node_floor + s_node * node_excess == limit`` because every
    subtree host's scale is <= ``s_node``.  Non-binding nodes (every node,
    for a flat tree inside its budget) leave caps bitwise untouched.

    Precondition: the floors themselves fit under every limit (the
    reserved-floor analogue of ``correct_constraints``); otherwise the
    projection bottoms out at the floors and the engine invariants flag
    the misconfigured tree.
    """
    fl = xp.where(on, xp.minimum(floors, caps), 0.0)
    ex = xp.where(on, caps, 0.0) - fl
    node_fl = xp.sum(xp.where(tree.anc, fl[..., None], 0.0), axis=-2)
    node_ex = xp.sum(xp.where(tree.anc, ex[..., None], 0.0), axis=-2)
    binding = node_fl + node_ex > tree.limit + TREE_PROJECT_EPS
    scale = xp.clip((tree.limit - node_fl) / xp.maximum(node_ex, 1e-300),
                    0.0, 1.0)
    s_node = xp.where(binding, scale, 1.0)
    s_host = xp.min(xp.where(tree.anc, s_node[..., None, :], xp.inf),
                    axis=-1)
    return xp.where(on & (s_host < 1.0), fl + s_host * ex, caps)


def tree_evac_scope(xp, tree: TreeCols, on, caps, victim):
    """Destination scope for evacuating ``victim``: the subtree of its
    deepest *saturated* ancestor (headroom < ``TREE_BIND_EPS``), so the
    freed watts and the displaced demand stay inside the binding domain.
    With no saturated ancestor (always, for a flat tree inside its budget)
    every host is in scope -- the scalar-protocol behavior.
    """
    s, h, _ = tree.anc.shape
    head = tree_headroom(xp, tree, on, caps)
    anc_v = tree_anc_at(xp, tree, victim)                     # (S, N)
    saturated = anc_v & (head < TREE_BIND_EPS)
    key = xp.where(saturated, tree.depth, -1)
    node = xp.argmax(key, axis=-1)                            # deepest
    scope = xp.take_along_axis(
        tree.anc, xp.broadcast_to(node[..., None, None], (s, h, 1)),
        axis=-1)[..., 0]                                      # (S, H)
    return xp.where(xp.any(saturated, axis=-1)[..., None], scope,
                    xp.ones_like(scope))


# ---------------------------------------------------------------- balance
def _masked_std(xp, values, mask, count):
    """Population stddev of ``values`` where ``mask`` (count = mask sum)."""
    safe = xp.maximum(count, 1)
    mean = xp.sum(values * mask, axis=-1) / safe
    var = xp.sum(mask * (values - mean[..., None]) ** 2, axis=-1) / safe
    return xp.sqrt(var)


def entitlement_sums(be, hosts: HostCols, caps, vm_floors, vm_ceils,
                     vm_weights, vm_seg, iters: int = 200):
    """Per-host VM-entitlement sums at the given caps: one lockstep
    waterfill over every (cell, host, VM) at once.

    VM columns are ``(S, V)`` with ``vm_seg`` the resident host index
    (inactive/padded VMs: zero floor/ceiling, seg 0).  Segments are
    flattened to ``S * H`` so a single bisection serves the whole batch.
    """
    xp = be.xp
    s, h = caps.shape
    v = vm_seg.shape[-1]
    offs = xp.arange(s)[:, None] * h
    seg_flat = (vm_seg + offs).reshape(s * v)
    capacity = managed_capacity(xp, hosts, caps)
    alloc = waterfill_core(
        be, capacity.reshape(s * h), vm_floors.reshape(s * v),
        vm_ceils.reshape(s * v), vm_weights.reshape(s * v), seg_flat,
        s * h, iters)
    return be.seg_sum(alloc, seg_flat, s * h).reshape(s, h)


def balance_round(xp, hosts: HostCols, caps, managed, ents, ns, done, did,
                  ents_at, cpu_reserved, budget, n_on, peak_managed,
                  params: BalanceParams):
    """One BalancePowerCap progressive-filling round (the body of the
    :func:`balance_caps` loop, extracted so the fused Pallas kernel executes
    the *same* function on its VMEM blocks -- bit-identity between the lax
    and Pallas executors is by construction, not by parallel maintenance).

    Takes and returns the loop state ``(caps, managed, ents, ns, done,
    did)``; ``ents_at(caps) -> (S, H)`` supplies per-host VM-entitlement
    sums at candidate caps.
    """
    on = hosts.on
    imbalance = _masked_std(xp, ns, on, n_on)
    total_cap = xp.sum(managed * on, axis=-1)
    # Cluster-average normalized entitlement: the water level every
    # host would sit at if capacity were perfectly divisible.
    n_avg = xp.sum(ents * on, axis=-1) / xp.maximum(total_cap, 1e-300)
    halt = ((imbalance <= params.imbalance_threshold)
            | (total_cap <= 0.0) | (n_avg <= 1e-12))

    # Batched progressive filling: every host above the average level
    # is a recipient (bounded by its physical peak), every host below
    # is a donor (bounded by the average level and by its reservations).
    cbar = ents / xp.maximum(n_avg, 1e-300)[..., None]
    recipients = on & (ns > n_avg[..., None])
    donors = on & (ns < n_avg[..., None])
    need = xp.where(
        recipients,
        xp.maximum(xp.minimum(peak_managed, cbar) - managed, 0.0), 0.0)
    avail = xp.where(
        donors,
        xp.maximum(managed - xp.maximum(cbar, cpu_reserved), 0.0), 0.0)
    total_need = xp.sum(need, axis=-1)
    total_avail = xp.sum(avail, axis=-1)
    transfer = xp.minimum(total_need, total_avail)
    # Powercap range exhausted -> DRS migration handles the residue.
    halt = halt | (transfer <= params.min_transfer)

    grow = recipients & (need > 0.0)
    new_caps = xp.where(grow, cap_for_managed_capacity(
        xp, hosts,
        managed + transfer[..., None] * need
        / xp.maximum(total_need, 1e-300)[..., None]), caps)
    shrink = donors & (avail > 0.0)
    new_caps = xp.where(shrink, cap_for_managed_capacity(
        xp, hosts,
        managed - transfer[..., None] * avail
        / xp.maximum(total_avail, 1e-300)[..., None]), new_caps)
    # Watts conservation under heterogeneous specs: trim recipients if
    # the budget would be exceeded (linear maps conserve exactly for
    # homogeneous specs; this is a safety net).
    over = xp.sum(new_caps * on, axis=-1) - budget
    n_rec = xp.sum(recipients, axis=-1)
    trim = (over > 1e-6)[..., None] & recipients
    new_caps = xp.where(
        trim,
        xp.maximum(new_caps
                   - (over / xp.maximum(n_rec, 1))[..., None],
                   hosts.power_idle),
        new_caps)

    new_managed = managed_capacity(xp, hosts, new_caps)
    new_ents = ents_at(new_caps)
    new_ns = xp.where(new_managed > 0.0,
                      new_ents / xp.maximum(new_managed, 1e-300), 0.0)
    # Heterogeneous Watts<->capacity maps (plus the trim above) can make
    # a round non-improving near convergence: skip it and stop rather
    # than oscillate.
    worse = _masked_std(xp, new_ns, on, n_on) > imbalance + 1e-12
    commit = ~done & ~halt & ~worse
    cm = commit[..., None]
    return (xp.where(cm, new_caps, caps),
            xp.where(cm, new_managed, managed),
            xp.where(cm, new_ents, ents),
            xp.where(cm, new_ns, ns),
            done | halt | worse,
            did | commit)


def balance_caps(be, hosts: HostCols, caps, ents_at, cpu_reserved, budget,
                 enabled, params: BalanceParams = BalanceParams(),
                 dense: DenseCols | None = None):
    """Algorithm 2 (BalancePowerCap) as a pure batched loop.

    Progressive filling toward max-min fairness on normalized entitlements
    N_h, moving Watts instead of VMs.  ``ents_at(caps) -> (S, H)`` supplies
    the per-host VM-entitlement sums at candidate caps (the object plane
    injects the segment waterfill :func:`entitlement_sums`; the batched
    engine injects the dense-slot form).  Returns ``(caps, did)`` where
    ``did`` is the per-cell did-anything flag.  Cells with
    ``enabled == False`` or fewer than two powered-on hosts pass through
    unchanged.

    The loop body is shared verbatim between backends: the NumPy driver
    (``S == 1`` in the object-plane manager) early-exits through
    ``be.while_loop`` on concrete booleans; the JAX driver runs the same
    ``while_loop`` under ``jit`` with per-cell ``done`` masking, so
    converged cells freeze while stragglers keep transferring.

    ``dense`` (optional) carries the dense-slot entitlement columns behind
    ``ents_at``; when the ``jax-pallas`` executor is active and the caller
    is on the JAX plane, the whole loop is delegated to the fused Pallas
    driver (one kernel launch per round: the balance math and the waterfill
    at the candidate caps in a single pass over ``(S, H, J)``).
    """
    if (dense is not None and getattr(be, "name", "") != "numpy"
            and backend_mod.pallas_enabled()):
        from repro.kernels.powercap.ops import pallas_balance_caps
        return pallas_balance_caps(hosts, caps, dense, cpu_reserved,
                                   budget, enabled, params)
    xp = be.xp
    on = hosts.on
    n_on = xp.sum(on, axis=-1)
    peak_managed = peak_managed_capacity(xp, hosts)

    managed = managed_capacity(xp, hosts, caps)
    ents = ents_at(caps)
    ns = xp.where(managed > 0.0,
                  ents / xp.maximum(managed, 1e-300), 0.0)
    done0 = ~enabled | (n_on < 2)
    did0 = xp.zeros_like(done0)

    def cond(state):
        caps, managed, ents, ns, done, did, rounds = state
        return (rounds < params.max_iters) & ~xp.all(done)

    def body(state):
        caps, managed, ents, ns, done, did, rounds = state
        out = balance_round(xp, hosts, caps, managed, ents, ns, done, did,
                            ents_at, cpu_reserved, budget, n_on,
                            peak_managed, params)
        return (*out, rounds + 1)

    state = (caps, managed, ents, ns, done0, did0, 0)
    caps, _, _, _, _, did, _ = be.while_loop(cond, body, state)
    return caps, did


# -------------------------------------------------- DPM + redistribution
def host_utilizations(xp, hosts: HostCols, caps, eff_demand_h, mem_demand_h,
                      host_mem):
    """Per-host (cpu, mem) utilizations, matching the object plane's
    ``ArrayView.host_cpu_utilization`` / ``host_mem_utilization``: zero for
    powered-off hosts and hosts with no capacity."""
    managed = managed_capacity(xp, hosts, caps)
    cpu = xp.where(managed > 0.0,
                   eff_demand_h / xp.maximum(managed, 1e-300), 0.0)
    ok = hosts.on & (host_mem > 0.0)
    mem = xp.where(ok, mem_demand_h / xp.maximum(host_mem, 1e-300), 0.0)
    return cpu, mem


def dpm_hot_mask(xp, on, cpu_util, mem_util, high_util):
    """DPM power-on trigger: powered-on hosts running hot on CPU or memory."""
    return on & ((cpu_util > high_util) | (mem_util > high_util))


def dpm_all_low(xp, on, cpu_util, mem_util, low_util):
    """DPM power-off consideration: every powered-on host below the low band
    on both CPU and memory (per cell; vacuously true with no hosts on)."""
    low = (cpu_util < low_util) & (mem_util < low_util)
    return xp.all(~on | low, axis=-1)


def power_on_funding_caps(be, hosts: HostCols, caps, cand, cpu_util,
                          host_demand, cpu_reserved, budget,
                          high_util: float, tree: TreeCols | None = None):
    """Algorithm 3 power-on funding (paper Fig. 5), batched.

    Funds the cap of candidate host ``cand`` (``(S,)`` index): unallocated
    budget first, then low-utilization donors drained -- lowest utilization
    first -- down to the capacity at which DPM's power-on trigger would fire
    (no oscillation), never below their reservations or idle power.  An
    already-powered-on candidate keeps its allocation; funding only tops it
    up toward peak.

    With a ``tree``, both funding sources additionally respect the budget
    hierarchy: the unallocated pool is clipped to the candidate's tightest
    ancestor headroom, and each donated watt that crosses a limit node on
    its way to the candidate (a node guarding the candidate but not the
    donor) debits that node's headroom and stops when it runs out -- so
    funding can never borrow across a saturated row boundary.  Donors
    inside the candidate's own binding subtree are untouched by the check
    (their watts never cross the boundary).  Without a tree (or with every
    crossed node slack) the result is bitwise the flat-protocol answer.

    Returns ``(new_caps, granted)`` where ``new_caps`` has donors drained
    and the candidate at its granted cap (``min(granted, peak)``), and
    ``granted`` is per cell.  The caller decides feasibility
    (``managed_capacity(granted) > 0``) and emission.
    """
    xp = be.xp
    on = hosts.on
    h_idx = xp.arange(caps.shape[-1])

    def at_cand(col):
        return xp.take_along_axis(col, cand[..., None], axis=-1)[..., 0]

    peak_c = at_cand(hosts.power_peak)
    cand_on = at_cand(on)
    granted0 = xp.where(cand_on, at_cand(caps), 0.0)
    needed = xp.maximum(peak_c - granted0, 0.0)

    # Step 1: unallocated budget (clipped to the candidate's ancestor
    # headroom when a tree is live -- unallocated watts still may not push
    # a row past its limit).
    pool = xp.maximum(budget - xp.sum(xp.where(on, caps, 0.0), axis=-1), 0.0)
    if tree is not None:
        head = tree_headroom(xp, tree, on, caps)
        anc_c = tree_anc_at(xp, tree, cand)                   # (S, N)
        pool_c = xp.min(xp.where(anc_c, head, xp.inf), axis=-1)
        pool = xp.minimum(pool, xp.maximum(pool_c, 0.0))
    take0 = xp.minimum(pool, needed)
    needed = needed - take0

    # Step 2: greedy drain, replicated exactly as a sorted prefix-sum: the
    # k-th coolest donor gives ``clip(needed - taken_so_far, 0, avail_k)``,
    # and donors past the 1e-9 residue give nothing (the object plane's
    # early break).
    is_cand = h_idx == cand[..., None]
    donor = on & ~is_cand & (cpu_util < high_util)
    floor_capacity = xp.maximum(host_demand / high_util, cpu_reserved)
    floor_cap = xp.maximum(
        cap_for_managed_capacity(xp, hosts, floor_capacity),
        hosts.power_idle)
    avail = xp.where(donor, xp.maximum(caps - floor_cap, 0.0), 0.0)
    order = be.argsort(xp.where(donor, cpu_util, xp.inf), axis=-1)
    sorted_avail = xp.take_along_axis(avail, order, axis=-1)
    cum_before = xp.cumsum(sorted_avail, axis=-1) - sorted_avail
    residue = needed[..., None] - cum_before
    take = xp.where(residue > 1e-9,
                    xp.clip(residue, 0.0, sorted_avail), 0.0)
    if tree is not None:
        # Tree pass over the same sorted donors: each donation is capped by
        # the remaining headroom of the nodes it crosses (ancestors of the
        # candidate that are not ancestors of the donor), then debits them.
        # The flat prefix-sum ``take`` stays the base amount, so when no
        # crossed node binds the result is bitwise the flat answer.
        s, n_hosts = caps.shape
        head = head - xp.where(anc_c, take0[..., None], 0.0)
        anc_sorted = xp.take_along_axis(
            tree.anc, order[..., None], axis=-2)              # (S, H, N)

        def drain(k, st):
            head_k, take_k = st
            anc_d = xp.take_along_axis(
                anc_sorted, xp.full((s, 1, 1), k, dtype=order.dtype),
                axis=-2)[..., 0, :]
            crossed = anc_c & ~anc_d                          # (S, N)
            room = xp.min(xp.where(crossed, head_k, xp.inf), axis=-1)
            base = xp.take_along_axis(
                take, xp.full((s, 1), k, dtype=order.dtype), axis=-1)[..., 0]
            t = xp.minimum(base, xp.maximum(room, 0.0))
            head_k = head_k - xp.where(crossed, t[..., None], 0.0)
            take_k = xp.where(h_idx[None, :] == k, t[..., None], take_k)
            return head_k, take_k

        _, take = be.fori(n_hosts, drain, (head, take))
    inverse = be.argsort(order, axis=-1)
    taken = xp.take_along_axis(take, inverse, axis=-1)

    granted = xp.minimum(granted0 + take0 + xp.sum(take, axis=-1), peak_c)
    new_caps = xp.where(is_cand, granted[..., None], caps - taken)
    return new_caps, granted


def power_off_reabsorb_caps(xp, hosts: HostCols, caps, off_idx, budget,
                            tree: TreeCols | None = None):
    """Algorithm 3 power-off reabsorption: the victim's cap returns to the
    pool and is spread over the remaining powered-on hosts proportionally to
    their headroom to peak.  Returns the new cap column (victim at 0).

    With a ``tree``, the grown caps are projected back under every node
    limit (floors at the pre-growth caps, so reabsorption growth -- never
    the surviving allocation -- is what gets scaled back).  For a flat tree
    inside its budget the projection is bitwise a no-op.
    """
    h_idx = xp.arange(caps.shape[-1])
    is_off = h_idx == off_idx[..., None]
    on_after = hosts.on & ~is_off
    caps0 = xp.where(is_off, 0.0, caps)
    pool = xp.maximum(
        budget - xp.sum(xp.where(on_after, caps0, 0.0), axis=-1), 0.0)
    recipients = on_after & (caps0 < hosts.power_peak - 1e-9)
    headroom = xp.where(recipients, hosts.power_peak - caps0, 0.0)
    total_head = xp.sum(headroom, axis=-1)
    grant_total = xp.minimum(pool, total_head)
    grown = xp.minimum(
        caps0 + grant_total[..., None] * headroom
        / xp.maximum(total_head, 1e-300)[..., None],
        hosts.power_peak)
    ok = (total_head > 0.0) & (pool > 0.0)
    result = xp.where(ok[..., None] & recipients, grown, caps0)
    if tree is None:
        return result
    return tree_project_caps(xp, tree, on_after, result, caps0)


def plan_evacuation(be, hosts: HostCols, caps, victim, occ, eff_slot,
                    mem_slot, res_slot, migratable, host_mem,
                    target_util: float, allowed=None, anti=None,
                    scope=None):
    """DPM evacuation planning on the dense slot layout ``(S, H, J)``.

    Replays ``repro.drs.dpm.run_dpm``'s greedy: the victim's VMs leave in
    decreasing current-memory order (stable on ties), each to the feasible
    powered-on host with the strictly lowest post-move utilization (first
    host on ties), subject to the reservation/memory fit check and the
    ``target_util`` ceiling on both CPU and memory.  All-or-nothing: a
    single unplaceable or unmigratable VM cancels the whole evacuation.

    Returns ``(ok, order, dests, n_evac, slot_pressure)``: ``order`` is the
    per-cell slot visit order, ``dests[:, k]`` the destination host of the
    k-th evacuee (-1 when unused), and ``slot_pressure`` flags cells where
    the ``J`` slot bound excluded an otherwise-feasible destination (the
    caller must treat those results as invalid -- repack with more slack).

    ``allowed`` (``(S, H, J, H)``) and ``anti`` (``(S, H, J, R)``) add rule
    admission to the fit check (the object plane's ``placement.fits``):
    each evacuee may only land on a host its VM-host bitmask allows and
    where no member of any of its anti-affinity rules lives -- counting
    evacuees already placed earlier in the same plan.

    ``scope`` (``(S, H)`` bool) restricts destinations, e.g. to the
    victim's tightest saturated budget-tree subtree
    (:func:`tree_evac_scope`), so displaced demand stays inside the
    binding power domain.
    """
    xp = be.xp
    s, h, j = occ.shape
    on = hosts.on
    h_idx = xp.arange(h)
    s_idx = xp.arange(s)
    managed = managed_capacity(xp, hosts, caps)
    act = occ & on[..., None]
    eff_h = xp.sum(xp.where(act, eff_slot, 0.0), axis=-1)
    mem_h = xp.sum(xp.where(act, mem_slot, 0.0), axis=-1)
    res_h = xp.sum(xp.where(act, res_slot, 0.0), axis=-1)
    cnt_h = xp.sum(occ, axis=-1)
    is_vic = h_idx == victim[..., None]

    def at_victim(col):
        shape = (s, 1) + col.shape[2:]
        idx = xp.broadcast_to(
            victim.reshape((s,) + (1,) * (col.ndim - 1)), shape)
        return xp.take_along_axis(col, idx, axis=1)[:, 0]

    vic_occ = at_victim(occ)
    vic_eff = at_victim(eff_slot)
    vic_mem = at_victim(mem_slot)
    vic_res = at_victim(res_slot)
    vic_mig = at_victim(migratable)
    vic_allowed = at_victim(allowed) if allowed is not None else None
    vic_anti = at_victim(anti) if anti is not None else None
    order = be.argsort(xp.where(vic_occ, -vic_mem, xp.inf), axis=-1)
    n_vic = xp.sum(vic_occ, axis=-1)

    def order_k(k):
        return xp.take_along_axis(order, xp.full((s, 1), k, order.dtype),
                                  axis=-1)[..., 0]

    def body(k, st):
        eff_h = st["eff_h"]
        mem_h = st["mem_h"]
        res_h = st["res_h"]
        cnt_h = st["cnt_h"]
        valid = k < n_vic
        ko = order_k(k)
        e = vic_eff[s_idx, ko]
        m = vic_mem[s_idx, ko]
        r = vic_res[s_idx, ko]
        mig = vic_mig[s_idx, ko]
        fit = on & ~is_vic
        if scope is not None:
            fit = fit & scope
        fit = fit & (res_h + r[..., None] <= managed + 1e-9)
        fit = fit & (mem_h + m[..., None] <= host_mem + 1e-9)
        util_after = (eff_h + e[..., None]) / xp.maximum(managed, 1e-9)
        mem_after = (mem_h + m[..., None]) / xp.maximum(host_mem, 1e-9)
        fit = fit & (util_after <= target_util) & (mem_after <= target_util)
        if vic_allowed is not None:
            fit = fit & vic_allowed[s_idx, ko]
        a_k = None
        if vic_anti is not None:
            a_k = vic_anti[s_idx, ko]                       # (S, R)
            conflict = xp.matmul(
                (st["anti_cnt"] > 0).astype(xp.float64),    # (S, H, R)
                a_k[..., None].astype(xp.float64))[..., 0] > 0.5
            fit = fit & ~conflict
        slot_ok = cnt_h < j
        pressure = st["pressure"] | xp.any(
            valid[..., None] & fit & ~slot_ok, axis=-1)
        fit = fit & slot_ok
        score = xp.where(fit, util_after, xp.inf)
        best = xp.argmin(score, axis=-1)
        found = xp.isfinite(xp.min(score, axis=-1))
        ok = st["ok"] & (~valid | (mig & found))
        place = valid & ok
        upd = place[..., None] & (h_idx == best[..., None])
        col_k = xp.arange(j) == k
        dests = xp.where(col_k[None, :] & place[..., None],
                         best[..., None], st["dests"])
        out = dict(
            st, dests=dests, ok=ok, pressure=pressure,
            eff_h=eff_h + xp.where(upd, e[..., None], 0.0),
            mem_h=mem_h + xp.where(upd, m[..., None], 0.0),
            res_h=res_h + xp.where(upd, r[..., None], 0.0),
            cnt_h=cnt_h + upd.astype(cnt_h.dtype))
        if a_k is not None:
            out["anti_cnt"] = st["anti_cnt"] + (
                upd[..., None] & a_k[:, None, :]).astype(st["anti_cnt"].dtype)
        return out

    init = {"eff_h": eff_h, "mem_h": mem_h, "res_h": res_h, "cnt_h": cnt_h,
            "dests": xp.full((s, j), -1, dtype=victim.dtype),
            "ok": xp.ones(s, dtype=bool),
            "pressure": xp.zeros(s, dtype=bool)}
    if vic_anti is not None:
        init["anti_cnt"] = xp.sum(
            (anti & act[..., None]).astype(xp.int64), axis=2)   # (S, H, R)
    st = be.fori(j, body, init)
    ok, dests, pressure = st["ok"], st["dests"], st["pressure"]
    n_evac = xp.where(ok, n_vic, 0)
    return ok, order, dests, n_evac, pressure


# ------------------------------------------------------- migration layer
#
# The migration decisions (constraint correction and the DRS load-balancing
# hill-climb) operate on the dense slot layout ``(S, H, J)`` -- the same
# layout the batched sweep engine carries through its ``lax.scan`` -- so one
# kernel source serves the object plane (NumPy, S == 1, via
# ``repro.core.migration_core.MigrationCore``) and the jitted grid program.
# Rules arrive pre-scattered into slot space (see
# ``repro.drs.arrays.RulesPack``): ``aff_group`` (S, H, J) int, ``allowed``
# (S, H, J, H) bool, ``anti`` (S, H, J, R) bool.

#: Pad values restored to a slot when its VM moves away.  Engines carrying
#: extra per-slot columns (demand traces, tag masks) extend this mapping.
SLOT_PAD = {
    "occ": False, "reservation": 0.0, "limit": float("inf"),
    "weights": 1e-12, "migratable": True, "cpu": 0.0, "mem": 0.0,
    "aff_group": -1, "allowed": True, "anti": False,
}


def _tail(mask, ndim):
    """Broadcast a leading-axes mask against an array with trailing dims."""
    return mask.reshape(mask.shape + (1,) * (ndim - mask.ndim))


def move_slot(xp, work, do, src, j, dst, pads=SLOT_PAD):
    """Move slot ``(src, j)`` to ``dst``'s first *free* slot, per cell.

    ``work`` maps column names to ``(S, H, J, ...)`` arrays (must contain
    ``"occ"``); every column travels with the VM and the vacated slot is
    restored to its pad value.  Free slots are found by occupancy (argmin
    over the ``occ`` row), so holes left by earlier moves are reused --
    unlike an occupancy-count cursor, this stays correct after arbitrary
    move sequences.  Returns ``(work, moved)`` where ``moved`` masks the
    cells whose destination actually had a free slot (callers gate on the
    admission kernels, which already require one).
    """
    occ = work["occ"]
    s_ax, h_ax, j_ax = occ.shape
    s_idx = xp.arange(s_ax)
    src_c = xp.clip(src, 0, h_ax - 1)
    j_c = xp.clip(j, 0, j_ax - 1)
    dst_c = xp.clip(dst, 0, h_ax - 1)
    occ_d = occ[s_idx, dst_c]                        # (S, J)
    ns = xp.argmin(occ_d, axis=-1)                   # first free (False)
    free = ~occ_d[s_idx, ns]
    moved = do & free
    out = {}
    for key, arr in work.items():
        # Scatter-style two-point update: O(cells * trailing) per move,
        # not O(whole column) -- the trace columns riding along make a
        # full-array rewrite per move the dominant cost otherwise.
        val = arr[s_idx, src_c, j_c]                 # (S, *trailing)
        m = _tail(moved, val.ndim)
        cur_d = arr[s_idx, dst_c, ns]
        new_d = xp.where(m, val, cur_d)
        if hasattr(arr, "at"):                       # JAX: XLA scatter
            arr = arr.at[s_idx, dst_c, ns].set(new_d)
            cur_s = arr[s_idx, src_c, j_c]
            arr = arr.at[s_idx, src_c, j_c].set(
                xp.where(m, pads[key], cur_s))
        else:                                        # NumPy: copy + assign
            arr = arr.copy()
            arr[s_idx, dst_c, ns] = new_d
            arr[s_idx, src_c, j_c] = xp.where(m, pads[key],
                                              arr[s_idx, src_c, j_c])
        out[key] = arr
    return out, moved


def record_move(xp, moves, n_moves, do, src, j, dst):
    """Append ``(src, j, dst)`` at each cell's cursor position where ``do``.

    ``moves`` is ``(S, M, 3)`` int (-1 padded), ``n_moves`` the per-cell
    cursor.  Returns the updated ``(moves, n_moves)``.
    """
    m = moves.shape[1]
    at = xp.arange(m)[None, :] == n_moves[:, None]   # (S, M)
    triple = xp.stack(
        [src, j, dst], axis=-1).astype(moves.dtype)  # (S, 3)
    upd = (at & do[:, None])[..., None]
    moves = xp.where(upd, triple[:, None, :], moves)
    return moves, n_moves + do.astype(n_moves.dtype)


def _gather_slots(xp, col, srcs, js):
    """Gather per-slot columns at K (host, slot) coordinates: (S, K, ...)."""
    s_idx = xp.arange(col.shape[0])[:, None]
    return col[s_idx, srcs, js]


def _affinity_keep_slots(xp, work, act, n_groups: int, srcs, js):
    """Mask of (gathered slot, dest) moves that do not *create* an affinity
    split: a grouped VM may move only where a group mate already lives (or
    if it is its group's only placed member).  ``(S, K, H)``."""
    s_ax, h_ax, _ = act.shape
    k_ax = srcs.shape[-1]
    if "aff_group" not in work or n_groups == 0:
        return xp.ones((s_ax, k_ax, h_ax), dtype=bool)
    grp = work["aff_group"]
    g_idx = xp.arange(n_groups)
    member = (grp[..., None] == g_idx) & act[..., None]   # (S, H, J, G)
    per_host = xp.sum(member, axis=2)                     # (S, H, G)
    total = xp.sum(per_host, axis=1)                      # (S, G)
    g_v = _gather_slots(xp, grp, srcs, js)                # (S, K)
    g_c = xp.clip(g_v, 0, max(n_groups - 1, 0))
    tot_v = xp.take_along_axis(total, g_c, axis=1)        # (S, K)
    host_g = xp.swapaxes(per_host, 1, 2)                  # (S, G, H)
    dest_cnt = xp.take_along_axis(
        host_g, g_c[..., None] * xp.ones((1, 1, h_ax), dtype=g_c.dtype),
        axis=1)                                           # (S, K, H)
    return (g_v[..., None] < 0) | (tot_v[..., None] <= 1) | (dest_cnt > 0)


def _admission_slots(xp, on, work, capacity, host_mem, srcs, js,
                     limits: MigrationLimits | None = None, launch=None):
    """Reservation + memory + rules + free-slot admission for K gathered
    candidate slots against every destination: ``(S, K, H)``.

    Returns ``(fit, fit_unbounded, res_h, mem_h)`` where ``fit_unbounded``
    ignores the free-slot bound (for slot-pressure detection) and
    ``res_h``/``mem_h`` are the per-host rollups at the current placement.
    The capacity column is the *injected* view -- current-cap or
    fundable-cap managed capacity (paper Fig. 3) -- zero for powered-off
    hosts.  Gathering the candidates first keeps every admission pass
    O(K * H) instead of O(V * H) with K = the few slots a phase can
    actually move.

    ``limits``/``launch`` apply the per-invocation launch gates: with
    ``launch = (launch_h, launch_n)`` -- per-host endpoint counts (S, H)
    and the per-cell total (S,) of moves already launched this invocation
    -- a candidate fits only if both its endpoints and the cluster budget
    still have headroom.  The gate lands on the *shared* fit (before the
    free-slot split), so a launch-gated deferral is deliberate policy, not
    slot pressure.
    """
    occ = work["occ"]
    act = occ & on[..., None]
    res_h = xp.sum(xp.where(act, work["reservation"], 0.0), axis=-1)
    mem_h = xp.sum(xp.where(act, work["mem"], 0.0), axis=-1)
    h_ax = occ.shape[1]
    h_idx = xp.arange(h_ax)
    res_v = _gather_slots(xp, work["reservation"], srcs, js)   # (S, K)
    mem_v = _gather_slots(xp, work["mem"], srcs, js)
    fit = on[:, None, :] & (h_idx[None, None, :] != srcs[..., None])
    fit = fit & (res_h[:, None, :] + res_v[..., None]
                 <= capacity[:, None, :] + 1e-9)
    fit = fit & (mem_h[:, None, :] + mem_v[..., None]
                 <= host_mem[:, None, :] + 1e-9)
    if "allowed" in work:
        fit = fit & _gather_slots(xp, work["allowed"], srcs, js)
    if "anti" in work and work["anti"].shape[-1] > 0:
        anti_cnt = xp.sum(work["anti"] & act[..., None], axis=2)  # (S,H,R)
        a_v = _gather_slots(xp, work["anti"], srcs, js)           # (S,K,R)
        conflict = xp.matmul(
            a_v.astype(xp.float64),
            xp.swapaxes((anti_cnt > 0).astype(xp.float64), 1, 2)) > 0.5
        fit = fit & ~conflict
    if limits is not None and limits.gated:
        launch_h, launch_n = launch
        if limits.slots_per_host is not None:
            src_launch = xp.take_along_axis(launch_h, srcs, axis=-1)
            fit = fit & (src_launch < limits.slots_per_host)[..., None]
            fit = fit & (launch_h < limits.slots_per_host)[:, None, :]
        if limits.bandwidth is not None:
            fit = fit & (launch_n < limits.bandwidth)[:, None, None]
    free_slot = xp.any(~occ, axis=-1)                 # (S, H)
    return fit & free_slot[:, None, :], fit, res_h, mem_h


def correct_constraints_slots(be, hosts: HostCols, capacity, work, host_mem,
                              rmeta: RulesMeta, enabled, moves, n_moves,
                              pads=SLOT_PAD,
                              limits: MigrationLimits = MigrationLimits(),
                              launch=None):
    """Constraint correction on the dense slot layout (paper Fig. 1a/3).

    Replays the object plane's correction protocol as bounded array loops:

      1. *Affinity*: per group, gather every member onto one home host,
         all-or-nothing -- the anchor's host (the member with the largest
         reservation) when it can admit the group, else the feasible
         member host with the most free capacity; with no feasible home
         the group stays split (reported upstream).
      2. *VM-host*: each misplaced VM moves to the admissible allowed host
         with the most free capacity.
      3. *Anti-affinity*: while some rule has two members sharing a host,
         move the first surplus member with a feasible destination to the
         admissible host with the most free capacity.

    ``capacity`` is the injected admission view (current-cap managed
    capacity for static policies, fundable capacity during Powercap
    Allocation).  Moves mutate ``work`` in slot space and are appended to
    ``moves``/``n_moves``; returns ``(work, moves, n_moves, pressure,
    launch)`` where ``pressure`` flags cells whose J slot bound blocked an
    otherwise-feasible correction and ``launch = (launch_h, launch_n)``
    carries the per-invocation launch counts (shared with the balancer
    phase) updated for every committed move.  ``limits`` gates launches
    per :class:`MigrationLimits`; affinity gathers stay all-or-nothing --
    a group whose remaining launch headroom cannot cover the whole gather
    is deferred intact to the next invocation.
    """
    xp = be.xp
    on = hosts.on
    s_ax, h_ax, j_ax = work["occ"].shape
    h_idx = xp.arange(h_ax)
    pressure = xp.zeros(s_ax, dtype=bool)
    gated = limits.gated
    if launch is None:
        launch = (xp.zeros((s_ax, h_ax), dtype=n_moves.dtype),
                  xp.zeros(s_ax, dtype=n_moves.dtype))
    launch_h, launch_n = launch

    # ---------------------------------------------------- 1. affinity
    def aff_body(g, state):
        work, moves, n_moves, pressure, launch_h, launch_n = state
        occ = work["occ"]
        act = occ & on[..., None]
        res = work["reservation"]
        memb = act & (work["aff_group"] == g)
        cnt_h = xp.sum(memb, axis=-1)                     # (S, H)
        violated = xp.sum(cnt_h > 0, axis=-1) > 1
        total = xp.sum(cnt_h, axis=-1)

        # Gather-feasibility of EVERY candidate home at once (vectorized
        # over H): a home must host a member, admit every other member's
        # reservation/memory under the injected capacity view, respect
        # each mover's VM-host bitmask and anti-affinity rules, and have
        # the free slots -- the object plane's historical multi-home
        # retry, evaluated in one pass.
        n_movers = total[:, None] - cnt_h                 # (S, H)
        nm_h = xp.sum(memb & ~work["migratable"], axis=-1)
        ok = (xp.sum(nm_h, axis=-1)[:, None] - nm_h) == 0
        if "allowed" in work:
            bad = memb[..., None] & ~work["allowed"]      # (S, H, J, H)
            bad_total = xp.sum(bad, axis=(1, 2))          # (S, H) per home
            bad_on_home = xp.sum(xp.moveaxis(
                xp.diagonal(bad, axis1=1, axis2=3), -1, 1), axis=-1)
            ok = ok & ((bad_total - bad_on_home) == 0)
        if "anti" in work and rmeta.n_anti:
            anti = work["anti"]
            c_rh = xp.sum(anti & act[..., None], axis=2)    # (S, H, R)
            g_rh = xp.sum(anti & memb[..., None], axis=2)   # (S, H, R)
            m_r = xp.sum(g_rh, axis=1)[:, None, :] - g_rh   # movers in r
            ok = ok & xp.all((m_r == 0) | (c_rh + m_r <= 1), axis=-1)
        res_h = xp.sum(xp.where(act, res, 0.0), axis=-1)
        mem_h = xp.sum(xp.where(act, work["mem"], 0.0), axis=-1)
        memb_res_h = xp.sum(xp.where(memb, res, 0.0), axis=-1)
        memb_mem_h = xp.sum(xp.where(memb, work["mem"], 0.0), axis=-1)
        moving_res = xp.sum(memb_res_h, axis=-1)[:, None] - memb_res_h
        moving_mem = xp.sum(memb_mem_h, axis=-1)[:, None] - memb_mem_h
        ok = ok & (res_h + moving_res <= capacity + 1e-9)
        ok = ok & (mem_h + moving_mem <= host_mem + 1e-9)
        ok = ok & (cnt_h > 0)
        if gated:
            # All-or-nothing under the launch gates too: every member
            # host must have endpoint headroom for its departures, the
            # home for all arrivals, and the cluster budget for the whole
            # gather -- otherwise the group defers intact.
            if limits.slots_per_host is not None:
                sl = limits.slots_per_host
                dep_bad = ((cnt_h > 0) & (launch_h + cnt_h > sl)).astype(
                    launch_h.dtype)
                ok = ok & ((xp.sum(dep_bad, axis=-1)[:, None]
                            - dep_bad) == 0)
                ok = ok & (launch_h + n_movers <= sl)
            if limits.bandwidth is not None:
                ok = ok & (launch_n[:, None] + n_movers
                           <= limits.bandwidth)
        free_h = j_ax - xp.sum(occ, axis=-1)
        ok_full = ok & (free_h >= n_movers)
        feasible = xp.any(ok_full, axis=-1)
        pressure = pressure | (enabled & violated & ~feasible
                               & xp.any(ok, axis=-1))

        # Home choice: the anchor's host (the member with the largest
        # reservation -- hardest to move) when feasible, else the feasible
        # member host with the most free admission capacity.
        flat = xp.where(memb, res, -xp.inf).reshape(s_ax, -1)
        anchor_home = xp.argmax(flat, axis=-1) // j_ax    # (S,)
        anchor_ok = xp.take_along_axis(
            ok_full, anchor_home[:, None], axis=-1)[..., 0]
        best_home = xp.argmax(
            xp.where(ok_full, capacity - res_h, -xp.inf), axis=-1)
        home = xp.where(anchor_ok, anchor_home, best_home)
        on_home = h_idx[None, :, None] == home[:, None, None]
        do_g = enabled & violated & feasible

        def mover_body(_, st):
            work, moves, n_moves, launch_h, launch_n = st
            movers_now = ((work["occ"] & on[..., None])
                          & (work["aff_group"] == g) & ~on_home)
            any_m = xp.any(movers_now, axis=(-1, -2))
            first = xp.argmax(movers_now.reshape(s_ax, -1), axis=-1)
            src = first // j_ax
            jj = first % j_ax
            do = do_g & any_m
            work, moved = move_slot(xp, work, do, src, jj, home, pads)
            moves, n_moves = record_move(xp, moves, n_moves, moved, src,
                                         jj, home)
            if gated:
                is_ep = ((h_idx[None, :] == src[:, None])
                         | (h_idx[None, :] == home[:, None]))
                launch_h = launch_h + (moved[:, None] & is_ep).astype(
                    launch_h.dtype)
                launch_n = launch_n + moved.astype(launch_n.dtype)
            return work, moves, n_moves, launch_h, launch_n

        work, moves, n_moves, launch_h, launch_n = be.fori(
            rmeta.max_group_members, mover_body,
            (work, moves, n_moves, launch_h, launch_n))
        return work, moves, n_moves, pressure, launch_h, launch_n

    if rmeta.n_groups:
        work, moves, n_moves, pressure, launch_h, launch_n = be.fori(
            rmeta.n_groups, aff_body,
            (work, moves, n_moves, pressure, launch_h, launch_n))

    # ----------------------------------- shared mover for phases 2 and 3
    def greedy_move(work, moves, n_moves, pressure, launch_h, launch_n,
                    viol, k_bound):
        """Move the first slot in ``viol`` that has a feasible destination
        to the admissible host with the most free capacity.

        Gathers the first ``k_bound`` violating slots per cell (``k_bound``
        is the phase's rule-count bound, so no violator is ever missed) and
        evaluates admission only for those -- O(K * H) per step instead of
        O(V * H)."""
        flat = viol.reshape(s_ax, -1)
        big = h_ax * j_ax
        keys = xp.where(flat, xp.arange(big), big)
        order = be.argsort(keys, axis=-1)[:, :k_bound]     # (S, K)
        kvalid = xp.take_along_axis(keys, order, axis=-1) < big
        srcs = order // j_ax
        js = order % j_ax
        fit, fit_unb, res_h, _ = _admission_slots(
            xp, on, work, capacity, host_mem, srcs, js,
            limits, (launch_h, launch_n))
        mig_v = _gather_slots(xp, work["migratable"], srcs, js)
        ok_v = (kvalid & mig_v)[..., None]
        fit = fit & ok_v
        fit_unb = fit_unb & ok_v
        has_dest = xp.any(fit, axis=-1)                    # (S, K)
        pressure = pressure | (
            enabled & xp.any(xp.any(fit_unb, axis=-1) & ~has_dest,
                             axis=-1))
        found = enabled & xp.any(has_dest, axis=-1)
        first_k = xp.argmax(has_dest, axis=-1)             # (S,)
        s_idx = xp.arange(s_ax)
        src = srcs[s_idx, first_k]
        jj = js[s_idx, first_k]
        free = capacity - res_h                            # (S, H)
        fit_v = fit[s_idx, first_k]                        # (S, H)
        dest = xp.argmax(xp.where(fit_v, free, -xp.inf), axis=-1)
        work, moved = move_slot(xp, work, found, src, jj, dest, pads)
        moves, n_moves = record_move(xp, moves, n_moves, moved, src, jj,
                                     dest)
        if gated:
            is_ep = ((h_idx[None, :] == src[:, None])
                     | (h_idx[None, :] == dest[:, None]))
            launch_h = launch_h + (moved[:, None] & is_ep).astype(
                launch_h.dtype)
            launch_n = launch_n + moved.astype(launch_n.dtype)
        return work, moves, n_moves, pressure, launch_h, launch_n, found

    # ---------------------------------------------------- 2. VM-host
    if rmeta.n_vmhost:
        def vh_viol(work):
            act = work["occ"] & on[..., None]
            allowed_self = xp.moveaxis(
                xp.diagonal(work["allowed"], axis1=1, axis2=3), -1, 1)
            return act & ~allowed_self

        def vh_cond(state):
            work, moves, n_moves, pressure, lh, ln, go, k = state
            return (k < rmeta.n_vmhost) & xp.any(go)

        def vh_body(state):
            work, moves, n_moves, pressure, lh, ln, go, k = state
            work, moves, n_moves, pressure, lh, ln, found = greedy_move(
                work, moves, n_moves, pressure, lh, ln, vh_viol(work),
                rmeta.n_vmhost)
            return work, moves, n_moves, pressure, lh, ln, go & found, k + 1

        go0 = enabled & xp.any(vh_viol(work), axis=(-1, -2))
        work, moves, n_moves, pressure, launch_h, launch_n, _, _ = \
            be.while_loop(vh_cond, vh_body,
                          (work, moves, n_moves, pressure, launch_h,
                           launch_n, go0, 0))

    # ------------------------------------------------ 3. anti-affinity
    if rmeta.n_anti:
        def anti_extra(work):
            act = work["occ"] & on[..., None]
            member = work["anti"] & act[..., None]          # (S, H, J, R)
            cnt = xp.sum(member, axis=2)                    # (S, H, R)
            keeper_j = xp.argmax(member, axis=2)            # (S, H, R)
            j_col = xp.arange(j_ax)[None, None, :, None]
            extra = (member & (j_col != keeper_j[:, :, None, :])
                     & (cnt[:, :, None, :] > 1))
            return xp.any(extra, axis=-1)                   # (S, H, J)

        def anti_cond(state):
            work, moves, n_moves, pressure, lh, ln, go, k = state
            return (k < rmeta.max_anti_members) & xp.any(go)

        def anti_body(state):
            work, moves, n_moves, pressure, lh, ln, go, k = state
            work, moves, n_moves, pressure, lh, ln, found = greedy_move(
                work, moves, n_moves, pressure, lh, ln, anti_extra(work),
                rmeta.max_anti_members)
            return work, moves, n_moves, pressure, lh, ln, go & found, k + 1

        go0 = enabled & xp.any(anti_extra(work), axis=(-1, -2))
        work, moves, n_moves, pressure, launch_h, launch_n, _, _ = \
            be.while_loop(anti_cond, anti_body,
                          (work, moves, n_moves, pressure, launch_h,
                           launch_n, go0, 0))

    return work, moves, n_moves, pressure, (launch_h, launch_n)


def balance_migrations(be, hosts: HostCols, caps, work, host_mem,
                       params: MigrationParams, rmeta: RulesMeta, enabled,
                       moves, n_moves, pads=SLOT_PAD,
                       iters: int = MIGRATION_WATERFILL_ITERS,
                       limits: MigrationLimits = MigrationLimits(),
                       launch=None):
    """DRS's greedy hill-climb balancer (paper Sec. IV-A), batched.

    One move per round: every (migratable slot on the *most-strained*
    donor host, below-average destination) candidate that passes
    reservation + memory + rule admission is scored by the drop in the
    imbalance metric it would produce -- the stddev of normalized
    entitlements with the moved VM carrying its current entitlement -- and
    the argmax wins if its gain beats the risk-cost-benefit floor
    (``min_goodness`` plus the memory-proportional migration cost).
    Rounds continue until the imbalance threshold is met, no candidate
    passes, the true imbalance stops improving, or ``max_moves`` is
    reached.  The contention gate (no strained host => migration cost
    outweighs benefit) is evaluated once on entry, as in the object plane.
    ``limits``/``launch`` apply the per-invocation launch gates shared
    with constraint correction (:class:`MigrationLimits`; a hot host with
    no endpoint headroom simply yields no admissible candidate); returns
    ``(work, moves, n_moves, pressure, launch)``.

    Two deliberate departures from the historical object-plane loop, shared
    by every engine so parity is exact by construction:

      * scoring is a closed-form update of the stddev from per-host
        entitlement sums instead of a full re-waterfill per candidate
        (which made a balancer pass O(V^2 H)); after a committed move only
        the two touched hosts are re-waterfilled (bit-identical, since the
        bisection is per-host independent);
      * candidates come from the hottest host each round -- the greedy
        argmax move relieves it anyway, and the restriction keeps a round
        O(J * H) instead of O(V * H).
    """
    xp = be.xp
    on = hosts.on
    s_ax, h_ax, j_ax = work["occ"].shape
    if launch is None:
        launch = (xp.zeros((s_ax, h_ax), dtype=n_moves.dtype),
                  xp.zeros(s_ax, dtype=n_moves.dtype))
    if params.max_moves <= 0:
        return (work, moves, n_moves, xp.zeros(s_ax, dtype=bool), launch)
    launch_h0, launch_n0 = launch
    n_on = xp.sum(on, axis=-1)
    managed = managed_capacity(xp, hosts, caps)

    def _fill(managed_cols, occ, res, lim, cpu, weights, on_cols):
        act = occ & on_cols[..., None]
        eff = xp.where(act, xp.clip(cpu, res, lim), 0.0)
        floors = xp.where(act, xp.minimum(res, lim), 0.0)
        alloc = waterfill_dense(xp, be.fori, managed_cols, floors, eff,
                                weights, iters, active=act)
        alloc = xp.where(act, alloc, 0.0)
        ents = xp.sum(alloc, axis=-1)
        ns = xp.where(managed_cols > 0.0,
                      ents / xp.maximum(managed_cols, 1e-300), 0.0)
        return act, alloc, ents, ns

    def entitlements(work):
        return _fill(managed, work["occ"], work["reservation"],
                     work["limit"], work["cpu"], work["weights"], on)

    _, alloc0, ents0, ns0 = entitlements(work)
    strained = xp.max(xp.where(on, ns0, 0.0), axis=-1)
    done0 = (~enabled | (n_on < 2)
             | (strained <= params.contention_threshold))
    pressure0 = xp.zeros(s_ax, dtype=bool)
    h_idx = xp.arange(h_ax)

    def _refill_pair(work, alloc, ents, ns, moved, src, dest):
        """Re-waterfill only the two hosts a move touched (the bisection
        is per-host independent, so this is bit-identical to a full
        pass), scattering the refreshed rows back into the carried
        entitlement state."""
        idx2 = xp.stack([src, dest], axis=-1)               # (S, 2)

        def g3(col):                                        # (S,H,J)->(S,2,J)
            return xp.take_along_axis(
                col, idx2[..., None]
                * xp.ones((1, 1, j_ax), dtype=idx2.dtype), axis=1)

        def g2(col):                                        # (S,H) -> (S,2)
            return xp.take_along_axis(col, idx2, axis=-1)

        _, alloc2, ents2, ns2 = _fill(
            g2(managed), g3(work["occ"]), g3(work["reservation"]),
            g3(work["limit"]), g3(work["cpu"]), g3(work["weights"]),
            g2(on))
        src_row = h_idx[None, :] == src[:, None]
        dst_row = h_idx[None, :] == dest[:, None]
        m2 = moved[:, None]
        m3 = moved[:, None, None]
        alloc = xp.where(m3 & src_row[..., None], alloc2[:, :1], alloc)
        alloc = xp.where(m3 & dst_row[..., None], alloc2[:, 1:], alloc)
        ents = xp.where(m2 & src_row, ents2[:, :1], ents)
        ents = xp.where(m2 & dst_row, ents2[:, 1:], ents)
        ns = xp.where(m2 & src_row, ns2[:, :1], ns)
        ns = xp.where(m2 & dst_row, ns2[:, 1:], ns)
        return alloc, ents, ns

    def cond(state):
        (work, moves, n_moves, done, prev_imb, pressure, alloc, ents, ns,
         launch_h, launch_n, k) = state
        return (k < params.max_moves) & ~xp.all(done)

    j_arange = xp.arange(j_ax)

    def body(state):
        (work, moves, n_moves, done, prev_imb, pressure, alloc, ents, ns,
         launch_h, launch_n, k) = state
        act = work["occ"] & on[..., None]
        imb = _masked_std(xp, ns, on, n_on)
        halt = (imb <= params.imbalance_threshold) | (imb >= prev_imb)
        mean_n = xp.sum(ns * on, axis=-1) / xp.maximum(n_on, 1)
        s_idx = xp.arange(s_ax)

        # Candidates come from the most-strained donor host this round:
        # the hill climb moves one VM per round anyway and the
        # argmax-gain move relieves the hottest host, so restricting the
        # candidate scan to it keeps every round O(J * H) instead of
        # O(V * H) -- at grid scale the difference between a migration
        # round and a full admission sweep.
        hot = xp.argmax(xp.where(on, ns, -xp.inf), axis=-1)     # (S,)
        ns_hot = ns[s_idx, hot]
        halt = halt | (ns_hot <= mean_n)                   # nothing above avg
        srcs = hot[:, None] * xp.ones((1, j_ax), dtype=hot.dtype)
        js = j_arange[None, :] * xp.ones((s_ax, 1), dtype=hot.dtype)
        cand = (_gather_slots(xp, act, srcs, js)
                & _gather_slots(xp, work["migratable"], srcs, js))
        # A destination with no managed capacity would starve the mover
        # (its normalized entitlement is pinned at 0): never a receiver.
        recv = (on & (ns <= mean_n[..., None]) & (managed > 0.0))
        fit, fit_unb, _, _ = _admission_slots(
            xp, on, work, managed, host_mem, srcs, js,
            limits, (launch_h, launch_n))
        aff_ok = _affinity_keep_slots(xp, work, act, rmeta.n_groups, srcs,
                                      js)
        fit = fit & aff_ok & cand[..., None] & recv[:, None, :]
        fit_unb = fit_unb & aff_ok & cand[..., None] & recv[:, None, :]
        live = ~done & ~halt
        pressure = pressure | (live & xp.any(
            fit_unb & ~fit, axis=(-1, -2)))

        # Closed-form stddev after the move: the VM carries its current
        # entitlement e_v from the hot host to the destination.
        e_v = _gather_slots(xp, alloc, srcs, js)           # (S, J)
        safe_cap = xp.where(managed > 0.0, managed, 1.0)
        cap_src = safe_cap[s_idx, hot][:, None]
        cap_d = safe_cap[:, None, :]
        ns_src = ns_hot[:, None]
        ns_d = ns[:, None, :]
        ents_src = ents[s_idx, hot][:, None]
        ns_src_new = (ents_src - e_v) / cap_src            # (S, J)
        ns_d_new = (ents[:, None, :] + e_v[..., None]) / cap_d
        t1 = xp.sum(ns * on, axis=-1)[:, None, None]
        t2 = xp.sum(ns * ns * on, axis=-1)[:, None, None]
        t1n = (t1 - ns_src[..., None] - ns_d
               + ns_src_new[..., None] + ns_d_new)
        t2n = (t2 - (ns_src ** 2)[..., None] - ns_d ** 2
               + (ns_src_new ** 2)[..., None] + ns_d_new ** 2)
        denom = xp.maximum(n_on, 1)[:, None, None]
        var = xp.maximum(t2n / denom - (t1n / denom) ** 2, 0.0)
        gain = imb[:, None, None] - xp.sqrt(var)
        cost = (params.min_goodness
                + params.cost_per_gb
                * _gather_slots(xp, work["mem"], srcs, js) / 1024.0)
        score = xp.where(fit & (gain > cost[..., None]), gain, -xp.inf)

        flat = score.reshape(s_ax, -1)                     # (S, J*H)
        best = xp.argmax(flat, axis=-1)
        found = xp.isfinite(
            xp.take_along_axis(flat, best[:, None], axis=-1)[..., 0])
        jj = best // h_ax
        dest = best % h_ax
        do = live & found
        work, moved = move_slot(xp, work, do, hot, jj, dest, pads)
        moves, n_moves = record_move(xp, moves, n_moves, moved, hot, jj,
                                     dest)
        alloc, ents, ns = _refill_pair(work, alloc, ents, ns, moved, hot,
                                       dest)
        if limits.gated:
            is_ep = ((h_idx[None, :] == hot[:, None])
                     | (h_idx[None, :] == dest[:, None]))
            launch_h = launch_h + (moved[:, None] & is_ep).astype(
                launch_h.dtype)
            launch_n = launch_n + moved.astype(launch_n.dtype)
        return (work, moves, n_moves, done | halt | ~found, imb, pressure,
                alloc, ents, ns, launch_h, launch_n, k + 1)

    state = (work, moves, n_moves, done0, xp.full(s_ax, xp.inf), pressure0,
             alloc0, ents0, ns0, launch_h0, launch_n0, 0)
    (work, moves, n_moves, _, _, pressure, _, _, _, launch_h, launch_n,
     _) = be.while_loop(cond, body, state)
    return work, moves, n_moves, pressure, (launch_h, launch_n)
