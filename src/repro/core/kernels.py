"""Backend-neutral pure-array kernels for the CloudPowerCap allocation math.

Every scale-sensitive decision in the manager pipeline -- the Eqs. 1/3/4
Watts<->capacity maps, reserved-floor computation, RedivvyPowerCap's
proportional-share cap redistribution, and BalancePowerCap's progressive
filling -- is expressed here as pure functions over plain column arrays
(caps, demands, reservations), parameterized by a ``repro.backend`` executor:

  * the object plane (``repro.core.balance`` / ``repro.core.redivvy`` via
    ``repro.drs.arrays``) runs them eagerly on NumPy with ``S == 1``;
  * the batched sweep engine (``repro.sim.batch``) runs the *same* functions
    under JAX ``jit``, batched over ``S`` scenario cells inside ``lax.scan``.

All kernels take a leading cell axis: host columns are ``(S, H)``, VM
columns ``(S, V)``, per-cell scalars ``(S,)``.  Padding convention: padded
hosts have ``on == False`` (and a nonzero ``power_peak - power_idle`` range
so the Eq. 3 division stays finite); padded/inactive VMs carry zero
floors/ceilings so they allocate nothing, with ``vm_seg`` pointing at host 0.
"""

from __future__ import annotations

from typing import NamedTuple

from repro.drs.entitlement import waterfill_core

#: Minimum cap delta that counts as a change -- must match the emission
#: threshold in ``repro.drs.actions.order_cap_changes`` so the batched
#: engine's action counting agrees with the object plane's.
CAP_CHANGE_EPS = 1e-9


class HostCols(NamedTuple):
    """Static host columns, ``(S, H)`` each (a pytree, so jit-transparent)."""

    on: object             # bool: powered on
    power_idle: object     # Watts at 0% utilization
    power_peak: object     # Watts at 100% utilization
    capacity_peak: object  # capacity at 100% utilization, uncapped
    hyp_overhead: object   # Eq. 4's C_H


class BalanceParams(NamedTuple):
    """Static configuration of the balance loop (mirrors BalanceConfig)."""

    imbalance_threshold: float = 0.01
    max_iters: int = 64
    min_transfer: float = 1e-3


class DPMParams(NamedTuple):
    """Static DPM thresholds (mirrors ``repro.drs.dpm.DPMConfig``)."""

    high_util: float = 0.81        # power-on trigger
    low_util: float = 0.45         # power-off consideration band
    target_util: float = 0.45      # post-consolidation ceiling on targets
    stable_window_s: float = 300.0 # utilization must be low this long


# ------------------------------------------------------------ power model
def capped_capacity(xp, hosts: HostCols, caps):
    """Eq. 3 per host; 0 for powered-off hosts."""
    c = xp.clip(caps, hosts.power_idle, hosts.power_peak)
    frac = (c - hosts.power_idle) / (hosts.power_peak - hosts.power_idle)
    return xp.where(hosts.on, hosts.capacity_peak * frac, 0.0)


def managed_capacity(xp, hosts: HostCols, caps):
    """Eq. 4 per host; 0 for powered-off hosts."""
    return xp.where(
        hosts.on,
        xp.maximum(capped_capacity(xp, hosts, caps) - hosts.hyp_overhead,
                   0.0),
        0.0)


def peak_managed_capacity(xp, hosts: HostCols):
    return xp.maximum(hosts.capacity_peak - hosts.hyp_overhead, 0.0)


def cap_for_managed_capacity(xp, hosts: HostCols, capacities):
    """Inverse of Eq. 4 (vectorized ``HostPowerSpec.cap_for_managed_capacity``)."""
    c = xp.clip(capacities + hosts.hyp_overhead, 0.0, hosts.capacity_peak)
    return hosts.power_idle + (hosts.power_peak - hosts.power_idle) * (
        c / hosts.capacity_peak)


def power_consumed(xp, hosts: HostCols, utilization):
    """Eq. 1: utilization -> consumed Watts (0 when powered off)."""
    u = xp.clip(utilization, 0.0, 1.0)
    return xp.where(hosts.on,
                    hosts.power_idle
                    + (hosts.power_peak - hosts.power_idle) * u,
                    0.0)


def reserved_floor_caps(xp, hosts: HostCols, cpu_reserved):
    """Per-host minimum cap honoring resident reservations (paper Fig. 3
    step 1); never below idle, 0 for powered-off hosts."""
    floor = xp.maximum(cap_for_managed_capacity(xp, hosts, cpu_reserved),
                       hosts.power_idle)
    return xp.where(hosts.on, floor, 0.0)


# ---------------------------------------------------------------- redivvy
def redivvy_caps(xp, on, caps_start, caps_floor):
    """Algorithm 1 (RedivvyPowerCap), conserving form.

    ``caps_start`` are pre-correction caps C_{i,S}; ``caps_floor`` the
    post-correction reservation floors C_{i,F}.  Hosts whose floor grew keep
    it; hosts whose floor shrank surrender exactly the fraction ``r`` of
    their excess that funds the growth and keep the rest.  Powered-off hosts
    keep ``caps_start`` untouched.
    """
    delta = xp.where(on, caps_floor - caps_start, 0.0)
    needed = xp.sum(xp.where(delta > 0.0, delta, 0.0), axis=-1)
    excess = xp.sum(xp.where(delta > 0.0, 0.0, -delta), axis=-1)
    r = xp.minimum(needed / xp.maximum(excess, 1e-300), 1.0)[..., None]
    shrunk = caps_floor + (1.0 - r) * (caps_start - caps_floor)
    new = xp.where(delta > 0.0, caps_floor, shrunk)
    # Corner cases exactly as the object-plane algorithm resolves them:
    # nothing grew -> every host keeps its original cap; growth with no
    # excess -> every host sits at its floor.
    new = xp.where((excess > 0.0)[..., None], new, caps_floor)
    new = xp.where((needed > 0.0)[..., None], new, caps_start)
    return xp.where(on, new, caps_start)


def count_cap_changes(xp, on, before, after):
    """Per-cell count of hosts whose cap change would emit a SetPowerCap
    action (the ``order_cap_changes`` threshold)."""
    changed = on & (xp.abs(after - before) > CAP_CHANGE_EPS)
    return xp.sum(changed, axis=-1)


# ---------------------------------------------------------------- balance
def _masked_std(xp, values, mask, count):
    """Population stddev of ``values`` where ``mask`` (count = mask sum)."""
    safe = xp.maximum(count, 1)
    mean = xp.sum(values * mask, axis=-1) / safe
    var = xp.sum(mask * (values - mean[..., None]) ** 2, axis=-1) / safe
    return xp.sqrt(var)


def entitlement_sums(be, hosts: HostCols, caps, vm_floors, vm_ceils,
                     vm_weights, vm_seg, iters: int = 200):
    """Per-host VM-entitlement sums at the given caps: one lockstep
    waterfill over every (cell, host, VM) at once.

    VM columns are ``(S, V)`` with ``vm_seg`` the resident host index
    (inactive/padded VMs: zero floor/ceiling, seg 0).  Segments are
    flattened to ``S * H`` so a single bisection serves the whole batch.
    """
    xp = be.xp
    s, h = caps.shape
    v = vm_seg.shape[-1]
    offs = xp.arange(s)[:, None] * h
    seg_flat = (vm_seg + offs).reshape(s * v)
    capacity = managed_capacity(xp, hosts, caps)
    alloc = waterfill_core(
        be, capacity.reshape(s * h), vm_floors.reshape(s * v),
        vm_ceils.reshape(s * v), vm_weights.reshape(s * v), seg_flat,
        s * h, iters)
    return be.seg_sum(alloc, seg_flat, s * h).reshape(s, h)


def balance_caps(be, hosts: HostCols, caps, ents_at, cpu_reserved, budget,
                 enabled, params: BalanceParams = BalanceParams()):
    """Algorithm 2 (BalancePowerCap) as a pure batched loop.

    Progressive filling toward max-min fairness on normalized entitlements
    N_h, moving Watts instead of VMs.  ``ents_at(caps) -> (S, H)`` supplies
    the per-host VM-entitlement sums at candidate caps (the object plane
    injects the segment waterfill :func:`entitlement_sums`; the batched
    engine injects the dense-slot form).  Returns ``(caps, did)`` where
    ``did`` is the per-cell did-anything flag.  Cells with
    ``enabled == False`` or fewer than two powered-on hosts pass through
    unchanged.

    The loop body is shared verbatim between backends: the NumPy driver
    (``S == 1`` in the object-plane manager) early-exits through
    ``be.while_loop`` on concrete booleans; the JAX driver runs the same
    ``while_loop`` under ``jit`` with per-cell ``done`` masking, so
    converged cells freeze while stragglers keep transferring.
    """
    xp = be.xp
    on = hosts.on
    n_on = xp.sum(on, axis=-1)
    peak_managed = peak_managed_capacity(xp, hosts)

    def norm(ents, managed):
        return xp.where(managed > 0.0,
                        ents / xp.maximum(managed, 1e-300), 0.0)

    managed = managed_capacity(xp, hosts, caps)
    ents = ents_at(caps)
    ns = norm(ents, managed)
    done0 = ~enabled | (n_on < 2)
    did0 = xp.zeros_like(done0)

    def cond(state):
        caps, managed, ents, ns, done, did, rounds = state
        return (rounds < params.max_iters) & ~xp.all(done)

    def body(state):
        caps, managed, ents, ns, done, did, rounds = state
        imbalance = _masked_std(xp, ns, on, n_on)
        total_cap = xp.sum(managed * on, axis=-1)
        # Cluster-average normalized entitlement: the water level every
        # host would sit at if capacity were perfectly divisible.
        n_avg = xp.sum(ents * on, axis=-1) / xp.maximum(total_cap, 1e-300)
        halt = ((imbalance <= params.imbalance_threshold)
                | (total_cap <= 0.0) | (n_avg <= 1e-12))

        # Batched progressive filling: every host above the average level
        # is a recipient (bounded by its physical peak), every host below
        # is a donor (bounded by the average level and by its reservations).
        cbar = ents / xp.maximum(n_avg, 1e-300)[..., None]
        recipients = on & (ns > n_avg[..., None])
        donors = on & (ns < n_avg[..., None])
        need = xp.where(
            recipients,
            xp.maximum(xp.minimum(peak_managed, cbar) - managed, 0.0), 0.0)
        avail = xp.where(
            donors,
            xp.maximum(managed - xp.maximum(cbar, cpu_reserved), 0.0), 0.0)
        total_need = xp.sum(need, axis=-1)
        total_avail = xp.sum(avail, axis=-1)
        transfer = xp.minimum(total_need, total_avail)
        # Powercap range exhausted -> DRS migration handles the residue.
        halt = halt | (transfer <= params.min_transfer)

        grow = recipients & (need > 0.0)
        new_caps = xp.where(grow, cap_for_managed_capacity(
            xp, hosts,
            managed + transfer[..., None] * need
            / xp.maximum(total_need, 1e-300)[..., None]), caps)
        shrink = donors & (avail > 0.0)
        new_caps = xp.where(shrink, cap_for_managed_capacity(
            xp, hosts,
            managed - transfer[..., None] * avail
            / xp.maximum(total_avail, 1e-300)[..., None]), new_caps)
        # Watts conservation under heterogeneous specs: trim recipients if
        # the budget would be exceeded (linear maps conserve exactly for
        # homogeneous specs; this is a safety net).
        over = xp.sum(new_caps * on, axis=-1) - budget
        n_rec = xp.sum(recipients, axis=-1)
        trim = (over > 1e-6)[..., None] & recipients
        new_caps = xp.where(
            trim,
            xp.maximum(new_caps
                       - (over / xp.maximum(n_rec, 1))[..., None],
                       hosts.power_idle),
            new_caps)

        new_managed = managed_capacity(xp, hosts, new_caps)
        new_ents = ents_at(new_caps)
        new_ns = norm(new_ents, new_managed)
        # Heterogeneous Watts<->capacity maps (plus the trim above) can make
        # a round non-improving near convergence: skip it and stop rather
        # than oscillate.
        worse = _masked_std(xp, new_ns, on, n_on) > imbalance + 1e-12
        commit = ~done & ~halt & ~worse
        cm = commit[..., None]
        return (xp.where(cm, new_caps, caps),
                xp.where(cm, new_managed, managed),
                xp.where(cm, new_ents, ents),
                xp.where(cm, new_ns, ns),
                done | halt | worse,
                did | commit,
                rounds + 1)

    state = (caps, managed, ents, ns, done0, did0, 0)
    caps, _, _, _, _, did, _ = be.while_loop(cond, body, state)
    return caps, did


# -------------------------------------------------- DPM + redistribution
def host_utilizations(xp, hosts: HostCols, caps, eff_demand_h, mem_demand_h,
                      host_mem):
    """Per-host (cpu, mem) utilizations, matching the object plane's
    ``ArrayView.host_cpu_utilization`` / ``host_mem_utilization``: zero for
    powered-off hosts and hosts with no capacity."""
    managed = managed_capacity(xp, hosts, caps)
    cpu = xp.where(managed > 0.0,
                   eff_demand_h / xp.maximum(managed, 1e-300), 0.0)
    ok = hosts.on & (host_mem > 0.0)
    mem = xp.where(ok, mem_demand_h / xp.maximum(host_mem, 1e-300), 0.0)
    return cpu, mem


def dpm_hot_mask(xp, on, cpu_util, mem_util, high_util):
    """DPM power-on trigger: powered-on hosts running hot on CPU or memory."""
    return on & ((cpu_util > high_util) | (mem_util > high_util))


def dpm_all_low(xp, on, cpu_util, mem_util, low_util):
    """DPM power-off consideration: every powered-on host below the low band
    on both CPU and memory (per cell; vacuously true with no hosts on)."""
    low = (cpu_util < low_util) & (mem_util < low_util)
    return xp.all(~on | low, axis=-1)


def power_on_funding_caps(be, hosts: HostCols, caps, cand, cpu_util,
                          host_demand, cpu_reserved, budget,
                          high_util: float):
    """Algorithm 3 power-on funding (paper Fig. 5), batched.

    Funds the cap of candidate host ``cand`` (``(S,)`` index): unallocated
    budget first, then low-utilization donors drained -- lowest utilization
    first -- down to the capacity at which DPM's power-on trigger would fire
    (no oscillation), never below their reservations or idle power.  An
    already-powered-on candidate keeps its allocation; funding only tops it
    up toward peak.

    Returns ``(new_caps, granted)`` where ``new_caps`` has donors drained
    and the candidate at its granted cap (``min(granted, peak)``), and
    ``granted`` is per cell.  The caller decides feasibility
    (``managed_capacity(granted) > 0``) and emission.
    """
    xp = be.xp
    on = hosts.on
    h_idx = xp.arange(caps.shape[-1])

    def at_cand(col):
        return xp.take_along_axis(col, cand[..., None], axis=-1)[..., 0]

    peak_c = at_cand(hosts.power_peak)
    cand_on = at_cand(on)
    granted0 = xp.where(cand_on, at_cand(caps), 0.0)
    needed = xp.maximum(peak_c - granted0, 0.0)

    # Step 1: unallocated budget.
    pool = xp.maximum(budget - xp.sum(xp.where(on, caps, 0.0), axis=-1), 0.0)
    take0 = xp.minimum(pool, needed)
    needed = needed - take0

    # Step 2: greedy drain, replicated exactly as a sorted prefix-sum: the
    # k-th coolest donor gives ``clip(needed - taken_so_far, 0, avail_k)``,
    # and donors past the 1e-9 residue give nothing (the object plane's
    # early break).
    is_cand = h_idx == cand[..., None]
    donor = on & ~is_cand & (cpu_util < high_util)
    floor_capacity = xp.maximum(host_demand / high_util, cpu_reserved)
    floor_cap = xp.maximum(
        cap_for_managed_capacity(xp, hosts, floor_capacity),
        hosts.power_idle)
    avail = xp.where(donor, xp.maximum(caps - floor_cap, 0.0), 0.0)
    order = be.argsort(xp.where(donor, cpu_util, xp.inf), axis=-1)
    sorted_avail = xp.take_along_axis(avail, order, axis=-1)
    cum_before = xp.cumsum(sorted_avail, axis=-1) - sorted_avail
    residue = needed[..., None] - cum_before
    take = xp.where(residue > 1e-9,
                    xp.clip(residue, 0.0, sorted_avail), 0.0)
    inverse = be.argsort(order, axis=-1)
    taken = xp.take_along_axis(take, inverse, axis=-1)

    granted = xp.minimum(granted0 + take0 + xp.sum(take, axis=-1), peak_c)
    new_caps = xp.where(is_cand, granted[..., None], caps - taken)
    return new_caps, granted


def power_off_reabsorb_caps(xp, hosts: HostCols, caps, off_idx, budget):
    """Algorithm 3 power-off reabsorption: the victim's cap returns to the
    pool and is spread over the remaining powered-on hosts proportionally to
    their headroom to peak.  Returns the new cap column (victim at 0)."""
    h_idx = xp.arange(caps.shape[-1])
    is_off = h_idx == off_idx[..., None]
    on_after = hosts.on & ~is_off
    caps0 = xp.where(is_off, 0.0, caps)
    pool = xp.maximum(
        budget - xp.sum(xp.where(on_after, caps0, 0.0), axis=-1), 0.0)
    recipients = on_after & (caps0 < hosts.power_peak - 1e-9)
    headroom = xp.where(recipients, hosts.power_peak - caps0, 0.0)
    total_head = xp.sum(headroom, axis=-1)
    grant_total = xp.minimum(pool, total_head)
    grown = xp.minimum(
        caps0 + grant_total[..., None] * headroom
        / xp.maximum(total_head, 1e-300)[..., None],
        hosts.power_peak)
    ok = (total_head > 0.0) & (pool > 0.0)
    return xp.where(ok[..., None] & recipients, grown, caps0)


def plan_evacuation(be, hosts: HostCols, caps, victim, occ, eff_slot,
                    mem_slot, res_slot, migratable, host_mem,
                    target_util: float):
    """DPM evacuation planning on the dense slot layout ``(S, H, J)``.

    Replays ``repro.drs.dpm.run_dpm``'s greedy: the victim's VMs leave in
    decreasing current-memory order (stable on ties), each to the feasible
    powered-on host with the strictly lowest post-move utilization (first
    host on ties), subject to the reservation/memory fit check and the
    ``target_util`` ceiling on both CPU and memory.  All-or-nothing: a
    single unplaceable or unmigratable VM cancels the whole evacuation.

    Returns ``(ok, order, dests, n_evac, slot_pressure)``: ``order`` is the
    per-cell slot visit order, ``dests[:, k]`` the destination host of the
    k-th evacuee (-1 when unused), and ``slot_pressure`` flags cells where
    the ``J`` slot bound excluded an otherwise-feasible destination (the
    caller must treat those results as invalid -- repack with more slack).
    """
    xp = be.xp
    s, h, j = occ.shape
    on = hosts.on
    h_idx = xp.arange(h)
    managed = managed_capacity(xp, hosts, caps)
    act = occ & on[..., None]
    eff_h = xp.sum(xp.where(act, eff_slot, 0.0), axis=-1)
    mem_h = xp.sum(xp.where(act, mem_slot, 0.0), axis=-1)
    res_h = xp.sum(xp.where(act, res_slot, 0.0), axis=-1)
    cnt_h = xp.sum(occ, axis=-1)
    is_vic = h_idx == victim[..., None]

    def at_victim(col):
        idx = victim[..., None, None] * xp.ones((s, 1, j), dtype=victim.dtype)
        return xp.take_along_axis(col, idx, axis=1)[:, 0]

    vic_occ = at_victim(occ)
    vic_eff = at_victim(eff_slot)
    vic_mem = at_victim(mem_slot)
    vic_res = at_victim(res_slot)
    vic_mig = at_victim(migratable)
    order = be.argsort(xp.where(vic_occ, -vic_mem, xp.inf), axis=-1)
    n_vic = xp.sum(vic_occ, axis=-1)

    def take_k(col, k):
        idx = xp.take_along_axis(order, xp.full((s, 1), k, order.dtype),
                                 axis=-1)
        return xp.take_along_axis(col, idx, axis=-1)[..., 0]

    def body(k, st):
        eff_h, mem_h, res_h, cnt_h, dests, ok, pressure = st
        valid = k < n_vic
        e = take_k(vic_eff, k)
        m = take_k(vic_mem, k)
        r = take_k(vic_res, k)
        mig = take_k(vic_mig, k)
        fit = on & ~is_vic
        fit = fit & (res_h + r[..., None] <= managed + 1e-9)
        fit = fit & (mem_h + m[..., None] <= host_mem + 1e-9)
        util_after = (eff_h + e[..., None]) / xp.maximum(managed, 1e-9)
        mem_after = (mem_h + m[..., None]) / xp.maximum(host_mem, 1e-9)
        fit = fit & (util_after <= target_util) & (mem_after <= target_util)
        slot_ok = cnt_h < j
        pressure = pressure | xp.any(
            valid[..., None] & fit & ~slot_ok, axis=-1)
        fit = fit & slot_ok
        score = xp.where(fit, util_after, xp.inf)
        best = xp.argmin(score, axis=-1)
        found = xp.isfinite(xp.min(score, axis=-1))
        ok = ok & (~valid | (mig & found))
        place = valid & ok
        upd = place[..., None] & (h_idx == best[..., None])
        col_k = xp.arange(j) == k
        dests = xp.where(col_k[None, :] & place[..., None],
                         best[..., None], dests)
        return (eff_h + xp.where(upd, e[..., None], 0.0),
                mem_h + xp.where(upd, m[..., None], 0.0),
                res_h + xp.where(upd, r[..., None], 0.0),
                cnt_h + upd.astype(cnt_h.dtype),
                dests, ok, pressure)

    init = (eff_h, mem_h, res_h, cnt_h,
            xp.full((s, j), -1, dtype=victim.dtype),
            xp.ones(s, dtype=bool), xp.zeros(s, dtype=bool))
    _, _, _, _, dests, ok, pressure = be.fori(j, body, init)
    n_evac = xp.where(ok, n_vic, 0)
    return ok, order, dests, n_evac, pressure
