"""Backend-neutral pure-array kernels for the CloudPowerCap allocation math.

Every scale-sensitive decision in the manager pipeline -- the Eqs. 1/3/4
Watts<->capacity maps, reserved-floor computation, RedivvyPowerCap's
proportional-share cap redistribution, and BalancePowerCap's progressive
filling -- is expressed here as pure functions over plain column arrays
(caps, demands, reservations), parameterized by a ``repro.backend`` executor:

  * the object plane (``repro.core.balance`` / ``repro.core.redivvy`` via
    ``repro.drs.arrays``) runs them eagerly on NumPy with ``S == 1``;
  * the batched sweep engine (``repro.sim.batch``) runs the *same* functions
    under JAX ``jit``, batched over ``S`` scenario cells inside ``lax.scan``.

All kernels take a leading cell axis: host columns are ``(S, H)``, VM
columns ``(S, V)``, per-cell scalars ``(S,)``.  Padding convention: padded
hosts have ``on == False`` (and a nonzero ``power_peak - power_idle`` range
so the Eq. 3 division stays finite); padded/inactive VMs carry zero
floors/ceilings so they allocate nothing, with ``vm_seg`` pointing at host 0.
"""

from __future__ import annotations

from typing import NamedTuple

from repro.drs.entitlement import waterfill_core

#: Minimum cap delta that counts as a change -- must match the emission
#: threshold in ``repro.drs.actions.order_cap_changes`` so the batched
#: engine's action counting agrees with the object plane's.
CAP_CHANGE_EPS = 1e-9


class HostCols(NamedTuple):
    """Static host columns, ``(S, H)`` each (a pytree, so jit-transparent)."""

    on: object             # bool: powered on
    power_idle: object     # Watts at 0% utilization
    power_peak: object     # Watts at 100% utilization
    capacity_peak: object  # capacity at 100% utilization, uncapped
    hyp_overhead: object   # Eq. 4's C_H


class BalanceParams(NamedTuple):
    """Static configuration of the balance loop (mirrors BalanceConfig)."""

    imbalance_threshold: float = 0.01
    max_iters: int = 64
    min_transfer: float = 1e-3


# ------------------------------------------------------------ power model
def capped_capacity(xp, hosts: HostCols, caps):
    """Eq. 3 per host; 0 for powered-off hosts."""
    c = xp.clip(caps, hosts.power_idle, hosts.power_peak)
    frac = (c - hosts.power_idle) / (hosts.power_peak - hosts.power_idle)
    return xp.where(hosts.on, hosts.capacity_peak * frac, 0.0)


def managed_capacity(xp, hosts: HostCols, caps):
    """Eq. 4 per host; 0 for powered-off hosts."""
    return xp.where(
        hosts.on,
        xp.maximum(capped_capacity(xp, hosts, caps) - hosts.hyp_overhead,
                   0.0),
        0.0)


def peak_managed_capacity(xp, hosts: HostCols):
    return xp.maximum(hosts.capacity_peak - hosts.hyp_overhead, 0.0)


def cap_for_managed_capacity(xp, hosts: HostCols, capacities):
    """Inverse of Eq. 4 (vectorized ``HostPowerSpec.cap_for_managed_capacity``)."""
    c = xp.clip(capacities + hosts.hyp_overhead, 0.0, hosts.capacity_peak)
    return hosts.power_idle + (hosts.power_peak - hosts.power_idle) * (
        c / hosts.capacity_peak)


def power_consumed(xp, hosts: HostCols, utilization):
    """Eq. 1: utilization -> consumed Watts (0 when powered off)."""
    u = xp.clip(utilization, 0.0, 1.0)
    return xp.where(hosts.on,
                    hosts.power_idle
                    + (hosts.power_peak - hosts.power_idle) * u,
                    0.0)


def reserved_floor_caps(xp, hosts: HostCols, cpu_reserved):
    """Per-host minimum cap honoring resident reservations (paper Fig. 3
    step 1); never below idle, 0 for powered-off hosts."""
    floor = xp.maximum(cap_for_managed_capacity(xp, hosts, cpu_reserved),
                       hosts.power_idle)
    return xp.where(hosts.on, floor, 0.0)


# ---------------------------------------------------------------- redivvy
def redivvy_caps(xp, on, caps_start, caps_floor):
    """Algorithm 1 (RedivvyPowerCap), conserving form.

    ``caps_start`` are pre-correction caps C_{i,S}; ``caps_floor`` the
    post-correction reservation floors C_{i,F}.  Hosts whose floor grew keep
    it; hosts whose floor shrank surrender exactly the fraction ``r`` of
    their excess that funds the growth and keep the rest.  Powered-off hosts
    keep ``caps_start`` untouched.
    """
    delta = xp.where(on, caps_floor - caps_start, 0.0)
    needed = xp.sum(xp.where(delta > 0.0, delta, 0.0), axis=-1)
    excess = xp.sum(xp.where(delta > 0.0, 0.0, -delta), axis=-1)
    r = xp.minimum(needed / xp.maximum(excess, 1e-300), 1.0)[..., None]
    shrunk = caps_floor + (1.0 - r) * (caps_start - caps_floor)
    new = xp.where(delta > 0.0, caps_floor, shrunk)
    # Corner cases exactly as the object-plane algorithm resolves them:
    # nothing grew -> every host keeps its original cap; growth with no
    # excess -> every host sits at its floor.
    new = xp.where((excess > 0.0)[..., None], new, caps_floor)
    new = xp.where((needed > 0.0)[..., None], new, caps_start)
    return xp.where(on, new, caps_start)


def count_cap_changes(xp, on, before, after):
    """Per-cell count of hosts whose cap change would emit a SetPowerCap
    action (the ``order_cap_changes`` threshold)."""
    changed = on & (xp.abs(after - before) > CAP_CHANGE_EPS)
    return xp.sum(changed, axis=-1)


# ---------------------------------------------------------------- balance
def _masked_std(xp, values, mask, count):
    """Population stddev of ``values`` where ``mask`` (count = mask sum)."""
    safe = xp.maximum(count, 1)
    mean = xp.sum(values * mask, axis=-1) / safe
    var = xp.sum(mask * (values - mean[..., None]) ** 2, axis=-1) / safe
    return xp.sqrt(var)


def entitlement_sums(be, hosts: HostCols, caps, vm_floors, vm_ceils,
                     vm_weights, vm_seg, iters: int = 200):
    """Per-host VM-entitlement sums at the given caps: one lockstep
    waterfill over every (cell, host, VM) at once.

    VM columns are ``(S, V)`` with ``vm_seg`` the resident host index
    (inactive/padded VMs: zero floor/ceiling, seg 0).  Segments are
    flattened to ``S * H`` so a single bisection serves the whole batch.
    """
    xp = be.xp
    s, h = caps.shape
    v = vm_seg.shape[-1]
    offs = xp.arange(s)[:, None] * h
    seg_flat = (vm_seg + offs).reshape(s * v)
    capacity = managed_capacity(xp, hosts, caps)
    alloc = waterfill_core(
        be, capacity.reshape(s * h), vm_floors.reshape(s * v),
        vm_ceils.reshape(s * v), vm_weights.reshape(s * v), seg_flat,
        s * h, iters)
    return be.seg_sum(alloc, seg_flat, s * h).reshape(s, h)


def balance_caps(be, hosts: HostCols, caps, ents_at, cpu_reserved, budget,
                 enabled, params: BalanceParams = BalanceParams()):
    """Algorithm 2 (BalancePowerCap) as a pure batched loop.

    Progressive filling toward max-min fairness on normalized entitlements
    N_h, moving Watts instead of VMs.  ``ents_at(caps) -> (S, H)`` supplies
    the per-host VM-entitlement sums at candidate caps (the object plane
    injects the segment waterfill :func:`entitlement_sums`; the batched
    engine injects the dense-slot form).  Returns ``(caps, did)`` where
    ``did`` is the per-cell did-anything flag.  Cells with
    ``enabled == False`` or fewer than two powered-on hosts pass through
    unchanged.

    The loop body is shared verbatim between backends: the NumPy driver
    (``S == 1`` in the object-plane manager) early-exits through
    ``be.while_loop`` on concrete booleans; the JAX driver runs the same
    ``while_loop`` under ``jit`` with per-cell ``done`` masking, so
    converged cells freeze while stragglers keep transferring.
    """
    xp = be.xp
    on = hosts.on
    n_on = xp.sum(on, axis=-1)
    peak_managed = peak_managed_capacity(xp, hosts)

    def norm(ents, managed):
        return xp.where(managed > 0.0,
                        ents / xp.maximum(managed, 1e-300), 0.0)

    managed = managed_capacity(xp, hosts, caps)
    ents = ents_at(caps)
    ns = norm(ents, managed)
    done0 = ~enabled | (n_on < 2)
    did0 = xp.zeros_like(done0)

    def cond(state):
        caps, managed, ents, ns, done, did, rounds = state
        return (rounds < params.max_iters) & ~xp.all(done)

    def body(state):
        caps, managed, ents, ns, done, did, rounds = state
        imbalance = _masked_std(xp, ns, on, n_on)
        total_cap = xp.sum(managed * on, axis=-1)
        # Cluster-average normalized entitlement: the water level every
        # host would sit at if capacity were perfectly divisible.
        n_avg = xp.sum(ents * on, axis=-1) / xp.maximum(total_cap, 1e-300)
        halt = ((imbalance <= params.imbalance_threshold)
                | (total_cap <= 0.0) | (n_avg <= 1e-12))

        # Batched progressive filling: every host above the average level
        # is a recipient (bounded by its physical peak), every host below
        # is a donor (bounded by the average level and by its reservations).
        cbar = ents / xp.maximum(n_avg, 1e-300)[..., None]
        recipients = on & (ns > n_avg[..., None])
        donors = on & (ns < n_avg[..., None])
        need = xp.where(
            recipients,
            xp.maximum(xp.minimum(peak_managed, cbar) - managed, 0.0), 0.0)
        avail = xp.where(
            donors,
            xp.maximum(managed - xp.maximum(cbar, cpu_reserved), 0.0), 0.0)
        total_need = xp.sum(need, axis=-1)
        total_avail = xp.sum(avail, axis=-1)
        transfer = xp.minimum(total_need, total_avail)
        # Powercap range exhausted -> DRS migration handles the residue.
        halt = halt | (transfer <= params.min_transfer)

        grow = recipients & (need > 0.0)
        new_caps = xp.where(grow, cap_for_managed_capacity(
            xp, hosts,
            managed + transfer[..., None] * need
            / xp.maximum(total_need, 1e-300)[..., None]), caps)
        shrink = donors & (avail > 0.0)
        new_caps = xp.where(shrink, cap_for_managed_capacity(
            xp, hosts,
            managed - transfer[..., None] * avail
            / xp.maximum(total_avail, 1e-300)[..., None]), new_caps)
        # Watts conservation under heterogeneous specs: trim recipients if
        # the budget would be exceeded (linear maps conserve exactly for
        # homogeneous specs; this is a safety net).
        over = xp.sum(new_caps * on, axis=-1) - budget
        n_rec = xp.sum(recipients, axis=-1)
        trim = (over > 1e-6)[..., None] & recipients
        new_caps = xp.where(
            trim,
            xp.maximum(new_caps
                       - (over / xp.maximum(n_rec, 1))[..., None],
                       hosts.power_idle),
            new_caps)

        new_managed = managed_capacity(xp, hosts, new_caps)
        new_ents = ents_at(new_caps)
        new_ns = norm(new_ents, new_managed)
        # Heterogeneous Watts<->capacity maps (plus the trim above) can make
        # a round non-improving near convergence: skip it and stop rather
        # than oscillate.
        worse = _masked_std(xp, new_ns, on, n_on) > imbalance + 1e-12
        commit = ~done & ~halt & ~worse
        cm = commit[..., None]
        return (xp.where(cm, new_caps, caps),
                xp.where(cm, new_managed, managed),
                xp.where(cm, new_ents, ents),
                xp.where(cm, new_ns, ns),
                done | halt | worse,
                did | commit,
                rounds + 1)

    state = (caps, managed, ents, ns, done0, did0, 0)
    caps, _, _, _, _, did, _ = be.while_loop(cond, body, state)
    return caps, did
