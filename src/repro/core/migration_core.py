"""MigrationCore: the constraint-correction + load-balancing migration
protocol, engine-neutral (sibling of :class:`repro.core.manager_core.ManagerCore`).

One DRS invocation generates migrations in two places:

  * *constraint correction* (phase 1): moves that fix affinity /
    anti-affinity / VM-host rule violations, with the fit check seeing an
    injected capacity view -- the current cap, or the *fundable* capacity a
    host could reach if its cap were raised from the unreserved budget
    (paper Fig. 1a / Fig. 3);
  * *entitlement balancing* (phase 2 residue): DRS's greedy hill-climb,
    one risk-cost-benefit-filtered move at a time, after BalancePowerCap
    has removed what imbalance Watts can.

The decisions live in ``repro.core.kernels`` (``correct_constraints_slots``,
``balance_migrations``, ``move_slot``) over the dense slot layout
``(S, H, J)`` with rules encoded as arrays (``repro.drs.arrays.RulesPack``).
This module is the object-plane adapter: it packs a ``ClusterSnapshot`` into
a one-cell slot layout, runs the same kernels the batched sweep engine
compiles into its ``lax.scan``, and replays the emitted slot moves onto the
snapshot as ``(vm_id, dest_host)`` pairs.  ``repro.drs.placement`` and
``repro.drs.balancer`` are thin wrappers over this class, so the object,
vector, and batched engines run the identical migration protocol; parity is
enforced by ``tests/test_migration_parity.py``.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro import backend as backend_mod
from repro.core import kernels
from repro.drs.arrays import RulesPack, dense_slot_assignment
from repro.drs.snapshot import ClusterSnapshot


class _DenseCell:
    """One snapshot packed into the kernels' dense slot layout (S == 1)."""

    def __init__(self, snapshot: ClusterSnapshot, extra_slots: int,
                 pack: Optional[RulesPack] = None):
        hosts = list(snapshot.hosts.values())
        self.host_ids = [h.host_id for h in hosts]
        host_index = {hid: i for i, hid in enumerate(self.host_ids)}
        n_hosts = len(hosts)
        vms, order, hj, slot, counts = dense_slot_assignment(
            snapshot, n_hosts)
        n_slots = int(max(counts.max() if counts.size else 0, 1)
                      + max(extra_slots, 1))
        f64 = np.float64

        def col(vals, fill, dtype=f64, trailing=()):
            arr = np.full((1, n_hosts, n_slots) + trailing, fill,
                          dtype=dtype)
            arr[0, hj, slot] = np.asarray(vals)[order]
            return arr

        self.work = {
            "occ": col(np.ones(len(vms), dtype=bool), False, bool),
            "reservation": col([v.reservation for v in vms], 0.0),
            "limit": col([v.limit for v in vms], np.inf),
            "weights": col([max(v.shares, 1e-12) for v in vms], 1e-12),
            "migratable": col([v.migratable for v in vms], True, bool),
            "cpu": col([v.demand for v in vms], 0.0),
            "mem": col([v.mem_demand for v in vms], 0.0),
        }
        if pack is None:
            pack = _rules_pack(snapshot)
        self.rmeta = pack.meta()
        if pack.n_groups:
            self.work["aff_group"] = col(pack.affinity_group, -1, np.int64)
        if pack.n_vmhost:
            self.work["allowed"] = col(pack.allowed, True, bool,
                                       trailing=(n_hosts,))
        if pack.n_anti:
            self.work["anti"] = col(pack.anti_member.T, False, bool,
                                    trailing=(pack.n_anti,))
        self.hosts = kernels.HostCols(
            on=np.array([[h.powered_on for h in hosts]], dtype=bool),
            power_idle=np.array([[h.spec.power_idle for h in hosts]],
                                dtype=f64),
            power_peak=np.array([[h.spec.power_peak for h in hosts]],
                                dtype=f64),
            capacity_peak=np.array([[h.spec.capacity_peak for h in hosts]],
                                   dtype=f64),
            hyp_overhead=np.array(
                [[h.spec.hypervisor_overhead for h in hosts]], dtype=f64))
        self.caps = np.array([[h.power_cap for h in hosts]], dtype=f64)
        self.host_mem = np.array([[h.spec.memory_mb for h in hosts]],
                                 dtype=f64)
        # Slot -> VM-row map for replaying kernel moves onto the snapshot.
        self._slot_vm = np.full((n_hosts, n_slots), -1, dtype=np.int64)
        self._slot_vm[hj, slot] = order
        self._occ = self.work["occ"][0].copy()
        self._vms = vms

    def replay(self, snapshot: ClusterSnapshot, moves: np.ndarray,
               n_moves: int) -> list[tuple[str, str]]:
        """Apply kernel moves to the snapshot, mirroring ``move_slot``'s
        first-free-slot placement so slot coordinates stay aligned."""
        out: list[tuple[str, str]] = []
        for src, j, dst in moves[0, :n_moves]:
            row = int(self._slot_vm[src, j])
            ns = int(np.argmin(self._occ[dst]))
            self._slot_vm[dst, ns] = row
            self._slot_vm[src, j] = -1
            self._occ[dst, ns] = True
            self._occ[src, j] = False
            vm_id = self._vms[row].vm_id
            dest_host = self.host_ids[int(dst)]
            snapshot.move_vm(vm_id, dest_host)
            out.append((vm_id, dest_host))
        return out


class LaunchBudget:
    """Per-invocation migration-launch ledger shared across phases.

    One instance is created per manager invocation (when the cluster is
    gated, :class:`repro.core.kernels.MigrationLimits`) and threaded
    through constraint correction *then* balancing, so the phases share
    one set of per-host endpoint counts and one cluster total -- exactly
    the launch state the batched engine carries between the two kernel
    calls inside its jitted invocation.  Host order is the snapshot's
    inventory order (``_DenseCell`` packs every phase identically).
    Evacuations are exempt (see ``MigrationLimits``) and never consult
    the ledger.
    """

    def __init__(self, limits: kernels.MigrationLimits, n_hosts: int):
        self.limits = limits
        self.launch_h = np.zeros((1, n_hosts), dtype=np.int64)
        self.launch_n = np.zeros(1, dtype=np.int64)

    @property
    def launch(self):
        return self.launch_h, self.launch_n

    def update(self, launch) -> None:
        self.launch_h, self.launch_n = launch


class MigrationCore:
    """Drives the migration protocol for one snapshot (object plane)."""

    def __init__(self,
                 params: Optional[kernels.MigrationParams] = None):
        self.params = params or kernels.MigrationParams()

    # ------------------------------------------------------------------
    def _moves_buffer(self, bound: int):
        bound = max(bound, 1)
        return (np.full((1, bound, 3), -1, dtype=np.int64),
                np.zeros(1, dtype=np.int64))

    def correct(self, snapshot: ClusterSnapshot,
                capacity_fn: Callable[[ClusterSnapshot, str], float],
                budget: Optional[LaunchBudget] = None
                ) -> list[tuple[str, str]]:
        """Constraint correction: fix rule violations, mutating
        ``snapshot`` in place; returns the (vm_id, dest_host) moves.
        ``budget`` (when the cluster gates migration launches) contributes
        the shared launch counts to admission and absorbs the updates."""
        pack = _rules_pack(snapshot)
        meta = pack.meta()
        if not meta.any:
            return []
        # Worst case every correction lands on one host (several affinity
        # groups anchoring on the same fullest host): provision the full
        # move bound so the slot axis can never bind a decision.
        cell = _DenseCell(snapshot, extra_slots=max(meta.move_bound, 1),
                          pack=pack)
        capacity = np.array(
            [[capacity_fn(snapshot, hid) if snapshot.hosts[hid].powered_on
              else 0.0 for hid in cell.host_ids]], dtype=np.float64)
        moves, n_moves = self._moves_buffer(cell.rmeta.move_bound)
        enabled = np.ones(1, dtype=bool)
        limits = budget.limits if budget else kernels.MigrationLimits()
        launch = budget.launch if budget else None
        _, moves, n_moves, pressure, launch = \
            kernels.correct_constraints_slots(
                backend_mod.NUMPY, cell.hosts, capacity, cell.work,
                cell.host_mem, cell.rmeta, enabled, moves, n_moves,
                limits=limits, launch=launch)
        _check_pressure(pressure)
        if budget:
            budget.update(launch)
        return cell.replay(snapshot, moves, int(n_moves[0]))

    def balance(self, snapshot: ClusterSnapshot,
                budget: Optional[LaunchBudget] = None
                ) -> list[tuple[str, str]]:
        """Greedy hill-climb balancing; mutates ``snapshot`` (what-if) and
        returns the chosen moves."""
        if self.params.max_moves <= 0:
            return []
        cell = _DenseCell(snapshot,
                          extra_slots=max(self.params.max_moves, 1))
        moves, n_moves = self._moves_buffer(self.params.max_moves)
        enabled = np.ones(1, dtype=bool)
        limits = budget.limits if budget else kernels.MigrationLimits()
        launch = budget.launch if budget else None
        _, moves, n_moves, pressure, launch = kernels.balance_migrations(
            backend_mod.NUMPY, cell.hosts, cell.caps, cell.work,
            cell.host_mem, self.params, cell.rmeta, enabled, moves, n_moves,
            limits=limits, launch=launch)
        _check_pressure(pressure)
        if budget:
            budget.update(launch)
        return cell.replay(snapshot, moves, int(n_moves[0]))


def _check_pressure(pressure: np.ndarray) -> None:
    """The slot axis binding a migration decision must fail loudly (the
    batched engine's invariant); the headroom above makes this provably
    unreachable, so tripping it is an internal sizing bug."""
    if bool(np.asarray(pressure).any()):
        raise RuntimeError(
            "slot capacity bound a migration decision on the object plane; "
            "dense-cell slot headroom undersized")


def _rules_pack(snapshot: ClusterSnapshot) -> RulesPack:
    """Build the snapshot's RulesPack (VM/host rows in inventory order --
    the same order ``dense_slot_assignment`` enumerates)."""
    return RulesPack.from_rules(
        snapshot.rules, {v: i for i, v in enumerate(snapshot.vms)},
        {h: i for i, h in enumerate(snapshot.hosts)})
