"""Algorithm 1: RedivvyPowerCap -- proportional-share power redivvy.

After constraint-correction moves change where reservations live, host caps
are redistributed so that (a) every host can honor its resident reservations
and (b) the remaining *unreserved* budget is spread by proportional sharing
(Waldspurger-style, paper ref [23]) instead of stranding it on hosts that no
longer need it.

Note on the paper's pseudocode: Algorithm 1 line 15 as printed
(``C_iF += r (C_iS - C_iF)`` with ``r = C_needed / C_excess``) would *grow*
the total allocation by ``2*C_needed - C_excess``; budget conservation
requires shrinking hosts to give up exactly ``r`` of their excess, i.e. keep
``(1 - r)``.  We implement the conserving form and assert conservation.
"""

from __future__ import annotations

import numpy as np

from repro.core import kernels
from repro.drs import actions as act
from repro.drs.snapshot import ClusterSnapshot


def redivvy_power_cap(before: ClusterSnapshot, after: ClusterSnapshot,
                      reason: str = "redivvy") -> dict[str, float]:
    """Compute post-correction caps on ``after`` (mutating it) and return the
    per-host cap map.

    ``before`` holds pre-correction caps C_{i,S}.  ``after`` holds the
    post-correction placements with caps set to each host's minimum
    (reservation-respecting) cap C_{i,F} -- callers build it via
    :func:`get_flexible_power` + placement.  The proportional-share math is
    the pure-array kernel ``repro.core.kernels.redivvy_caps`` (shared with
    the batched sweep engine); this adapter maps snapshots to columns and
    back and asserts budget conservation.
    """
    av = after.as_arrays()
    caps_start = np.array([before.hosts[hid].power_cap
                           for hid in av.host_ids], dtype=np.float64)
    new_caps = kernels.redivvy_caps(np, av.host_on[None], caps_start[None],
                                    av.power_cap[None])[0]
    tree = after.effective_tree()
    if tree is not None:
        # Hierarchical budgets: scale the redivvied caps back under every
        # node limit, protecting the reserved floors (``av.power_cap`` is
        # the floor column here -- ``after`` arrives floored).
        new_caps = kernels.tree_project_caps(
            np, tree.cols(), av.host_on[None], new_caps[None],
            av.power_cap[None])[0]
    for i, hid in enumerate(av.host_ids):
        if av.host_on[i]:
            after.hosts[hid].power_cap = float(new_caps[i])
    total_before = sum(h.power_cap for h in before.hosts.values()
                       if h.powered_on)
    total_after = sum(h.power_cap for h in after.hosts.values()
                      if h.powered_on)
    assert total_after <= max(total_before, after.power_budget) + 1e-6, (
        f"redivvy grew allocation {total_before:.1f} -> {total_after:.1f}")
    return {h.host_id: h.power_cap for h in after.hosts.values()
            if h.powered_on}


def set_reserved_floor_caps(snapshot: ClusterSnapshot) -> None:
    """Drop every powered-on host's cap to its reserved floor, in place.

    One vectorized pass through the shared reserved-floor kernel: per-host
    reserved capacity and its Watts floor instead of an O(VMs) scan per
    host.
    """
    av = snapshot.as_arrays()
    floors = kernels.reserved_floor_caps(np, av.host_cols(),
                                         av.cpu_reserved()[None])[0]
    for i, hid in enumerate(av.host_ids):
        if av.host_on[i]:
            snapshot.hosts[hid].power_cap = float(floors[i])


def get_flexible_power(snapshot: ClusterSnapshot) -> ClusterSnapshot:
    """Clone with every host's cap at its reserved floor (paper Fig. 3 step 1).

    The clone exposes the cluster's full unreserved budget as *flexible*
    headroom that constraint correction may spend.
    """
    flex = snapshot.clone()
    set_reserved_floor_caps(flex)
    return flex


def fundable_capacity(flex: ClusterSnapshot, host_id: str) -> float:
    """Max managed capacity ``host_id`` could reach if granted as much of the
    unreserved budget as physics allows (used as the placement fit check's
    capacity function during Powercap Allocation)."""
    host = flex.hosts[host_id]
    if not host.powered_on:
        return 0.0
    spare = max(flex.power_budget - sum(
        h.power_cap for h in flex.powered_on_hosts()), 0.0)
    tree = flex.effective_tree()
    if tree is not None:
        # The host can only absorb spare watts up to the tightest headroom
        # along its root path (a saturated row strands spare budget).
        av = flex.as_arrays()
        slack = tree.host_slack(av.power_cap, av.host_on)
        spare = min(spare, max(float(slack[av.host_index[host_id]]), 0.0))
    cap = min(host.power_cap + spare, host.spec.power_peak)
    return float(host.spec.managed_capacity(cap))


def emit_actions(before: ClusterSnapshot, new_caps: dict[str, float],
                 reason: str = "redivvy") -> list[act.Action]:
    return act.order_cap_changes(before, new_caps, reason=reason)
