"""Algorithm 3: Powercap Redistribution for DPM host power-on/off.

Power-on: the candidate host needs a power cap before it can join the
cluster.  Take unallocated budget first; if short, drain hosts whose
utilization is low, never reducing any below the capacity at which DPM's
power-on trigger would fire (no oscillation) nor below its reservations.

Power-off: the host's cap returns to the pool and is redivvied across the
remaining hosts, proportional to each host's headroom to peak.
"""

from __future__ import annotations

from repro.drs import actions as act
from repro.drs.dpm import DPMConfig
from repro.drs.snapshot import ClusterSnapshot


def redistribute_for_power_on(snapshot: ClusterSnapshot, candidate_id: str,
                              dpm_config: DPMConfig | None = None
                              ) -> tuple[ClusterSnapshot, float]:
    """Fund ``candidate_id``'s cap.  Returns (what-if snapshot, granted W).

    The candidate ends with the largest cap the budget allows, at most its
    physical peak; the function never violates donors' reservations or drives
    them into DPM's power-on band.
    """
    dpm_config = dpm_config or DPMConfig()
    f = snapshot.clone()
    cand = f.hosts[candidate_id]
    spec = cand.spec

    needed = spec.power_peak  # target: full peak cap (best robustness)
    granted = 0.0
    if cand.powered_on:
        # Already-on candidate (defensive: DPM only nominates standby
        # hosts): its current allocation counts toward the target and is
        # never taken away -- redistribution only tops it up toward peak.
        granted = cand.power_cap
        needed = max(needed - granted, 0.0)

    # 1. Unallocated budget first (paper Fig. 5 step 1).
    pool = max(f.unallocated_power_budget(), 0.0)
    take = min(pool, needed)
    granted += take
    needed -= take

    # 2. Drain low-utilization hosts down to their power-on-threshold floor.
    if needed > 1e-9:
        # Per-host rollups (utilization, demand, reservations) in one
        # vectorized pass; the greedy drain below is O(hosts).
        av = f.as_arrays()
        cpu_util = av.host_cpu_utilization()
        host_demand = av.host_demand()
        cpu_res = av.cpu_reserved()
        donors = sorted(
            (i for i in range(av.n_hosts)
             if av.host_on[i] and cpu_util[i] < dpm_config.high_util
             and av.host_ids[i] != candidate_id),
            key=lambda i: cpu_util[i])
        for i in donors:
            if needed <= 1e-9:
                break
            donor = f.hosts[av.host_ids[i]]
            # Floor capacity: utilization stays strictly below the power-on
            # trigger, and reservations stay whole; the cap never drops
            # below idle (a powered-on host draws idle regardless).
            floor_capacity = max(host_demand[i] / dpm_config.high_util,
                                 cpu_res[i])
            floor_cap = max(float(donor.spec.cap_for_managed_capacity(
                floor_capacity)), donor.spec.power_idle)
            avail = max(donor.power_cap - floor_cap, 0.0)
            take = min(avail, needed)
            if take > 0:
                donor.power_cap -= take
                granted += take
                needed -= take

    # The cap IS the budget allocation: never larger than what was granted.
    # Below idle the host cannot even sit powered-on -- the caller (DPM
    # protocol) treats that as power-on infeasible.
    cand.power_cap = min(granted, spec.power_peak)
    return f, cand.power_cap


def redistribute_after_power_off(snapshot: ClusterSnapshot, off_id: str
                                 ) -> ClusterSnapshot:
    """Reabsorb ``off_id``'s budget into the remaining hosts' caps,
    proportionally to each host's headroom to peak."""
    f = snapshot.clone()
    off = f.hosts[off_id]
    off.powered_on = False
    freed = off.power_cap
    off.power_cap = 0.0

    pool = freed + max(f.unallocated_power_budget() - freed, 0.0)
    pool = min(pool, max(f.power_budget - f.total_allocated_power(), 0.0))
    recipients = [h for h in f.powered_on_hosts()
                  if h.power_cap < h.spec.power_peak - 1e-9]
    total_headroom = sum(h.spec.power_peak - h.power_cap for h in recipients)
    if total_headroom <= 0 or pool <= 0:
        return f
    grant_total = min(pool, total_headroom)
    for h in recipients:
        share = (h.spec.power_peak - h.power_cap) / total_headroom
        h.power_cap = min(h.power_cap + grant_total * share,
                          h.spec.power_peak)
    f.validate()
    return f


def emit_actions(before: ClusterSnapshot, after: ClusterSnapshot,
                 reason: str = "powercap-redistribute") -> list[act.Action]:
    new_caps = {h.host_id: h.power_cap for h in after.hosts.values()
                if h.powered_on or before.hosts[h.host_id].powered_on}
    return act.order_cap_changes(before, new_caps, reason=reason)
