"""Algorithm 3: Powercap Redistribution for DPM host power-on/off.

Power-on: the candidate host needs a power cap before it can join the
cluster.  Take unallocated budget first; if short, drain hosts whose
utilization is low, never reducing any below the capacity at which DPM's
power-on trigger would fire (no oscillation) nor below its reservations.

Power-off: the host's cap returns to the pool and is redivvied across the
remaining hosts, proportional to each host's headroom to peak.

Both decisions are the pure-array kernels ``power_on_funding_caps`` /
``power_off_reabsorb_caps`` in ``repro.core.kernels`` (shared with the
batched sweep engine's jitted DPM path); this module is the object-plane
adapter mapping snapshots to columns and back.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.backend import NUMPY
from repro.core import kernels
from repro.drs import actions as act
from repro.drs.snapshot import ClusterSnapshot

if TYPE_CHECKING:  # annotation-only: avoids a repro.drs.dpm import cycle
    from repro.drs.dpm import DPMConfig


def redistribute_for_power_on(snapshot: ClusterSnapshot, candidate_id: str,
                              dpm_config: "DPMConfig | None" = None
                              ) -> tuple[ClusterSnapshot, float]:
    """Fund ``candidate_id``'s cap.  Returns (what-if snapshot, granted W).

    The candidate ends with the largest cap the budget allows, at most its
    physical peak; the function never violates donors' reservations or drives
    them into DPM's power-on band.
    """
    from repro.drs.dpm import DPMConfig  # local import, no cycle
    dpm_config = dpm_config or DPMConfig()
    f = snapshot.clone()
    av = f.as_arrays()
    cand = np.asarray([av.host_index[candidate_id]])
    tree = f.effective_tree()
    new_caps, granted = kernels.power_on_funding_caps(
        NUMPY, av.host_cols(), av.power_cap[None], cand,
        av.host_cpu_utilization()[None], av.host_demand()[None],
        av.cpu_reserved()[None], np.asarray([f.power_budget]),
        dpm_config.high_util,
        tree=tree.cols() if tree is not None else None)
    av.write_caps(f, new_caps[0])
    # The cap IS the budget allocation: never larger than what was granted.
    # Below idle the host cannot even sit powered-on -- the caller (DPM
    # protocol) treats that as power-on infeasible.
    return f, f.hosts[candidate_id].power_cap


def redistribute_after_power_off(snapshot: ClusterSnapshot, off_id: str
                                 ) -> ClusterSnapshot:
    """Reabsorb ``off_id``'s budget into the remaining hosts' caps,
    proportionally to each host's headroom to peak."""
    f = snapshot.clone()
    av = f.as_arrays()
    off = np.asarray([av.host_index[off_id]])
    tree = f.effective_tree()
    new_caps = kernels.power_off_reabsorb_caps(
        np, av.host_cols(), av.power_cap[None], off,
        np.asarray([f.power_budget]),
        tree=tree.cols() if tree is not None else None)
    f.hosts[off_id].powered_on = False
    av.write_caps(f, new_caps[0])
    f.validate()
    return f


def emit_actions(before: ClusterSnapshot, after: ClusterSnapshot,
                 reason: str = "powercap-redistribute",
                 include: tuple[str, ...] = ()) -> list[act.Action]:
    """Cap-change actions for every host powered on in either snapshot,
    plus ``include`` (the power-on candidate, whose funded cap must be
    applied even though it is still in standby when the actions execute)."""
    new_caps = {h.host_id: h.power_cap for h in after.hosts.values()
                if h.powered_on or before.hosts[h.host_id].powered_on
                or h.host_id in include}
    return act.order_cap_changes(before, new_caps, reason=reason)
