"""CloudPowerCap power model (paper Eqs. 1-4).

Maps a host's power cap to its effective compute capacity and back, so the
power budget can be managed as a first-class schedulable resource by the
resource manager.  The paper's linear utilization<->power model (validated by
Fan et al. for CPU-dominated servers) is kept as the default calibration; the
model is pluggable so a measured cap->sustained-clock curve for a TPU host can
be dropped in at the same interface.

Capacity units are MHz in the simulator plane (matching the paper) and FLOP/s
in the data plane -- the model is unit-agnostic: ``capacity`` is whatever
linear resource the host delivers at 100% utilization of its peak.
"""

from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

ArrayLike = Union[float, np.ndarray]


@dataclasses.dataclass(frozen=True)
class HostPowerSpec:
    """Static power/capacity description of one host.

    Attributes:
      capacity_peak: capacity delivered at 100% utilization, uncapped (MHz or
        FLOP/s).
      power_idle: Watts drawn at 0% utilization (includes non-CPU components,
        per the paper -- memory / disk / NIC draw is roughly flat).
      power_peak: Watts drawn at 100% utilization, uncapped.
      power_nameplate: label power, only used for deployment math (Table II).
      hypervisor_overhead: capacity reserved by the hypervisor / host agent
        (Eq. 4's ``C_H``); subtracted from power-capped capacity to obtain the
        capacity the resource manager may allocate.
      memory_mb: host memory (the other first-class resource in the paper).
    """

    capacity_peak: float
    power_idle: float
    power_peak: float
    power_nameplate: float = 0.0
    hypervisor_overhead: float = 0.0
    memory_mb: float = 0.0

    def __post_init__(self) -> None:
        if self.power_peak <= self.power_idle:
            raise ValueError(
                f"power_peak ({self.power_peak}) must exceed power_idle "
                f"({self.power_idle})")
        if self.capacity_peak <= 0:
            raise ValueError("capacity_peak must be positive")

    # -- Eq. 1: utilization -> consumed power (upper bound under DVFS) -------
    def power_consumed(self, utilization: ArrayLike) -> ArrayLike:
        u = np.clip(utilization, 0.0, 1.0)
        return self.power_idle + (self.power_peak - self.power_idle) * u

    # -- Eq. 3: power cap -> power-capped capacity ---------------------------
    def capped_capacity(self, power_cap: ArrayLike) -> ArrayLike:
        """Lower-bound capacity reachable under ``power_cap`` Watts."""
        cap = np.clip(power_cap, self.power_idle, self.power_peak)
        frac = (cap - self.power_idle) / (self.power_peak - self.power_idle)
        return self.capacity_peak * frac

    # -- Eq. 3 inverted: capacity -> minimum power cap that supports it ------
    def cap_for_capacity(self, capacity: ArrayLike) -> ArrayLike:
        c = np.clip(capacity, 0.0, self.capacity_peak)
        return self.power_idle + (self.power_peak - self.power_idle) * (
            c / self.capacity_peak)

    # -- Eq. 4: managed (resource-manager-visible) capacity ------------------
    def managed_capacity(self, power_cap: ArrayLike) -> ArrayLike:
        return np.maximum(
            self.capped_capacity(power_cap) - self.hypervisor_overhead, 0.0)

    def cap_for_managed_capacity(self, capacity: ArrayLike) -> ArrayLike:
        return self.cap_for_capacity(
            np.asarray(capacity) + self.hypervisor_overhead)


# Paper Table I server: 12 cores x 2.9 GHz = 34.8 GHz, 96 GB,
# nameplate 400 W, peak 320 W, idle 160 W.
PAPER_HOST = HostPowerSpec(
    capacity_peak=34_800.0,       # MHz
    power_idle=160.0,
    power_peak=320.0,
    power_nameplate=400.0,
    hypervisor_overhead=0.0,
    memory_mb=96 * 1024,
)


# TPU v5e host (4 chips): used by the data plane.  197 TFLOP/s bf16 per chip.
# Power figures follow public v5e board estimates; the exact constants only
# scale the Watts<->FLOP/s line and are configurable.
TPU_V5E_HOST = HostPowerSpec(
    capacity_peak=4 * 197e12,     # FLOP/s, 4 chips per host
    power_idle=4 * 70.0,
    power_peak=4 * 220.0,
    power_nameplate=4 * 250.0,
    hypervisor_overhead=0.0,
    memory_mb=4 * 16 * 1024,
)


def deployment_table(spec: HostPowerSpec, rack_budget_watts: float,
                     power_caps: list[float]) -> list[dict]:
    """Reproduces the shape of paper Table II.

    For each candidate per-host power cap, how many hosts fit in the rack
    budget and what aggregate capacity / memory results.
    """
    rows = []
    base = None
    for cap in power_caps:
        count = int(rack_budget_watts // cap)
        total_capacity = count * float(spec.capped_capacity(cap))
        total_memory = count * spec.memory_mb
        if base is None:
            base = (total_capacity, total_memory)
        rows.append({
            "power_cap_w": cap,
            "host_count": count,
            "capacity": total_capacity,
            "capacity_ratio": total_capacity / base[0] if base[0] else 0.0,
            "memory_mb": total_memory,
            "memory_ratio": total_memory / base[1] if base[1] else 0.0,
        })
    return rows
