"""ManagerCore: the three-phase CloudPowerCap protocol, engine-neutral.

One DRS invocation (default every 300 s) runs:

  Phase 1  Powercap Allocation      (paper Fig. 3)  constraint correction on
           a GetFlexiblePower clone, then RedivvyPowerCap.
  Phase 2  Powercap-based Balancing (paper Fig. 4)  BalancePowerCap first,
           residual imbalance fixed by DRS's migration balancer.
  Phase 3  Powercap Redistribution  (paper Fig. 5)  DPM power-on/off with
           budget funding / reabsorption.

This module is the *single* source of that sequencing.  Every engine adapts
over it rather than reimplementing it:

  * the per-object ``Simulator`` and the NumPy ``VectorSimulator`` call
    :meth:`ManagerCore.invoke` (via ``repro.core.manager``'s
    ``CloudPowerCapManager`` facade) on snapshot clones and execute the
    emitted :mod:`repro.drs.actions` list with its prerequisite edges;
  * the jitted ``BatchedSimulator`` (``repro.sim.batch``) replays the same
    sequence inside ``lax.scan`` from the same decision kernels
    (``repro.core.kernels``: ``correct_constraints_slots`` ->
    ``redivvy_caps`` -> ``balance_caps`` -> ``balance_migrations`` ->
    ``dpm_hot_mask``/``dpm_all_low`` -> ``power_on_funding_caps`` /
    ``power_off_reabsorb_caps`` / ``plan_evacuation``), applying the same
    action schema semantics (decreases before the increases they fund,
    funding before power-on, evacuation before power-off) as timer state
    carried through the scan.

The *migration* decisions inside phases 1 and 2 -- constraint correction
and the hill-climb balancer -- have their own engine-neutral owner,
:class:`repro.core.migration_core.MigrationCore`; ``drs/placement.py``
and ``drs/balancer.py`` are thin adapters over it.

Because the decision math lives in the kernels, a change to any phase's
policy lands in all three engines at once; parity is enforced by
``tests/test_batch_parity.py`` and ``tests/test_vector_parity.py``.

Baselines from the paper's evaluation (``Static``, ``StaticHigh``) run the
same pipeline with cap changes disabled.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import balance as bal
from repro.core import redistribute as redist
from repro.core import redivvy
from repro.drs import actions as act
from repro.drs import balancer, dpm, placement
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class InvocationResult:
    actions: list
    snapshot: ClusterSnapshot            # what-if end state
    migrations: int = 0
    cap_changes: int = 0
    notes: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ManagerConfig:
    powercap_enabled: bool = True        # False => Static/StaticHigh baseline
    balance: bal.BalanceConfig = dataclasses.field(
        default_factory=bal.BalanceConfig)
    balancer: balancer.BalancerConfig = dataclasses.field(
        default_factory=balancer.BalancerConfig)
    dpm: dpm.DPMConfig = dataclasses.field(default_factory=dpm.DPMConfig)
    dpm_enabled: bool = True


class ManagerCore:
    """Drives one cluster; stateless between invocations except config."""

    def __init__(self, config: Optional[ManagerConfig] = None):
        self.config = config or ManagerConfig()

    # ------------------------------------------------------------------
    def invoke(self, snapshot: ClusterSnapshot, now: float = 0.0,
               low_since: Optional[dict] = None,
               last_config_change: float = -1e18,
               limits=None) -> InvocationResult:
        """``limits`` (a :class:`repro.core.kernels.MigrationLimits`) gates
        how many migrations correction + balancing may launch this
        invocation; both phases share one :class:`LaunchBudget` ledger, so
        a host saturated by corrections receives no balancer moves either.
        Evacuations (phase 3) are exempt -- power-off is all-or-nothing."""
        actions: list[act.Action] = []
        notes: list[str] = []
        budget = None
        if limits is not None and limits.gated:
            from repro.core.migration_core import LaunchBudget
            budget = LaunchBudget(limits, len(snapshot.hosts))
        working = self._phase_allocation(snapshot, actions, notes, budget)
        working = self._phase_balancing(working, actions, notes, budget)
        working = self._phase_redistribution(working, actions, notes, now,
                                             low_since, last_config_change)
        # Hierarchical budgets: every phase projects/scopes its own caps,
        # so the tree invariant must hold on whatever state the invocation
        # hands back (a powering-on candidate's pending grant counts via
        # its already-set cap).
        assert working.tree_respected(), (
            "manager invocation left a budget-tree node over its limit")
        migrations = sum(1 for a in actions if a.kind == "migrate")
        cap_changes = sum(1 for a in actions if a.kind == "set_power_cap")
        return InvocationResult(actions=actions, snapshot=working,
                                migrations=migrations,
                                cap_changes=cap_changes, notes=notes)

    # ---------------- Phase 1: constraint correction ------------------
    def _phase_allocation(self, snapshot: ClusterSnapshot, actions: list,
                          notes: list, budget=None) -> ClusterSnapshot:
        if self.config.powercap_enabled:
            flex = redivvy.get_flexible_power(snapshot)
            moves = placement.correct_constraints(
                flex, capacity_fn=redivvy.fundable_capacity, budget=budget)
            # Post-correction reserved floors (reservations moved with VMs).
            redivvy.set_reserved_floor_caps(flex)
            new_caps = redivvy.redivvy_power_cap(snapshot, flex)
            cap_actions = redivvy.emit_actions(snapshot, new_caps,
                                               reason="powercap-allocation")
            cap_ids = tuple(a.action_id for a in cap_actions)
            move_actions = [act.migrate(vm, dest, prereqs=cap_ids,
                                        reason="constraint-correction")
                            for vm, dest in moves]
            actions += cap_actions + move_actions
            working = flex
        else:
            working = snapshot.clone()
            moves = placement.correct_constraints(working, budget=budget)
            actions += [act.migrate(vm, dest, reason="constraint-correction")
                        for vm, dest in moves]
        if moves:
            notes.append(f"constraint-correction: {len(moves)} moves")
        return working

    # ---------------- Phase 2: entitlement balancing ------------------
    def _phase_balancing(self, working: ClusterSnapshot, actions: list,
                         notes: list, budget=None) -> ClusterSnapshot:
        cfg = self.config
        if cfg.powercap_enabled:
            balanced, did = bal.balance_power_cap(working, cfg.balance)
            if did:
                cap_actions = bal.emit_actions(working, balanced)
                actions += cap_actions
                notes.append(
                    f"powercap-balance: {len(cap_actions)} cap changes, "
                    f"imbalance {working.imbalance():.3f}->"
                    f"{balanced.imbalance():.3f}")
                working = balanced
        residual_moves = balancer.balance(working, cfg.balancer, budget)
        if residual_moves:
            actions += [act.migrate(vm, dest, reason="entitlement-balance")
                        for vm, dest in residual_moves]
            notes.append(f"migration-balance: {len(residual_moves)} moves")
        return working

    # ---------------- Phase 3: DPM + redistribution -------------------
    def _phase_redistribution(self, working: ClusterSnapshot, actions: list,
                              notes: list, now: float,
                              low_since: Optional[dict],
                              last_config_change: float) -> ClusterSnapshot:
        cfg = self.config
        if not cfg.dpm_enabled:
            return working
        rec = dpm.run_dpm(working, cfg.dpm, low_since=low_since, now=now,
                          last_config_change=last_config_change)
        if rec.power_on is not None and cfg.powercap_enabled:
            funded, granted = redist.redistribute_for_power_on(
                working, rec.power_on, cfg.dpm)
            spec = working.hosts[rec.power_on].spec
            if spec.managed_capacity(granted) <= 0.0:
                notes.append(
                    f"dpm power-on {rec.power_on} infeasible: "
                    f"only {granted:.0f} W available")
            else:
                # The candidate's funded cap is an emitted action like any
                # other (after the decreases that fund it): the host must
                # come up with its grant applied, not a stale cap.
                cap_actions = redist.emit_actions(
                    working, funded, reason="powercap-poweron",
                    include=(rec.power_on,))
                pon = act.power_on(
                    rec.power_on,
                    prereqs=tuple(a.action_id for a in cap_actions),
                    reason="dpm")
                actions += cap_actions + [pon]
                working = funded
                working.hosts[rec.power_on].powered_on = True
                notes.append(f"dpm power-on {rec.power_on} "
                             f"granted {granted:.0f} W")
        elif rec.power_on is not None:
            actions.append(act.power_on(rec.power_on, reason="dpm"))
            notes.append(f"dpm power-on {rec.power_on}")
            working.hosts[rec.power_on].powered_on = True
        elif rec.power_off is not None:
            evac = [act.migrate(vm, dest, reason="dpm-evacuate")
                    for vm, dest in rec.evacuations]
            for vm, dest in rec.evacuations:
                working.move_vm(vm, dest)
            poff = act.power_off(
                rec.power_off,
                prereqs=tuple(a.action_id for a in evac), reason="dpm")
            actions += evac + [poff]
            if cfg.powercap_enabled:
                redistributed = redist.redistribute_after_power_off(
                    working, rec.power_off)
                cap_actions = redist.emit_actions(
                    working, redistributed, reason="powercap-poweroff")
                for a in cap_actions:
                    a.prereqs = a.prereqs + (poff.action_id,)
                actions += cap_actions
                working = redistributed
            else:
                working.hosts[rec.power_off].powered_on = False
            notes.append(
                f"dpm power-off {rec.power_off} "
                f"({len(rec.evacuations)} evacuations)")
        return working
