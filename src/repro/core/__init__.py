"""CloudPowerCap core: the paper's primary contribution.

Power model (Eqs. 1-4), the three coordination algorithms (RedivvyPowerCap,
BalancePowerCap, RedistributePowerCap) and the manager that weaves them into
the resource-management pipeline.
"""

from repro.core.power_model import (HostPowerSpec, PAPER_HOST, TPU_V5E_HOST,
                                    deployment_table)
from repro.core.redivvy import (redivvy_power_cap, get_flexible_power,
                                fundable_capacity)
from repro.core.balance import balance_power_cap, BalanceConfig
from repro.core.redistribute import (redistribute_for_power_on,
                                     redistribute_after_power_off)
from repro.core.manager import (CloudPowerCapManager, ManagerConfig,
                                ManagerCore, static_manager,
                                InvocationResult)

__all__ = [
    "HostPowerSpec", "PAPER_HOST", "TPU_V5E_HOST", "deployment_table",
    "redivvy_power_cap", "get_flexible_power", "fundable_capacity",
    "balance_power_cap", "BalanceConfig", "redistribute_for_power_on",
    "redistribute_after_power_off", "CloudPowerCapManager", "ManagerConfig",
    "ManagerCore", "static_manager", "InvocationResult",
]
