"""CloudPowerCap orchestrator facade.

The three coordination protocols (Powercap Allocation -> Powercap-based
Balancing -> Powercap Redistribution) live in
:class:`repro.core.manager_core.ManagerCore`, the single engine-neutral
definition of the invocation sequence; this module keeps the historical
``CloudPowerCapManager`` entry point that the simulators and tests drive.

Baselines from the paper's evaluation (`Static`, `StaticHigh`) run the same
DRS pipeline with cap changes disabled.

See ``docs/ARCHITECTURE.md`` for how this pipeline sits between the
simulator tick loop (``repro.sim.cluster``) and the array-based hot path
(``repro.drs.arrays``, ``repro.sim.engine``, ``repro.sim.batch``).
"""

from __future__ import annotations

from typing import Optional

from repro.core.manager_core import (InvocationResult, ManagerConfig,
                                     ManagerCore)
from repro.drs.snapshot import ClusterSnapshot

__all__ = ["CloudPowerCapManager", "InvocationResult", "ManagerConfig",
           "ManagerCore", "static_manager"]


class CloudPowerCapManager:
    """Drives one cluster; stateless between invocations except config."""

    def __init__(self, config: Optional[ManagerConfig] = None):
        self.core = ManagerCore(config)

    @property
    def config(self) -> ManagerConfig:
        return self.core.config

    # ------------------------------------------------------------------
    def run_invocation(self, snapshot: ClusterSnapshot, now: float = 0.0,
                       low_since: Optional[dict] = None,
                       last_config_change: float = -1e18,
                       limits=None) -> InvocationResult:
        return self.core.invoke(snapshot, now=now, low_since=low_since,
                                last_config_change=last_config_change,
                                limits=limits)


def static_manager(dpm_enabled: bool = True) -> CloudPowerCapManager:
    """Static / StaticHigh baseline: caps never change after deployment."""
    return CloudPowerCapManager(ManagerConfig(
        powercap_enabled=False, dpm_enabled=dpm_enabled))
