"""CloudPowerCap orchestrator: the three coordination protocols.

One DRS invocation (default every 300 s) runs:

  Phase 1  Powercap Allocation      (paper Fig. 3)  constraint correction on
           a GetFlexiblePower clone, then RedivvyPowerCap.
  Phase 2  Powercap-based Balancing (paper Fig. 4)  BalancePowerCap first,
           residual imbalance fixed by DRS's migration balancer.
  Phase 3  Powercap Redistribution  (paper Fig. 5)  DPM power-on/off with
           budget funding / reabsorption.

Baselines from the paper's evaluation (`Static`, `StaticHigh`) run the same
DRS pipeline with cap changes disabled.

See ``docs/ARCHITECTURE.md`` for how this pipeline sits between the
simulator tick loop (``repro.sim.cluster``) and the array-based hot path
(``repro.drs.arrays``, ``repro.sim.engine``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import balance as bal
from repro.core import redistribute as redist
from repro.core import redivvy
from repro.drs import actions as act
from repro.drs import balancer, dpm, placement
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class InvocationResult:
    actions: list
    snapshot: ClusterSnapshot            # what-if end state
    migrations: int = 0
    cap_changes: int = 0
    notes: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ManagerConfig:
    powercap_enabled: bool = True        # False => Static/StaticHigh baseline
    balance: bal.BalanceConfig = dataclasses.field(
        default_factory=bal.BalanceConfig)
    balancer: balancer.BalancerConfig = dataclasses.field(
        default_factory=balancer.BalancerConfig)
    dpm: dpm.DPMConfig = dataclasses.field(default_factory=dpm.DPMConfig)
    dpm_enabled: bool = True


class CloudPowerCapManager:
    """Drives one cluster; stateless between invocations except config."""

    def __init__(self, config: Optional[ManagerConfig] = None):
        self.config = config or ManagerConfig()

    # ------------------------------------------------------------------
    def run_invocation(self, snapshot: ClusterSnapshot, now: float = 0.0,
                       low_since: Optional[dict] = None,
                       last_config_change: float = -1e18
                       ) -> InvocationResult:
        cfg = self.config
        actions: list[act.Action] = []
        notes: list[str] = []

        # ---------------- Phase 1: constraint correction ------------------
        if cfg.powercap_enabled:
            flex = redivvy.get_flexible_power(snapshot)
            moves = placement.correct_constraints(
                flex, capacity_fn=redivvy.fundable_capacity)
            # Post-correction reserved floors (reservations moved with VMs).
            redivvy.set_reserved_floor_caps(flex)
            new_caps = redivvy.redivvy_power_cap(snapshot, flex)
            cap_actions = redivvy.emit_actions(snapshot, new_caps,
                                               reason="powercap-allocation")
            cap_ids = tuple(a.action_id for a in cap_actions)
            move_actions = [act.migrate(vm, dest, prereqs=cap_ids,
                                        reason="constraint-correction")
                            for vm, dest in moves]
            actions += cap_actions + move_actions
            working = flex
        else:
            working = snapshot.clone()
            moves = placement.correct_constraints(working)
            actions += [act.migrate(vm, dest, reason="constraint-correction")
                        for vm, dest in moves]
        if moves:
            notes.append(f"constraint-correction: {len(moves)} moves")

        # ---------------- Phase 2: entitlement balancing ------------------
        if cfg.powercap_enabled:
            balanced, did = bal.balance_power_cap(working, cfg.balance)
            if did:
                cap_actions = bal.emit_actions(working, balanced)
                actions += cap_actions
                notes.append(
                    f"powercap-balance: {len(cap_actions)} cap changes, "
                    f"imbalance {working.imbalance():.3f}->"
                    f"{balanced.imbalance():.3f}")
                working = balanced
        residual_moves = balancer.balance(working, cfg.balancer)
        if residual_moves:
            actions += [act.migrate(vm, dest, reason="entitlement-balance")
                        for vm, dest in residual_moves]
            notes.append(f"migration-balance: {len(residual_moves)} moves")

        # ---------------- Phase 3: DPM + redistribution -------------------
        if cfg.dpm_enabled:
            rec = dpm.run_dpm(working, cfg.dpm, low_since=low_since, now=now,
                              last_config_change=last_config_change)
            if rec.power_on is not None and cfg.powercap_enabled:
                funded, granted = redist.redistribute_for_power_on(
                    working, rec.power_on, cfg.dpm)
                spec = working.hosts[rec.power_on].spec
                if spec.managed_capacity(granted) <= 0.0:
                    notes.append(
                        f"dpm power-on {rec.power_on} infeasible: "
                        f"only {granted:.0f} W available")
                else:
                    cap_actions = redist.emit_actions(
                        working, funded, reason="powercap-poweron")
                    pon = act.power_on(
                        rec.power_on,
                        prereqs=tuple(a.action_id for a in cap_actions),
                        reason="dpm")
                    actions += cap_actions + [pon]
                    working = funded
                    working.hosts[rec.power_on].powered_on = True
                    notes.append(f"dpm power-on {rec.power_on} "
                                 f"granted {granted:.0f} W")
            elif rec.power_on is not None:
                actions.append(act.power_on(rec.power_on, reason="dpm"))
                notes.append(f"dpm power-on {rec.power_on}")
                working.hosts[rec.power_on].powered_on = True
            elif rec.power_off is not None:
                evac = [act.migrate(vm, dest, reason="dpm-evacuate")
                        for vm, dest in rec.evacuations]
                for vm, dest in rec.evacuations:
                    working.vms[vm].host_id = dest
                poff = act.power_off(
                    rec.power_off,
                    prereqs=tuple(a.action_id for a in evac), reason="dpm")
                actions += evac + [poff]
                if cfg.powercap_enabled:
                    redistributed = redist.redistribute_after_power_off(
                        working, rec.power_off)
                    cap_actions = redist.emit_actions(
                        working, redistributed, reason="powercap-poweroff")
                    for a in cap_actions:
                        a.prereqs = a.prereqs + (poff.action_id,)
                    actions += cap_actions
                    working = redistributed
                else:
                    working.hosts[rec.power_off].powered_on = False
                notes.append(
                    f"dpm power-off {rec.power_off} "
                    f"({len(rec.evacuations)} evacuations)")

        migrations = sum(1 for a in actions if a.kind == "migrate")
        cap_changes = sum(1 for a in actions if a.kind == "set_power_cap")
        return InvocationResult(actions=actions, snapshot=working,
                                migrations=migrations,
                                cap_changes=cap_changes, notes=notes)


def static_manager(dpm_enabled: bool = True) -> CloudPowerCapManager:
    """Static / StaticHigh baseline: caps never change after deployment."""
    return CloudPowerCapManager(ManagerConfig(
        powercap_enabled=False, dpm_enabled=dpm_enabled))
