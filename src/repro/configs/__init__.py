"""Assigned architecture configs (public-literature, exact dims).

``get(name)`` returns the full ModelConfig; ``get_smoke(name)`` the reduced
same-family variant for CPU smoke tests.  ``ARCHS`` lists all assigned ids.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "internvl2_26b",
    "mamba2_2p7b",
    "olmoe_1b_7b",
    "deepseek_moe_16b",
    "whisper_tiny",
    "nemotron_4_340b",
    "granite_8b",
    "minicpm_2b",
    "granite_20b",
    "zamba2_7b",
]

ALIASES = {
    "internvl2-26b": "internvl2_26b",
    "mamba2-2.7b": "mamba2_2p7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-tiny": "whisper_tiny",
    "nemotron-4-340b": "nemotron_4_340b",
    "granite-8b": "granite_8b",
    "minicpm-2b": "minicpm_2b",
    "granite-20b": "granite_20b",
    "zamba2-7b": "zamba2_7b",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke(name: str):
    return get(name).smoke()


def all_configs():
    return {a: get(a) for a in ARCHS}
