"""Nemotron-4-340B: dense GQA, squared-ReLU MLP.

[arXiv:2402.16819; unverified]  96L d_model=18432 96H (kv=8) d_ff=73728
vocab=256000.  Optimizer states in bf16 (state-memory trick recorded in
EXPERIMENTS.md) so train_4k fits v5e HBM on both dry-run meshes.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    optimizer_state_dtype="bfloat16",
    microbatches=8,
    shard_activation_seq=True,
    xent_chunk=4096,  # seq-sharded activations: single-chunk xent
)
