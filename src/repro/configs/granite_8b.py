"""Granite-8B (code): llama-arch GQA.

[arXiv:2405.04324; hf]  36L d_model=4096 32H (kv=8) d_ff=14336 vocab=49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    microbatches=4,   # used by the tp fallback (multi-pod); dp path uses 1
    parallelism="dp",
)
