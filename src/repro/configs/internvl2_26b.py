"""InternVL2-26B: InternViT frontend (stub) + InternLM2-20B-class backbone.

[arXiv:2404.16821; hf]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553.  The vision frontend supplies precomputed patch embeddings
(256 patches) via input_specs(); the backbone treats them as a prefix.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    activation="swiglu",
    rope_theta=1e6,
    frontend="vision",
    n_prefix_embeds=256,
    microbatches=4,
    shard_activation_seq=True,  # tp fallback (multi-pod)
    parallelism="dp",
)
