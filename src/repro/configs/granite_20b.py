"""Granite-20B (code): MQA (single KV head).

[arXiv:2405.04324; hf]  52L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",  # GPT-BigCode-style MLP (2 matrices), matches 20B,
    microbatches=4,
    shard_activation_seq=True,  # tp fallback (multi-pod)
    parallelism="dp",
)
