"""Whisper-tiny: encoder-decoder ASR backbone; conv frontend stubbed.

[arXiv:2212.04356; unverified]  4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865.  input_specs() supplies 1500 precomputed frame embeddings;
the decoder runs the assigned LM shapes.  RoPE replaces Whisper's learned
positions (TPU adaptation, noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    frontend="audio",
    enc_layers=4,
    enc_seq=1500,
    xent_chunk=4096,  # seq is model-sharded (odd heads): no xent seq-scan
    parallelism="dp",
)
