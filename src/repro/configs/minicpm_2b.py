"""MiniCPM-2B: llama-like; trained with the WSD schedule (repro.optim).

[arXiv:2404.06395; hf]  40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    xent_chunk=4096,  # seq is model-sharded (odd heads): no xent seq-scan
    parallelism="dp",  # batch 256 == single-pod mesh: pure DP beats TP (SPerf)
)
