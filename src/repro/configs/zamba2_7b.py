"""Zamba2-7B: Mamba2 backbone + shared attention block every 6 layers.

[arXiv:2411.15242; unverified]  81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64.  The shared transformer block (attn+MLP) is one
parameter set applied at 13 sites (81//6), Zamba2-style.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    microbatches=4,   # tp fallback; dp path uses 1
    parallelism="dp",
)
