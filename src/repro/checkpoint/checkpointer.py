"""Checkpointing with mesh-reshape restore (elastic scaling / fault
tolerance).

Format: one .npz per checkpoint step holding every leaf by its pytree path,
plus a JSON metadata sidecar (step, data-pipeline cursor, config fingerprint,
completion marker).  Leaves are saved as *global* dense arrays, so restore
can place them onto any mesh/sharding -- that is what lets a 2-pod run
resume on 1 pod after a DPM scale-down (repro.runtime.elastic) or after a
pod failure.

Writes are atomic (tmp + rename, marker last) and can run on a background
thread (``save_async``) so the step loop is not blocked; ``wait`` joins the
in-flight write before the next save or process exit.  On real multi-host
pods this module's role is played per-host with sharded files (orbax-style);
the layout keeps that swap local to this file.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _flatten(self, tree: PyTree) -> dict[str, np.ndarray]:
        flat = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            flat[_path_str(path)] = np.asarray(leaf)
        return flat

    def save(self, step: int, tree: PyTree,
             extra_metadata: Optional[dict] = None) -> str:
        self.wait()
        flat = self._flatten(tree)
        return self._write(step, flat, extra_metadata or {})

    def save_async(self, step: int, tree: PyTree,
                   extra_metadata: Optional[dict] = None) -> None:
        self.wait()
        # Device->host copy happens here (synchronously, consistent view);
        # serialization + disk I/O happen on the thread.
        flat = self._flatten(tree)
        meta = dict(extra_metadata or {})
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, meta), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, meta: dict) -> str:
        base = os.path.join(self.directory, f"step_{step:010d}")
        tmp = base + ".tmp.npz"
        np.savez(tmp, **flat)
        os.replace(tmp, base + ".npz")
        meta = dict(meta, step=step, leaves=len(flat))
        with open(base + ".json.tmp", "w") as f:
            json.dump(meta, f)
        os.replace(base + ".json.tmp", base + ".json")   # completion marker
        self._gc()
        return base + ".npz"

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.directory,
                                           f"step_{s:010d}{ext}"))
                except FileNotFoundError:
                    pass

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.endswith(".json") and name.startswith("step_"):
                out.append(int(name[len("step_"):-len(".json")]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self.directory,
                               f"step_{step:010d}.json")) as f:
            return json.load(f)

    def restore(self, step: int, target: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore onto ``target``'s structure; ``shardings`` (same
        structure) places each leaf on the (possibly different) mesh."""
        self.wait()
        data = np.load(os.path.join(self.directory,
                                    f"step_{step:010d}.npz"))
        flat_target, treedef = jax.tree_util.tree_flatten_with_path(target)
        flat_shardings = (jax.tree_util.tree_leaves(shardings)
                          if shardings is not None
                          else [None] * len(flat_target))
        leaves = []
        for (path, leaf), shard in zip(flat_target, flat_shardings):
            arr = data[_path_str(path)]
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"{_path_str(path)}: checkpoint shape {arr.shape} != "
                    f"target {tuple(leaf.shape)}")
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), leaves)
