"""Deterministic, shardable, checkpointable synthetic token pipeline.

Batches are a pure function of (seed, step), so:
  * restart-from-checkpoint reproduces the exact stream (fault tolerance),
  * each data shard can generate only its slice on real pods (no I/O skew),
  * power-aware batching just overlays a weight mask (repro.runtime
    .power_integration) -- the generator is oblivious.

The stream is a Zipf-ish unigram mix with a shifted-copy structure so the
model has learnable signal (quickstart trains loss well below uniform).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass
class Batch:
    tokens: jax.Array            # (B, S) int32 inputs
    labels: jax.Array            # (B, S) int32 targets (shifted)
    weights: jax.Array           # (B, S) f32 loss weights (0 = padding)
    extras: dict = dataclasses.field(default_factory=dict)


jax.tree_util.register_pytree_node(
    Batch,
    lambda b: ((b.tokens, b.labels, b.weights, b.extras), None),
    lambda aux, ch: Batch(*ch))


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0                 # checkpointable cursor
    copy_offset: int = 16         # learnable structure: token repeats

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.seed, self.step = int(d["seed"]), int(d["step"])

    def _tokens_for(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s = self.global_batch, self.seq_len
        # Zipf-ish unigrams in a smallish active vocab band.
        active = min(self.vocab_size, 4096)
        ranks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        toks = np.minimum(ranks, active - 1).astype(np.int32)
        # Structured copies: second half repeats the first half shifted.
        half = (s + 1) // 2
        toks[:, half:half + half - self.copy_offset] = \
            toks[:, self.copy_offset:half]
        return toks

    def next_batch(self) -> Batch:
        toks = self._tokens_for(self.step)
        self.step += 1
        return Batch(
            tokens=jnp.asarray(toks[:, :-1]),
            labels=jnp.asarray(toks[:, 1:]),
            weights=jnp.ones((self.global_batch, self.seq_len), jnp.float32),
        )

    def batch_specs(self, extras: Optional[dict] = None) -> dict:
        """ShapeDtypeStructs for jit lowering (dry-run)."""
        b, s = self.global_batch, self.seq_len
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "weights": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
        out.update(extras or {})
        return out
