from repro.data.pipeline import SyntheticTokens, Batch

__all__ = ["SyntheticTokens", "Batch"]
