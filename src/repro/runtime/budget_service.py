"""Live headroom / admission service over a hierarchical budget tree.

The simulation engines run the CloudPowerCap protocol in batch; this module
is the *control-plane* face of the same budget state: a service that holds
the cluster's :class:`repro.core.budget_tree.BudgetTree` plus the live cap
vector, ingests a replayed event feed (demand updates, power-on requests,
node-limit changes), answers headroom / admission queries, and streams the
cap decisions each event forces.  It is the piece a serving or training
runtime talks to between manager invocations:

  * :class:`repro.runtime.serve_loop.CapacityAwareRouter` re-weights
    dispatch from the caps the service streams
    (:func:`sync_router_capacities`);
  * :class:`repro.runtime.power_integration.PowerAwareBatchScheduler`
    re-plans per-pod batch shares from the same snapshot.

Every mutation preserves the tree invariant -- no node's powered-on (or
pending power-on) cap sum above its limit -- and every answer is checked
against brute-force recomputation by ``tests/test_budget_tree.py``.
Malformed input raises :class:`BudgetServiceError` with a structured
``code`` instead of corrupting state; the error taxonomy is pinned by
``tests/test_budget_service.py``.

``replay`` clocks each event with ``time.perf_counter`` and reports p50 /
p99 latencies; the ``budget_service`` benchmark
(``benchmarks/run.py``) commits them to ``BENCH_sweep.json`` and
``benchmarks/check_regression.py`` gates them in CI.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.budget_tree import BudgetTree

#: Tolerance on the tree invariant, matching the engines' budget asserts.
ATOL = 1e-6


class BudgetServiceError(ValueError):
    """Structured service error: ``code`` is machine-readable, stable."""

    def __init__(self, code: str, message: str):
        super().__init__(f"{code}: {message}")
        self.code = code


# ------------------------------------------------------------------ events
@dataclasses.dataclass(frozen=True)
class DemandUpdate:
    """A host asks for a new cap; the grant is clipped to its headroom."""
    host_id: str
    cap_w: float


@dataclasses.dataclass(frozen=True)
class PowerOnRequest:
    """Admit a standby host with ``cap_w`` if its root path has the room;
    the grant is reserved (counts as allocated) until the boot commits."""
    host_id: str
    cap_w: float


@dataclasses.dataclass(frozen=True)
class PowerOnComplete:
    """The pending boot finished: the host joins with its reserved grant."""
    host_id: str


@dataclasses.dataclass(frozen=True)
class PowerOff:
    host_id: str


@dataclasses.dataclass(frozen=True)
class NodeLimitChange:
    """Re-limit one tree node; binding rows are re-projected immediately
    (pending grants included), streaming the forced cap decreases."""
    node: int
    limit_w: float


@dataclasses.dataclass(frozen=True)
class HeadroomQuery:
    host_id: str


@dataclasses.dataclass(frozen=True)
class AdmissionQuery:
    """Would ``cap_w`` more watts fit under every limit on the host's root
    path right now?  Pure query -- no state change."""
    host_id: str
    cap_w: float


Event = Union[DemandUpdate, PowerOnRequest, PowerOnComplete, PowerOff,
              NodeLimitChange, HeadroomQuery, AdmissionQuery]


@dataclasses.dataclass(frozen=True)
class CapDecision:
    host_id: str
    cap_w: float
    reason: str


@dataclasses.dataclass
class ReplayReport:
    n_events: int
    n_decisions: int
    n_errors: int
    p50_us: float
    p99_us: float
    answers: list
    decisions: list
    errors: list


# ----------------------------------------------------------------- service
class BudgetService:
    """Holds the live (tree, caps, power states) and serves events.

    State mirrors the engines' accounting: a host whose power-on is
    pending holds its grant -- it counts toward every ancestor sum and
    the scalar budget exactly like the simulators' budget invariants
    count it -- but delivers nothing until :class:`PowerOnComplete`.
    """

    def __init__(self, tree: BudgetTree, host_ids: Sequence[str],
                 caps: np.ndarray, powered_on: np.ndarray,
                 budget: Optional[float] = None):
        if len(host_ids) != tree.n_hosts:
            raise BudgetServiceError(
                "bad-topology", f"{len(host_ids)} hosts for a tree with "
                f"{tree.n_hosts} leaves")
        self.tree = tree
        self.host_ids = list(host_ids)
        self._idx = {h: i for i, h in enumerate(self.host_ids)}
        self.caps = np.asarray(caps, dtype=np.float64).copy()
        self.on = np.asarray(powered_on, dtype=bool).copy()
        self.pending = np.zeros(tree.n_hosts, dtype=bool)
        self.budget = (float(budget) if budget is not None
                       else float(tree.limit[0]))
        over = tree.max_overshoot(self.caps, self.on)
        if over > ATOL:
            raise BudgetServiceError(
                "invariant", f"initial caps over a node limit by {over:.6f} W")

    # ------------------------------------------------------------- queries
    def _host(self, host_id) -> int:
        i = self._idx.get(host_id)
        if i is None:
            raise BudgetServiceError("unknown-host",
                                     f"no host {host_id!r}")
        return i

    def _alloc_mask(self) -> np.ndarray:
        return self.on | self.pending

    def headroom(self, host_id: str) -> float:
        """Watts the host could gain before some ancestor limit (or the
        scalar budget) binds, with pending grants counted as allocated."""
        i = self._host(host_id)
        mask = self._alloc_mask()
        slack = float(self.tree.host_slack(self.caps, mask)[i])
        budget_room = self.budget - float(self.caps[mask].sum())
        return max(min(slack, budget_room), 0.0)

    def admissible(self, host_id: str, cap_w: float) -> tuple[bool, float]:
        """(fits fully, watts grantable now) for ``cap_w`` *more* watts."""
        if not np.isfinite(cap_w) or cap_w < 0.0:
            raise BudgetServiceError("bad-watts",
                                     f"non-finite or negative {cap_w!r}")
        room = self.headroom(host_id)
        return cap_w <= room + ATOL, min(cap_w, room)

    # ----------------------------------------------------------- mutations
    def handle(self, event: Event):
        """Apply one event; returns (answer, [CapDecision, ...])."""
        decisions: list[CapDecision] = []
        answer = None
        if isinstance(event, HeadroomQuery):
            answer = self.headroom(event.host_id)
        elif isinstance(event, AdmissionQuery):
            answer = self.admissible(event.host_id, event.cap_w)
        elif isinstance(event, DemandUpdate):
            answer = self._demand_update(event, decisions)
        elif isinstance(event, PowerOnRequest):
            answer = self._power_on_request(event, decisions)
        elif isinstance(event, PowerOnComplete):
            self._power_on_complete(event)
        elif isinstance(event, PowerOff):
            self._power_off(event)
        elif isinstance(event, NodeLimitChange):
            self._node_limit_change(event, decisions)
        else:
            raise BudgetServiceError(
                "unknown-event", f"unhandled event type {type(event)!r}")
        self._check_invariant()
        return answer, decisions

    def _demand_update(self, ev: DemandUpdate, decisions: list) -> float:
        i = self._host(ev.host_id)
        if not np.isfinite(ev.cap_w) or ev.cap_w < 0.0:
            raise BudgetServiceError("bad-watts",
                                     f"non-finite or negative {ev.cap_w!r}")
        if not self.on[i] and not self.pending[i]:
            raise BudgetServiceError(
                "host-off", f"{ev.host_id!r} is powered off; use a "
                "PowerOnRequest to admit it")
        cur = float(self.caps[i])
        grant = (cur + self.headroom(ev.host_id) if ev.cap_w > cur
                 else ev.cap_w)
        new = min(ev.cap_w, grant)
        if new != cur:
            self.caps[i] = new
            decisions.append(CapDecision(ev.host_id, new, "demand-update"))
        return new

    def _power_on_request(self, ev: PowerOnRequest, decisions: list):
        i = self._host(ev.host_id)
        if not np.isfinite(ev.cap_w) or ev.cap_w < 0.0:
            raise BudgetServiceError("bad-watts",
                                     f"non-finite or negative {ev.cap_w!r}")
        if self.on[i]:
            raise BudgetServiceError("already-on",
                                     f"{ev.host_id!r} is already powered on")
        if self.pending[i]:
            raise BudgetServiceError(
                "already-pending",
                f"{ev.host_id!r} already has a power-on in flight")
        # The off host's stale cap does not count toward any sum, so the
        # grant is bounded by plain headroom.
        granted = min(ev.cap_w, self.headroom(ev.host_id))
        self.caps[i] = granted
        self.pending[i] = True
        decisions.append(CapDecision(ev.host_id, granted, "power-on-grant"))
        return granted

    def _power_on_complete(self, ev: PowerOnComplete) -> None:
        i = self._host(ev.host_id)
        if not self.pending[i]:
            raise BudgetServiceError(
                "not-pending", f"{ev.host_id!r} has no power-on in flight")
        self.pending[i] = False
        self.on[i] = True

    def _power_off(self, ev: PowerOff) -> None:
        i = self._host(ev.host_id)
        if not self.on[i] and not self.pending[i]:
            raise BudgetServiceError("host-off",
                                     f"{ev.host_id!r} is already off")
        self.on[i] = False
        self.pending[i] = False

    def _node_limit_change(self, ev: NodeLimitChange,
                           decisions: list) -> None:
        node = int(ev.node)
        if not 0 <= node < self.tree.n_nodes:
            raise BudgetServiceError("unknown-node",
                                     f"no tree node {node}")
        if not np.isfinite(ev.limit_w) and ev.limit_w != np.inf:
            raise BudgetServiceError("bad-watts",
                                     f"non-finite limit {ev.limit_w!r}")
        if ev.limit_w < 0.0:
            raise BudgetServiceError("bad-watts",
                                     f"negative limit {ev.limit_w!r}")
        self.tree = self.tree.with_limit(node, ev.limit_w)
        # Tightening may strand allocated watts (pending grants included):
        # re-project immediately so no node sits over its limit, and
        # stream the forced decreases.
        mask = self._alloc_mask()
        new = self.tree.project(self.caps, mask,
                                floors=np.zeros(self.tree.n_hosts))
        changed = mask & (new != self.caps)
        for i in np.nonzero(changed)[0]:
            decisions.append(CapDecision(self.host_ids[i], float(new[i]),
                                         "limit-change"))
        self.caps = np.where(mask, new, self.caps)

    def _check_invariant(self) -> None:
        mask = self._alloc_mask()
        over = self.tree.max_overshoot(self.caps, mask)
        assert over <= ATOL, (
            f"budget tree violated mid-transition: worst node over by "
            f"{over:.6f} W")
        total = float(self.caps[mask].sum())
        assert total <= self.budget + ATOL, (
            f"scalar budget violated: {total:.1f} W > {self.budget:.1f} W")

    # ------------------------------------------------------------- replay
    def replay(self, events: Sequence[Event],
               strict: bool = False) -> ReplayReport:
        """Feed an event stream; clock each event end to end.

        Malformed events are collected (code, event) unless ``strict``;
        state is never left mid-transition either way."""
        lat = np.empty(len(events))
        answers, all_decisions, errors = [], [], []
        for k, ev in enumerate(events):
            t0 = time.perf_counter()
            try:
                answer, decisions = self.handle(ev)
            except BudgetServiceError as e:
                if strict:
                    raise
                errors.append((e.code, ev))
                answer, decisions = None, []
            lat[k] = time.perf_counter() - t0
            answers.append(answer)
            all_decisions.extend(decisions)
        p50, p99 = (np.percentile(lat, (50, 99)) * 1e6
                    if len(events) else (0.0, 0.0))
        return ReplayReport(
            n_events=len(events), n_decisions=len(all_decisions),
            n_errors=len(errors), p50_us=float(p50), p99_us=float(p99),
            answers=answers, decisions=all_decisions, errors=errors)

    # --------------------------------------------------- runtime bridges
    def brute_force_headroom(self, host_id: str) -> float:
        """Reference recomputation from first principles (per-node Python
        sums over ``subtree_hosts``); the property suite pins
        ``headroom`` to this."""
        i = self._host(host_id)
        mask = self._alloc_mask()
        room = self.budget - sum(float(self.caps[j])
                                 for j in range(self.tree.n_hosts)
                                 if mask[j])
        node = int(self.tree.host_node[i])
        while node >= 0:
            members = np.nonzero(self.tree.subtree_hosts(node))[0]
            used = sum(float(self.caps[j]) for j in members if mask[j])
            room = min(room, float(self.tree.limit[node]) - used)
            node = int(self.tree.parent[node])
        return max(room, 0.0)


def sync_router_capacities(service: BudgetService, router,
                           replica_hosts: dict[str, str],
                           capacity_per_watt: float = 1.0) -> None:
    """Push the service's live caps into a
    :class:`repro.runtime.serve_loop.CapacityAwareRouter`: replicas on
    powered-off (or still-pending) hosts weight zero, so dispatch follows
    cap redistribution within one control period."""
    for rid, host_id in replica_hosts.items():
        i = service._host(host_id)
        cap = float(service.caps[i]) if service.on[i] else 0.0
        router.capacity[rid] = max(cap * capacity_per_watt, 0.0)


def service_from_snapshot(snapshot) -> BudgetService:
    """Build a service from a :class:`ClusterSnapshot` carrying a
    ``budget_tree`` (falls back to a flat one-node tree without it)."""
    host_ids = list(snapshot.hosts)
    caps = np.array([snapshot.hosts[h].power_cap for h in host_ids])
    on = np.array([snapshot.hosts[h].powered_on for h in host_ids])
    tree = snapshot.budget_tree or BudgetTree.flat(snapshot.power_budget,
                                                   len(host_ids))
    return BudgetService(tree, host_ids, caps, on,
                         budget=snapshot.power_budget)


def synthetic_feed(tree: BudgetTree, n_events: int = 2000,
                   seed: int = 0) -> list[Event]:
    """A mixed replayable event stream for the ``budget_service``
    benchmark: ~60% queries, ~30% demand updates, plus power churn and
    occasional limit changes, all against the given tree's leaf count."""
    rng = np.random.RandomState(seed)
    hosts = [f"host{i}" for i in range(tree.n_hosts)]
    events: list[Event] = []
    for _ in range(n_events):
        r = rng.rand()
        h = hosts[rng.randint(len(hosts))]
        if r < 0.35:
            events.append(HeadroomQuery(h))
        elif r < 0.6:
            events.append(AdmissionQuery(h, float(rng.uniform(0, 400))))
        elif r < 0.9:
            events.append(DemandUpdate(h, float(rng.uniform(0, 400))))
        elif r < 0.94:
            events.append(PowerOff(h))
        elif r < 0.98:
            events.append(PowerOnRequest(h, float(rng.uniform(0, 300))))
            events.append(PowerOnComplete(h))
        else:
            node = int(rng.randint(tree.n_nodes))
            scale = float(rng.uniform(0.6, 1.2))
            base = (float(tree.limit[node]) if np.isfinite(tree.limit[node])
                    else float(tree.limit[0]))
            events.append(NodeLimitChange(node, base * scale))
    return events
