"""Distributed runtime: sharding rules, train/serve steps, power-cap
integration, straggler mitigation, elastic resize."""
