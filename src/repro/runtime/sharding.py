"""Logical-axis sharding: rules, context, and constraint helpers.

Model code annotates tensors with *logical* axis names; the launcher binds a
mesh plus a rule table mapping logical names to mesh axes.  Outside a bound
context every annotation is a no-op, so the same model code runs in CPU smoke
tests, the 512-device dry-run, and on real pods.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical axis -> mesh axes (None = replicated)."""
    batch: tuple = ("pod", "data")       # data parallel (pods x hosts)
    seq: Optional[tuple] = None          # sequence of between-block activations
    inner_seq: Optional[tuple] = None    # sequence *inside* attention/MLP
    kv_seq: Optional[tuple] = None       # KV-cache sequence (long-context)
    heads: tuple = ("model",)            # attention heads / tensor parallel
    kv_heads: tuple = ("model",)
    ffn: tuple = ("model",)              # MLP hidden
    vocab: tuple = ("model",)
    expert: tuple = ("model",)           # MoE expert parallelism
    fsdp: Optional[tuple] = ("data",)    # parameter storage sharding
    embed: Optional[tuple] = None        # d_model activations
    embed_p: Optional[tuple] = ("data",) # d_model axis of *parameters* (FSDP)
    layer: Optional[tuple] = None        # stacked-layer axis of parameters

    def lookup(self, name: Optional[str]):
        if name is None:
            return None
        axes = getattr(self, name)
        return axes

    def mesh_axes(self, name: Optional[str], mesh: Mesh):
        axes = self.lookup(name)
        if axes is None:
            return None
        present = tuple(a for a in axes if a in mesh.axis_names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]


_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "sharding_ctx", default=None)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Rules):
    token = _CTX.set((mesh, rules))
    try:
        with mesh:
            yield
    finally:
        _CTX.reset(token)


def current_context() -> Optional[tuple[Mesh, Rules]]:
    return _CTX.get()


def logical_spec(*names: Optional[str]) -> Optional[P]:
    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, rules = ctx
    return P(*(rules.mesh_axes(n, mesh) for n in names))


def shard(x, *names: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op w/o context)."""
    spec = logical_spec(*names)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(*names: Optional[str]) -> Optional[NamedSharding]:
    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, _ = ctx
    spec = logical_spec(*names)
    return NamedSharding(mesh, spec)


def spec_to_sharding(mesh: Mesh, rules: Rules, names) -> NamedSharding:
    return NamedSharding(
        mesh, P(*(rules.mesh_axes(n, mesh) for n in names)))
