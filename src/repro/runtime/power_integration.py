"""Power-cap <-> training-plane integration: the part the paper could not
build in 2014.

``PowerAwareBatchScheduler`` converts the per-host power caps CloudPowerCap
maintains into per-pod batch shares: a pod capped at 80% throughput gets 80%
of the examples, expressed as a weight mask over the (fixed-shape) global
batch so SPMD stays in lockstep and nothing recompiles when caps move.

``StragglerMitigator`` is the paper's "Watts move faster than state" insight
applied to synchronous training: when one pod persistently lags, the first
response is a cap redistribution toward it (<1 ms, no step disruption);
only if caps are exhausted does it fall back to shrinking the straggler's
batch share (and ultimately to elastic resize, repro.runtime.elastic).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.balance import BalanceConfig, balance_power_cap
from repro.drs.snapshot import ClusterSnapshot


@dataclasses.dataclass
class BatchPlan:
    examples_per_pod: np.ndarray     # (n_pods,) ints, sum <= global_batch
    weights: np.ndarray              # (global_batch,) {0,1} mask
    shares: np.ndarray               # (n_pods,) capacity fractions

    @property
    def active_examples(self) -> int:
        return int(self.examples_per_pod.sum())


class PowerAwareBatchScheduler:
    """Maps host power caps to per-pod example counts.

    The global batch is laid out pod-major (examples [i*B/P:(i+1)*B/P) live
    on pod i under the ("pod","data") batch sharding), so masking the tail
    of each pod's slice implements the uneven split without data movement.
    """

    def __init__(self, global_batch: int, pod_hosts: list[list[str]],
                 hysteresis: float = 0.05):
        self.global_batch = global_batch
        self.pod_hosts = pod_hosts
        self.n_pods = len(pod_hosts)
        assert global_batch % self.n_pods == 0
        self.per_pod = global_batch // self.n_pods
        self.hysteresis = hysteresis
        self._last_shares: Optional[np.ndarray] = None

    def pod_capacities(self, snapshot: ClusterSnapshot) -> np.ndarray:
        caps = []
        for hosts in self.pod_hosts:
            caps.append(sum(snapshot.hosts[h].managed_capacity
                            for h in hosts))
        return np.asarray(caps, dtype=np.float64)

    def plan(self, snapshot: ClusterSnapshot) -> BatchPlan:
        cap = self.pod_capacities(snapshot)
        total = cap.sum()
        shares = (cap / total if total > 0
                  else np.full(self.n_pods, 1.0 / self.n_pods))
        if (self._last_shares is not None and
                np.abs(shares - self._last_shares).max() < self.hysteresis):
            shares = self._last_shares        # hysteresis: keep the old plan
        self._last_shares = shares

        # Step time is set by the slowest pod: pod i processes n_i examples
        # in time n_i / cap_i, so the optimal lockstep split is n_i ~ cap_i
        # with n_i <= per-pod slot count.
        raw = shares * self.global_batch
        n = np.minimum(np.floor(raw), self.per_pod).astype(int)
        # Hand leftover slots back ONLY where they do not raise the lockstep
        # step time (otherwise dropping the examples is faster than running
        # them on a capped pod -- the whole slice would wait).
        step_time = float(np.max(n / np.maximum(cap, 1e-9)))
        leftover = self.global_batch - int(n.sum())
        for _ in range(leftover):
            times = (n + 1) / np.maximum(cap, 1e-9)
            candidates = np.where((times <= step_time * (1 + 1e-9))
                                  & (n < self.per_pod))[0]
            if candidates.size == 0:
                break
            n[candidates[0]] += 1
        weights = np.zeros(self.global_batch, dtype=np.float32)
        for i, ni in enumerate(n):
            weights[i * self.per_pod: i * self.per_pod + ni] = 1.0
        return BatchPlan(examples_per_pod=n, weights=weights, shares=shares)

    def apply(self, batch: dict, plan: BatchPlan) -> dict:
        """Overlay the plan's mask onto a batch dict (weights: (B, S))."""
        w = batch["weights"] * plan.weights[:, None]
        out = dict(batch)
        out["weights"] = w
        return out


@dataclasses.dataclass
class StragglerReport:
    step_times: dict[str, float]        # host -> recent mean step seconds


class StragglerMitigator:
    """Cap-first straggler mitigation.

    detect(): a host is a straggler when its step time exceeds the cluster
    median by ``threshold`` for ``patience`` consecutive reports.
    mitigate(): rebalance power caps toward stragglers by treating measured
    throughput deficit as entitlement (reuses BalancePowerCap); returns the
    rebalanced snapshot or None if Watts cannot help (then the caller shrinks
    the straggler's batch share / triggers elastic resize).
    """

    def __init__(self, threshold: float = 0.15, patience: int = 3):
        self.threshold = threshold
        self.patience = patience
        self._strikes: dict[str, int] = {}

    def detect(self, report: StragglerReport) -> list[str]:
        times = report.step_times
        med = float(np.median(list(times.values())))
        out = []
        for host, t in times.items():
            if t > med * (1 + self.threshold):
                self._strikes[host] = self._strikes.get(host, 0) + 1
                if self._strikes[host] >= self.patience:
                    out.append(host)
            else:
                self._strikes[host] = 0
        return out

    def mitigate(self, snapshot: ClusterSnapshot, report: StragglerReport
                 ) -> Optional[ClusterSnapshot]:
        # Encode "runs slower than it should" as demand on the host: demand
        # proportional to step-time excess, then let powercap balancing move
        # Watts toward the hot hosts.
        med = float(np.median(list(report.step_times.values())))
        for host_id, t in report.step_times.items():
            host = snapshot.hosts[host_id]
            scale = t / max(med, 1e-9)
            for vm in snapshot.vms_on(host_id):
                vm.demand = vm.demand * scale
        balanced, did = balance_power_cap(snapshot, BalanceConfig())
        return balanced if did else None
