"""Serving: prefill + decode steps and a capacity-aware request router.

``decode_step`` is the function the decode_32k / long_500k dry-run cells
lower: one new token against a full-length cache, with the cache sequence
axis sharded over "data" for the long-context cell (distributed
flash-decode: XLA inserts the cross-device softmax combine).

The router is the serving-plane face of CloudPowerCap: replica throughput is
proportional to power-capped capacity, so dispatch weights follow the caps
the manager sets, and DPM power-on/off of replicas flows through the same
budget redistribution as the training plane.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig

PyTree = Any


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill(params, tokens, extras: Optional[dict] = None):
        extras = extras or {}
        b, s = tokens.shape
        cache = tfm.init_decode_state(cfg, b, max_len)
        kwargs = {}
        if cfg.family == "vlm" and "vision_embeds" in extras:
            kwargs["vision_embeds"] = extras["vision_embeds"]
        enc_out = None
        if cfg.family == "encdec":
            kwargs["frames"] = extras["frames"]
        res = tfm.forward(params, cfg, tokens=tokens, cache=cache, **kwargs)
        w_out = tfm.unembed_weight(params, cfg)
        logits = (res.hidden[:, -1] @ w_out).astype(jnp.float32)
        state = {"cache": res.cache, "pos": jnp.full((b,), s, jnp.int32)}
        if cfg.family == "encdec":
            # Cross-attention source is fixed after prefill.
            state["enc_frames"] = extras["frames"]
        return logits, state
    return prefill


def make_decode_step(cfg: ModelConfig, sample: str = "greedy"):
    def decode(params, state, tokens):
        """tokens: (B,) last emitted tokens -> (next_logits, new state)."""
        b = tokens.shape[0]
        pos = state["pos"]
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["frames"] = state["enc_frames"]
        res = tfm.forward(params, cfg, tokens=tokens[:, None],
                          cache=state["cache"],
                          positions=pos[:, None], **kwargs)
        w_out = tfm.unembed_weight(params, cfg)
        logits = (res.hidden[:, -1] @ w_out).astype(jnp.float32)
        new_state = dict(state)
        new_state["cache"] = res.cache
        new_state["pos"] = pos + 1
        return logits, new_state
    return decode


def greedy_generate(cfg: ModelConfig, params, prompt, steps: int,
                    max_len: int, extras: Optional[dict] = None):
    """Convenience: prefill + N greedy decode steps (examples/tests)."""
    prefill = make_prefill_step(cfg, max_len)
    decode = jax.jit(make_decode_step(cfg))
    logits, state = prefill(params, prompt, extras)
    out = [jnp.argmax(logits, -1)]
    for _ in range(steps - 1):
        logits, state = decode(params, state, out[-1])
        out.append(jnp.argmax(logits, -1))
    return jnp.stack(out, axis=1)


# ------------------------------------------------------------------ router
@dataclasses.dataclass
class Replica:
    replica_id: str
    host_id: str                  # host in the CPC cluster snapshot
    queue: int = 0                # outstanding requests


class CapacityAwareRouter:
    """Weighted least-loaded dispatch, weights = power-capped capacity.

    ``sync_capacities`` reads the capacities straight from the CloudPowerCap
    snapshot, so a cap redistribution (e.g. after a DPM power-off) shifts
    traffic within one control-loop period with no further coordination.
    """

    def __init__(self, replicas: list[Replica]):
        self.replicas = {r.replica_id: r for r in replicas}
        self.capacity: dict[str, float] = {r: 1.0 for r in self.replicas}

    def sync_capacities(self, snapshot) -> None:
        for rid, rep in self.replicas.items():
            host = snapshot.hosts[rep.host_id]
            self.capacity[rid] = max(host.managed_capacity, 0.0)

    def route(self, n_requests: int = 1) -> list[str]:
        """Assign requests to replicas; returns replica ids (one per req)."""
        out = []
        for _ in range(n_requests):
            live = [(rid, rep) for rid, rep in self.replicas.items()
                    if self.capacity.get(rid, 0.0) > 0.0]
            if not live:
                raise RuntimeError("no replica has capacity")
            rid, rep = min(
                live,
                key=lambda kv: (kv[1].queue + 1) / self.capacity[kv[0]])
            rep.queue += 1
            out.append(rid)
        return out

    def complete(self, replica_id: str) -> None:
        self.replicas[replica_id].queue -= 1
