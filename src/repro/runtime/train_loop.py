"""Training step: streamed-xent loss, grads, AdamW update.

The batch is a plain dict (tokens/labels/weights + optional frontend
embeddings) so the dry-run can lower the exact same function from
ShapeDtypeStructs.  ``weights`` carries the power-aware batch mask (see
repro.runtime.power_integration): examples a capped pod cannot afford this
step have weight zero and the loss renormalizes, keeping SPMD lockstep with
*uneven effective* batch sizes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.layers import streamed_xent
from repro.optim.adamw import AdamW, OptState
from repro.optim.compress import ErrorFeedbackCompressor

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: OptState
    step: jax.Array
    compress_residual: Optional[PyTree] = None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step, s.compress_residual), None),
    lambda aux, ch: TrainState(*ch))


def init_train_state(key, cfg: ModelConfig, opt: AdamW,
                     compression: bool = False) -> TrainState:
    params = tfm.init_params(key, cfg)
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    if compression:
        state.compress_residual = ErrorFeedbackCompressor().init(params)
    return state


def make_loss_fn(cfg: ModelConfig, aux_weight: float = 0.01):
    def loss_fn(params, batch):
        kwargs = {}
        if cfg.family == "vlm" and "vision_embeds" in batch:
            kwargs["vision_embeds"] = batch["vision_embeds"]
        if cfg.family == "encdec":
            kwargs["frames"] = batch["frames"]
        res = tfm.forward(params, cfg, tokens=batch["tokens"], **kwargs)
        h = res.hidden
        if cfg.family == "vlm" and "vision_embeds" in batch:
            h = h[:, batch["vision_embeds"].shape[1]:]   # text positions only
        w_out = tfm.unembed_weight(params, cfg)
        loss_sum, w_sum = streamed_xent(h, w_out, batch["labels"],
                                        batch["weights"],
                                        chunk=cfg.xent_chunk)
        w_sum = jnp.maximum(w_sum, 1.0)
        loss = loss_sum / w_sum + aux_weight * res.aux_loss
        metrics = {"loss": loss_sum / w_sum, "aux_loss": res.aux_loss,
                   "tokens": w_sum}
        return loss, metrics
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamW, aux_weight: float = 0.01,
                    compression: bool = False, donate: bool = True,
                    grad_shardings: Optional[PyTree] = None):
    """Returns train_step(state, batch) -> (state, metrics), jit-ready.

    ``cfg.microbatches > 1`` scans gradient accumulation over batch slices:
    each microbatch's backward consumes its remat residuals before the next
    begins, dividing peak activation memory by the accumulation factor (and
    letting XLA overlap one microbatch's grad collectives with the next
    one's compute).  Token-weighted accumulation keeps the gradient exactly
    equal to the single-shot batch gradient under power-aware masking.

    ``grad_shardings`` (pytree of NamedSharding matching params) constrains
    each microbatch's gradients to the parameter layout, turning the per-mb
    data-axis psum into a reduce-scatter onto the FSDP shard instead of a
    full f32 all-reduce (see EXPERIMENTS.md SPerf, nemotron iteration 3).
    """
    loss_fn = make_loss_fn(cfg, aux_weight)
    k = max(cfg.microbatches, 1)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, grad_shardings)

    def grads_and_metrics(params, batch):
        if k == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return constrain(grads), metrics

        def split(x):
            b = x.shape[0]
            return jnp.moveaxis(
                x.reshape((k, b // k) + x.shape[1:]), 0, 0)

        mbs = {key: split(v) for key, v in batch.items()}

        def mb_step(carry, mb):
            gsum, loss_sum, tok_sum, aux_sum = carry
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads = constrain(grads)
            tokens = metrics["tokens"]
            gsum = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) * tokens, gsum, grads)
            return (gsum, loss_sum + metrics["loss"] * tokens,
                    tok_sum + tokens, aux_sum + metrics["aux_loss"]), None

        g0 = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (gsum, loss_sum, tok_sum, aux_sum), _ = jax.lax.scan(
            mb_step, (g0, jnp.zeros((), jnp.float32),
                      jnp.zeros((), jnp.float32),
                      jnp.zeros((), jnp.float32)), mbs)
        tok = jnp.maximum(tok_sum, 1.0)
        grads = jax.tree_util.tree_map(lambda g: g / tok, gsum)
        metrics = {"loss": loss_sum / tok, "aux_loss": aux_sum / k,
                   "tokens": tok_sum}
        return grads, metrics

    def train_step(state: TrainState, batch: dict):
        grads, metrics = grads_and_metrics(state.params, batch)
        residual = state.compress_residual
        if compression and residual is not None:
            grads, residual = ErrorFeedbackCompressor().compress(
                grads, residual)
        params, opt_state = opt.update(grads, state.opt_state, state.params)
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1,
                               compress_residual=residual)
        return new_state, metrics

    return train_step
