"""Elastic resize: DPM-driven scale-up/down via checkpoint-reshard.

When CloudPowerCap's DPM path powers pods off (sustained low demand) or on
(hot cluster), the training job resizes: the controller checkpoints, builds
the new mesh, restores every leaf onto the new shardings (global arrays ->
any mesh), rebuilds the power-aware batch plan, and resumes.  The same path
is the *failure* path: losing a pod is a scale-down whose checkpoint is the
last completed async save.

The controller is deliberately synchronous and explicit -- resize is a rare,
heavyweight transition; correctness (no budget violation, no lost optimizer
state, reproducible data cursor) matters more than overlap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import Checkpointer

PyTree = Any


@dataclasses.dataclass
class ResizeEvent:
    step: int
    from_pods: int
    to_pods: int
    reason: str                    # "dpm-poweroff" | "dpm-poweron" | "failure"


class ElasticController:
    """Owns the resize protocol.

    make_mesh(n_pods) and make_shardings(mesh, target) are injected so the
    controller is independent of model/config specifics.
    """

    def __init__(self, checkpointer: Checkpointer,
                 make_mesh: Callable[[int], Any],
                 make_shardings: Callable[[Any, PyTree], PyTree]):
        self.checkpointer = checkpointer
        self.make_mesh = make_mesh
        self.make_shardings = make_shardings
        self.history: list[ResizeEvent] = []

    def resize(self, state: PyTree, step: int, from_pods: int, to_pods: int,
               reason: str, extra_metadata: Optional[dict] = None
               ) -> tuple[Any, PyTree]:
        """Checkpoint -> new mesh -> restore resharded.  Returns
        (new_mesh, new_state)."""
        self.checkpointer.save(step, state, extra_metadata)
        mesh = self.make_mesh(to_pods)
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shardings = self.make_shardings(mesh, target)
        new_state = self.checkpointer.restore(step, target, shardings)
        self.history.append(ResizeEvent(step, from_pods, to_pods, reason))
        return mesh, new_state

    def recover(self, target: PyTree, to_pods: int, reason: str = "failure"
                ) -> tuple[Any, PyTree, int]:
        """Restart from the last completed checkpoint onto ``to_pods``."""
        step = self.checkpointer.latest_step()
        if step is None:
            raise RuntimeError("no checkpoint to recover from")
        mesh = self.make_mesh(to_pods)
        shardings = self.make_shardings(mesh, target)
        state = self.checkpointer.restore(step, target, shardings)
        self.history.append(ResizeEvent(step, -1, to_pods, reason))
        return mesh, state, step
