"""Array backends: one kernel source, three executors.

The allocation math in ``repro.core.kernels`` and ``repro.drs.entitlement``
is written once against this tiny namespace-plus-segment-ops protocol and
runs on any of three executors:

  * ``numpy``      -- eager NumPy.  Python-level loop drivers may early-exit
    on concrete booleans, which keeps the per-object manager path cheap.
  * ``jax``        -- ``jax.numpy`` plus ``lax`` structured loops, so the
    same kernels are `jit`/`vmap`-able and compile into the batched sweep
    engine (``repro.sim.batch``) as a single program.
  * ``jax-pallas`` -- the JAX executor with the hot allocation kernels
    (dense waterfill, the fused waterfill + BalancePowerCap round) routed
    through the Pallas kernels in ``repro.kernels.powercap`` instead of
    plain lax ops.  Off-TPU the kernels run in interpret mode, where they
    are bit-identical to the lax path (enforced by
    ``tests/test_kernel_parity.py``).

The active executor is selected by the ``REPRO_EXECUTOR`` environment
variable or :func:`set_executor` / :func:`executor_scope`; it changes only
*where* the allocation math executes, never the decision protocol --
``ManagerCore`` (via the ``repro.core.balance`` adapter), the NumPy
``VectorSimulator`` delivery path, and the jitted ``BatchedSimulator`` all
pick up the selected executor through the ``repro.drs.entitlement`` /
``repro.core.kernels`` dispatchers.

Only the operations the kernels actually need are abstracted: the shared
elementwise vocabulary (``where``/``clip``/``minimum``/...) is identical
between ``numpy`` and ``jax.numpy`` and is reached through ``backend.xp``;
segment reductions and fixed-trip loops differ and get explicit methods.

JAX is imported lazily: the NumPy path (tier-1 simulator tests, the
per-object manager) never touches jax device state.
"""

from __future__ import annotations

import contextlib
import os

import numpy as np


class NumpyBackend:
    """Eager NumPy executor."""

    name = "numpy"
    xp = np

    @staticmethod
    def seg_sum(values, seg_ids, n_segs):
        return np.bincount(seg_ids, weights=values, minlength=n_segs)

    @staticmethod
    def seg_max(values, seg_ids, n_segs):
        """Per-segment max, 0 for empty segments (values assumed >= 0)."""
        out = np.zeros(n_segs, dtype=np.float64)
        np.maximum.at(out, seg_ids, values)
        return out

    @staticmethod
    def seg_min(values, seg_ids, n_segs):
        """Per-segment min, +inf for empty segments (the budget-tree
        slack gather: min headroom over each host's ancestor path)."""
        out = np.full(n_segs, np.inf, dtype=np.float64)
        np.minimum.at(out, seg_ids, values)
        return out

    @staticmethod
    def fori(n, body, init):
        """``state = body(i, state)`` for i in [0, n)."""
        state = init
        for i in range(n):
            state = body(i, state)
        return state

    @staticmethod
    def while_loop(cond, body, init):
        state = init
        while bool(cond(state)):
            state = body(state)
        return state

    @staticmethod
    def argsort(values, axis=-1):
        """Stable argsort: ties keep their original order, so greedy
        tie-breaks ("first host wins") agree between backends."""
        return np.argsort(values, axis=axis, kind="stable")

    @staticmethod
    def asarray(values, dtype=np.float64):
        return np.asarray(values, dtype=dtype)


class JaxBackend:
    """jit/vmap-able executor over jax.numpy + lax."""

    name = "jax"

    def __init__(self):
        import jax
        import jax.numpy as jnp
        self._jax = jax
        self.xp = jnp

    def seg_sum(self, values, seg_ids, n_segs):
        return self._jax.ops.segment_sum(values, seg_ids,
                                         num_segments=n_segs)

    def seg_max(self, values, seg_ids, n_segs):
        # segment_max yields -inf for empty segments; clamp to the NumPy
        # backend's zero-initialized semantics (values are >= 0).
        out = self._jax.ops.segment_max(values, seg_ids, num_segments=n_segs)
        return self.xp.maximum(out, 0.0)

    def seg_min(self, values, seg_ids, n_segs):
        # segment_min yields +inf for empty segments, matching the NumPy
        # backend's inf-initialized semantics.
        return self._jax.ops.segment_min(values, seg_ids,
                                         num_segments=n_segs)

    def fori(self, n, body, init):
        return self._jax.lax.fori_loop(0, n, body, init)

    def while_loop(self, cond, body, init):
        return self._jax.lax.while_loop(cond, body, init)

    def argsort(self, values, axis=-1):
        return self.xp.argsort(values, axis=axis, stable=True)

    def asarray(self, values, dtype=None):
        return self.xp.asarray(values, dtype=dtype)


NUMPY = NumpyBackend()

_JAX = None


def jax_backend() -> JaxBackend:
    """The process-wide JAX backend (constructed on first use)."""
    global _JAX
    if _JAX is None:
        _JAX = JaxBackend()
    return _JAX


# --------------------------------------------------------------- executors
#: Valid values for the allocation-kernel executor switch.
EXECUTORS = ("numpy", "jax", "jax-pallas")

#: Process-wide override set by :func:`set_executor`; ``None`` defers to the
#: ``REPRO_EXECUTOR`` environment variable (default ``"jax"``: NumPy callers
#: stay on NumPy, JAX callers use plain lax ops).
_EXECUTOR_OVERRIDE: str | None = None


def executor_name() -> str:
    """The active allocation-kernel executor.

    ``numpy``/``jax`` keep every caller on its native array plane (the
    historical behavior).  ``jax-pallas`` routes the hot allocation kernels
    -- dense waterfill and the fused BalancePowerCap round -- through the
    Pallas kernels in ``repro.kernels.powercap``: JAX callers (the batched
    sweep engine) swap them in place of the lax ops, and the object-plane
    adapters (``repro.core.balance``, ``VectorSimulator`` delivery) lift
    their columns onto the JAX plane to reach them.
    """
    name = _EXECUTOR_OVERRIDE or os.environ.get("REPRO_EXECUTOR", "jax")
    if name not in EXECUTORS:
        raise ValueError(
            f"REPRO_EXECUTOR={name!r} is not one of {EXECUTORS}")
    return name


def set_executor(name: str | None) -> None:
    """Set (or with ``None`` clear) the process-wide executor override."""
    global _EXECUTOR_OVERRIDE
    if name is not None and name not in EXECUTORS:
        raise ValueError(f"executor {name!r} is not one of {EXECUTORS}")
    _EXECUTOR_OVERRIDE = name


@contextlib.contextmanager
def executor_scope(name: str):
    """Temporarily pin the executor (used by the batched engine so the
    executor captured at pack time governs trace-time dispatch)."""
    global _EXECUTOR_OVERRIDE
    prev = _EXECUTOR_OVERRIDE
    set_executor(name)
    try:
        yield
    finally:
        _EXECUTOR_OVERRIDE = prev


def pallas_enabled() -> bool:
    """Whether the hot allocation kernels should dispatch to Pallas."""
    return executor_name() == "jax-pallas"
