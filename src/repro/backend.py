"""Array backends: one kernel source, two executors (NumPy and JAX).

The allocation math in ``repro.core.kernels`` and ``repro.drs.entitlement``
is written once against this tiny namespace-plus-segment-ops protocol and
runs on either backend:

  * ``NUMPY`` -- eager NumPy.  Python-level loop drivers may early-exit on
    concrete booleans, which keeps the per-object manager path cheap.
  * ``JAX``   -- ``jax.numpy`` plus ``lax`` structured loops, so the same
    kernels are `jit`/`vmap`-able and compile into the batched sweep engine
    (``repro.sim.batch``) as a single program.

Only the operations the kernels actually need are abstracted: the shared
elementwise vocabulary (``where``/``clip``/``minimum``/...) is identical
between ``numpy`` and ``jax.numpy`` and is reached through ``backend.xp``;
segment reductions and fixed-trip loops differ and get explicit methods.

JAX is imported lazily: the NumPy path (tier-1 simulator tests, the
per-object manager) never touches jax device state.
"""

from __future__ import annotations

import numpy as np


class NumpyBackend:
    """Eager NumPy executor."""

    name = "numpy"
    xp = np

    @staticmethod
    def seg_sum(values, seg_ids, n_segs):
        return np.bincount(seg_ids, weights=values, minlength=n_segs)

    @staticmethod
    def seg_max(values, seg_ids, n_segs):
        """Per-segment max, 0 for empty segments (values assumed >= 0)."""
        out = np.zeros(n_segs, dtype=np.float64)
        np.maximum.at(out, seg_ids, values)
        return out

    @staticmethod
    def fori(n, body, init):
        """``state = body(i, state)`` for i in [0, n)."""
        state = init
        for i in range(n):
            state = body(i, state)
        return state

    @staticmethod
    def while_loop(cond, body, init):
        state = init
        while bool(cond(state)):
            state = body(state)
        return state

    @staticmethod
    def argsort(values, axis=-1):
        """Stable argsort: ties keep their original order, so greedy
        tie-breaks ("first host wins") agree between backends."""
        return np.argsort(values, axis=axis, kind="stable")

    @staticmethod
    def asarray(values, dtype=np.float64):
        return np.asarray(values, dtype=dtype)


class JaxBackend:
    """jit/vmap-able executor over jax.numpy + lax."""

    name = "jax"

    def __init__(self):
        import jax
        import jax.numpy as jnp
        self._jax = jax
        self.xp = jnp

    def seg_sum(self, values, seg_ids, n_segs):
        return self._jax.ops.segment_sum(values, seg_ids,
                                         num_segments=n_segs)

    def seg_max(self, values, seg_ids, n_segs):
        # segment_max yields -inf for empty segments; clamp to the NumPy
        # backend's zero-initialized semantics (values are >= 0).
        out = self._jax.ops.segment_max(values, seg_ids, num_segments=n_segs)
        return self.xp.maximum(out, 0.0)

    def fori(self, n, body, init):
        return self._jax.lax.fori_loop(0, n, body, init)

    def while_loop(self, cond, body, init):
        return self._jax.lax.while_loop(cond, body, init)

    def argsort(self, values, axis=-1):
        return self.xp.argsort(values, axis=axis, stable=True)

    def asarray(self, values, dtype=None):
        return self.xp.asarray(values, dtype=dtype)


NUMPY = NumpyBackend()

_JAX = None


def jax_backend() -> JaxBackend:
    """The process-wide JAX backend (constructed on first use)."""
    global _JAX
    if _JAX is None:
        _JAX = JaxBackend()
    return _JAX
