"""Serving driver: CloudPowerCap-managed replica fleet.

Each replica is a pod-hosted model instance; the CloudPowerCap manager owns
the fleet's power budget, and the router follows power-capped capacities.
``--smoke`` runs the reduced config on CPU and actually decodes; on real
pods each replica process runs the same loop under its own mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \
      --requests 32 --decode-steps 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.core.power_model import TPU_V5E_HOST
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.models import transformer as tfm
from repro.runtime.serve_loop import (CapacityAwareRouter, Replica,
                                      greedy_generate)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--cap-frac", type=float, nargs="*", default=None,
                    help="initial per-replica cap fractions of peak")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    fracs = args.cap_frac or [1.0] * args.replicas
    hosts = [Host(f"h{i}", TPU_V5E_HOST,
                  power_cap=fracs[i % len(fracs)] * TPU_V5E_HOST.power_peak)
             for i in range(args.replicas)]
    vms = [VirtualMachine(vm_id=f"rep{i}", host_id=f"h{i}",
                          demand=TPU_V5E_HOST.capacity_peak * 0.8)
           for i in range(args.replicas)]
    snap = ClusterSnapshot(
        hosts, vms, power_budget=sum(h.power_cap for h in hosts))
    manager = CloudPowerCapManager(ManagerConfig(dpm_enabled=False))
    router = CapacityAwareRouter(
        [Replica(f"rep{i}", f"h{i}") for i in range(args.replicas)])
    router.sync_capacities(snap)

    key = jax.random.PRNGKey(1)
    assigned = router.route(args.requests)
    by_rep: dict[str, int] = {}
    for r in assigned:
        by_rep[r] = by_rep.get(r, 0) + 1
    print(f"routing {args.requests} requests over {args.replicas} replicas "
          f"(caps {[round(h.power_cap) for h in hosts]} W): {by_rep}")

    # Serve each replica's batch (real decode on the smoke model).
    t0 = time.time()
    total_tokens = 0
    for rep_id, n in by_rep.items():
        prompts = jax.random.randint(key, (n, args.prompt_len), 0,
                                     cfg.vocab_size)
        toks = greedy_generate(cfg, params, prompts,
                               steps=args.decode_steps,
                               max_len=args.max_len)
        total_tokens += int(np.prod(toks.shape))
        for r in range(n):
            router.complete(rep_id)
    dt = time.time() - t0
    print(f"decoded {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.0f} tok/s on this backend)")

    # Power event: rebalance caps, watch routing follow.
    snap.hosts["h0"].power_cap *= 0.5
    result = manager.run_invocation(snap)
    snap = result.snapshot
    router.sync_capacities(snap)
    assigned = router.route(args.requests)
    by_rep = {}
    for r in assigned:
        by_rep[r] = by_rep.get(r, 0) + 1
    print(f"after cap event (caps "
          f"{[round(h.power_cap) for h in snap.hosts.values()]} W): "
          f"{by_rep}")


if __name__ == "__main__":
    main()
