import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: jax.jit with
explicit in_shardings over the production mesh, ``.lower().compile()`` must
succeed, and the compiled artifact yields the roofline terms
(cost_analysis FLOPs/bytes; collective bytes parsed from the partitioned
HLO).  Results land in ``results/dryrun/<cell>.json`` for EXPERIMENTS.md.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite_8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch import inputs as inp
from repro.launch import shardspecs as ss
from repro.launch.costing import hlo_collective_bytes, jaxpr_cost
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, shapes_for
from repro.optim.adamw import AdamW
from repro.runtime.sharding import sharding_context
from repro.runtime.train_loop import make_train_step
from repro.runtime.serve_loop import make_decode_step, make_prefill_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# TPU v5e hardware model (roofline constants).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def _lower_cell(cfg, shape, mesh, rules):
    """Build + lower the cell's step fn.  Returns (lowered, step, args)."""
    if shape.kind == "train":
        opt = AdamW(state_dtype=cfg.optimizer_state_dtype)
        step = make_train_step(
            cfg, opt, grad_shardings=ss.param_shardings(cfg, mesh, rules))
        state_abs = ss.abstract_train_state(cfg)
        batch_abs = inp.train_batch_specs(cfg, shape)
        in_sh = (ss.train_state_shardings(cfg, mesh, rules),
                 ss.batch_shardings(cfg, mesh, rules, batch_abs))
        lowered = jax.jit(step, in_shardings=in_sh).lower(state_abs,
                                                          batch_abs)
        return lowered, step, (state_abs, batch_abs)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        tokens_abs, extras_abs = inp.prefill_specs(cfg, shape)
        in_sh = (ss.param_shardings(cfg, mesh, rules),
                 ss.batch_shardings(cfg, mesh, rules, {"tokens": None}
                                    )["tokens"],
                 ss.batch_shardings(cfg, mesh, rules, extras_abs))
        args = (ss_abstract_params(cfg), tokens_abs, extras_abs)
        lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
        return lowered, step, args
    # decode
    step = make_decode_step(cfg)
    state_abs = inp.decode_state_specs(cfg, shape)
    tokens_abs = inp.decode_token_specs(shape)
    in_sh = (ss.param_shardings(cfg, mesh, rules),
             ss.decode_state_shardings(cfg, mesh, rules, state_abs),
             ss.batch_shardings(cfg, mesh, rules,
                                {"last_tokens": None})["last_tokens"])
    args = (ss_abstract_params(cfg), state_abs, tokens_abs)
    lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
    return lowered, step, args


def ss_abstract_params(cfg):
    from repro.models import transformer as tfm
    return tfm.abstract_params(cfg)


def score_tile_bytes(cfg, shape, n_chips: int) -> float:
    """HBM traffic of attention-score / SSD-decay intermediates that the
    Pallas kernels (repro.kernels, validated in interpret mode against the
    jnp oracles) keep in VMEM on the TPU target.

    The XLA fallback path materializes the whole f32 score chain
    (scores -> mask -> exp, ~3 tensors per pass) between the two attention
    dots; per (arch x shape) the analytic estimate is
    passes x chain x B x H x Sq x Skv x 4 bytes (causal halves it), with
    passes ~= 4 for training (fwd + remat recompute + ~2 bwd) and 1 for
    prefill, chain ~= 3 (matching the jaxpr byte model, which charges each
    elementwise output).  Subtracting it yields the kernel-path memory
    roofline."""
    b, s = shape.global_batch, shape.seq_len
    passes = (4.0 if shape.kind == "train" else 1.0) * 3.0
    total = 0.0
    if cfg.attn_layers and cfg.n_heads and shape.kind != "decode":
        total += (passes * b * cfg.n_heads * s * s * 4 * 0.5
                  * cfg.attn_layers)
    if cfg.ssm_layers and shape.kind != "decode":
        q = cfg.ssm_chunk
        total += (passes * b * cfg.n_ssm_heads * s * q * 4
                  * cfg.ssm_layers)
    return total / n_chips


def _kernel_adjusted(cfg, shape, n_chips, bytes_dev, t_compute,
                     t_collective) -> dict:
    adj_bytes = max(bytes_dev - score_tile_bytes(cfg, shape, n_chips),
                    bytes_dev * 0.1)
    t_mem = adj_bytes / HBM_BW
    dom = max((("compute", t_compute), ("memory", t_mem),
               ("collective", t_collective)), key=lambda kv: kv[1])
    return {"t_memory_s": t_mem, "dominant": dom[0], "bound_s": dom[1]}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, force: bool = False,
             overrides: dict | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{configs.canonical(arch)}__{shape_name}__{mesh_name}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = configs.get(arch)
    if overrides:
        import dataclasses
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(v) if cur is not None else v
        cfg = dataclasses.replace(cfg, **typed)
    shape = SHAPES[shape_name]
    result = {"cell": cell, "arch": configs.canonical(arch),
              "shape": shape_name, "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = int(mesh.devices.size)
        rules = ss.rules_for(cfg, shape, mesh_size=n_chips)
        cfg = ss.effective_config(cfg, shape, n_chips)
        with sharding_context(mesh, rules):
            lowered, step_fn, abstract_args = _lower_cell(cfg, shape, mesh,
                                                          rules)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            xla_cost = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            coll_raw = hlo_collective_bytes(hlo_text)
            coll = hlo_collective_bytes(hlo_text, f32_as_bf16=True)
            del hlo_text
            # Exact FLOPs/bytes from the jaxpr (scan trip counts included);
            # global, so divide by chips for the per-device roofline terms.
            jcost = jaxpr_cost(jax.make_jaxpr(step_fn)(*abstract_args))
        flops_dev = jcost["flops"] / n_chips
        bytes_dev = jcost["bytes"] / n_chips
        t_compute = flops_dev / PEAK_FLOPS
        t_memory = bytes_dev / HBM_BW
        t_collective = coll.get("total", 0) / ICI_BW
        dominant = max((("compute", t_compute), ("memory", t_memory),
                        ("collective", t_collective)), key=lambda kv: kv[1])
        model_flops = cfg.flops_per_token(shape.seq_len) * (
            shape.global_batch * shape.seq_len if shape.kind == "train"
            else 0)
        result.update({
            "ok": True,
            "n_chips": n_chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "collective_bytes_per_device": coll,
            "collective_bytes_raw_f32_legalized": coll_raw,
            "xla_cost_analysis": {
                "flops": float(xla_cost.get("flops", 0.0)),
                "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
                "note": "while bodies counted once by XLA; see costing.py",
            },
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "roofline": {
                "t_compute_s": t_compute,
                "t_memory_s": t_memory,
                "t_collective_s": t_collective,
                "dominant": dominant[0],
                "bound_s": dominant[1],
            },
            "roofline_kernel_path": _kernel_adjusted(
                cfg, shape, n_chips, bytes_dev, t_compute, t_collective),
            "model_flops_global": model_flops,
            "useful_flops_ratio": (model_flops / jcost["flops"]
                                   if jcost["flops"] and model_flops
                                   else None),
        })
    except Exception as e:  # record failures, they are bugs to fix
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["wall_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def cells(mesh: str = "both"):
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for shape_name in shapes_for(cfg):
            if mesh in ("single", "both"):
                yield arch, shape_name, False
            if mesh in ("multi", "both"):
                yield arch, shape_name, True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    ap.add_argument("--overrides", default=None,
                    help="comma-separated cfg overrides, e.g. "
                         "microbatches=16,parallelism=tp (baseline runs)")
    args = ap.parse_args()
    overrides = None
    if args.overrides:
        overrides = dict(kv.split("=", 1) for kv in args.overrides.split(","))

    todo = []
    if args.all:
        todo = list(cells(args.mesh))
    else:
        archs = [args.arch] if args.arch else configs.ARCHS
        for arch in archs:
            shapes = ([args.shape] if args.shape
                      else shapes_for(configs.get(arch)))
            for sh in shapes:
                if args.mesh in ("single", "both"):
                    todo.append((arch, sh, False))
                if args.mesh in ("multi", "both"):
                    todo.append((arch, sh, True))

    failures = 0
    for arch, shape_name, multi in todo:
        r = run_cell(arch, shape_name, multi, force=args.force,
                     out_dir=args.out_dir, overrides=overrides)
        status = "OK " if r["ok"] else "FAIL"
        extra = (f"flops/dev={r['flops_per_device']:.3e} "
                 f"dominant={r['roofline']['dominant']}"
                 if r["ok"] else r.get("error", ""))
        print(f"[{status}] {r['cell']:55s} {r['wall_s']:7.1f}s  {extra}",
              flush=True)
        failures += 0 if r["ok"] else 1
    print(f"\n{len(todo) - failures}/{len(todo)} cells compiled")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
