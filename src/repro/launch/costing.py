"""Roofline cost extraction.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE (trip count
is opaque to it), which under-counts scanned-layer models by orders of
magnitude.  Two replacements:

  * ``jaxpr_cost``  -- walks the (pre-SPMD) jaxpr, counting dot FLOPs exactly
    and multiplying scan bodies by their static trip count.  Remat recompute
    appears explicitly in the grad jaxpr, so MODEL_FLOPS / jaxpr FLOPs
    faithfully exposes recompute waste.  Bytes are a fusion-optimistic HBM
    model: matmul operands/results + memory-bound op outputs (elementwise
    chains assumed fused), scan xs/ys counted once per iteration.

  * ``hlo_collective_bytes`` -- parses the partitioned HLO, attributes each
    collective to its enclosing computation, and multiplies while bodies by
    the trip count recovered from the loop condition's comparison constant.
    Shapes in partitioned HLO are already per-device.

Raw cost_analysis numbers are still recorded in the dry-run JSON for
reference.
"""

from __future__ import annotations

import math
import re
from typing import Any

import jax
import numpy as np

# ------------------------------------------------------------------ jaxpr
_MEMBOUND_OUT_ONLY = {
    "add", "mul", "sub", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "neg", "sign",
    "erf", "abs", "floor", "ceil", "round", "select_n", "compare", "and",
    "or", "not", "xor", "convert_element_type", "reduce_sum", "reduce_max",
    "reduce_min", "reduce_and", "reduce_or", "cumsum", "cumlogsumexp",
    "rev", "clamp", "is_finite", "stop_gradient", "cos", "sin",
}
_ZERO_COST = {
    "broadcast_in_dim", "reshape", "squeeze", "transpose", "slice",
    "iota", "eq", "convert_element_type", "copy", "sharding_constraint",
    "split", "concatenate", "pad",
}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
    k = int(np.prod([a.shape[i] for i in lc])) if lc else 1
    m = _size(a) // max(batch * k, 1)
    n = _size(b) // max(batch * k, 1)
    return 2 * batch * m * n * k


def jaxpr_cost(jaxpr) -> dict:
    """Returns {'flops': float, 'bytes': float} for a (Closed)Jaxpr."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    flops = 0.0
    mem = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            f = _dot_flops(eqn)
            flops += f
            mem += sum(_nbytes(v.aval) for v in eqn.invars)
            mem += sum(_nbytes(v.aval) for v in eqn.outvars)
        elif prim == "scan":
            inner = jaxpr_cost(eqn.params["jaxpr"])
            length = eqn.params["length"]
            flops += inner["flops"] * length
            mem += inner["bytes"] * length
        elif prim == "while":
            inner = jaxpr_cost(eqn.params["body_jaxpr"])
            flops += inner["flops"]       # unknown trip count: count once
            mem += inner["bytes"]
        elif prim == "cond":
            branches = [jaxpr_cost(b) for b in eqn.params["branches"]]
            flops += max(b["flops"] for b in branches)
            mem += max(b["bytes"] for b in branches)
        elif prim == "shard_map":
            # Body shapes are per-shard; every device runs the body, so the
            # global cost is local x mesh size.
            inner = jaxpr_cost(eqn.params["jaxpr"])
            mesh = eqn.params.get("mesh")
            factor = getattr(mesh, "size", None) or int(
                np.prod([s for _, s in getattr(mesh, "shape_tuple", [])])
                or 1)
            flops += inner["flops"] * factor
            mem += inner["bytes"] * factor
        elif "jaxpr" in eqn.params:        # pjit, remat2, custom_*, checkpoint
            inner = jaxpr_cost(eqn.params["jaxpr"])
            flops += inner["flops"]
            mem += inner["bytes"]
        elif "call_jaxpr" in eqn.params:
            inner = jaxpr_cost(eqn.params["call_jaxpr"])
            flops += inner["flops"]
            mem += inner["bytes"]
        elif prim in ("gather", "dynamic_slice"):
            mem += sum(_nbytes(v.aval) for v in eqn.outvars)
        elif prim in ("scatter", "scatter-add", "scatter_add",
                      "dynamic_update_slice"):
            # In-place update: traffic ~ the update operand, not the buffer.
            upd = eqn.invars[-1].aval if prim == "dynamic_update_slice" \
                else eqn.invars[-1].aval
            mem += 2 * _nbytes(upd)
        elif prim in ("sort", "argsort", "top_k"):
            mem += sum(_nbytes(v.aval) for v in eqn.invars)
            mem += sum(_nbytes(v.aval) for v in eqn.outvars)
            n = max(_size(eqn.invars[0].aval), 2)
            flops += n * math.log2(n)      # comparator work, negligible
        elif prim in _ZERO_COST:
            pass
        else:
            # Memory-bound default: one fused write per produced element,
            # a flop per element.
            out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
            mem += out_b
            flops += sum(_size(v.aval) for v in eqn.outvars)
    return {"flops": flops, "bytes": mem}


def cost_of(fn, *abstract_args) -> dict:
    jpr = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_cost(jpr)


# -------------------------------------------------------------------- HLO
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_COLL = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)\s*(all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\(")
_RESULT_SHAPE = re.compile(r"=\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\]))")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_WHILE = re.compile(r"while\(.*body=%?([\w\.\-]+)")
_CALL = re.compile(r"\scall\(.*to_apply=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s8": 1, "u8": 1, "pred": 1, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(text: str, f32_as_bf16: bool = False) -> int:
    """Bytes of all shapes in ``text``.

    ``f32_as_bf16``: the CPU backend legalizes bf16 compute to f32 and
    hoists the converts above collectives, so a bf16 model's collectives
    all read f32 in CPU-compiled HLO.  On the TPU target they stay bf16;
    this flag counts f32 tensors at 2 bytes/elem to undo the artifact
    (raw numbers are reported alongside).
    """
    total = 0
    for m in _SHAPE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 2 if (f32_as_bf16 and dtype == "f32") else _DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def hlo_collective_bytes(hlo_text: str, f32_as_bf16: bool = False) -> dict:
    """Per-device collective bytes with while-loop trip multiplication."""
    comps: dict[str, dict] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_START.match(line) or _COMP_START.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = {"coll": {}, "whiles": [], "calls": [],
                          "max_const": 1}
            continue
        if cur is None:
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        entry = comps[cur]
        cm = _COLL.search(stripped)
        if cm:
            kind = cm.group(1)
            rs = _RESULT_SHAPE.search(stripped)
            nbytes = _shape_bytes(rs.group(1), f32_as_bf16) if rs else 0
            entry["coll"][kind] = entry["coll"].get(kind, 0) + nbytes
        wm = _WHILE.search(stripped)
        if wm:
            # condition name: usually body name with 'body'->'cond'; find via
            # attribute if present.
            cm2 = re.search(r"condition=%?([\w\.\-]+)", stripped)
            entry["whiles"].append((wm.group(1),
                                    cm2.group(1) if cm2 else None))
        for cmatch in _CALL.finditer(stripped):
            entry["calls"].append(cmatch.group(1))
        for k in _CONST_INT.finditer(stripped):
            entry["max_const"] = max(entry["max_const"], int(k.group(1)))

    def trip_count(cond_name) -> int:
        if cond_name and cond_name in comps:
            return max(comps[cond_name]["max_const"], 1)
        return 1

    # Wire-byte convention: a ring all-reduce moves ~2x its result bytes per
    # device (reduce-scatter pass + all-gather pass); all-gather /
    # reduce-scatter / all-to-all / permute move ~1x.  Keeping this factor
    # makes AR-heavy and AG+RS (Megatron-SP) schedules comparable.
    _WIRE_FACTOR = {"all-reduce": 2}

    memo: dict[str, dict] = {}

    def effective(name: str, depth=0) -> dict:
        if name in memo or depth > 50 or name not in comps:
            return memo.get(name, {})
        entry = comps[name]
        total = {k: v * _WIRE_FACTOR.get(k, 1)
                 for k, v in entry["coll"].items()}
        for body, cond in entry["whiles"]:
            t = trip_count(cond)
            sub = effective(body, depth + 1)
            for k, v in sub.items():
                total[k] = total.get(k, 0) + t * v
        for callee in entry["calls"]:
            sub = effective(callee, depth + 1)
            for k, v in sub.items():
                total[k] = total.get(k, 0) + v
        memo[name] = total
        return total

    # ENTRY computation: jax names it 'main' typically; fall back to the
    # computation that no one else references.
    entry_name = None
    for name in comps:
        if name.startswith("main") or name.endswith(".main"):
            entry_name = name
            break
    if entry_name is None and comps:
        referenced = set()
        for e in comps.values():
            referenced.update(b for b, _ in e["whiles"])
            referenced.update(e["calls"])
        candidates = [n for n in comps if n not in referenced]
        entry_name = candidates[-1] if candidates else list(comps)[-1]
    out = effective(entry_name) if entry_name else {}
    result = {k: int(v) for k, v in out.items()}
    result["total"] = sum(result.values())
    return result
