"""Build the concrete NamedShardings for every lowered function's inputs.

All shardings derive from the logical-axis rule table (repro.runtime
.sharding.Rules); per-(arch x shape) specializations -- e.g. the KV-cache
sequence axis sharded over "data" for long_500k -- are picked in
``rules_for``.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ShapeConfig
from repro.runtime.sharding import Rules

PyTree = Any


def dp_applicable(cfg: ModelConfig, shape: ShapeConfig,
                  mesh_size: int) -> bool:
    # MoE archs keep expert parallelism: without an expert axis the dispatch
    # falls back to the GSPMD scatter path, whose bucket replication costs
    # ~100x the EP shard_map collectives (measured; EXPERIMENTS.md SPerf).
    return (cfg.parallelism == "dp" and shape.kind == "train"
            and shape.global_batch % mesh_size == 0
            and cfg.n_experts == 0)


def effective_config(cfg: ModelConfig, shape: ShapeConfig,
                     mesh_size: int) -> ModelConfig:
    """Config adjustments implied by the chosen parallelism: pure DP puts
    one example per chip, so gradient accumulation is unnecessary (and
    would make the per-chip microbatch fractional)."""
    import dataclasses
    if dp_applicable(cfg, shape, mesh_size) and cfg.microbatches > 1:
        return dataclasses.replace(cfg, microbatches=1)
    return cfg


def rules_for(cfg: ModelConfig, shape: ShapeConfig,
              overrides: Optional[dict] = None,
              model_axis: int = 16, mesh_size: int = 256) -> Rules:
    """Per-(arch x shape) rule specialization.

    Head counts that do not divide the model axis cannot be tensor-parallel
    without resharding storms (GSPMD's "involuntary full rematerialization"),
    so:
      * odd q-head archs (minicpm 36H, whisper 6H) drop TP entirely and
        divide compute over the *sequence* axis instead (Megatron-SP-style
        activation sharding; weights FSDP over both data and model axes);
      * odd kv-head archs (GQA kv=8 / MQA kv=1 on a 16-way axis) replicate
        KV heads for train/prefill and shard the *cache sequence* for decode
        (distributed flash-decode) -- otherwise a 32k MQA cache would be
        replicated 16x and blow HBM.
    """
    kw: dict = {}
    odd_heads = bool(cfg.n_heads) and cfg.n_heads % model_axis != 0
    odd_kv = bool(cfg.n_kv_heads) and cfg.n_kv_heads % model_axis != 0

    if dp_applicable(cfg, shape, mesh_size):
        # Pure DP + ZeRO-3: one example per chip, no tensor parallelism --
        # activation collectives vanish; the wire carries only per-layer
        # parameter all-gathers and the gradient reduce-scatter.
        kw.update(batch=("pod", "data", "model"), heads=None, kv_heads=None,
                  ffn=None, vocab=None, expert=None,
                  embed_p=("data", "model"))
        if overrides:
            kw.update(overrides)
        return Rules(**kw)

    if odd_heads:
        kw.update(heads=None, kv_heads=None, ffn=None, vocab=None,
                  embed_p=("data", "model"))
        if shape.kind in ("train", "prefill"):
            kw["seq"] = ("model",)
            kw["inner_seq"] = ("model",)
        else:
            kw["kv_seq"] = ("model",)
    elif cfg.shard_activation_seq and shape.kind == "train":
        # Megatron-SP: between-block activations (and remat residuals)
        # seq-sharded over "model"; blocks gather/scatter at their edges.
        kw["seq"] = ("model",)
    if not odd_heads and odd_kv:
        kw["kv_heads"] = None
        if shape.kind == "decode":
            # Shard the cache over sequence; attention reduces over it.
            kw["kv_seq"] = ("model",)
            kw["heads"] = None

    if shape.name == "long_500k":
        # global_batch=1: the batch axis cannot absorb "data"; the KV/state
        # sequence dim takes it (distributed flash-decode over 32 ways).
        kw["kv_seq"] = ("pod", "data")
        kw["batch"] = ()

    if overrides:
        kw.update(overrides)
    return Rules(**kw)


def _axis_size(mesh: Mesh, spec_entry) -> int:
    if spec_entry is None:
        return 1
    if isinstance(spec_entry, str):
        return mesh.shape[spec_entry]
    out = 1
    for a in spec_entry:
        out *= mesh.shape[a]
    return out


def _sharding(mesh: Mesh, rules: Rules, axes, shape=None) -> NamedSharding:
    """Logical axes -> NamedSharding; ``shape`` (if given) drops sharding on
    dims the mesh axes do not divide (explicit in_shardings require exact
    divisibility, unlike with_sharding_constraint)."""
    entries = [rules.mesh_axes(a, mesh) for a in axes]
    if shape is not None:
        entries = [e if (e is None or shape[i] % _axis_size(mesh, e) == 0)
                   else None
                   for i, e in enumerate(entries)]
    return NamedSharding(mesh, P(*entries))


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: Rules) -> PyTree:
    from repro.models.transformer import param_specs
    return jax.tree_util.tree_map(
        lambda spec: _sharding(mesh, rules, spec[1], shape=spec[0]),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_shardings(cfg: ModelConfig, mesh: Mesh, rules: Rules,
                    batch_specs: dict) -> dict:
    out = {}
    for k, spec in batch_specs.items():
        shape = getattr(spec, "shape", None)
        if k in ("tokens", "labels", "weights"):
            out[k] = _sharding(mesh, rules, ("batch", None), shape)
        elif k in ("vision_embeds", "frames"):
            out[k] = _sharding(mesh, rules, ("batch", None, None), shape)
        elif k in ("pos", "last_tokens"):
            out[k] = _sharding(mesh, rules, ("batch",), shape)
        else:
            out[k] = replicated(mesh)
    return out


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh, rules: Rules):
    ps = param_shardings(cfg, mesh, rules)
    from repro.optim.adamw import OptState
    return OptState(m=ps, v=ps, count=replicated(mesh))


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, rules: Rules):
    from repro.runtime.train_loop import TrainState
    return TrainState(
        params=param_shardings(cfg, mesh, rules),
        opt_state=opt_state_shardings(cfg, mesh, rules),
        step=replicated(mesh),
        compress_residual=None)


def decode_state_shardings(cfg: ModelConfig, mesh: Mesh, rules: Rules,
                           state: PyTree) -> PyTree:
    """Match init_decode_state's structure (stacked-layer caches)."""
    def for_leaf(path, leaf):
        names = [p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
                 for p in path]
        name = names[-1]
        joined = "/".join(str(n) for n in names)
        nd = len(leaf.shape)
        shp = tuple(leaf.shape)
        if name in ("k", "v"):          # (L, B, S, Hkv, D)
            return _sharding(mesh, rules,
                             ("layer", "batch", "kv_seq", "kv_heads", None),
                             shp)
        if name == "cursor":
            return replicated(mesh)
        if name == "ssm":               # (L, B, H, P, N)
            return _sharding(mesh, rules,
                             ("layer", "batch", "heads", None, None), shp)
        if "conv" in joined:            # (L, B, W-1, C): C sharded for x
            return _sharding(mesh, rules,
                             ("layer", "batch", None,
                              "heads" if leaf.shape[-1] > 512 else None),
                             shp)
        if name == "pos":               # (B,)
            return _sharding(mesh, rules, ("batch",), shp)
        if name == "enc_frames":        # (B, S_enc, D)
            return _sharding(mesh, rules, ("batch", None, None), shp)
        return replicated(mesh) if nd == 0 else _sharding(
            mesh, rules, ("batch",) + (None,) * (nd - 1), shp)
    return jax.tree_util.tree_map_with_path(for_leaf, state)


def abstract_opt_state(cfg: ModelConfig, params_abs: PyTree):
    from repro.optim.adamw import OptState
    dt = jnp.dtype(cfg.optimizer_state_dtype)
    mv = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dt), params_abs)
    return OptState(m=mv, v=mv,
                    count=jax.ShapeDtypeStruct((), jnp.int32))


def abstract_train_state(cfg: ModelConfig):
    from repro.runtime.train_loop import TrainState
    params = tfm.abstract_params(cfg)
    return TrainState(params=params,
                      opt_state=abstract_opt_state(cfg, params),
                      step=jax.ShapeDtypeStruct((), jnp.int32),
                      compress_residual=None)
