"""Training driver: CloudPowerCap-managed multi-pod training.

On real pods this runs under one process per host with the production mesh;
on CPU (``--smoke``) it runs the reduced config on the local device so the
full control loop -- power-aware batch planning, straggler mitigation by cap
redistribution, DPM-driven elastic resize, checkpoint/restart -- is
exercised end to end.

  PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
      --steps 100 --checkpoint-dir /tmp/ckpt

The power plane is driven by a CloudPowerCap cluster snapshot whose hosts
are the pods; cap events (operator rebalance, straggler response, budget
changes) flow into per-pod batch shares without recompilation.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import Checkpointer
from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.core.power_model import TPU_V5E_HOST
from repro.data.pipeline import SyntheticTokens
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule, wsd_schedule
from repro.runtime.power_integration import (PowerAwareBatchScheduler,
                                             StragglerMitigator,
                                             StragglerReport)
from repro.runtime.train_loop import init_train_state, make_train_step


def build_power_plane(n_pods: int, cap_watts: float | None = None):
    """Pods as CPC hosts; one 'job shard' VM per pod."""
    cap = cap_watts or TPU_V5E_HOST.power_peak
    hosts = [Host(f"pod{i}", TPU_V5E_HOST, power_cap=cap)
             for i in range(n_pods)]
    vms = [VirtualMachine(vm_id=f"shard{i}", host_id=f"pod{i}",
                          demand=TPU_V5E_HOST.capacity_peak * 0.9,
                          mem_demand=1024.0)
           for i in range(n_pods)]
    snap = ClusterSnapshot(hosts, vms, power_budget=cap * n_pods)
    manager = CloudPowerCapManager(ManagerConfig(dpm_enabled=False))
    return snap, manager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--initial-cap-frac", type=float, default=0.85,
                    help="initial per-pod cap as a fraction of peak "
                         "(leaves headroom for cap-first mitigation)")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["cosine", "wsd"],
                    default="cosine")
    ap.add_argument("--power-budget-drop-at", type=int, default=-1,
                    help="step at which 20%% of the power budget is lost "
                         "(demonstrates cap redistribution -> batch replan)")
    ap.add_argument("--straggler-at", type=int, default=-1,
                    help="step at which pod1 starts running 30%% slow "
                         "(demonstrates cap-first straggler mitigation)")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    sched = (wsd_schedule(args.lr, 10, int(args.steps * 0.7),
                          max(args.steps // 5, 1))
             if args.schedule == "wsd" or args.arch == "minicpm_2b"
             else cosine_schedule(args.lr, 10, args.steps))
    opt = AdamW(learning_rate=sched, state_dtype=cfg.optimizer_state_dtype)
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                           global_batch=args.global_batch)
    ckpt = Checkpointer(args.checkpoint_dir)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    if args.resume and ckpt.latest_step() is not None:
        step0 = ckpt.latest_step()
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state = ckpt.restore(step0, target)
        data.load_state_dict(ckpt.metadata(step0)["data"])
        print(f"resumed from step {step0}")

    snap, manager = build_power_plane(
        args.pods, cap_watts=args.initial_cap_frac * TPU_V5E_HOST.power_peak)
    scheduler = PowerAwareBatchScheduler(
        args.global_batch, [[f"pod{i}"] for i in range(args.pods)])
    mitigator = StragglerMitigator()
    train_step = jax.jit(make_train_step(cfg, opt))

    plan = scheduler.plan(snap)
    print(f"initial batch plan: {plan.examples_per_pod.tolist()} "
          f"(shares {np.round(plan.shares, 3).tolist()})")

    t_last = time.time()
    while int(state.step) < args.steps:
        step = int(state.step)
        if step == args.power_budget_drop_at:
            snap.power_budget *= 0.8
            snap.hosts["pod0"].power_cap *= 0.6  # operator caps pod0 hard
            result = manager.run_invocation(snap)
            snap = result.snapshot
            plan = scheduler.plan(snap)
            print(f"step {step}: budget cut; caps="
                  f"{[round(h.power_cap) for h in snap.hosts.values()]} "
                  f"-> plan {plan.examples_per_pod.tolist()}")
        if args.straggler_at >= 0 and step >= args.straggler_at:
            # Simulated telemetry: pod1 persistently 45% slow.  The paper's
            # insight applied to SPMD: move Watts first (<1 ms), re-shard
            # only if Watts run out.
            report = StragglerReport(step_times={
                h.host_id: (1.45 if h.host_id == "pod1" else 1.0)
                for h in snap.powered_on_hosts()})
            if mitigator.detect(report):
                balanced = mitigator.mitigate(snap.clone(), report)
                if balanced is not None:
                    snap = balanced
                    plan = scheduler.plan(snap)
                    print(f"step {step}: straggler pod1 -> caps "
                          f"{[round(h.power_cap) for h in snap.hosts.values()]} "
                          f"-> plan {plan.examples_per_pod.tolist()}")
                else:
                    plan = scheduler.plan(snap)
                    print(f"step {step}: straggler pod1, caps exhausted -> "
                          f"batch replan {plan.examples_per_pod.tolist()}")
                args.straggler_at = -1  # handled
        b = data.next_batch()
        batch = scheduler.apply(
            {"tokens": b.tokens, "labels": b.labels, "weights": b.weights},
            plan)
        state, metrics = train_step(state, batch)
        if step % 10 == 0:
            dt = time.time() - t_last
            t_last = time.time()
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"tokens {int(metrics['tokens'])} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.1f}s)")
        if args.checkpoint_every and step and \
                step % args.checkpoint_every == 0:
            ckpt.save_async(step, state, {"data": data.state_dict()})
    ckpt.save(int(state.step), state, {"data": data.state_dict()})
    print(f"done at step {int(state.step)}; checkpoints in "
          f"{args.checkpoint_dir}")


if __name__ == "__main__":
    main()
