"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Single pod: 16x16 = 256 chips over ("data", "model").
Multi-pod:  2x16x16 = 512 chips over ("pod", "data", "model"); the "pod"
axis crosses the DCN, so cross-pod traffic is only data-parallel gradient
reduction (optionally int8-compressed, repro.optim.compress).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_pod_mesh(n_pods: int):
    """Elastic-resize meshes: n_pods x 16 x 16 (n_pods=1 drops the axis)."""
    if n_pods == 1:
        return make_production_mesh(multi_pod=False)
    return jax.make_mesh((n_pods, 16, 16), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
