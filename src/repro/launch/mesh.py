"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Single pod: 16x16 = 256 chips over ("data", "model").
Multi-pod:  2x16x16 = 512 chips over ("pod", "data", "model"); the "pod"
axis crosses the DCN, so cross-pod traffic is only data-parallel gradient
reduction (optionally int8-compressed, repro.optim.compress).

``AxisType`` / explicit axis types only exist in newer jax releases; the
shim below keeps every mesh constructor (and its callers in tests and
examples) working on the pinned jax, where meshes are implicitly Auto.
"""

from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5: first-class mesh axis types
    from jax.sharding import AxisType
    HAS_AXIS_TYPES = True
except ImportError:  # pinned jax: every axis is implicitly Auto
    class AxisType:  # type: ignore[no-redef]
        """Stand-in for jax.sharding.AxisType on older jax."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    HAS_AXIS_TYPES = False

_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh_compat(shape, axes, *, axis_types=None, devices=None):
    """``jax.make_mesh`` across jax versions.

    Forwards ``axis_types`` only when the installed jax understands it;
    older releases treat every axis as Auto, which is exactly what dropping
    the argument yields.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes,
                            axis_types=(AxisType.Auto,) * len(axes))


def make_pod_mesh(n_pods: int):
    """Elastic-resize meshes: n_pods x 16 x 16 (n_pods=1 drops the axis)."""
    if n_pods == 1:
        return make_production_mesh(multi_pod=False)
    return make_mesh_compat((n_pods, 16, 16), ("pod", "data", "model"),
                            axis_types=(AxisType.Auto,) * 3)


def make_cells_mesh(n_devices=None):
    """1-D ``("cells",)`` mesh for the sharded sweep engine.

    The batched simulator's scenario cells are embarrassingly parallel, so
    the mesh has a single axis: each device runs its shard of cells through
    the identical compiled scan, no collectives inside the program.  With
    ``n_devices=None`` every visible device joins; otherwise the first
    ``n_devices`` (the sweep layer clamps to the cell count and pads the
    cells axis to a device multiple).
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"n_devices={n} outside [1, {len(devs)}] visible devices")
    return make_mesh_compat((n,), ("cells",), devices=devs[:n],
                            axis_types=(AxisType.Auto,))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return make_mesh_compat(shape, axes,
                            axis_types=(AxisType.Auto,) * len(axes))
