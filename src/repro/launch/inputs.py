"""ShapeDtypeStruct stand-ins for every lowered function's model inputs.

Weak-type-correct, shardable, no device allocation -- the dry-run lowers
directly from these.  Modality frontends are stubs: input_specs supplies
precomputed patch/frame embeddings (the assigned-architecture contract).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ShapeConfig

PyTree = Any


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.n_prefix_embeds if cfg.family == "vlm" else s
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
        "weights": jax.ShapeDtypeStruct((b, s_text), jnp.float32),
    }
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return specs


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig
                  ) -> tuple[jax.ShapeDtypeStruct, dict]:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.n_prefix_embeds if cfg.family == "vlm" else s
    tokens = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extras["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return tokens, extras


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    """Abstract serve state: caches sized to shape.seq_len."""
    b, s = shape.global_batch, shape.seq_len

    def build():
        state = {"cache": tfm.init_decode_state(cfg, b, s),
                 "pos": jnp.zeros((b,), jnp.int32)}
        if cfg.family == "encdec":
            state["enc_frames"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                                            jnp.float32)
        return state

    return jax.eval_shape(build)


def decode_token_specs(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
