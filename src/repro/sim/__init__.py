"""Trace-driven cluster simulator (the paper's DRS-simulator equivalent).

Provides a realistic execution environment for the CloudPowerCap + DRS
pipeline: per-tick host scheduling (waterfill delivery), a vMotion cost model
(copy duration from memory footprint + CPU overhead on source and target),
host power-on/off latencies, Eq. 1 power accounting, and payload metrics.
"""

from repro.sim.cluster import Simulator, SimConfig, SimResult
from repro.sim.engine import VectorSimulator
from repro.sim.workloads import TraceBank
from repro.sim import workloads, metrics

__all__ = ["Simulator", "VectorSimulator", "SimConfig", "SimResult",
           "TraceBank", "workloads", "metrics"]
