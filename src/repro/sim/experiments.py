"""The paper's three evaluation scenarios (Sec. V-B/C/D), policy-swappable.

Each builder returns (snapshot, traces, sim_config); ``run_policies`` executes
CloudPowerCap / Static / StaticHigh and produces the Table III/IV/V metrics.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.manager import (CloudPowerCapManager, ManagerConfig,
                                static_manager)
from repro.core.power_model import PAPER_HOST, HostPowerSpec
from repro.drs import dpm as dpm_mod
from repro.drs.rules import VMHostRule
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.sim.cluster import SimConfig, Simulator, SimResult
from repro.sim.engine import VectorSimulator
from repro.sim import workloads

#: Pluggable tick engines: per-object reference vs vectorized hot path.
ENGINES = {"legacy": Simulator, "vector": VectorSimulator}


@dataclasses.dataclass
class Scenario:
    name: str
    build: Callable[[str], tuple[ClusterSnapshot, dict, SimConfig,
                                 Optional[tuple[float, float]]]]


def _mk_hosts(n: int, cap_w: float, spec: HostPowerSpec = PAPER_HOST
              ) -> list[Host]:
    return [Host(host_id=f"host{i}", spec=spec, power_cap=cap_w)
            for i in range(n)]


def _manager(policy: str, dpm_enabled: bool) -> CloudPowerCapManager:
    cfg = ManagerConfig(powercap_enabled=(policy == "cpc"),
                        dpm_enabled=dpm_enabled)
    cfg.dpm = dpm_mod.DPMConfig(stable_window_s=150.0)
    return CloudPowerCapManager(cfg)


# --------------------------------------------------------------- Sec. V-B
def build_headroom(policy: str):
    """30 VMs / 3 hosts; one host's VMs spike 1.0 -> 2.4 GHz at t=750 s."""
    cap = 320.0 if policy == "statichigh" else 250.0
    hosts = _mk_hosts(3, cap)
    budget = 3 * cap
    vms, traces = [], {}
    for i in range(30):
        host = f"host{i // 10}"
        vm = VirtualMachine(vm_id=f"vm{i}", vcpus=1, memory_mb=8 * 1024,
                            host_id=host)
        vms.append(vm)
        if i < 10:   # the spiking host's VMs
            traces[vm.vm_id] = workloads.burst(
                base_cpu=1000.0, burst_cpu=2400.0, mem_mb=2 * 1024,
                t_start=750.0, t_end=1400.0)
        else:
            traces[vm.vm_id] = workloads.constant(1000.0, 2 * 1024)
    snap = ClusterSnapshot(hosts, vms, power_budget=budget)
    cfg = SimConfig(duration_s=2100.0, drs_first_at_s=300.0)
    return snap, traces, cfg, (750.0, 1400.0)


# --------------------------------------------------------------- Sec. V-C
def build_standby(policy: str):
    """Demand 1.2 GHz -> 0.4 GHz at 750 s (DPM consolidates), spike back at
    1400 s.  CPC reallocates the powered-off host's budget; Static must power
    the host back on."""
    cap = 320.0 if policy == "statichigh" else 250.0
    hosts = _mk_hosts(3, cap)
    budget = 3 * cap
    vms, traces = [], {}
    for i in range(30):
        host = f"host{i // 10}"
        vm = VirtualMachine(vm_id=f"vm{i}", vcpus=1, memory_mb=8 * 1024,
                            host_id=host)
        vms.append(vm)
        traces[vm.vm_id] = workloads.step_trace([
            (0.0, 1200.0, 2 * 1024),
            (750.0, 400.0, 2 * 1024),
            (1400.0, 1200.0, 2 * 1024),
        ])
    snap = ClusterSnapshot(hosts, vms, power_budget=budget)
    cfg = SimConfig(duration_s=2100.0, drs_first_at_s=300.0)
    return snap, traces, cfg, None


# --------------------------------------------------------------- Sec. V-D
def build_flexible(policy: str):
    """Trading (prime-time bursty, storage-constrained to 8 hosts) + hadoop
    (steady, pinned) across a rack-scale cluster.

    Static/CPC: 32 hosts @ 250 W;  StaticHigh: 25 hosts @ 320 W  (8 kW rack).
    """
    if policy == "statichigh":
        n_hosts, cap = 25, 320.0
    else:
        n_hosts, cap = 32, 250.0
    hosts = _mk_hosts(n_hosts, cap)
    budget = 8000.0
    storage_hosts = [f"host{i}" for i in range(8)]
    vms, traces, rules = [], {}, []
    day = 21600.0  # compressed "day" (6 h) to keep the sim cheap; phases scale
    prime = (0.25, 0.5)  # prime from 0.25*day to 0.75*day

    vid = 0
    for h in range(n_hosts):
        host_id = f"host{h}"
        is_storage = host_id in storage_hosts
        if is_storage:
            for _ in range(6):   # trading VMs
                vm = VirtualMachine(vm_id=f"trd{vid}", vcpus=2,
                                    memory_mb=8 * 1024, host_id=host_id,
                                    tags=frozenset({"trading"}))
                vms.append(vm)
                traces[vm.vm_id] = workloads.prime_time(
                    off_cpu=200.0, prime_cpu=5200.0,
                    off_mem=2 * 1024, prime_mem=7 * 1024,
                    period_s=day, prime_start_frac=prime[0],
                    prime_frac=prime[1])
                rules.append(VMHostRule(vm.vm_id, frozenset(storage_hosts)))
                vid += 1
            n_hadoop = 3
        else:
            n_hadoop = 6
        for _ in range(n_hadoop):
            vm = VirtualMachine(vm_id=f"hdp{vid}", vcpus=2,
                                memory_mb=16 * 1024, host_id=host_id,
                                migratable=False,
                                tags=frozenset({"hadoop"}))
            vms.append(vm)
            if is_storage:
                # Elastic scheduler: no hadoop tasks on trading hosts in prime.
                traces[vm.vm_id] = workloads.prime_time(
                    off_cpu=2500.0, prime_cpu=0.0,
                    off_mem=14 * 1024, prime_mem=14 * 1024,
                    period_s=day, prime_start_frac=prime[0],
                    prime_frac=prime[1])
            else:
                traces[vm.vm_id] = workloads.constant(2500.0, 14 * 1024)
            vid += 1
    snap = ClusterSnapshot(hosts, vms, power_budget=budget, rules=rules)
    cfg = SimConfig(duration_s=day, tick_s=60.0, drs_first_at_s=300.0,
                    record_timeline=False)
    return snap, traces, cfg, None


SCENARIOS = {
    "headroom": Scenario("headroom", build_headroom),
    "standby": Scenario("standby", build_standby),
    "flexible": Scenario("flexible", build_flexible),
}

POLICIES = ("cpc", "static", "statichigh")


def run_policy(scenario: str, policy: str,
               dpm_enabled: Optional[bool] = None,
               engine: str = "legacy") -> SimResult:
    build = SCENARIOS[scenario].build
    snap, traces, cfg, window = build(policy)
    if dpm_enabled is None:
        dpm_enabled = scenario == "standby"
    manager = _manager(policy, dpm_enabled)
    sim = ENGINES[engine](snap, manager, traces, cfg, window=window)
    return sim.run()


def run_all(scenario: str, engine: str = "legacy") -> dict[str, SimResult]:
    return {p: run_policy(scenario, p, engine=engine) for p in POLICIES}
