"""Batched multi-cluster engine: one jitted program for a whole scenario grid.

``BatchedSimulator`` packs S scenario cells x H hosts x J VM slots per host
into padded device arrays (reusing :class:`repro.sim.workloads.TraceBank`'s
step-function layout for the demand traces) and runs the whole grid as a
single JAX program: tick delivery is a ``lax.scan`` over time, and every DRS
period the jitted manager invocation -- the same redivvy -> balance -> DPM
redistribution sequence :class:`repro.core.manager_core.ManagerCore` drives
on the object plane, built from the same ``repro.core.kernels`` -- runs for
all cells at once.  Where ``repro.sim.sweep.run_sweep`` executes the grid
cell-at-a-time through the NumPy ``VectorSimulator``, this engine executes
it grid-at-a-time -- the step that makes policy experiments grid-scale
instead of cell-scale (the ``sweep_grid`` / ``sweep_grid_dpm`` benchmark
entries).

Layout note: VMs live in a *dense slot* layout ``(S, H, J)`` -- each VM
occupies a slot under its resident host -- so every per-host reduction
(waterfill sums, delivered capacity, memory pressure) is a trailing-axis
``sum`` instead of a scatter-add: the difference between an
accelerator-friendly program and one bottlenecked on ``segment_sum``.

Two regimes, chosen at pack time:

  * **cap-only** (no cell has DPM, scripted power events, or a reason to
    migrate): placements and host power states are frozen, the
    static-schedule fast path of PR 2.
  * **dynamic** (any cell has ``dpm_enabled`` or ``config.power_events``,
    or the grid can migrate -- placement-rule violations to correct, or a
    live migration balancer): the host power-state axis and the dense slot
    assignment both become scan state.  Every DRS invocation replays the
    full object-plane sequence from the shared kernels: constraint
    correction with the injected capacity view (fundable capacity under
    CloudPowerCap, paper Fig. 3), RedivvyPowerCap, BalancePowerCap, the
    greedy migration balancer (``kernels.balance_migrations``), then the
    DPM triggers and Powercap Redistribution with rule-aware evacuation
    planning.  Migrations execute as atomic dense-slot remaps when the
    cells run the object plane's ``instant_migrations`` regime, or -- for
    gated timed cells (``SimConfig.migration_gated``) -- through a
    per-cell in-flight table carried as scan state: launches are bounded
    by per-host migration slots and a cluster bandwidth budget (deferred
    moves are simply re-scored next invocation), both endpoints burn
    vMotion overhead during the copy, and entries commit FIFO via the
    same ``move_slot`` scatter the what-if used, so the planes stay
    bit-identical (Sec. V's migration cost model).  A power-off's
    deferred cap changes apply when its timer fires, exactly as the
    action schema's prerequisite edges order them.  Scripted events (host
    failure, maintenance windows) flip the mask on schedule.  DRS
    invocations defer while power actions or migrations are in flight, so
    the schedule itself is carried per cell.

Placement rules ride along as dense slot columns (built from
``repro.drs.arrays.RulesPack``): per-VM affinity-group ids, per-rule
anti-affinity membership masks, and allowed-host bitmasks, all remapped
with their VM when it moves.

Within its regime the engine replays the exact protocol of
``Simulator.run()``; parity against ``VectorSimulator`` is enforced by
``tests/test_batch_parity.py`` and ``tests/test_migration_parity.py``
(exact cap-change / power-on / power-off / vmotion counts,
float-tolerance payload/energy).

Cells requesting anything the engine cannot replay exactly (per-VM trace
callables without a declarative spec, *ungated* timed migrations -- whose
runtime concurrency gate is data-dependent scheduling the scan cannot
precompute -- or mixed time grids / migration models) raise
:class:`BatchUnsupported` at pack time rather than silently freezing the
unsupported dimension.

The S-cells axis shards across devices (``n_devices=``): the packed
arrays split over a 1-D ``("cells",)`` mesh
(:func:`repro.launch.mesh.make_cells_mesh`) with ``shard_map``, each
device scanning its slice of cells through the identical compiled step.
Cells are embarrassingly parallel, so no collective crosses the cells
axis inside the scan -- sharding is a pure reshape of the work and
per-cell results stay bit-identical to the single-device run
(``tests/test_sharded_parity.py``).  When S doesn't divide the mesh the
cells axis is padded with duplicates of the leading cells and outputs
sliced back.  ``pad_hosts``/``pad_slots`` let ``run_sweep``'s pad-bucket
partitioner compile one program per pow2 ``(H, J)`` shape class instead
of one per unique grid shape.

Everything runs in float64 (``jax.experimental.enable_x64``) so the compiled
program tracks the NumPy object plane to reduction-order rounding.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import threading
import time
import warnings
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro import backend as backend_mod
from repro.backend import jax_backend
from repro.core import kernels
from repro.drs import rules as rules_mod
from repro.drs.arrays import RulesPack, dense_slot_assignment
from repro.drs.entitlement import waterfill_dense
from repro.drs.snapshot import ClusterSnapshot
from repro.sim.cluster import SimConfig
from repro.sim.metrics import Accumulators, fold_timeseries
from repro.sim.workloads import DemandTrace, TraceBank


class BatchUnsupported(ValueError):
    """A cell requests a regime the batched engine cannot replay exactly."""


@dataclasses.dataclass
class BatchCell:
    """One scenario cell: a cluster, its demand traces, and its policy."""

    name: str
    snapshot: ClusterSnapshot
    traces: dict[str, DemandTrace]
    config: SimConfig
    powercap_enabled: bool = True            # False => Static/StaticHigh
    window: Optional[tuple[float, float]] = None
    dpm_enabled: bool = False                # phase-3 DPM + redistribution
    # Whether the hill-climb migration balancer runs for this cell (the
    # simulator-level twin of the manager's ``BalancerConfig.max_moves``
    # being nonzero); only meaningful when the batch is built with a
    # ``balancer`` whose ``max_moves > 0``.
    balancer_enabled: bool = True
    # Optional pre-packed ``TraceBank`` over ``list(snapshot.vms)`` (the
    # order ``dense_slot_assignment`` enumerates).  The sweep layer packs
    # each spec's traces once and shares the bank across the policies and
    # pad buckets that reuse them -- host-side packing dominated the
    # end-to-end sweep wall before this.  ``None`` packs from ``traces``.
    trace_bank: Optional[TraceBank] = None


class _StaticSpec(NamedTuple):
    """Hashable compile key: everything that shapes the jitted program."""

    n_cells: int
    n_hosts: int
    n_slots: int
    n_tags: int
    n_events: int
    tick_s: float
    waterfill_iters: int
    balance: kernels.BalanceParams
    churn: bool
    dpm: kernels.DPMParams
    drs_period_s: float
    drs_first_at_s: float
    power_on_latency_s: float
    power_off_latency_s: float
    migration: bool = False                  # correction/balancer live
    rules: kernels.RulesMeta = kernels.RulesMeta()
    balancer: kernels.MigrationParams = kernels.MigrationParams(max_moves=0)
    # Timed-vMotion regime: migrations live in a per-cell in-flight table
    # carried as scan state (``mig_table`` rows), launches are gated by
    # ``limits`` (the batch twin of ``SimConfig.migration_gated``), and
    # both endpoints burn ``vmotion_overhead_mhz`` until the copy at
    # ``vmotion_rate_mb_s`` commits.  ``limits`` also applies to gated
    # *instant* grids (launch bounding without the copy window).
    timed: bool = False
    mig_table: int = 1
    limits: kernels.MigrationLimits = kernels.MigrationLimits()
    vmotion_rate_mb_s: float = 128.0
    vmotion_overhead_mhz: float = 1500.0
    # Allocation-kernel executor captured at pack time ("jax" or
    # "jax-pallas"): part of the compile key, and re-pinned around the
    # program run so trace-time dispatch cannot drift if the process-wide
    # executor changes between pack() and the first run().
    executor: str = "jax"
    # Emit the full per-tick metric series as scan outputs instead of only
    # the reduced in-carry summaries.  The default (False) transfers just
    # the ``(S,)`` reductions off device; parity tests flip this on and
    # check the carry fold against ``fold_timeseries`` bit for bit.
    keep_timeseries: bool = False
    # Budget-tree node axis: 0 compiles the flat scalar-budget program
    # (byte-identical to pre-tree builds); > 0 packs per-cell ancestor
    # incidence / limit / depth columns and threads the tree through every
    # cap-producing kernel (projection after redivvy and balance, scoped
    # funding/reabsorption/evacuation) plus an ``over_tree`` invariant
    # carried through the scan.  Cells without a tree ride along as a
    # single root node limited at their scalar budget (a bitwise no-op).
    n_tree_nodes: int = 0


@dataclasses.dataclass
class BatchResult:
    """Per-cell accumulators, as arrays over the S cells."""

    names: list
    cpu_payload_mhz_s: np.ndarray
    cpu_demand_mhz_s: np.ndarray
    mem_payload_mb_s: np.ndarray
    mem_demand_mb_s: np.ndarray
    energy_j: np.ndarray
    cap_changes: np.ndarray                  # int per cell
    vmotions: np.ndarray                     # int per cell (DPM evacuations)
    power_ons: np.ndarray                    # int per cell
    power_offs: np.ndarray                   # int per cell
    tag_names: list
    tag_payload: np.ndarray                  # (S, G)
    tag_demand: np.ndarray                   # (S, G)
    window_fields: dict                      # field -> (S,) array
    has_window: np.ndarray                   # bool per cell
    final_caps: np.ndarray                   # (S, H)
    final_on: np.ndarray                     # (S, H) power states at the end
    final_occ: np.ndarray                    # (S, H, J) final slot occupancy
    ticks: int
    wall_s: float = 0.0                      # compile_s + run_s of this call
    n_devices: int = 1                       # cells-mesh size the run used
    # Timing split (PR 9): AOT compile wall for this batch's program shape
    # (0.0 on a warm in-process cache), host-side packing wall from
    # ``_pack``, and dispatch-to-harvest device wall.  ``wall_s`` keeps the
    # old meaning -- the whole ``run()`` call -- so speedup arithmetic in
    # the benchmarks is unchanged.
    compile_s: float = 0.0
    pack_s: float = 0.0
    run_s: float = 0.0
    # ``keep_timeseries=True`` only: field -> (T, S) per-tick rates (floats)
    # and per-tick action counts (ints); ``None`` on the reduced path.
    timeseries: Optional[dict] = None
    tick_s: float = 0.0                      # dt the timeseries folds with

    def reduced_timeseries(self) -> dict:
        """Fold :attr:`timeseries` into run summaries via the carry's exact
        arithmetic (see :func:`repro.sim.metrics.fold_timeseries`)."""
        if self.timeseries is None:
            raise ValueError("run with keep_timeseries=True first")
        return fold_timeseries(self.timeseries, self.tick_s)

    def accumulators(self, i: int) -> Accumulators:
        acc = Accumulators(
            cpu_payload_mhz_s=float(self.cpu_payload_mhz_s[i]),
            cpu_demand_mhz_s=float(self.cpu_demand_mhz_s[i]),
            mem_payload_mb_s=float(self.mem_payload_mb_s[i]),
            mem_demand_mb_s=float(self.mem_demand_mb_s[i]),
            energy_j=float(self.energy_j[i]),
            cap_changes=int(self.cap_changes[i]),
            vmotions=int(self.vmotions[i]),
            power_ons=int(self.power_ons[i]),
            power_offs=int(self.power_offs[i]))
        for g, tag in enumerate(self.tag_names):
            if self.tag_demand[i, g] > 0.0 or self.tag_payload[i, g] > 0.0:
                acc.tag_payload[tag] = float(self.tag_payload[i, g])
                acc.tag_demand[tag] = float(self.tag_demand[i, g])
        return acc

    def window_accumulators(self, i: int) -> Optional[Accumulators]:
        if not bool(self.has_window[i]):
            return None
        w = self.window_fields
        return Accumulators(
            cpu_payload_mhz_s=float(w["cpu_payload_mhz_s"][i]),
            cpu_demand_mhz_s=float(w["cpu_demand_mhz_s"][i]),
            mem_payload_mb_s=float(w["mem_payload_mb_s"][i]),
            mem_demand_mb_s=float(w["mem_demand_mb_s"][i]),
            energy_j=float(w["energy_j"][i]))


def _drs_schedule(cfg: SimConfig) -> tuple[np.ndarray, np.ndarray]:
    """Tick times and manager-invocation mask, mirroring ``Simulator.run()``
    (cap changes are instantaneous, so no invocation is ever deferred)."""
    ts, fire = [], []
    next_drs = cfg.drs_first_at_s
    t = 0.0
    while t < cfg.duration_s:
        hit = t >= next_drs
        if hit:
            next_drs = t + cfg.drs_period_s
        ts.append(t)
        fire.append(hit)
        t += cfg.tick_s
    return np.asarray(ts, dtype=np.float64), np.asarray(fire, dtype=bool)


# Padding values restored to a slot when its VM migrates to another host
# (extends the kernel layer's pads with the trace/tag columns; "bps" needs
# an array pattern and is added per-program).
_SLOT_PAD = dict(kernels.SLOT_PAD, period=np.inf, cpu_vals=0.0,
                 mem_vals=0.0, tag_masks=False, vm=-1)


def _build_program(static: _StaticSpec):
    """Build the (untraced) whole-grid program for one per-device shape."""
    import jax
    import jax.numpy as jnp

    be = jax_backend()
    S, H, J = static.n_cells, static.n_hosts, static.n_slots
    dt = static.tick_s
    wf_iters = static.waterfill_iters
    dpmp = static.dpm
    h_idx = np.arange(H)
    s_idx = np.arange(S)

    FIELDS = ("cpu_payload_mhz_s", "cpu_demand_mhz_s",
              "mem_payload_mb_s", "mem_demand_mb_s", "energy_j")

    def make_demands(a):
        finite_period = jnp.isfinite(a["period"])

        def demands(t, trace=None):
            tr = a if trace is None else trace
            fp = (finite_period if trace is None
                  else jnp.isfinite(tr["period"]))
            phase = jnp.where(fp, jnp.mod(t, tr["period"]), t)
            idx = jnp.clip(
                jnp.sum(tr["bps"] <= phase[..., None], axis=-1) - 1, 0, None)
            cpu = jnp.take_along_axis(tr["cpu_vals"], idx[..., None],
                                      axis=-1)[..., 0]
            mem = jnp.take_along_axis(tr["mem_vals"], idx[..., None],
                                      axis=-1)[..., 0]
            return cpu, mem
        return demands

    def make_deliver(a):
        def deliver(hosts, caps, on, active, weights, reservation, limit,
                    tag_masks, cpu, mem, overhead=None):
            host_mem = jnp.where(on, a["host_mem"], 0.0)
            managed = kernels.managed_capacity(jnp, hosts, caps)
            if overhead is not None:
                # In-flight vMotions burn endpoint CPU: delivery capacity
                # shrinks, and the burned cycles still count toward Eq. 1
                # utilization below (they never exceed managed capacity,
                # so the object plane's clip at 1.0 stays a no-op).
                managed = jnp.maximum(managed - overhead, 0.0)
            dem = jnp.where(active, jnp.minimum(cpu, limit), 0.0)
            floors = jnp.where(active, jnp.minimum(reservation, dem), 0.0)
            alloc = waterfill_dense(jnp, be.fori, managed, floors, dem,
                                    weights, wf_iters, active=active)
            delivered_h = jnp.sum(alloc, axis=-1)
            mem_d = jnp.where(active, mem, 0.0)
            mem_dem_h = jnp.sum(mem_d, axis=-1)
            mem_deliv = jnp.minimum(mem_dem_h, host_mem)
            # Eq. 1 power, utilization measured against peak capacity.
            util = delivered_h / a["cap_peak"]
            if overhead is not None:
                util = (delivered_h + overhead) / a["cap_peak"]
            power = kernels.power_consumed(jnp, hosts, util)
            tick = {
                "cpu_payload_mhz_s": jnp.sum(alloc, axis=(-1, -2)),
                "cpu_demand_mhz_s": jnp.sum(dem, axis=(-1, -2)),
                "mem_payload_mb_s": jnp.sum(mem_deliv, axis=-1),
                "mem_demand_mb_s": jnp.sum(mem_dem_h, axis=-1),
                "energy_j": jnp.sum(power * on, axis=-1),
            }
            # tag_masks: (S, H, J, G)
            tag_pay = jnp.sum(tag_masks * alloc[..., None], axis=(-3, -2))
            tag_dem = jnp.sum(tag_masks * dem[..., None], axis=(-3, -2))
            return tick, tag_pay, tag_dem, mem_dem_h
        return deliver

    # ------------------------------------------------------------------
    def build_static(a):
        """Cap-only regime: frozen placements and power states (PR 2)."""
        hosts = kernels.HostCols(a["on"], a["idle"], a["peak"],
                                 a["cap_peak"], a["hyp"])
        on = a["on"]
        active = a["occ"] & on[..., None]
        weights = a["weights"]
        floor_caps = kernels.reserved_floor_caps(jnp, hosts, a["cpu_res"])
        vm_floors = jnp.where(active,
                              jnp.minimum(a["reservation"], a["limit"]), 0.0)
        demands = make_demands(a)
        deliver = make_deliver(a)
        tcols = None
        if static.n_tree_nodes:
            tcols = kernels.TreeCols(a["tree_anc"], a["tree_limit"],
                                     a["tree_depth"])

        def invoke_manager(caps, cpu):
            """Phase 1 (reserved-floor redivvy) + phase 2 (BalancePowerCap),
            counting cap changes exactly as ``order_cap_changes`` emits."""
            redivvied = kernels.redivvy_caps(jnp, on, caps, floor_caps)
            if tcols is not None:
                # Tree projection inside the CPC branch only, exactly where
                # the object plane's ``redivvy_power_cap`` applies it.
                redivvied = kernels.tree_project_caps(jnp, tcols, on,
                                                      redivvied, floor_caps)
            caps1 = jnp.where(a["enabled"][:, None], redivvied, caps)
            changes = kernels.count_cap_changes(jnp, on, caps, caps1)
            vm_ceils = jnp.where(
                active, jnp.clip(cpu, a["reservation"], a["limit"]), 0.0)

            def ents_at(c):
                managed = kernels.managed_capacity(jnp, hosts, c)
                alloc = waterfill_dense(jnp, be.fori, managed, vm_floors,
                                        vm_ceils, weights, wf_iters,
                                        active=active)
                return jnp.sum(alloc, axis=-1)

            caps2, _ = kernels.balance_caps(
                be, hosts, caps1, ents_at, a["cpu_res"], a["budget"],
                a["enabled"], static.balance,
                dense=kernels.DenseCols(vm_floors, vm_ceils, weights,
                                        active, wf_iters))
            if tcols is not None:
                caps2 = jnp.where(
                    a["enabled"][:, None],
                    kernels.tree_project_caps(jnp, tcols, on, caps2,
                                              floor_caps),
                    caps2)
            changes = changes + kernels.count_cap_changes(jnp, on, caps1,
                                                          caps2)
            return caps2, changes.astype(jnp.int32)

        def step(carry, x):
            if tcols is None:
                (caps, acc, win, tag_pay, tag_dem, n_changes,
                 max_total) = carry
            else:
                (caps, acc, win, tag_pay, tag_dem, n_changes, max_total,
                 over_tree) = carry
            t, is_drs, in_win = x
            cpu, mem = demands(t)
            caps, changes = jax.lax.cond(
                is_drs,
                lambda c: invoke_manager(c, cpu),
                lambda c: (c, jnp.zeros(S, dtype=jnp.int32)),
                caps)
            tick, tp, td, _ = deliver(hosts, caps, on, active, weights,
                                      a["reservation"], a["limit"],
                                      a["tag_masks"], cpu, mem)
            acc = {k: acc[k] + tick[k] * dt for k in acc}
            win = {k: win[k] + jnp.where(in_win, tick[k], 0.0) * dt
                   for k in win}
            carry = (caps, acc, win, tag_pay + tp * dt, tag_dem + td * dt,
                     n_changes + changes,
                     jnp.maximum(max_total, jnp.sum(caps * on, axis=-1)))
            if tcols is not None:
                carry = carry + (jnp.maximum(
                    over_tree,
                    jnp.max(kernels.tree_node_sums(jnp, tcols, on, caps)
                            - tcols.limit, axis=-1)),)
            if not static.keep_timeseries:
                return carry, None
            zc = jnp.zeros(S, dtype=jnp.int32)
            return carry, dict(tick, cap_changes=changes, vmotions=zc,
                               power_ons=zc, power_offs=zc)

        zeros = {k: jnp.zeros(S) for k in FIELDS}
        init = (a["caps0"], dict(zeros), dict(zeros),
                jnp.zeros((S, static.n_tags)), jnp.zeros((S, static.n_tags)),
                jnp.zeros(S, dtype=jnp.int32),
                jnp.sum(a["caps0"] * a["on"], axis=-1))
        if tcols is not None:
            init = init + (jnp.full(S, -jnp.inf),)
        xs = (a["ts"], a["drs_mask"], a["win_mask"])
        final, ys = jax.lax.scan(step, init, xs)
        (caps, acc, win, tag_pay, tag_dem, n_changes, max_total) = final[:7]
        zi = jnp.zeros(S, dtype=jnp.int32)
        out = {"acc": acc, "win": win, "tag_payload": tag_pay,
               "tag_demand": tag_dem, "cap_changes": n_changes,
               "vmotions": zi, "power_ons": zi, "power_offs": zi,
               "max_total_cap": max_total, "over_budget": max_total * 0.0,
               "final_caps": caps, "final_on": a["on"],
               "final_occ": a["occ"],
               "slot_pressure": jnp.zeros(S, dtype=bool)}
        if tcols is not None:
            out["over_tree"] = final[7]
        if static.keep_timeseries:
            out["timeseries"] = ys
        return out

    # ------------------------------------------------------------------
    def build_churn(a):
        """Capacity-churn regime: the power-state axis is scan state."""
        demands = make_demands(a)
        deliver = make_deliver(a)
        exists = a["exists"]
        host_mem_spec = a["host_mem"]
        tcols = None
        if static.n_tree_nodes:
            tcols = kernels.TreeCols(a["tree_anc"], a["tree_limit"],
                                     a["tree_depth"])

        rule_keys = tuple(k for k in ("aff_group", "allowed", "anti")
                          if k in a)
        slot_keys = ("occ", "reservation", "limit", "weights",
                     "migratable", "period", "bps", "cpu_vals", "mem_vals",
                     "tag_masks", "vm") + rule_keys
        pads = dict(_SLOT_PAD, bps=jnp.where(
            jnp.arange(a["bps"].shape[-1]) == 0, 0.0, jnp.inf))
        M = static.mig_table                 # in-flight table rows (timed)

        def hosts_of(on):
            return kernels.HostCols(on, a["idle"], a["peak"], a["cap_peak"],
                                    a["hyp"])

        def gather_host(col, idx):
            return jnp.take_along_axis(col, idx[..., None], axis=-1)[..., 0]

        def host_sum_vm_order(vals, act, vm):
            # Per-host sum with addends in ascending global-VM-index order,
            # matching the object plane's ``np.bincount`` reduction bit for
            # bit.  A plain slot-axis ``sum`` adds in slot order, which
            # stops agreeing once a migration lands in a first-free slot;
            # on near-ties (BalancePowerCap equalizes utilizations by
            # construction) the one-ULP difference flips argmin-style
            # decisions like the DPM evacuation victim.  Sorting each host
            # row by VM index (empty slots last) and accumulating
            # left-to-right restores the exact add order; the trailing
            # +0.0 terms cannot perturb a non-negative partial sum.
            key = jnp.where(act, vm, jnp.iinfo(jnp.int64).max)
            ordr = jnp.argsort(key, axis=-1)
            sv = jnp.take_along_axis(jnp.where(act, vals, 0.0), ordr,
                                     axis=-1)
            return be.fori(sv.shape[-1], lambda j, acc: acc + sv[..., j],
                           jnp.zeros(sv.shape[:-1]))

        # ---------------------------------------------------- invocation
        def invocation(c, can, t):
            # Demands at t in the pre-invocation slot layout; they ride in
            # the working bundle so migrations move them with their VM
            # (delivery re-evaluates from the post-move slots).
            cpu, mem = demands(t, trace=c["slots"])
            mem_pre = mem                  # pre-invocation layout, for the
            on = c["on"]                   # timed duration replay below
            hosts = hosts_of(on)
            caps = c["caps"]
            work = dict(c["slots"], cpu=cpu, mem=mem)
            vmot = jnp.zeros(S, dtype=jnp.int32)
            mig_pressure = jnp.zeros(S, dtype=bool)
            # Per-invocation launch ledger, shared by correction and the
            # balancer (the batch twin of ``LaunchBudget``); the kernels
            # seed it with zeros on first use when gating is live.
            launch = None
            corr_moves = bal_moves = None
            n_corr = n_bal = None

            # Phase 1a: constraint correction under the injected capacity
            # view -- fundable capacity (reserved-floor caps plus the whole
            # unreserved pool, paper Fig. 3) for CloudPowerCap cells,
            # managed capacity at the current caps for static policies.
            if static.migration and static.rules.any:
                act0 = work["occ"] & on[..., None]
                res_pre = jnp.sum(
                    jnp.where(act0, work["reservation"], 0.0), axis=-1)
                floors_pre = kernels.reserved_floor_caps(jnp, hosts,
                                                         res_pre)
                spare = jnp.maximum(
                    a["budget"] - jnp.sum(jnp.where(on, floors_pre, 0.0),
                                          axis=-1), 0.0)
                fundable = kernels.managed_capacity(
                    jnp, hosts,
                    jnp.minimum(floors_pre + spare[:, None], a["peak"]))
                cap_view = jnp.where(
                    a["enabled"][:, None], fundable,
                    kernels.managed_capacity(jnp, hosts, caps))
                cap_view = jnp.where(on, cap_view, 0.0)
                work, corr_moves, n_corr, prs, launch = \
                    kernels.correct_constraints_slots(
                        be, hosts, cap_view, work, host_mem_spec,
                        static.rules, can,
                        jnp.full((S, max(static.rules.move_bound, 1), 3),
                                 -1, dtype=jnp.int64),
                        jnp.zeros(S, dtype=jnp.int64), pads=pads,
                        limits=static.limits, launch=launch)
                vmot = vmot + n_corr.astype(jnp.int32)
                mig_pressure = mig_pressure | prs

            act3 = work["occ"] & on[..., None]
            res = work["reservation"]
            lim = work["limit"]
            cpu_res = jnp.sum(jnp.where(act3, res, 0.0), axis=-1)

            # Phase 1b: reserved-floor redivvy (Powercap Allocation) on
            # the post-correction placements.
            apply_cpc = can & a["enabled"]
            floor_caps = kernels.reserved_floor_caps(jnp, hosts, cpu_res)
            redivvied = kernels.redivvy_caps(jnp, on, caps, floor_caps)
            if tcols is not None:
                redivvied = kernels.tree_project_caps(jnp, tcols, on,
                                                      redivvied, floor_caps)
            caps1 = jnp.where(apply_cpc[:, None], redivvied, caps)
            changes = jnp.where(
                can, kernels.count_cap_changes(jnp, on, caps, caps1), 0)

            # Phase 2: BalancePowerCap.
            vm_floors = jnp.where(act3, jnp.minimum(res, lim), 0.0)
            vm_ceils = jnp.where(act3, jnp.clip(work["cpu"], res, lim), 0.0)

            def ents_at(cc):
                managed = kernels.managed_capacity(jnp, hosts, cc)
                alloc = waterfill_dense(jnp, be.fori, managed, vm_floors,
                                        vm_ceils, work["weights"],
                                        wf_iters, active=act3)
                return jnp.sum(alloc, axis=-1)

            caps2, _ = kernels.balance_caps(
                be, hosts, caps1, ents_at, cpu_res, a["budget"], apply_cpc,
                static.balance,
                dense=kernels.DenseCols(vm_floors, vm_ceils,
                                        work["weights"], act3, wf_iters))
            if tcols is not None:
                caps2 = jnp.where(
                    apply_cpc[:, None],
                    kernels.tree_project_caps(jnp, tcols, on, caps2,
                                              floor_caps),
                    caps2)
            changes = changes + jnp.where(
                can, kernels.count_cap_changes(jnp, on, caps1, caps2), 0)

            # Phase 2b: residual imbalance fixed by actual migrations
            # (DRS's hill-climb; runs for every policy, like the object
            # plane's ManagerCore).
            if static.migration and static.balancer.max_moves > 0:
                work, bal_moves, n_bal, prs, launch = \
                    kernels.balance_migrations(
                        be, hosts, caps2, work, host_mem_spec,
                        static.balancer, static.rules, can & a["bal_on"],
                        jnp.full((S, static.balancer.max_moves, 3), -1,
                                 dtype=jnp.int64),
                        jnp.zeros(S, dtype=jnp.int64), pads=pads,
                        iters=kernels.MIGRATION_WATERFILL_ITERS,
                        limits=static.limits, launch=launch)
                vmot = vmot + n_bal.astype(jnp.int32)
                mig_pressure = mig_pressure | prs
                act3 = work["occ"] & on[..., None]
                res = work["reservation"]
                lim = work["limit"]
                cpu_res = jnp.sum(jnp.where(act3, res, 0.0), axis=-1)

            # Phase 3: DPM triggers + Powercap Redistribution, on the
            # post-migration layout.
            occ = work["occ"]
            cpu = work["cpu"]
            mem = work["mem"]
            eff_slot = jnp.where(act3, jnp.clip(cpu, res, lim), 0.0)
            eff_h = host_sum_vm_order(eff_slot, act3, work["vm"])
            mem_h = host_sum_vm_order(mem, act3, work["vm"])
            cpu_util, mem_util = kernels.host_utilizations(
                jnp, hosts, caps2, eff_h, mem_h, host_mem_spec)
            hot_any = jnp.any(kernels.dpm_hot_mask(
                jnp, on, cpu_util, mem_util, dpmp.high_util), axis=-1)
            standby = exists & ~on
            cand = jnp.argmax(standby, axis=-1)
            do_dpm = can & a["dpm"]

            # Power-on: fund the first standby host's cap (decreases execute
            # now; the candidate's cap applies now too -- it only counts
            # toward the budget while pending -- and the host joins when the
            # power-on timer fires).
            want_on = do_dpm & hot_any & jnp.any(standby, axis=-1)
            funded, granted = kernels.power_on_funding_caps(
                be, hosts, caps2, cand, cpu_util, eff_h, cpu_res,
                a["budget"], dpmp.high_util, tree=tcols)
            cand_cols = kernels.HostCols(
                *(gather_host(col, cand)[..., None]
                  for col in (jnp.ones_like(on), a["idle"], a["peak"],
                              a["cap_peak"], a["hyp"])))
            feasible = kernels.managed_capacity(
                jnp, cand_cols, granted[..., None])[..., 0] > 0.0
            do_on = want_on & jnp.where(a["enabled"], feasible, True)
            fund = do_on & a["enabled"]
            is_cand = h_idx[None, :] == cand[..., None]
            caps3 = jnp.where(fund[:, None], funded, caps2)
            changes = changes + jnp.where(
                fund,
                kernels.count_cap_changes(jnp, on | is_cand, caps2, funded),
                0)
            pon_idx = jnp.where(do_on, cand, c["pon_idx"])
            pon_end = jnp.where(do_on, t + static.power_on_latency_s,
                                c["pon_end"])

            # Power-off: sustained cluster-wide low utilization, stability
            # window elapsed, and a complete evacuation plan.
            n_on = jnp.sum(on, axis=-1)
            all_low = kernels.dpm_all_low(jnp, on, cpu_util, mem_util,
                                          dpmp.low_util)
            ls = jnp.where(jnp.isnan(c["low_since"]), t, c["low_since"])
            oldest = jnp.maximum(
                jnp.max(jnp.where(on, ls, -jnp.inf), axis=-1),
                c["last_cfg"])
            window_ok = (t - oldest) >= dpmp.stable_window_s
            maybe_off = (do_dpm & ~hot_any & (n_on > 1) & all_low
                         & window_ok)
            victim = jnp.argmin(jnp.where(on, cpu_util, jnp.inf), axis=-1)
            evac_scope = None
            if tcols is not None:
                evac_scope = kernels.tree_evac_scope(jnp, tcols, on, caps2,
                                                     victim)
            ok, order, dests, n_evac, pressure = kernels.plan_evacuation(
                be, hosts, caps2, victim, occ, eff_slot, mem,
                res, work["migratable"], host_mem_spec,
                dpmp.target_util, allowed=work.get("allowed"),
                anti=work.get("anti"), scope=evac_scope)
            do_off = maybe_off & ok
            work = _apply_remap(work, do_off, victim, order, dests)
            vmot = vmot + jnp.where(do_off, n_evac, 0).astype(jnp.int32)

            reabsorbed = kernels.power_off_reabsorb_caps(
                jnp, hosts, caps2, victim, a["budget"], tree=tcols)
            # The deferred actions touch exactly the hosts whose cap
            # change clears the emission threshold (order_cap_changes).
            changed = on & (jnp.abs(reabsorbed - caps2)
                            > kernels.CAP_CHANGE_EPS)
            off_cpc = do_off & a["enabled"]
            pend_caps = jnp.where(
                do_off[:, None],
                jnp.where(off_cpc[:, None], reabsorbed, caps3),
                c["pend_caps"])
            pend_mask = jnp.where(do_off[:, None],
                                  off_cpc[:, None] & changed,
                                  c["pend_mask"])
            pend_cnt = jnp.where(off_cpc, jnp.sum(changed, axis=-1),
                                 0).astype(jnp.int32)
            pend_cnt = jnp.where(do_off, pend_cnt, c["pend_cnt"])
            poff_idx = jnp.where(do_off, victim, c["poff_idx"])
            if static.timed:
                # ---- Timed regime: the what-if layout above only shaped
                # *decisions*.  The carry keeps the pre-invocation slots;
                # every emitted move is appended to the in-flight table and
                # commits against the live layout on its vMotion schedule
                # (step phase 2b), replaying the identical ``move_slot``
                # sequence -- first-free placement makes the trajectories
                # coincide, so the planes stay bit-identical.
                #
                # Durations replay the move sequence on a scratch
                # ``(occ, mem)`` copy so chained moves read the memory
                # footprint that travelled with their VM; each entry's
                # stored end is the running max so far (FIFO: a migration
                # cannot complete before those emitted ahead of it, the
                # object plane's ``_complete_actions`` drain).  ``idx``
                # tracks which entry last touched a slot so chained
                # launches record their predecessor: the endpoint-overhead
                # charge follows the VM's *current* host while earlier
                # chain legs are still in flight (``vm.host_id`` in the
                # object plane).
                k_idx = jnp.arange(M)
                scratch = {"occ": c["slots"]["occ"], "mem": mem_pre,
                           "idx": jnp.full((S, H, J), -1, dtype=jnp.int64)}
                spads = {"occ": False, "mem": 0.0, "idx": -1}
                tb = (scratch, c["mig_src"], c["mig_j"], c["mig_dst"],
                      c["mig_end"], c["mig_prev"],
                      jnp.zeros(S, dtype=jnp.int64),     # append cursor
                      jnp.full(S, -jnp.inf))             # FIFO running max

                def replay(n_k, take, tb):
                    def body(k, tb):
                        (sc, msrc, mj, mdst, mend, mprev, cur, eff) = tb
                        do, src, j, dst = take(k)
                        si = jnp.clip(src, 0, H - 1)
                        ji = jnp.clip(j, 0, J - 1)
                        mem_v = sc["mem"][s_idx, si, ji]
                        prev_v = sc["idx"][s_idx, si, ji]
                        dur = jnp.maximum(
                            jnp.maximum(mem_v, 64.0)
                            / static.vmotion_rate_mb_s, dt)
                        eff = jnp.where(do, jnp.maximum(eff, t + dur), eff)
                        at = do[:, None] & (k_idx[None, :] == cur[:, None])
                        msrc = jnp.where(at, src[:, None], msrc)
                        mj = jnp.where(at, j[:, None], mj)
                        mdst = jnp.where(at, dst[:, None], mdst)
                        mend = jnp.where(at, eff[:, None], mend)
                        mprev = jnp.where(at, prev_v[:, None], mprev)
                        sc = dict(sc, idx=sc["idx"].at[s_idx, si, ji].set(
                            jnp.where(do, cur, prev_v)))
                        sc, _ = kernels.move_slot(jnp, sc, do, src, j, dst,
                                                  spads)
                        cur = cur + do.astype(cur.dtype)
                        return (sc, msrc, mj, mdst, mend, mprev, cur, eff)
                    return be.fori(n_k, body, tb)

                if corr_moves is not None:
                    tb = replay(corr_moves.shape[1], lambda k: (
                        k < n_corr, corr_moves[:, k, 0],
                        corr_moves[:, k, 1], corr_moves[:, k, 2]), tb)
                if bal_moves is not None:
                    tb = replay(bal_moves.shape[1], lambda k: (
                        k < n_bal, bal_moves[:, k, 0],
                        bal_moves[:, k, 1], bal_moves[:, k, 2]), tb)
                tb = replay(J, lambda k: (
                    do_off & (dests[:, k] >= 0), victim, order[:, k],
                    dests[:, k]), tb)
                _, mig_src, mig_j, mig_dst, mig_end, mig_prev, _, _ = tb

                # A power-off waits for its evacuation entries to commit
                # (its prerequisite edges); evacuations are appended last
                # and ends are FIFO-monotone, so "last evacuation done"
                # is exactly "table drained".  No evacuees => the timer
                # starts now, even with manager moves still in flight.
                wait = do_off & (n_evac > 0)
                poff_end = jnp.where(do_off & ~wait,
                                     t + static.power_off_latency_s,
                                     c["poff_end"])
                poff_wait = jnp.where(do_off, wait, c["poff_wait"])
            else:
                poff_end = jnp.where(do_off, t + static.power_off_latency_s,
                                     c["poff_end"])

            c = dict(c, caps=caps3,
                     slots=(c["slots"] if static.timed
                            else {k: work[k] for k in slot_keys}),
                     pon_idx=pon_idx,
                     pon_end=pon_end, poff_idx=poff_idx, poff_end=poff_end,
                     pend_caps=pend_caps, pend_mask=pend_mask,
                     pend_cnt=pend_cnt,
                     n_changes=c["n_changes"] + changes.astype(jnp.int32),
                     # Timed cells count vMotions at commit time (the
                     # object plane counts at completion); all launches
                     # eventually commit -- transfers are oblivious to
                     # endpoint power flips -- so totals agree.
                     vmotions=(c["vmotions"] if static.timed
                               else c["vmotions"] + vmot),
                     slot_pressure=c["slot_pressure"] | mig_pressure
                     | (maybe_off & pressure))
            if static.timed:
                c = dict(c, mig_src=mig_src, mig_j=mig_j, mig_dst=mig_dst,
                         mig_end=mig_end, mig_prev=mig_prev,
                         poff_wait=poff_wait)
            return c

        def _apply_remap(work, move, victim, order, dests):
            """Move the victim's occupied slots to their destinations'
            first free slots, restoring pad values behind them (one shared
            ``move_slot`` per evacuee, so holes left by balancer moves are
            reused correctly)."""
            def body(k, w):
                j = jnp.take_along_axis(
                    order, jnp.full((S, 1), k, order.dtype), axis=-1)[..., 0]
                dest = jnp.take_along_axis(
                    dests, jnp.full((S, 1), k, dests.dtype), axis=-1)[..., 0]
                do = move & (dest >= 0)
                w, _ = kernels.move_slot(jnp, w, do, victim, j, dest, pads)
                return w

            return be.fori(J, body, work)

        # ----------------------------------------------------------- step
        def step(c, x):
            t, in_win = x
            # Counter values at step entry: the per-tick action counts the
            # timeseries path emits are end-minus-start deltas, so they sum
            # (exactly, as ints) back to the carried totals.
            prev_counts = {k: c[k] for k in ("n_changes", "vmotions",
                                             "power_ons", "power_offs")}

            # 1. Scripted host lifecycle events.  A returning host boots
            # with at most the unallocated budget as its cap (the manager
            # may have reabsorbed its watts while it was away); a grant
            # held by a host whose power-on is still in flight counts as
            # allocated, like the budget invariant counts it.
            on, last_cfg, ev_done = c["on"], c["last_cfg"], c["ev_done"]
            caps = c["caps"]
            pend_grant = jnp.where(
                c["pon_idx"] >= 0,
                gather_host(caps, jnp.clip(c["pon_idx"], 0, H - 1)), 0.0)
            for e in range(static.n_events):
                due = ~ev_done[:, e] & (a["ev_t"][:, e] <= t)
                eh = a["ev_host"][:, e]
                target = a["ev_on"][:, e]
                cur = gather_host(on, eh)
                onehot = h_idx[None, :] == eh[..., None]
                boot = due & target & ~cur
                pool = jnp.maximum(
                    a["budget"] - jnp.sum(caps * on, axis=-1) - pend_grant,
                    0.0)
                caps = jnp.where(
                    boot[:, None] & onehot,
                    jnp.minimum(caps, pool[:, None]), caps)
                if tcols is not None:
                    # The returning host's cap must also fit its ancestor
                    # headroom, with the pending power-on grant counted as
                    # allocated (Simulator._apply_power_events).
                    pend_on = ((c["pon_idx"] >= 0)[:, None]
                               & (h_idx[None, :] == c["pon_idx"][:, None]))
                    head = kernels.tree_headroom(jnp, tcols, on | pend_on,
                                                 caps)
                    anc_b = kernels.tree_anc_at(jnp, tcols, eh)
                    room = jnp.min(jnp.where(anc_b, head, jnp.inf), axis=-1)
                    caps = jnp.where(
                        boot[:, None] & onehot,
                        jnp.minimum(caps,
                                    jnp.maximum(room, 0.0)[:, None]), caps)
                on = jnp.where((due & target)[:, None] & onehot, True, on)
                on = jnp.where((due & ~target)[:, None] & onehot, False, on)
                last_cfg = jnp.where(due & (cur != target), t, last_cfg)
                ev_done = ev_done.at[:, e].set(ev_done[:, e] | due)

            # 2. Pending power-on/off timers come due.
            pon_fire = (c["pon_idx"] >= 0) & (t >= c["pon_end"])
            on = on | (pon_fire[:, None]
                       & (h_idx[None, :] == c["pon_idx"][..., None]))
            poff_fire = (c["poff_idx"] >= 0) & (t >= c["poff_end"])
            if static.timed:
                # A power-off waiting on its evacuation holds a stale
                # ``poff_end``; its timer starts when the table drains.
                poff_fire = poff_fire & ~c["poff_wait"]
            on = on & ~(poff_fire[:, None]
                        & (h_idx[None, :] == c["poff_idx"][..., None]))
            # Apply only the hosts the deferred cap *actions* set (the
            # emitted-change mask), not the whole decision-time column: a
            # host a scripted event booted during the pending window had
            # no action and keeps its boot cap.
            caps = jnp.where(poff_fire[:, None] & c["pend_mask"],
                             c["pend_caps"], caps)
            last_cfg = jnp.where(pon_fire | poff_fire, t, last_cfg)
            c = dict(
                c, on=on, caps=caps, last_cfg=last_cfg, ev_done=ev_done,
                n_changes=c["n_changes"]
                + jnp.where(poff_fire, c["pend_cnt"], 0),
                power_ons=c["power_ons"] + pon_fire.astype(jnp.int32),
                power_offs=c["power_offs"] + poff_fire.astype(jnp.int32),
                pon_idx=jnp.where(pon_fire, -1, c["pon_idx"]),
                poff_idx=jnp.where(poff_fire, -1, c["poff_idx"]))

            # 2b. In-flight migrations commit FIFO (timed regime): each
            # due table entry replays its recorded ``move_slot`` against
            # the live layout -- in table order from the same base layout
            # as the invocation's what-if, so landing slots coincide.
            # Commits are oblivious to endpoint power state (a VM can
            # land on a host that failed or powered off mid-copy, exactly
            # like the object plane's ``move_vm``).
            if static.timed:
                def commit(cc):
                    def body(k, st):
                        slots, msrc, nmig = st
                        src = cc["mig_src"][:, k]
                        due = (src >= 0) & (cc["mig_end"][:, k] <= t)
                        slots, _ = kernels.move_slot(
                            jnp, slots, due, src, cc["mig_j"][:, k],
                            cc["mig_dst"][:, k], pads)
                        msrc = msrc.at[:, k].set(jnp.where(due, -1, src))
                        return slots, msrc, nmig + due.astype(jnp.int32)
                    slots, msrc, nmig = be.fori(
                        M, body, (cc["slots"], cc["mig_src"],
                                  jnp.zeros(S, dtype=jnp.int32)))
                    return dict(cc, slots=slots, mig_src=msrc,
                                vmotions=cc["vmotions"] + nmig)

                c = jax.lax.cond(
                    jnp.any((c["mig_src"] >= 0) & (c["mig_end"] <= t)),
                    commit, lambda cc: cc, c)
                # Evacuation entries committed => the deferred power-off's
                # prerequisites are met: start its latency timer now
                # (object plane: ``_complete_actions`` then
                # ``_start_actions`` in the same tick).
                drained = ~jnp.any(c["mig_src"] >= 0, axis=-1)
                start_off = c["poff_wait"] & drained
                c = dict(c, poff_wait=c["poff_wait"] & ~start_off,
                         poff_end=jnp.where(
                             start_off, t + static.power_off_latency_s,
                             c["poff_end"]))

            # 3. Manager invocation on the carried DRS schedule; deferred
            # per cell while its power actions are in flight.
            outstanding = (c["pon_idx"] >= 0) | (c["poff_idx"] >= 0)
            if static.timed:
                outstanding = outstanding | ~drained
            can = (t >= c["next_drs"]) & ~outstanding
            c = dict(c, next_drs=jnp.where(
                can, t + static.drs_period_s,
                jnp.where(t >= c["next_drs"], t + dt, c["next_drs"])))
            c = jax.lax.cond(
                jnp.any(can),
                lambda cc: invocation(cc, can, t),
                lambda cc: cc, c)

            # 4. Demands at t from the (possibly just remapped) trace
            # slots, then delivery + accounting at the post-invocation
            # state.
            cpu, mem = demands(t, trace=c["slots"])
            on, caps = c["on"], c["caps"]
            hosts = hosts_of(on)
            active = c["slots"]["occ"] & on[..., None]
            overhead = None
            if static.timed:
                # Endpoint vMotion overhead from the (post-invocation)
                # in-flight table: each entry charges its destination and
                # its VM's *current* host.  For chained launches that is
                # the earliest uncommitted leg's source -- commits drain
                # FIFO, so the committed prefix never interleaves and a
                # bounded predecessor walk finds it.
                act_m = c["mig_src"] >= 0
                eff_src, prev = c["mig_src"], c["mig_prev"]

                def hop(_, st):
                    eff_src, prev = st
                    pc = jnp.clip(prev, 0, M - 1)
                    live = (prev >= 0) & jnp.take_along_axis(act_m, pc,
                                                             axis=-1)
                    eff_src = jnp.where(
                        live,
                        jnp.take_along_axis(c["mig_src"], pc, axis=-1),
                        eff_src)
                    prev = jnp.where(
                        live,
                        jnp.take_along_axis(c["mig_prev"], pc, axis=-1),
                        jnp.full_like(prev, -1))
                    return eff_src, prev

                eff_src, _ = be.fori(M, hop, (eff_src, prev))
                ep = ((eff_src[..., None] == h_idx[None, None, :])
                      | (c["mig_dst"][..., None] == h_idx[None, None, :]))
                overhead = static.vmotion_overhead_mhz * jnp.sum(
                    act_m[..., None] & ep, axis=1)
            tick, tp, td, mem_dem_h = deliver(
                hosts, caps, on, active, c["slots"]["weights"],
                c["slots"]["reservation"], c["slots"]["limit"],
                c["slots"]["tag_masks"], cpu, mem, overhead=overhead)

            # Budget invariant: powered-on caps plus the cap of a host whose
            # power-on is pending (it holds its grant while joining).
            pend_cap = jnp.where(
                c["pon_idx"] >= 0,
                gather_host(caps, jnp.clip(c["pon_idx"], 0, H - 1)), 0.0)
            total = jnp.sum(caps * on, axis=-1) + pend_cap
            if tcols is not None:
                # Per-node invariant with the pending power-on target
                # counted as allocated (its grant is its already-set cap).
                tree_mask = on | ((c["pon_idx"] >= 0)[:, None]
                                  & (h_idx[None, :] == c["pon_idx"][:, None]))
                node_over = (kernels.tree_node_sums(jnp, tcols, tree_mask,
                                                    caps)
                             - tcols.limit)
                over_tree = jnp.maximum(c["over_tree"],
                                        jnp.max(node_over, axis=-1))

            # 6. DPM low-watermark tracking at delivered capacity, through
            # the same utilization kernel the invocation's triggers use.
            eff = jnp.clip(cpu, c["slots"]["reservation"],
                           c["slots"]["limit"])
            eff_h = jnp.sum(jnp.where(active, eff, 0.0), axis=-1)
            cpu_util, mem_util = kernels.host_utilizations(
                jnp, hosts, caps, eff_h, mem_dem_h, host_mem_spec)
            low = on & (cpu_util < dpmp.low_util) & (
                mem_util < dpmp.low_util)
            entering = low & jnp.isnan(c["low_since"])
            low_since = jnp.where(entering, t, c["low_since"])
            low_since = jnp.where(on & ~low, jnp.nan, low_since)

            c = dict(
                c, low_since=low_since,
                acc={k: c["acc"][k] + tick[k] * dt for k in c["acc"]},
                win={k: c["win"][k] + jnp.where(in_win, tick[k], 0.0) * dt
                     for k in c["win"]},
                tag_pay=c["tag_pay"] + tp * dt,
                tag_dem=c["tag_dem"] + td * dt,
                over_budget=jnp.maximum(c["over_budget"],
                                        total - a["budget"]))
            if tcols is not None:
                c["over_tree"] = over_tree
            if not static.keep_timeseries:
                return c, None
            return c, dict(
                tick,
                cap_changes=c["n_changes"] - prev_counts["n_changes"],
                vmotions=c["vmotions"] - prev_counts["vmotions"],
                power_ons=c["power_ons"] - prev_counts["power_ons"],
                power_offs=c["power_offs"] - prev_counts["power_offs"])

        zeros = {k: jnp.zeros(S) for k in FIELDS}
        zi = jnp.zeros(S, dtype=jnp.int32)
        init = {
            "caps": a["caps0"], "on": a["on"],
            "slots": {k: a[k] for k in slot_keys},
            "low_since": jnp.full((S, H), jnp.nan),
            "last_cfg": jnp.full(S, -1e18),
            "next_drs": jnp.full(S, static.drs_first_at_s),
            "pon_idx": jnp.full(S, -1, dtype=jnp.int64),
            "pon_end": jnp.zeros(S),
            "poff_idx": jnp.full(S, -1, dtype=jnp.int64),
            "poff_end": jnp.zeros(S),
            "pend_caps": a["caps0"], "pend_cnt": zi,
            "pend_mask": jnp.zeros((S, H), dtype=bool),
            "ev_done": jnp.zeros((S, static.n_events), dtype=bool),
            "acc": dict(zeros), "win": dict(zeros),
            "tag_pay": jnp.zeros((S, static.n_tags)),
            "tag_dem": jnp.zeros((S, static.n_tags)),
            "n_changes": zi, "vmotions": zi,
            "power_ons": zi, "power_offs": zi,
            "over_budget": jnp.full(S, -jnp.inf),
            "slot_pressure": jnp.zeros(S, dtype=bool),
        }
        if tcols is not None:
            init["over_tree"] = jnp.full(S, -jnp.inf)
        if static.timed:
            init.update({
                "mig_src": jnp.full((S, M), -1, dtype=jnp.int64),
                "mig_j": jnp.full((S, M), -1, dtype=jnp.int64),
                "mig_dst": jnp.full((S, M), -1, dtype=jnp.int64),
                "mig_prev": jnp.full((S, M), -1, dtype=jnp.int64),
                "mig_end": jnp.zeros((S, M)),
                "poff_wait": jnp.zeros(S, dtype=bool)})
        xs = (a["ts"], a["win_mask"])
        c, ys = jax.lax.scan(step, init, xs)
        out = {"acc": c["acc"], "win": c["win"],
               "tag_payload": c["tag_pay"], "tag_demand": c["tag_dem"],
               "cap_changes": c["n_changes"], "vmotions": c["vmotions"],
               "power_ons": c["power_ons"], "power_offs": c["power_offs"],
               "max_total_cap": c["over_budget"],
               "over_budget": c["over_budget"],
               "final_caps": c["caps"], "final_on": c["on"],
               "final_occ": c["slots"]["occ"],
               "slot_pressure": c["slot_pressure"]}
        if tcols is not None:
            out["over_tree"] = c["over_tree"]
        if static.keep_timeseries:
            out["timeseries"] = ys
        return out

    program = build_churn if static.churn else build_static
    return program


def _cells_specs(a, P):
    """shard_map partition specs for the packed array dict: every per-cell
    array splits on its leading S axis; the shared time axis replicates."""
    return {k: (P() if k in ("ts", "drs_mask")
                else P(None, "cells") if k == "win_mask"
                else P("cells")) for k in a}


def _out_specs(static: _StaticSpec, P):
    """shard_map output specs: per-cell results split on their leading S
    axis; the per-tick timeseries (``(T, S)``) splits on axis 1."""
    specs = {k: P("cells") for k in (
        "acc", "win", "tag_payload", "tag_demand", "cap_changes",
        "vmotions", "power_ons", "power_offs", "max_total_cap",
        "over_budget", "final_caps", "final_on", "final_occ",
        "slot_pressure")}
    if static.n_tree_nodes:
        specs["over_tree"] = P("cells")
    if static.keep_timeseries:
        specs["timeseries"] = P(None, "cells")
    return specs


@functools.lru_cache(maxsize=None)
def _compiled_program(static: _StaticSpec, n_devices: int = 1):
    """Jit (and cache) the whole-grid program.

    The packed input dict is marked for donation: the scan carry aliases
    the transferred buffers instead of holding both live, cutting peak
    device memory on the largest cells (inputs re-transfer from the host
    copy on every call, so repeated ``run()`` stays valid).

    With ``n_devices > 1`` the program is wrapped in ``shard_map`` over the
    1-D ``cells`` mesh (``repro.launch.mesh.make_cells_mesh``): ``static``
    describes the *global* grid and each device traces the identical
    per-shard program over ``n_cells / n_devices`` cells.  Cells never
    interact -- every reduction in the scan body runs over the trailing
    host/slot axes -- so the mapped body contains no collectives; the only
    cross-device traffic is the final gather of the per-cell accumulators
    when results leave the mesh.
    """
    import jax

    if n_devices <= 1:
        return jax.jit(_build_program(static), donate_argnums=0)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_cells_mesh

    if static.n_cells % n_devices:
        raise ValueError(  # BatchedSimulator.run pads the cells axis first
            f"{static.n_cells} cells not divisible by {n_devices} devices")
    local = static._replace(n_cells=static.n_cells // n_devices)
    program = _build_program(local)
    mesh = make_cells_mesh(n_devices)

    def sharded(a):
        return shard_map(program, mesh=mesh,
                         in_specs=(_cells_specs(a, P),),
                         out_specs=_out_specs(static, P),
                         check_rep=False)(a)

    return jax.jit(sharded, donate_argnums=0)


#: AOT-compiled executables keyed by (static, n_devices, input-shape
#: signature): ``BatchedSimulator.compile`` populates it -- concurrently
#: from the sweep pipeline's worker threads -- and ``run_async`` dispatches
#: against it without re-tracing.
_AOT_EXECUTABLES: dict = {}
_AOT_LOCK = threading.Lock()


@contextlib.contextmanager
def _quiet_donation():
    """Suppress XLA's "donated buffers were not usable" advisory: shared
    time-axis inputs (``ts``/``drs_mask``) and sub-word masks have no
    aliasable output, which is expected, not actionable."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


class BatchedSimulator:
    """Simulate S scenario cells as one compiled program.

    Cells must share the time grid (``duration_s``/``tick_s``) and DRS
    schedule; host counts, VM counts, traces, budgets, policies, windows,
    DPM flags, and scripted power events vary freely per cell (smaller
    cells are padded).

    ``waterfill_iters`` defaults to 100: the lockstep bisection reaches its
    float64 fixed point in ~60 trips for realistic magnitudes, so this
    matches the NumPy primitive's 200-trip result exactly at half the cost.

    ``slot_slack`` over-provisions the per-host VM slot axis for dynamic
    grids so DPM evacuations and balancer/correction migrations have
    somewhere to land; if a run's consolidation would exceed it, the engine
    raises after the run (``slot_pressure``) rather than silently diverging.

    ``balancer`` (a ``kernels.MigrationParams``) enables the hill-climb
    migration balancer for cells with ``balancer_enabled`` -- the batched
    twin of the manager's ``BalancerConfig``; the default (``max_moves=0``)
    matches the sweep regime with migration search disabled.

    ``n_devices`` shards the S-cells axis over a 1-D ``cells`` mesh
    (``shard_map``): ``None`` uses every visible jax device, ``1`` pins the
    single-device program.  Cells are embarrassingly parallel, so each
    device runs its shard through the identical compiled scan and per-cell
    results are bit-identical to the single-device run; when the cell count
    is not a device multiple the cells axis is padded with duplicates of the
    leading cells (dropped from the results).

    ``pad_hosts`` / ``pad_slots`` force the packed host axis (and the
    pre-slack slot axis) up to at least the given sizes -- the sweep
    layer's pad-bucketing uses them to pin every grid in a pow2 shape
    class to the same compiled program.
    """

    def __init__(self, cells: Sequence[BatchCell],
                 balance: Optional[kernels.BalanceParams] = None,
                 dpm: Optional[kernels.DPMParams] = None,
                 waterfill_iters: int = 100,
                 slot_slack: float = 2.0,
                 balancer: Optional[kernels.MigrationParams] = None,
                 n_devices: Optional[int] = None,
                 pad_hosts: int = 0,
                 pad_slots: int = 0,
                 keep_timeseries: bool = False):
        if not cells:
            raise ValueError("no cells")
        self.cells = list(cells)
        self.config = cells[0].config
        self._n_devices = n_devices
        self._keep_timeseries = bool(keep_timeseries)
        self._pad_hosts = int(pad_hosts)
        self._pad_slots = int(pad_slots)
        self._balancer = balancer or kernels.MigrationParams(max_moves=0)
        self._churn = any(c.dpm_enabled or c.config.power_events
                          for c in cells)
        # The migration layer compiles in when the grid can actually move a
        # VM: rule violations to correct at t=0, a live hill-climb
        # balancer, or rules that DPM evacuations might have to respect
        # (and whose affinity groups a later correction must re-gather).
        has_rules = any(c.snapshot.rules for c in cells)
        violated = any(rules_mod.all_violations(c.snapshot)
                       for c in cells)
        balancer_live = (self._balancer.max_moves > 0
                         and any(c.balancer_enabled for c in cells))
        self._migration = (balancer_live or violated
                           or (has_rules
                               and any(c.dpm_enabled for c in cells)))
        self._dynamic = self._churn or self._migration
        self._validate()
        # Timed-vMotion regime: the migration-capable cells run the copy
        # window + FIFO-commit model (gated launches, endpoint overhead)
        # instead of atomic remaps.
        self._timed = (self._mig_ref is not None
                       and not self._mig_ref.instant_migrations)
        self._pack(balance or kernels.BalanceParams(),
                   dpm or kernels.DPMParams(), waterfill_iters, slot_slack)

    # ---------------------------------------------------------- validation
    @staticmethod
    def _mig_capable(c: BatchCell,
                     balancer: kernels.MigrationParams) -> bool:
        """Whether this cell can actually move a VM -- and therefore cares
        about the migration execution model (instant vs timed vMotion)."""
        return bool(c.dpm_enabled
                    or (balancer.max_moves > 0 and c.balancer_enabled)
                    or (c.snapshot.rules
                        and rules_mod.all_violations(c.snapshot)))

    @classmethod
    def _cell_reason(cls, c: BatchCell, ref: SimConfig, churn: bool,
                     balancer: kernels.MigrationParams,
                     check_traces: bool = False,
                     ref_mig: Optional[SimConfig] = None) -> Optional[str]:
        """Why this cell cannot join a batch anchored on ``ref`` (None if
        it can).  ``ref_mig`` is the migration-model anchor: the config of
        the first migration-capable cell already admitted (the model is
        compiled into the program, so all such cells must agree on it)."""
        same = (c.config.duration_s == ref.duration_s
                and c.config.tick_s == ref.tick_s
                and c.config.drs_period_s == ref.drs_period_s
                and c.config.drs_first_at_s == ref.drs_first_at_s)
        if not same:
            return "disagrees on the shared time grid"
        if cls._mig_capable(c, balancer):
            if (not c.config.instant_migrations
                    and not c.config.migration_gated):
                return ("timed migrations in the batched engine need "
                        "launch gating (set migration_slots_per_host "
                        "and/or migration_bandwidth, and use the same on "
                        "the reference engine); ungated timed cells run "
                        "on the vector engine")
            if ref_mig is not None:
                mine = (c.config.instant_migrations,
                        c.config.vmotion_rate_mb_s,
                        c.config.vmotion_overhead_mhz,
                        c.config.migration_slots_per_host,
                        c.config.migration_bandwidth)
                want = (ref_mig.instant_migrations,
                        ref_mig.vmotion_rate_mb_s,
                        ref_mig.vmotion_overhead_mhz,
                        ref_mig.migration_slots_per_host,
                        ref_mig.migration_bandwidth)
                if mine != want:
                    return ("disagrees on the migration execution model "
                            "(instant/timed, vMotion rate/overhead, and "
                            "launch gates are shared across a batch)")
        if churn:
            same = (c.config.power_on_latency_s == ref.power_on_latency_s
                    and c.config.power_off_latency_s
                    == ref.power_off_latency_s)
            if not same:
                return ("disagrees on power latencies (shared across a "
                        "capacity-churn batch)")
        for t, host_id, _ in c.config.power_events:
            if host_id not in c.snapshot.hosts:
                return f"power event at t={t} targets unknown host {host_id!r}"
        if c.snapshot.effective_tree() is not None and c.snapshot.rules:
            return ("budget trees with placement rules cannot be batched "
                    "(constraint correction's cap funding is tree-unaware); "
                    "such cells run on the vector engine")
        if check_traces:
            bank = c.trace_bank
            if bank is None:
                bank = TraceBank.from_traces(c.traces,
                                             list(c.snapshot.vms))
            if bank.fallback:
                return "traces without a declarative spec cannot be batched"
        return None

    @classmethod
    def unsupported_cells(cls, cells: Sequence[BatchCell],
                          balancer: Optional[kernels.MigrationParams] = None
                          ) -> dict[str, str]:
        """Map of cell name -> reason for every cell the batched engine
        cannot replay, anchored on the first supportable cell's time grid.
        Used by ``run_sweep``'s per-cell fallback partitioning."""
        balancer = balancer or kernels.MigrationParams(max_moves=0)
        churn = any(c.dpm_enabled or c.config.power_events for c in cells)
        out: dict[str, str] = {}
        ref: Optional[SimConfig] = None
        ref_mig: Optional[SimConfig] = None
        for c in cells:
            capable = cls._mig_capable(c, balancer)
            reason = cls._cell_reason(c, ref or c.config, churn, balancer,
                                      check_traces=True,
                                      ref_mig=ref_mig if capable else None)
            if reason is None:
                if ref is None:
                    ref = c.config
                if capable and ref_mig is None:
                    ref_mig = c.config
            else:
                out[c.name] = reason
        return out

    def _validate(self) -> None:
        """Reject regimes the jitted program cannot replay exactly, loudly
        (the alternative -- freezing the unsupported dimension -- produces
        plausible-looking wrong results)."""
        ref_mig: Optional[SimConfig] = None
        for c in self.cells:
            capable = self._mig_capable(c, self._balancer)
            reason = self._cell_reason(c, self.config, self._churn,
                                       self._balancer,
                                       ref_mig=ref_mig if capable else None)
            if reason is not None:
                raise BatchUnsupported(f"cell {c.name!r}: {reason}")
            if capable and ref_mig is None:
                ref_mig = c.config
        # Migration-model anchor: the config every migration-capable cell
        # agreed with (None when nothing in the grid can move a VM).
        self._mig_ref = ref_mig

    # ------------------------------------------------------------- packing
    def _pack(self, balance: kernels.BalanceParams,
              dpm: kernels.DPMParams, waterfill_iters: int,
              slot_slack: float) -> None:
        t_pack0 = time.perf_counter()
        cells = self.cells
        S = len(cells)
        H = max(max(len(c.snapshot.hosts) for c in cells), self._pad_hosts)
        ts, drs_mask = _drs_schedule(self.config)
        T = ts.shape[0]

        # Pass 1: per-cell VM columns and the dense slot assignment.  Each
        # cell's placed, powered-on VMs are grouped under their resident
        # host (a VM on a powered-off host occupies a slot but delivers
        # nothing until the host comes on -- the object engines'
        # active-mask semantics).  All per-VM work is vectorized: one stable
        # sort by host index yields every VM's (host, slot) coordinate.
        prepped = []
        n_bps = 1
        rmeta = kernels.RulesMeta()
        pack_rules = self._migration and any(c.snapshot.rules
                                             for c in cells)
        for c in cells:
            snap = c.snapshot
            vms, order, hj, slot, counts = dense_slot_assignment(snap, H)
            vm_ids = [v.vm_id for v in vms]

            # ``trace_bank`` rows index ``list(snap.vms)`` -- the same
            # order ``dense_slot_assignment`` returned in ``vms``.
            bank = c.trace_bank
            if bank is None:
                bank = TraceBank.from_traces(c.traces, vm_ids)
            if bank.fallback:
                bad = [vm_ids[r] for r, _ in bank.fallback]
                raise BatchUnsupported(
                    f"cell {c.name!r}: traces without a declarative spec "
                    f"cannot be batched: {bad[:5]}")
            if bank.rows.size:
                n_bps = max(n_bps, bank.bps.shape[1])
            pack = None
            if pack_rules:
                pack = RulesPack.from_rules(
                    snap.rules, {v: i for i, v in enumerate(vm_ids)},
                    {hid: j for j, hid in enumerate(snap.hosts)})
                # Grid bounds: fieldwise max of every cell's static shape.
                rmeta = kernels.RulesMeta(
                    *(max(a, b) for a, b in zip(rmeta, pack.meta())))
            prepped.append((vms, bank, order, hj, slot, counts, pack))
        J = max(max((int(p[5].max()) for p in prepped if p[5].size),
                    default=1), 1, self._pad_slots)
        if (self._churn and any(c.dpm_enabled for c in cells)) \
                or self._migration:
            # Headroom for consolidation and balancer moves: migrating VMs
            # land in free slots.
            J = int(math.ceil(J * max(slot_slack, 1.0)))

        tag_names = sorted({t for c in cells
                            for v in c.snapshot.vms.values() for t in v.tags})
        G = len(tag_names)
        E = max([len(c.config.power_events) for c in cells] + [1])
        # Hierarchical budgets: pad every cell to the widest tree.  A
        # tree-less cell in a tree batch keeps the padded defaults (no
        # ancestors, infinite limits), which make every tree op a provable
        # no-op -- its caps replay bit-identically to a tree-free batch.
        trees = [c.snapshot.effective_tree() for c in cells]
        n_tree = max((t.n_nodes for t in trees if t is not None), default=0)

        def host_col(fill=0.0):
            return np.full((S, H), fill, dtype=np.float64)

        a = {
            "on": np.zeros((S, H), dtype=bool),
            "exists": np.zeros((S, H), dtype=bool),
            # Padded hosts keep a nonzero idle->peak range so Eq. 3 stays
            # finite; the `on`/`exists` masks zero everything they produce.
            "idle": host_col(1.0), "peak": host_col(2.0),
            "cap_peak": host_col(1.0), "hyp": host_col(0.0),
            "host_mem": host_col(0.0), "caps0": host_col(0.0),
            "cpu_res": host_col(0.0),
            "budget": np.zeros(S), "enabled": np.zeros(S, dtype=bool),
            "dpm": np.zeros(S, dtype=bool),
            "bal_on": np.zeros(S, dtype=bool),
            "occ": np.zeros((S, H, J), dtype=bool),
            # Global VM index (the cell's ArrayView order) of each slot's
            # resident, -1 when empty: host reductions that must match the
            # object plane's bincount add in this order, not slot order.
            "vm": np.full((S, H, J), -1, dtype=np.int64),
            "reservation": np.zeros((S, H, J)),
            "limit": np.full((S, H, J), np.inf),
            "weights": np.full((S, H, J), 1e-12),
            "migratable": np.ones((S, H, J), dtype=bool),
            "tag_masks": np.zeros((S, H, J, G), dtype=bool),
            "bps": np.full((S, H, J, n_bps), np.inf),
            "cpu_vals": np.zeros((S, H, J, n_bps)),
            "mem_vals": np.zeros((S, H, J, n_bps)),
            "period": np.full((S, H, J), np.inf),
            "ev_t": np.full((S, E), np.inf),
            "ev_host": np.zeros((S, E), dtype=np.int64),
            "ev_on": np.zeros((S, E), dtype=bool),
            "ts": ts, "drs_mask": drs_mask,
            "win_mask": np.zeros((T, S), dtype=bool),
        }
        a["bps"][..., 0] = 0.0
        if n_tree:
            a["tree_anc"] = np.zeros((S, H, n_tree), dtype=bool)
            a["tree_limit"] = np.full((S, n_tree), np.inf)
            a["tree_depth"] = np.full((S, n_tree), -1, dtype=np.int64)
        # Rule columns only exist when some cell actually has that rule
        # kind -- absent columns skip their admission term entirely.
        if pack_rules and rmeta.n_groups:
            a["aff_group"] = np.full((S, H, J), -1, dtype=np.int64)
        if pack_rules and rmeta.n_vmhost:
            a["allowed"] = np.ones((S, H, J, H), dtype=bool)
        if pack_rules and rmeta.n_anti:
            a["anti"] = np.zeros((S, H, J, rmeta.n_anti), dtype=bool)

        for i, c in enumerate(cells):
            snap = c.snapshot
            vms, bank, order, hj, slot, counts, pack = prepped[i]
            host_idx = {hid: j for j, hid in enumerate(snap.hosts)}
            for j, h in enumerate(snap.hosts.values()):
                a["on"][i, j] = h.powered_on
                a["exists"][i, j] = True
                a["idle"][i, j] = h.spec.power_idle
                a["peak"][i, j] = h.spec.power_peak
                a["cap_peak"][i, j] = h.spec.capacity_peak
                a["hyp"][i, j] = h.spec.hypervisor_overhead
                a["host_mem"][i, j] = h.spec.memory_mb
                a["caps0"][i, j] = h.power_cap
            n = len(vms)
            res = np.array([v.reservation for v in vms])
            a["occ"][i, hj, slot] = True
            a["vm"][i, hj, slot] = order
            a["reservation"][i, hj, slot] = res[order]
            a["limit"][i, hj, slot] = np.array([v.limit for v in vms])[order]
            a["weights"][i, hj, slot] = np.maximum(
                np.array([v.shares for v in vms]), 1e-12)[order]
            a["migratable"][i, hj, slot] = np.array(
                [v.migratable for v in vms], dtype=bool)[order]
            host_on = np.zeros(H, dtype=bool)
            host_on[:len(snap.hosts)] = [h.powered_on
                                         for h in snap.hosts.values()]
            a["cpu_res"][i, :] = np.where(
                host_on, np.bincount(hj, weights=res[order], minlength=H), 0.0)
            for g, tag in enumerate(tag_names):
                tagged = np.array([tag in v.tags for v in vms], dtype=bool)
                a["tag_masks"][i, hj, slot, g] = tagged[order]
            if pack_rules:
                h_c = len(snap.hosts)
                if "aff_group" in a:
                    a["aff_group"][i, hj, slot] = pack.affinity_group[order]
                if "allowed" in a:
                    a["allowed"][i, hj, slot, :h_c] = pack.allowed[order]
                if "anti" in a and pack.n_anti:
                    a["anti"][i, hj, slot, :pack.n_anti] = \
                        pack.anti_member.T[order]
            # Demand traces in TraceBank's padded step-function layout;
            # trace-less VMs freeze at their initial demand.
            dem0 = np.array([v.demand for v in vms])
            mem0 = np.array([v.mem_demand for v in vms])
            bps = np.full((n, n_bps), np.inf)
            bps[:, 0] = 0.0
            cpu = np.repeat(dem0[:, None], n_bps, axis=1)
            mem = np.repeat(mem0[:, None], n_bps, axis=1)
            period = np.full(n, np.inf)
            if bank.rows.size:
                k = bank.bps.shape[1]
                bps[bank.rows, :k] = bank.bps
                cpu[bank.rows, :k] = bank.cpu_vals
                mem[bank.rows, :k] = bank.mem_vals
                cpu[bank.rows, k:] = bank.cpu_vals[:, -1:]
                mem[bank.rows, k:] = bank.mem_vals[:, -1:]
                period[bank.rows] = bank.period
            a["bps"][i, hj, slot] = bps[order]
            a["cpu_vals"][i, hj, slot] = cpu[order]
            a["mem_vals"][i, hj, slot] = mem[order]
            a["period"][i, hj, slot] = period[order]
            a["budget"][i] = snap.power_budget
            if n_tree and trees[i] is not None:
                tree = trees[i]
                h_c = len(snap.hosts)
                a["tree_anc"][i, :h_c, :tree.n_nodes] = tree.host_anc
                a["tree_limit"][i, :tree.n_nodes] = tree.limit
                a["tree_depth"][i, :tree.n_nodes] = tree.depth
            a["enabled"][i] = c.powercap_enabled
            a["dpm"][i] = c.dpm_enabled
            a["bal_on"][i] = c.balancer_enabled
            for e, (ev_t, host_id, on) in enumerate(
                    sorted(c.config.power_events)):
                a["ev_t"][i, e] = ev_t
                a["ev_host"][i, e] = host_idx[host_id]
                a["ev_on"][i, e] = bool(on)
            if c.window is not None:
                w0, w1 = c.window
                a["win_mask"][:, i] = (w0 <= ts) & (ts < w1)
        self._arrays = a
        self._tag_names = tag_names
        # Migration execution model (shared by every migration-capable
        # cell, enforced by _validate): launch gates apply to gated
        # instant grids too; the in-flight table sizes to the worst-case
        # launches of one invocation (correction + balancer, capped by
        # the cluster bandwidth gate, plus a full evacuation).
        limits = kernels.MigrationLimits()
        rate, ovh, mig_table = 128.0, 1500.0, 1
        if self._mig_ref is not None:
            limits = kernels.MigrationLimits(
                slots_per_host=self._mig_ref.migration_slots_per_host,
                bandwidth=self._mig_ref.migration_bandwidth)
            rate = self._mig_ref.vmotion_rate_mb_s
            ovh = self._mig_ref.vmotion_overhead_mhz
        if self._timed:
            corr_b = (rmeta.move_bound
                      if self._migration and rmeta.any else 0)
            bal_b = (self._balancer.max_moves
                     if self._migration and self._balancer.max_moves > 0
                     else 0)
            mgr_b = corr_b + bal_b
            if limits.bandwidth is not None:
                mgr_b = min(mgr_b, limits.bandwidth)
            mig_table = max(mgr_b + J, 1)
        self._static = _StaticSpec(
            n_cells=S, n_hosts=H, n_slots=J, n_tags=G, n_events=E,
            tick_s=self.config.tick_s, waterfill_iters=waterfill_iters,
            balance=balance, churn=self._dynamic, dpm=dpm,
            drs_period_s=self.config.drs_period_s,
            drs_first_at_s=self.config.drs_first_at_s,
            power_on_latency_s=self.config.power_on_latency_s,
            power_off_latency_s=self.config.power_off_latency_s,
            migration=self._migration,
            rules=rmeta if self._migration else kernels.RulesMeta(),
            balancer=self._balancer,
            timed=self._timed, mig_table=mig_table, limits=limits,
            vmotion_rate_mb_s=rate, vmotion_overhead_mhz=ovh,
            executor=backend_mod.executor_name(),
            keep_timeseries=self._keep_timeseries,
            n_tree_nodes=n_tree)
        self._ticks = T
        self._prepared = None
        self.pack_s = time.perf_counter() - t_pack0

    # ------------------------------------------------------------- running
    def _prepare(self):
        """Resolve the mesh size, pad the cells axis, and compute the AOT
        cache signature.  Cached after the first call: padding a large grid
        is not free and ``compile``/``run_async`` both need it."""
        if self._prepared is not None:
            return self._prepared
        import jax

        S = self._static.n_cells
        n_dev = (len(jax.devices()) if self._n_devices is None
                 else int(self._n_devices))
        n_dev = max(1, min(n_dev, S))
        pad = (-S) % n_dev
        static = (self._static._replace(n_cells=S + pad) if pad
                  else self._static)
        a = self._arrays
        if pad:
            # Cells are independent, so padding the axis with duplicates of
            # the leading cells (and dropping their results) is exact.
            a = {k: (v if k in ("ts", "drs_mask")
                     else np.concatenate([v, v[:, :pad]], axis=1)
                     if k == "win_mask"
                     else np.concatenate([v, v[:pad]], axis=0))
                 for k, v in a.items()}
        sig = (static, n_dev,
               tuple(sorted((k, v.shape) for k, v in a.items())))
        self._prepared = (static, n_dev, a, sig)
        return self._prepared

    def compile(self) -> float:
        """Ensure this batch's program shape is AOT-compiled.

        ``jit(...).lower(a).compile()`` lands the executable in
        :data:`_AOT_EXECUTABLES` keyed by the shape signature (the XLA
        persistent compile cache still backs the expensive part across
        processes).  Returns the wall seconds this call spent compiling,
        0.0 on a warm cache.  Thread-safe: the sweep pipeline fires one
        ``compile`` per shape class concurrently from its worker pool
        (``enable_x64`` is thread-local; the executor pin is re-read from
        the static spec)."""
        static, n_dev, a, sig = self._prepare()
        with _AOT_LOCK:
            if sig in _AOT_EXECUTABLES:
                return 0.0
        from jax.experimental import enable_x64
        t0 = time.perf_counter()
        with enable_x64(), \
                backend_mod.executor_scope(self._static.executor), \
                _quiet_donation():
            exe = _compiled_program(static, n_dev).lower(a).compile()
        with _AOT_LOCK:
            _AOT_EXECUTABLES[sig] = exe
        return time.perf_counter() - t0

    def run_async(self) -> "PendingBatch":
        """Compile (if not already) and dispatch without blocking: jax
        execution is asynchronous, so this returns once the program is
        enqueued, letting the caller dispatch further batches (or keep
        packing) while the device works.  Harvest with
        :meth:`PendingBatch.result`."""
        compile_s = self.compile()
        static, n_dev, a, sig = self._prepare()
        from jax.experimental import enable_x64
        t0 = time.perf_counter()
        with enable_x64(), \
                backend_mod.executor_scope(self._static.executor), \
                _quiet_donation():
            raw = _AOT_EXECUTABLES[sig](a)
        return PendingBatch(sim=self, raw=raw, dispatch_t0=t0,
                            compile_s=compile_s, n_devices=n_dev)

    def run(self) -> BatchResult:
        return self.run_async().result()

    def _harvest(self, raw, dispatch_t0: float, compile_s: float,
                 n_dev: int) -> BatchResult:
        """Block on the dispatched outputs, check invariants, and assemble
        the :class:`BatchResult` (the ``np.asarray`` conversions are the
        synchronization point)."""
        S = self._static.n_cells
        out = {}
        for k, v in raw.items():
            if k == "timeseries":
                # Per-tick series are (T, S): the cells axis is axis 1.
                out[k] = {kk: np.asarray(vv)[:, :S] for kk, vv in v.items()}
            elif isinstance(v, dict):
                out[k] = {kk: np.asarray(vv)[:S] for kk, vv in v.items()}
            else:
                out[k] = np.asarray(v)[:S]
        run_s = time.perf_counter() - dispatch_t0

        # Post-hoc invariants, checked in one shot for the whole grid.
        if bool(out["slot_pressure"].any()):
            bad = [self.cells[i].name
                   for i in np.nonzero(out["slot_pressure"])[0]]
            raise RuntimeError(
                f"slot capacity bound a migration/evacuation decision in "
                f"cells {bad[:5]}: repack with a larger slot_slack")
        if self._static.churn:
            over = out["over_budget"]
        else:
            over = out["max_total_cap"] - self._arrays["budget"]
        assert float(over.max()) <= 1e-6, (
            f"budget violated during execution: worst overshoot "
            f"{float(over.max()):.3f} W (cell "
            f"{self.cells[int(over.argmax())].name})")
        if "over_tree" in out:
            ot = out["over_tree"]
            assert float(ot.max()) <= 1e-6, (
                f"budget tree violated during execution: worst node over by "
                f"{float(ot.max()):.3f} W (cell "
                f"{self.cells[int(ot.argmax())].name})")

        acc = out["acc"]
        return BatchResult(
            names=[c.name for c in self.cells],
            cpu_payload_mhz_s=acc["cpu_payload_mhz_s"],
            cpu_demand_mhz_s=acc["cpu_demand_mhz_s"],
            mem_payload_mb_s=acc["mem_payload_mb_s"],
            mem_demand_mb_s=acc["mem_demand_mb_s"],
            energy_j=acc["energy_j"],
            cap_changes=out["cap_changes"],
            vmotions=out["vmotions"],
            power_ons=out["power_ons"],
            power_offs=out["power_offs"],
            tag_names=self._tag_names,
            tag_payload=out["tag_payload"],
            tag_demand=out["tag_demand"],
            window_fields=out["win"],
            has_window=np.array([c.window is not None for c in self.cells]),
            final_caps=out["final_caps"],
            final_on=out["final_on"],
            final_occ=out["final_occ"],
            ticks=self._ticks,
            wall_s=compile_s + run_s,
            n_devices=n_dev,
            compile_s=compile_s,
            pack_s=self.pack_s,
            run_s=run_s,
            timeseries=out.get("timeseries"),
            tick_s=self._static.tick_s)


@dataclasses.dataclass
class PendingBatch:
    """A dispatched-but-unharvested batch: ``run_async``'s handle.

    ``raw`` holds the program's on-device output tree; ``result()`` blocks
    until execution finishes and builds the :class:`BatchResult`.  The
    sweep pipeline holds one of these per bucket so every bucket is in
    flight before any is harvested.
    """

    sim: BatchedSimulator
    raw: dict
    dispatch_t0: float
    compile_s: float
    n_devices: int

    def result(self) -> BatchResult:
        return self.sim._harvest(self.raw, self.dispatch_t0,
                                 self.compile_s, self.n_devices)
