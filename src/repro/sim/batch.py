"""Batched multi-cluster engine: one jitted program for a whole scenario grid.

``BatchedSimulator`` packs S scenario cells x H hosts x J VM slots per host
into padded device arrays (reusing :class:`repro.sim.workloads.TraceBank`'s
step-function layout for the demand traces) and runs the whole grid as a
single JAX program: tick delivery is a ``lax.scan`` over time, and every DRS
period the jitted redivvy + balance kernels from ``repro.core.kernels``
recompute the caps for all cells at once.  Where
``repro.sim.sweep.run_sweep`` executes the grid cell-at-a-time through the
NumPy ``VectorSimulator``, this engine executes it grid-at-a-time -- the
step that makes policy experiments grid-scale instead of cell-scale (the
``sweep_grid`` benchmark entry).

Layout note: VMs live in a *dense slot* layout ``(S, H, J)`` -- each VM
occupies a slot under its resident host -- rather than the object plane's
flat VM axis + host-index column.  Placements are frozen in this regime, so
every per-host reduction (waterfill sums, delivered capacity, memory
pressure) is a trailing-axis ``sum`` instead of a scatter-add: the
difference between an accelerator-friendly program and one bottlenecked on
``segment_sum``.

Scope: the cap-only management regime the sweeps isolate (see
``repro.sim.sweep``'s design notes) -- no DPM power state changes and no
migration search, so placements and host power states are frozen for the
run.  Within that regime the engine replays the exact protocol of
``Simulator.run()``: demand update, manager invocation on the DRS schedule
(phase 1 reserved-floor redivvy + phase 2 BalancePowerCap, with cap changes
counted by the ``order_cap_changes`` threshold), waterfill delivery, Eq. 1
energy accounting, and the budget invariant.  Parity against
``VectorSimulator`` on the paper's three evaluation scenarios is enforced by
``tests/test_batch_parity.py``.

Everything runs in float64 (``jax.experimental.enable_x64``) so the compiled
program tracks the NumPy object plane to reduction-order rounding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.backend import jax_backend
from repro.core import kernels
from repro.drs.entitlement import waterfill_dense
from repro.drs.snapshot import ClusterSnapshot
from repro.sim.cluster import SimConfig
from repro.sim.metrics import Accumulators
from repro.sim.workloads import DemandTrace, TraceBank


@dataclasses.dataclass
class BatchCell:
    """One scenario cell: a cluster, its demand traces, and its policy."""

    name: str
    snapshot: ClusterSnapshot
    traces: dict[str, DemandTrace]
    config: SimConfig
    powercap_enabled: bool = True            # False => Static/StaticHigh
    window: Optional[tuple[float, float]] = None


class _StaticSpec(NamedTuple):
    """Hashable compile key: everything that shapes the jitted program."""

    n_cells: int
    n_hosts: int
    n_slots: int
    n_tags: int
    tick_s: float
    waterfill_iters: int
    balance: kernels.BalanceParams


@dataclasses.dataclass
class BatchResult:
    """Per-cell accumulators, as arrays over the S cells."""

    names: list
    cpu_payload_mhz_s: np.ndarray
    cpu_demand_mhz_s: np.ndarray
    mem_payload_mb_s: np.ndarray
    mem_demand_mb_s: np.ndarray
    energy_j: np.ndarray
    cap_changes: np.ndarray                  # int per cell
    tag_names: list
    tag_payload: np.ndarray                  # (S, G)
    tag_demand: np.ndarray                   # (S, G)
    window_fields: dict                      # field -> (S,) array
    has_window: np.ndarray                   # bool per cell
    final_caps: np.ndarray                   # (S, H)
    ticks: int
    wall_s: float = 0.0

    def accumulators(self, i: int) -> Accumulators:
        acc = Accumulators(
            cpu_payload_mhz_s=float(self.cpu_payload_mhz_s[i]),
            cpu_demand_mhz_s=float(self.cpu_demand_mhz_s[i]),
            mem_payload_mb_s=float(self.mem_payload_mb_s[i]),
            mem_demand_mb_s=float(self.mem_demand_mb_s[i]),
            energy_j=float(self.energy_j[i]),
            cap_changes=int(self.cap_changes[i]))
        for g, tag in enumerate(self.tag_names):
            if self.tag_demand[i, g] > 0.0 or self.tag_payload[i, g] > 0.0:
                acc.tag_payload[tag] = float(self.tag_payload[i, g])
                acc.tag_demand[tag] = float(self.tag_demand[i, g])
        return acc

    def window_accumulators(self, i: int) -> Optional[Accumulators]:
        if not bool(self.has_window[i]):
            return None
        w = self.window_fields
        return Accumulators(
            cpu_payload_mhz_s=float(w["cpu_payload_mhz_s"][i]),
            cpu_demand_mhz_s=float(w["cpu_demand_mhz_s"][i]),
            mem_payload_mb_s=float(w["mem_payload_mb_s"][i]),
            mem_demand_mb_s=float(w["mem_demand_mb_s"][i]),
            energy_j=float(w["energy_j"][i]))


def _drs_schedule(cfg: SimConfig) -> tuple[np.ndarray, np.ndarray]:
    """Tick times and manager-invocation mask, mirroring ``Simulator.run()``
    (cap changes are instantaneous, so no invocation is ever deferred)."""
    ts, fire = [], []
    next_drs = cfg.drs_first_at_s
    t = 0.0
    while t < cfg.duration_s:
        hit = t >= next_drs
        if hit:
            next_drs = t + cfg.drs_period_s
        ts.append(t)
        fire.append(hit)
        t += cfg.tick_s
    return np.asarray(ts, dtype=np.float64), np.asarray(fire, dtype=bool)


@functools.lru_cache(maxsize=None)
def _compiled_program(static: _StaticSpec):
    """Build (and cache) the jitted whole-grid program for one shape."""
    import jax
    import jax.numpy as jnp

    be = jax_backend()
    S = static.n_cells
    dt = static.tick_s
    wf_iters = static.waterfill_iters

    def program(a):
        hosts = kernels.HostCols(a["on"], a["idle"], a["peak"],
                                 a["cap_peak"], a["hyp"])
        on = a["on"]
        active = a["active"]                  # (S, H, J) slot occupied
        weights = a["weights"]
        host_mem = jnp.where(on, a["host_mem"], 0.0)
        # Static balance inputs: reservations never move in this regime.
        floor_caps = kernels.reserved_floor_caps(jnp, hosts, a["cpu_res"])
        vm_floors = jnp.where(active,
                              jnp.minimum(a["reservation"], a["limit"]), 0.0)
        finite_period = jnp.isfinite(a["period"])

        def demands(t):
            phase = jnp.where(finite_period, jnp.mod(t, a["period"]), t)
            idx = jnp.clip(
                jnp.sum(a["bps"] <= phase[..., None], axis=-1) - 1, 0, None)
            cpu = jnp.take_along_axis(a["cpu_vals"], idx[..., None],
                                      axis=-1)[..., 0]
            mem = jnp.take_along_axis(a["mem_vals"], idx[..., None],
                                      axis=-1)[..., 0]
            return cpu, mem

        def invoke_manager(caps, cpu):
            """Phase 1 (reserved-floor redivvy) + phase 2 (BalancePowerCap),
            counting cap changes exactly as ``order_cap_changes`` emits."""
            redivvied = kernels.redivvy_caps(jnp, on, caps, floor_caps)
            caps1 = jnp.where(a["enabled"][:, None], redivvied, caps)
            changes = kernels.count_cap_changes(jnp, on, caps, caps1)
            vm_ceils = jnp.where(
                active, jnp.clip(cpu, a["reservation"], a["limit"]), 0.0)

            def ents_at(c):
                managed = kernels.managed_capacity(jnp, hosts, c)
                alloc = waterfill_dense(jnp, be.fori, managed, vm_floors,
                                        vm_ceils, weights, wf_iters)
                return jnp.sum(alloc, axis=-1)

            caps2, _ = kernels.balance_caps(
                be, hosts, caps1, ents_at, a["cpu_res"], a["budget"],
                a["enabled"], static.balance)
            changes = changes + kernels.count_cap_changes(jnp, on, caps1,
                                                          caps2)
            return caps2, changes.astype(jnp.int32)

        def deliver(caps, cpu, mem):
            managed = kernels.managed_capacity(jnp, hosts, caps)
            dem = jnp.where(active, jnp.minimum(cpu, a["limit"]), 0.0)
            floors = jnp.where(active,
                               jnp.minimum(a["reservation"], dem), 0.0)
            alloc = waterfill_dense(jnp, be.fori, managed, floors, dem,
                                    weights, wf_iters)
            delivered_h = jnp.sum(alloc, axis=-1)
            mem_d = jnp.where(active, mem, 0.0)
            mem_dem_h = jnp.sum(mem_d, axis=-1)
            mem_deliv = jnp.minimum(mem_dem_h, host_mem)
            # Eq. 1 power, utilization measured against peak capacity.
            util = delivered_h / a["cap_peak"]
            power = kernels.power_consumed(jnp, hosts, util)
            tick = {
                "cpu_payload_mhz_s": jnp.sum(alloc, axis=(-1, -2)),
                "cpu_demand_mhz_s": jnp.sum(dem, axis=(-1, -2)),
                "mem_payload_mb_s": jnp.sum(mem_deliv, axis=-1),
                "mem_demand_mb_s": jnp.sum(mem_dem_h, axis=-1),
                "energy_j": jnp.sum(power * on, axis=-1),
            }
            tag_pay = jnp.sum(a["tag_masks"] * alloc[None],
                              axis=(-1, -2)).T
            tag_dem = jnp.sum(a["tag_masks"] * dem[None], axis=(-1, -2)).T
            return tick, tag_pay, tag_dem

        def step(carry, x):
            caps, acc, win, tag_pay, tag_dem, n_changes, max_total = carry
            t, is_drs, in_win = x
            cpu, mem = demands(t)
            caps, changes = jax.lax.cond(
                is_drs,
                lambda c: invoke_manager(c, cpu),
                lambda c: (c, jnp.zeros(S, dtype=jnp.int32)),
                caps)
            tick, tp, td = deliver(caps, cpu, mem)
            acc = {k: acc[k] + tick[k] * dt for k in acc}
            win = {k: win[k] + jnp.where(in_win, tick[k], 0.0) * dt
                   for k in win}
            carry = (caps, acc, win, tag_pay + tp * dt, tag_dem + td * dt,
                     n_changes + changes,
                     jnp.maximum(max_total, jnp.sum(caps * on, axis=-1)))
            return carry, None

        fields = ("cpu_payload_mhz_s", "cpu_demand_mhz_s",
                  "mem_payload_mb_s", "mem_demand_mb_s", "energy_j")
        zeros = {k: jnp.zeros(S) for k in fields}
        init = (a["caps0"], dict(zeros), dict(zeros),
                jnp.zeros((S, static.n_tags)), jnp.zeros((S, static.n_tags)),
                jnp.zeros(S, dtype=jnp.int32),
                jnp.sum(a["caps0"] * a["on"], axis=-1))
        xs = (a["ts"], a["drs_mask"], a["win_mask"])
        (caps, acc, win, tag_pay, tag_dem, n_changes, max_total), _ = (
            jax.lax.scan(step, init, xs))
        return {"acc": acc, "win": win, "tag_payload": tag_pay,
                "tag_demand": tag_dem, "cap_changes": n_changes,
                "max_total_cap": max_total, "final_caps": caps}

    return jax.jit(program)


class BatchedSimulator:
    """Simulate S scenario cells as one compiled program.

    Cells must share the time grid (``duration_s``/``tick_s``) and DRS
    schedule; host counts, VM counts, traces, budgets, policies, and windows
    vary freely per cell (smaller cells are padded).

    ``waterfill_iters`` defaults to 100: the lockstep bisection reaches its
    float64 fixed point in ~60 trips for realistic magnitudes, so this
    matches the NumPy primitive's 200-trip result exactly at half the cost.
    """

    def __init__(self, cells: Sequence[BatchCell],
                 balance: Optional[kernels.BalanceParams] = None,
                 waterfill_iters: int = 100):
        if not cells:
            raise ValueError("no cells")
        self.cells = list(cells)
        cfg = cells[0].config
        for c in cells[1:]:
            same = (c.config.duration_s == cfg.duration_s
                    and c.config.tick_s == cfg.tick_s
                    and c.config.drs_period_s == cfg.drs_period_s
                    and c.config.drs_first_at_s == cfg.drs_first_at_s)
            if not same:
                raise ValueError(
                    f"cell {c.name!r} disagrees on the shared time grid")
        self.config = cfg
        self._pack(balance or kernels.BalanceParams(), waterfill_iters)

    # ------------------------------------------------------------- packing
    def _pack(self, balance: kernels.BalanceParams,
              waterfill_iters: int) -> None:
        cells = self.cells
        S = len(cells)
        H = max(len(c.snapshot.hosts) for c in cells)
        ts, drs_mask = _drs_schedule(self.config)
        T = ts.shape[0]

        # Pass 1: per-cell VM columns and the dense slot assignment.  Each
        # cell's *active* VMs (powered on, placed on a powered-on host) are
        # grouped under their resident host; inactive VMs contribute nothing
        # to delivery or accounting, exactly as the object engines'
        # active-mask semantics.  All per-VM work is vectorized: one stable
        # sort by host index yields every VM's (host, slot) coordinate.
        prepped = []
        n_bps = 1
        for c in cells:
            snap = c.snapshot
            vms = list(snap.vms.values())
            vm_ids = [v.vm_id for v in vms]
            host_idx = {hid: j for j, hid in enumerate(snap.hosts)}
            host_on = np.array([h.powered_on
                                for h in snap.hosts.values()], dtype=bool)
            host_j = np.array([host_idx.get(v.host_id, -1) for v in vms],
                              dtype=np.int64)
            act = np.array([v.powered_on for v in vms], dtype=bool)
            act &= host_j >= 0
            act[act] &= host_on[host_j[act]]
            order = np.nonzero(act)[0]
            hj = host_j[order]
            srt = np.argsort(hj, kind="stable")
            order, hj = order[srt], hj[srt]
            counts = np.bincount(hj, minlength=H)
            starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
            slot = np.arange(hj.size) - np.repeat(starts, counts)

            bank = TraceBank.from_traces(c.traces, vm_ids)
            if bank.fallback:
                bad = [vm_ids[r] for r, _ in bank.fallback]
                raise ValueError(
                    f"cell {c.name!r}: traces without a declarative spec "
                    f"cannot be batched: {bad[:5]}")
            if bank.rows.size:
                n_bps = max(n_bps, bank.bps.shape[1])
            prepped.append((vms, bank, order, hj, slot, counts))
        J = max(max((int(p[5].max()) for p in prepped if p[5].size),
                    default=1), 1)

        tag_names = sorted({t for c in cells
                            for v in c.snapshot.vms.values() for t in v.tags})
        G = len(tag_names)

        def host_col(fill=0.0):
            return np.full((S, H), fill, dtype=np.float64)

        a = {
            "on": np.zeros((S, H), dtype=bool),
            # Padded hosts keep a nonzero idle->peak range so Eq. 3 stays
            # finite; the `on` mask zeroes everything they would produce.
            "idle": host_col(1.0), "peak": host_col(2.0),
            "cap_peak": host_col(1.0), "hyp": host_col(0.0),
            "host_mem": host_col(0.0), "caps0": host_col(0.0),
            "cpu_res": host_col(0.0),
            "budget": np.zeros(S), "enabled": np.zeros(S, dtype=bool),
            "active": np.zeros((S, H, J), dtype=bool),
            "reservation": np.zeros((S, H, J)),
            "limit": np.full((S, H, J), np.inf),
            "weights": np.full((S, H, J), 1e-12),
            "tag_masks": np.zeros((G, S, H, J), dtype=bool),
            "bps": np.full((S, H, J, n_bps), np.inf),
            "cpu_vals": np.zeros((S, H, J, n_bps)),
            "mem_vals": np.zeros((S, H, J, n_bps)),
            "period": np.full((S, H, J), np.inf),
            "ts": ts, "drs_mask": drs_mask,
            "win_mask": np.zeros((T, S), dtype=bool),
        }
        a["bps"][..., 0] = 0.0

        for i, c in enumerate(cells):
            snap = c.snapshot
            vms, bank, order, hj, slot, counts = prepped[i]
            for j, h in enumerate(snap.hosts.values()):
                a["on"][i, j] = h.powered_on
                a["idle"][i, j] = h.spec.power_idle
                a["peak"][i, j] = h.spec.power_peak
                a["cap_peak"][i, j] = h.spec.capacity_peak
                a["hyp"][i, j] = h.spec.hypervisor_overhead
                a["host_mem"][i, j] = h.spec.memory_mb
                a["caps0"][i, j] = h.power_cap
            n = len(vms)
            res = np.array([v.reservation for v in vms])
            a["active"][i, hj, slot] = True
            a["reservation"][i, hj, slot] = res[order]
            a["limit"][i, hj, slot] = np.array([v.limit for v in vms])[order]
            a["weights"][i, hj, slot] = np.maximum(
                np.array([v.shares for v in vms]), 1e-12)[order]
            a["cpu_res"][i, :] = np.bincount(hj, weights=res[order],
                                             minlength=H)
            for g, tag in enumerate(tag_names):
                tagged = np.array([tag in v.tags for v in vms], dtype=bool)
                a["tag_masks"][g, i, hj, slot] = tagged[order]
            # Demand traces in TraceBank's padded step-function layout;
            # trace-less VMs freeze at their initial demand.
            dem0 = np.array([v.demand for v in vms])
            mem0 = np.array([v.mem_demand for v in vms])
            bps = np.full((n, n_bps), np.inf)
            bps[:, 0] = 0.0
            cpu = np.repeat(dem0[:, None], n_bps, axis=1)
            mem = np.repeat(mem0[:, None], n_bps, axis=1)
            period = np.full(n, np.inf)
            if bank.rows.size:
                k = bank.bps.shape[1]
                bps[bank.rows, :k] = bank.bps
                cpu[bank.rows, :k] = bank.cpu_vals
                mem[bank.rows, :k] = bank.mem_vals
                cpu[bank.rows, k:] = bank.cpu_vals[:, -1:]
                mem[bank.rows, k:] = bank.mem_vals[:, -1:]
                period[bank.rows] = bank.period
            a["bps"][i, hj, slot] = bps[order]
            a["cpu_vals"][i, hj, slot] = cpu[order]
            a["mem_vals"][i, hj, slot] = mem[order]
            a["period"][i, hj, slot] = period[order]
            a["budget"][i] = snap.power_budget
            a["enabled"][i] = c.powercap_enabled
            if c.window is not None:
                w0, w1 = c.window
                a["win_mask"][:, i] = (w0 <= ts) & (ts < w1)
        self._arrays = a
        self._tag_names = tag_names
        self._static = _StaticSpec(
            n_cells=S, n_hosts=H, n_slots=J, n_tags=G,
            tick_s=self.config.tick_s, waterfill_iters=waterfill_iters,
            balance=balance)
        self._ticks = T

    # ------------------------------------------------------------- running
    def run(self) -> BatchResult:
        import time

        from jax.experimental import enable_x64

        t0 = time.perf_counter()
        with enable_x64():
            out = _compiled_program(self._static)(self._arrays)
            out = {k: ({kk: np.asarray(vv) for kk, vv in v.items()}
                       if isinstance(v, dict) else np.asarray(v))
                   for k, v in out.items()}
        wall = time.perf_counter() - t0

        # The tick-level budget invariant, checked in one shot post-hoc.
        over = out["max_total_cap"] - self._arrays["budget"]
        assert float(over.max()) <= 1e-6, (
            f"budget violated during execution: worst overshoot "
            f"{float(over.max()):.3f} W (cell "
            f"{self.cells[int(over.argmax())].name})")

        acc = out["acc"]
        return BatchResult(
            names=[c.name for c in self.cells],
            cpu_payload_mhz_s=acc["cpu_payload_mhz_s"],
            cpu_demand_mhz_s=acc["cpu_demand_mhz_s"],
            mem_payload_mb_s=acc["mem_payload_mb_s"],
            mem_demand_mb_s=acc["mem_demand_mb_s"],
            energy_j=acc["energy_j"],
            cap_changes=out["cap_changes"],
            tag_names=self._tag_names,
            tag_payload=out["tag_payload"],
            tag_demand=out["tag_demand"],
            window_fields=out["win"],
            has_window=np.array([c.window is not None for c in self.cells]),
            final_caps=out["final_caps"],
            ticks=self._ticks,
            wall_s=wall)
