"""Payload / power / migration accounting (paper Tables III-V)."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Accumulators:
    cpu_payload_mhz_s: float = 0.0     # useful cycles delivered to VMs
    cpu_demand_mhz_s: float = 0.0      # cycles VMs wanted
    mem_payload_mb_s: float = 0.0
    mem_demand_mb_s: float = 0.0
    energy_j: float = 0.0              # integral of Eq. 1 power
    vmotions: int = 0
    cap_changes: int = 0
    power_ons: int = 0
    power_offs: int = 0
    # Per-VM-tag payload (e.g. "trading" vs "hadoop" in Table V).
    tag_payload: dict = dataclasses.field(default_factory=dict)
    tag_demand: dict = dataclasses.field(default_factory=dict)

    def cpu_satisfaction(self) -> float:
        return (self.cpu_payload_mhz_s / self.cpu_demand_mhz_s
                if self.cpu_demand_mhz_s else 1.0)

    def tag_satisfaction(self, tag: str) -> float:
        d = self.tag_demand.get(tag, 0.0)
        return self.tag_payload.get(tag, 0.0) / d if d else 1.0


def fold_timeseries(timeseries: dict, tick_s: float) -> dict:
    """Reduce per-tick series to run summaries exactly as the scan carry
    does.

    ``timeseries`` maps field name to a ``(T, ...)`` array of per-tick
    rates (floats) or per-tick event counts (ints).  Float fields fold
    left-to-right as ``acc = acc + ts[t] * tick_s`` -- executed as a jitted
    scan so the backend emits the *same* instruction pattern as the in-scan
    accumulation (XLA CPU contracts the mul-add into an FMA; a NumPy fold
    would diverge in the last ULP) -- so the result is bit-identical to the
    reduced path, not merely close.  Integer counters sum exactly in any
    order.
    """
    import jax
    from jax.experimental import enable_x64

    @jax.jit
    def fold(ts):
        def step(acc, y):
            return acc + y * tick_s, None
        acc, _ = jax.lax.scan(step, np.zeros(ts.shape[1:]), ts)
        return acc

    out = {}
    with enable_x64():
        for k, ts in timeseries.items():
            ts = np.asarray(ts)
            if np.issubdtype(ts.dtype, np.integer):
                out[k] = ts.sum(axis=0)
                continue
            out[k] = np.asarray(fold(ts))
    return out


def ratio_table(results: dict[str, "Accumulators"], baseline: str
                ) -> dict[str, dict[str, float]]:
    """Normalize each policy's metrics against ``baseline`` (paper convention:
    StaticHigh = 1.00)."""
    base = results[baseline]
    out = {}
    for name, acc in results.items():
        out[name] = {
            "cpu_payload_ratio": (acc.cpu_payload_mhz_s /
                                  base.cpu_payload_mhz_s
                                  if base.cpu_payload_mhz_s else 0.0),
            "mem_payload_ratio": (acc.mem_payload_mb_s /
                                  base.mem_payload_mb_s
                                  if base.mem_payload_mb_s else 0.0),
            "power_ratio": (acc.energy_j / base.energy_j
                            if base.energy_j else 0.0),
            "vmotions": acc.vmotions,
            "cpu_satisfaction": acc.cpu_satisfaction(),
        }
    return out
