"""Payload / power / migration accounting (paper Tables III-V)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Accumulators:
    cpu_payload_mhz_s: float = 0.0     # useful cycles delivered to VMs
    cpu_demand_mhz_s: float = 0.0      # cycles VMs wanted
    mem_payload_mb_s: float = 0.0
    mem_demand_mb_s: float = 0.0
    energy_j: float = 0.0              # integral of Eq. 1 power
    vmotions: int = 0
    cap_changes: int = 0
    power_ons: int = 0
    power_offs: int = 0
    # Per-VM-tag payload (e.g. "trading" vs "hadoop" in Table V).
    tag_payload: dict = dataclasses.field(default_factory=dict)
    tag_demand: dict = dataclasses.field(default_factory=dict)

    def cpu_satisfaction(self) -> float:
        return (self.cpu_payload_mhz_s / self.cpu_demand_mhz_s
                if self.cpu_demand_mhz_s else 1.0)

    def tag_satisfaction(self, tag: str) -> float:
        d = self.tag_demand.get(tag, 0.0)
        return self.tag_payload.get(tag, 0.0) / d if d else 1.0


def ratio_table(results: dict[str, "Accumulators"], baseline: str
                ) -> dict[str, dict[str, float]]:
    """Normalize each policy's metrics against ``baseline`` (paper convention:
    StaticHigh = 1.00)."""
    base = results[baseline]
    out = {}
    for name, acc in results.items():
        out[name] = {
            "cpu_payload_ratio": (acc.cpu_payload_mhz_s /
                                  base.cpu_payload_mhz_s
                                  if base.cpu_payload_mhz_s else 0.0),
            "mem_payload_ratio": (acc.mem_payload_mb_s /
                                  base.mem_payload_mb_s
                                  if base.mem_payload_mb_s else 0.0),
            "power_ratio": (acc.energy_j / base.energy_j
                            if base.energy_j else 0.0),
            "vmotions": acc.vmotions,
            "cpu_satisfaction": acc.cpu_satisfaction(),
        }
    return out
