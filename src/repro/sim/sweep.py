"""Scenario-sweep harness: programmatic scenario families at cluster scale.

The paper evaluates CloudPowerCap on 3 hosts / 30 VMs; this module
generates whole families of scenarios -- cluster size x rack budget x
spike pattern x host-spec mix x capacity churn x placement rules -- and
runs each policy on the vectorized engine, reporting throughput
(ticks/sec) alongside the paper's payload / power metrics.  It feeds the
``sweep_scale`` / ``sweep_grid`` / ``sweep_grid_dpm`` /
``sweep_grid_rules`` / ``sweep_grid_timed`` / ``sweep_scale_sharded``
benchmark entries (``python -m benchmarks.run``).

Design notes:
  * Migration *search* stays disabled in the cap-only/churn families
    (``max_moves=0``): there the interesting regimes are cap-only
    management and capacity churn (cf. prediction-based oversubscription
    at Azure).  The *rule* families turn the full migration layer on --
    constraint correction plus the hill-climb balancer
    (:data:`RULE_BALANCER`) -- now that it runs as batched kernels
    (``sweep_grid_rules``).
  * Capacity-churn families (``SweepSpec.churn``) exercise the host
    lifecycle: ``dpm`` (a demand valley consolidates and powers a host
    off, a later burst powers it back on with Powercap Redistribution
    funding the cap), ``maintenance`` (a scripted power-off/power-on
    window), and ``failure`` (a scripted power-off that stays down, with
    DPM free to bring capacity back).  Those three run with instantaneous
    migrations; ``timed_churn`` / ``failure_cascade`` rerun the dpm /
    failure scenarios under the *timed* gated vMotion model (copy windows
    of at least one tick, both endpoints charged overhead, per-host slot
    and cluster bandwidth launch limits) with the full migration layer
    on, so deferred moves cascade across invocations -- the
    production-realistic churn regime.  All families replay identically
    on every engine.
  * Scenarios use zero reservations and default shares so admission
    control stays trivial and the sweep isolates powercap behavior.
"""

from __future__ import annotations

import contextlib
import dataclasses
import gc
import os
import tempfile
import time
import warnings
from typing import Optional, Sequence

import numpy as np

from repro.core.manager import CloudPowerCapManager, ManagerConfig
from repro.core.power_model import PAPER_HOST, HostPowerSpec
from repro.drs import balancer as balancer_mod
from repro.drs.rules import AffinityRule, AntiAffinityRule, VMHostRule
from repro.drs.snapshot import ClusterSnapshot, Host, VirtualMachine
from repro.sim.cluster import SimConfig
from repro.sim.experiments import ENGINES, POLICIES
from repro.sim import workloads

# A smaller, less efficient host mixed in for heterogeneous sweeps:
# 8 cores x 2.4 GHz, 64 GB, idle 120 W / peak 240 W.
SMALL_HOST = HostPowerSpec(
    capacity_peak=19_200.0,
    power_idle=120.0,
    power_peak=240.0,
    power_nameplate=300.0,
    memory_mb=64 * 1024,
)

SPIKES = ("flat", "burst", "step", "prime")
CHURNS = ("none", "dpm", "maintenance", "failure", "timed_churn",
          "failure_cascade")
RULESETS = ("none", "violation_burst", "cap_blocked")
TREES = ("none", "two_row")

#: ``two_row`` tree family: row 0 (the first half of the hosts) is limited
#: to this fraction of the rack budget -- below its pro-rata share, so the
#: row limit binds before the rack budget does.  The burst is concentrated
#: on row 0 (see :func:`build_sweep`), so CloudPowerCap must redistribute
#: *within* the binding row; Static strands the capacity.
TWO_ROW_LIMIT_FRAC = 0.45

#: Launch gating for the timed-vMotion churn families: per-host concurrent
#: migration slots and a cluster-wide launches-per-invocation budget.
#: Deferred moves are re-scored at the next invocation (cascading churn).
TIMED_SLOTS_PER_HOST = 2
TIMED_BANDWIDTH = 8

#: The migration balancer used by rule-scenario cells, on every engine (the
#: object manager for vector cells, ``kernels.MigrationParams`` for the
#: batched program); non-rule sweep cells keep migration search disabled.
RULE_BALANCER = balancer_mod.BalancerConfig(max_moves=8)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One cell of the scenario grid."""

    name: str
    n_hosts: int = 10
    vms_per_host: int = 10
    rack_budget_w: Optional[float] = None   # default: 250 W per host
    spike: str = "burst"                    # one of SPIKES
    heterogeneous: bool = False             # mix PAPER_HOST with SMALL_HOST
    churn: str = "none"                     # one of CHURNS
    rules: str = "none"                     # one of RULESETS
    tree: str = "none"                      # one of TREES
    duration_s: float = 1200.0
    tick_s: float = 10.0
    drs_period_s: float = 300.0
    seed: int = 0

    @property
    def budget(self) -> float:
        return (self.rack_budget_w if self.rack_budget_w is not None
                else 250.0 * self.n_hosts)

    @property
    def n_vms(self) -> int:
        return self.n_hosts * self.vms_per_host

    @property
    def dpm_enabled(self) -> bool:
        """Churn families where the manager itself drives the lifecycle."""
        return self.churn in ("dpm", "failure", "timed_churn",
                              "failure_cascade")

    @property
    def timed(self) -> bool:
        """Families running the timed (gated) vMotion execution model:
        migrations occupy a copy window, both endpoints burn overhead, and
        per-host slot / cluster bandwidth limits gate launches."""
        return self.churn in ("timed_churn", "failure_cascade")

    @property
    def migration_enabled(self) -> bool:
        """Rule families run the migration layer (correction + balancer);
        the timed churn families always do -- deferred moves re-scored
        across invocations are the point."""
        return self.rules != "none" or self.timed


def _specs_for(spec: SweepSpec) -> list[HostPowerSpec]:
    if not spec.heterogeneous:
        return [PAPER_HOST] * spec.n_hosts
    return [PAPER_HOST if i % 2 == 0 else SMALL_HOST
            for i in range(spec.n_hosts)]


def _sweep_traces(spec: SweepSpec, base: np.ndarray, hot_host: np.ndarray,
                  phase_frac: np.ndarray, n_on: int,
                  vm_ids: Sequence[str]) -> dict:
    """Vectorized demand-trace construction for one cell.

    Builds the whole cluster's ``(t0, cpu, mem)`` segment table as array
    ops and hands it to :func:`workloads.traces_from_table` -- per-VM
    factory calls dominated end-to-end cell construction at sweep scale.
    Values are IEEE-identical to the scalar factories the loop used to
    call.
    """
    n, d = spec.n_vms, spec.duration_s
    mem = 2 * 1024.0
    segs = np.zeros((n, 3, 3))
    segs[:, :, 2] = mem
    segs[:, 0, 1] = base
    counts = np.ones(n, dtype=np.int64)
    periods = np.full(n, np.inf)
    if spec.churn in ("dpm", "timed_churn"):
        # Valley-then-burst: the middle third idles the cluster into
        # DPM's power-off band; the final third runs hot enough to trip
        # the power-on trigger, so Powercap Redistribution must free a
        # consolidating host's budget and later fund its return.
        counts[:] = 3
        segs[:, 1, 0] = d / 3.0
        segs[:, 1, 1] = 0.2 * base
        segs[:, 2, 0] = 2.0 * d / 3.0
        segs[:, 2, 1] = 2.2 * base + 1500.0
    elif spec.spike == "flat":
        pass
    elif spec.spike == "burst":
        # VMs on ~20% of hosts spike >2x in the middle third of the run.
        hot = hot_host[np.arange(n) % n_on]
        counts[hot] = 3
        segs[hot, 1, 0] = d / 3.0
        segs[hot, 1, 1] = 2.0 * base[hot] + 1200.0
        segs[hot, 2, 0] = 2.0 * d / 3.0
        segs[hot, 2, 1] = base[hot]
    elif spec.spike == "step":
        # Cluster-wide step down then back up (standby-style).
        counts[:] = 3
        segs[:, 1, 0] = d / 3.0
        segs[:, 1, 1] = base / 3.0
        segs[:, 2, 0] = 2.0 * d / 3.0
        segs[:, 2, 1] = base
    else:  # prime: periodic off/prime/off window, phase drawn per VM
        periods[:] = d
        off, prime = 0.3 * base, 2.2 * base
        counts[:] = 3
        segs[:, 0, 1] = off
        segs[:, 1, 0] = phase_frac * d
        segs[:, 1, 1] = prime
        segs[:, 2, 0] = (phase_frac + 0.4) * d
        segs[:, 2, 1] = off
        z = phase_frac <= 0.0        # measure-zero draw: window opens at 0
        if z.any():
            counts[z] = 2
            segs[z, 0, 1] = prime[z]
            segs[z, 1, 0] = (phase_frac[z] + 0.4) * d
            segs[z, 1, 1] = off[z]
    return workloads.traces_from_table(vm_ids, segs, counts, periods)


def build_sweep(spec: SweepSpec, policy: str,
                trace_memo: Optional[dict] = None,
                vm_memo: Optional[dict] = None
                ) -> tuple[ClusterSnapshot, dict, SimConfig]:
    """Materialize one (spec, policy) cell.

    Deployment mirrors paper Table II: ``cpc``/``static`` spread the rack
    budget across every host; ``statichigh`` runs fewer hosts at their
    physical peak (the rest stay in standby with a zero cap).

    ``trace_memo`` (scoped to one spec) shares the trace dict across the
    policies whose deployment yields the same powered-on host count -- the
    only placement fact the trace draw depends on -- so ``cpc``/``static``
    build the cluster's traces once between them.

    ``vm_memo`` (scoped to one grid) shares the ``VirtualMachine`` list
    across every cell with the same (VM count, powered-on host sequence)
    -- the only facts the list depends on -- so a whole grid builds its
    VM objects once.  Callers passing it promise the returned snapshot is
    treated read-only (true for the batched engine, which only packs);
    cells that customize VMs (the ``cap_blocked`` reservations) replace
    the affected entries copy-on-write instead of mutating.
    """
    if spec.spike not in SPIKES:
        raise ValueError(f"unknown spike pattern {spec.spike!r}")
    if spec.churn not in CHURNS:
        raise ValueError(f"unknown churn family {spec.churn!r}")
    if spec.rules not in RULESETS:
        raise ValueError(f"unknown rule family {spec.rules!r}")
    if spec.tree not in TREES:
        raise ValueError(f"unknown tree family {spec.tree!r}")
    host_specs = _specs_for(spec)
    budget = spec.budget
    total_peak = sum(s.power_peak for s in host_specs)

    hosts: list[Host] = []
    if policy == "statichigh":
        # Peak caps until the budget is exhausted.
        spent = 0.0
        for i, s in enumerate(host_specs):
            on = spent + s.power_peak <= budget + 1e-9
            hosts.append(Host(host_id=f"host{i}", spec=s,
                              power_cap=s.power_peak if on else 0.0,
                              powered_on=on))
            if on:
                spent += s.power_peak
    else:
        # Budget split pro-rata by peak power (uniform for homogeneous).
        for i, s in enumerate(host_specs):
            cap = budget * s.power_peak / total_peak
            hosts.append(Host(host_id=f"host{i}", spec=s,
                              power_cap=min(cap, s.power_peak)))
    on_hosts = [h.host_id for h in hosts if h.powered_on]
    if not on_hosts:
        raise ValueError("budget too small: no host can power on")

    rng = np.random.RandomState(spec.seed)
    base = rng.uniform(600.0, 1400.0, size=spec.n_vms)
    # Bursts are host-correlated (like the paper's headroom scenario): every
    # VM on a "hot" host spikes together, so static caps actually strand
    # capacity and the policies separate.
    hot_host = rng.rand(spec.n_hosts) < 0.2
    phase_frac = rng.uniform(0.0, 0.5, size=spec.n_vms)
    if spec.tree == "two_row":
        # Concentrate the burst on row 0 so its limit is what binds (the
        # random draws above still happen, keeping the stream identical
        # for tree-less specs with the same seed).
        hot_host = np.zeros(spec.n_hosts, dtype=bool)
        hot_host[:max(spec.n_hosts // 4, 1)] = True

    n_on = len(on_hosts)
    vm_key = (spec.n_vms, tuple(on_hosts))
    vms = None if vm_memo is None else vm_memo.get(vm_key)
    if vms is None:
        vms = [VirtualMachine(vm_id=f"vm{v}", vcpus=1, memory_mb=8 * 1024,
                              host_id=on_hosts[v % n_on])
               for v in range(spec.n_vms)]
        if vm_memo is not None:
            vm_memo[vm_key] = vms
    if trace_memo is not None and n_on in trace_memo:
        traces = trace_memo[n_on]
    else:
        traces = _sweep_traces(spec, base, hot_host, phase_frac, n_on,
                               [vm.vm_id for vm in vms])
        if trace_memo is not None:
            trace_memo[n_on] = traces

    rules: list = []
    if spec.rules != "none":
        on_count = len(on_hosts)
        if on_count < 4:
            raise ValueError("rule families need >= 4 powered-on hosts")
        if spec.rules == "violation_burst":
            # A burst of corrections for the first DRS invocation: two
            # affinity groups split across hosts, two anti-affinity pairs
            # co-placed, two VMs parked off their allowed hosts.
            rules = [
                AffinityRule(("vm0", "vm1")),
                AffinityRule(("vm2", "vm3")),
                AntiAffinityRule(("vm4", f"vm{4 + on_count}")),
                AntiAffinityRule(("vm5", f"vm{5 + on_count}")),
                VMHostRule("vm6", frozenset(
                    {on_hosts[7 % on_count], on_hosts[8 % on_count]})),
                VMHostRule("vm7", frozenset(
                    {on_hosts[8 % on_count], on_hosts[9 % on_count]})),
            ]
        else:  # cap_blocked -- paper Fig. 1a at sweep scale
            # Affinity correction whose fit only passes when the check
            # sees *fundable* capacity: the anchor host must reach beyond
            # its current cap (CloudPowerCap corrects; Static cannot).
            anchor, mover = "vm2", "vm0"
            filler = f"vm{on_count}"            # second VM on host 0
            overrides = {anchor: 14_000.0, mover: 6_000.0,
                         filler: 12_000.0}
            if vm_memo is None:
                vm_by_id = {v.vm_id: v for v in vms}
                for vid, res in overrides.items():
                    vm_by_id[vid].reservation = res
            else:
                # The memoized list is shared across cells: replace the
                # customized VMs copy-on-write, never mutate in place.
                vms = [dataclasses.replace(v, reservation=overrides[v.vm_id])
                       if v.vm_id in overrides else v for v in vms]
            rules = [AffinityRule((mover, anchor))]
    tree = None
    if spec.tree == "two_row":
        from repro.core.budget_tree import BudgetTree
        tree = BudgetTree.two_rows(budget, spec.n_hosts,
                                   row0_limit=TWO_ROW_LIMIT_FRAC * budget)
        # Deployment must respect the tree from t=0: scale each binding
        # row's initial caps down to its limit (zero floors -- sweep VMs
        # carry no reservations).
        caps = np.array([h.power_cap for h in hosts])
        on_mask = np.array([h.powered_on for h in hosts])
        caps = tree.project(caps, on_mask, floors=np.zeros(spec.n_hosts))
        for h, cap in zip(hosts, caps):
            h.power_cap = float(cap)
    snap = ClusterSnapshot(hosts, vms, power_budget=budget, rules=rules,
                           budget_tree=tree)
    power_events: tuple = ()
    if spec.churn == "maintenance":
        # One powered-on host leaves for the middle third and returns.
        power_events = ((spec.duration_s / 3.0, on_hosts[0], False),
                        (2.0 * spec.duration_s / 3.0, on_hosts[0], True))
    elif spec.churn in ("failure", "failure_cascade"):
        # Abrupt capacity loss at mid-run; DPM may repair it.  In the
        # cascade family the repair happens under timed gated migrations,
        # so the rebalancing churn spreads across invocations.
        power_events = ((spec.duration_s / 2.0, on_hosts[0], False),)
    cfg = SimConfig(duration_s=spec.duration_s, tick_s=spec.tick_s,
                    drs_period_s=spec.drs_period_s,
                    drs_first_at_s=spec.drs_period_s,
                    record_timeline=False,
                    instant_migrations=((spec.dpm_enabled
                                         or spec.migration_enabled)
                                        and not spec.timed),
                    migration_slots_per_host=(TIMED_SLOTS_PER_HOST
                                              if spec.timed else None),
                    migration_bandwidth=(TIMED_BANDWIDTH
                                         if spec.timed else None),
                    power_events=power_events)
    return snap, traces, cfg


def _sweep_manager(policy: str,
                   spec: Optional[SweepSpec] = None) -> CloudPowerCapManager:
    cfg = ManagerConfig(powercap_enabled=(policy == "cpc"),
                        dpm_enabled=bool(spec and spec.dpm_enabled))
    if spec is not None and spec.migration_enabled:
        # Rule families exercise the full migration layer: constraint
        # correction plus the hill-climb balancer.
        cfg.balancer = dataclasses.replace(RULE_BALANCER)
    else:
        # No migration *search* at scale (see module note); DPM's targeted
        # evacuations still run for the churn families.
        cfg.balancer = balancer_mod.BalancerConfig(max_moves=0)
    return CloudPowerCapManager(cfg)


@dataclasses.dataclass
class SweepCellResult:
    spec: SweepSpec
    policy: str
    wall_s: float                # batch engine: share of the batch's wall
    ticks: int
    ticks_per_s: float
    cpu_satisfaction: float
    cpu_payload_mhz_s: float
    energy_j: float
    cap_changes: int
    vmotions: int
    power_ons: int = 0
    power_offs: int = 0


def run_cell(spec: SweepSpec, policy: str,
             engine: str = "vector") -> SweepCellResult:
    snap, traces, cfg = build_sweep(spec, policy)
    manager = _sweep_manager(policy, spec)
    sim = ENGINES[engine](snap, manager, traces, cfg)
    t0 = time.perf_counter()
    result = sim.run()
    wall = time.perf_counter() - t0
    ticks = int(round(cfg.duration_s / cfg.tick_s))
    acc = result.acc
    return SweepCellResult(
        spec=spec, policy=policy, wall_s=wall, ticks=ticks,
        ticks_per_s=ticks / max(wall, 1e-9),
        cpu_satisfaction=acc.cpu_satisfaction(),
        cpu_payload_mhz_s=acc.cpu_payload_mhz_s,
        energy_j=acc.energy_j,
        cap_changes=acc.cap_changes,
        vmotions=acc.vmotions,
        power_ons=acc.power_ons,
        power_offs=acc.power_offs)


def _grid_balancer(specs: Sequence[SweepSpec]):
    """The batched engine's MigrationParams when any spec runs migrations."""
    if any(s.migration_enabled for s in specs):
        return RULE_BALANCER.params()
    return None


_CACHE_STATE: dict = {"enabled": False, "path": None}


def enable_compilation_cache(path: Optional[str] = None) -> Optional[str]:
    """Best-effort enable of jax's persistent compilation cache.

    Re-invoking the same grid shapes previously paid the full XLA compile
    every process (the rules grid alone costs ~14 s); with the cache on,
    a warm re-invocation only pays trace + executable load.  The directory
    is ``REPRO_JAX_CACHE_DIR`` when set (set it to the empty string to
    disable), else a per-user directory under the system temp dir.
    Returns the cache path, or ``None`` when disabled/unsupported.
    """
    if _CACHE_STATE["enabled"]:
        return _CACHE_STATE["path"]
    env = os.environ.get("REPRO_JAX_CACHE_DIR")
    if env == "":
        return None
    import jax
    path = path or env or os.path.join(
        tempfile.gettempdir(), f"repro-jax-cache-{os.getuid()}")
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        # Sweep programs are small but slow to build: cache everything.
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:                        # older jax without the knobs
        return None
    _CACHE_STATE.update(enabled=True, path=path)
    return path


#: Per-bucket records from the most recent batched ``run_sweep`` /
#: ``run_sweep_batched`` call: shape class, cell count, mesh size, and the
#: split timing -- ``compile_s`` (AOT compile wall for never-seen program
#: shapes, ~0 on a warm in-process or persistent cache), ``pack_s``
#: (host-side array packing), ``run_s`` (dispatch-to-harvest device wall),
#: and ``wall_s`` (compile + run, the old whole-call meaning).  Benchmarks
#: read it to report the cost split per bucket.
LAST_BATCH_INFO: list = []

#: Worker threads for the overlapped pipeline: bucket N+1 packs and
#: AOT-compiles while bucket N executes on the device.
_PIPELINE_WORKERS = 4


def _pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


def _bucket_key(cell) -> tuple[int, int]:
    """Pow2-padded shape class of one cell: (hosts, max VMs on one host).

    Mirrors the CSR/pow2-pad approach of the segmented Pallas kernel: cells
    pack to their class bounds instead of the global grid max, so a mixed
    10/100/1000-host grid compiles a few small programs rather than padding
    every cell to 1000 hosts, and recompiles only happen on doublings.
    """
    counts: dict[str, int] = {}
    for v in cell.snapshot.vms.values():
        counts[v.host_id] = counts.get(v.host_id, 0) + 1
    return (_pow2(len(cell.snapshot.hosts)),
            _pow2(max(counts.values(), default=1)))


def _harvest_order(n: int) -> Sequence[int]:
    """Order in which the pipeline harvests its dispatched buckets (indices
    into the bucket list).  Results are keyed per cell and re-assembled in
    specs x policies order afterwards, so *any* order yields the same grid;
    tests monkeypatch this to shuffle completion and prove it."""
    return range(n)


def _cell_results(res, keys) -> dict:
    """{(spec.name, policy): SweepCellResult} for one bucket's BatchResult.

    Device wall (``run_s``, excluding compile) is attributed evenly:
    per-cell ``wall_s`` is ``run_s / n_cells``, so ``ticks_per_s`` reads as
    aggregate throughput."""
    per_cell_wall = max(res.run_s, 1e-9) / len(keys)
    out = {}
    for i, (spec, p) in enumerate(keys):
        acc = res.accumulators(i)
        out[(spec.name, p)] = SweepCellResult(
            spec=spec, policy=p, wall_s=per_cell_wall, ticks=res.ticks,
            ticks_per_s=res.ticks / per_cell_wall,
            cpu_satisfaction=acc.cpu_satisfaction(),
            cpu_payload_mhz_s=acc.cpu_payload_mhz_s,
            energy_j=acc.energy_j,
            cap_changes=acc.cap_changes,
            vmotions=acc.vmotions,
            power_ons=acc.power_ons,
            power_offs=acc.power_offs)
    return out


def _run_pipeline(buckets, n_devices: Optional[int] = None,
                  slot_slack: float = 3.0) -> dict:
    """Overlapped execution of prepared buckets; the device never waits on
    the host.

    ``buckets`` is a list of ``(pad_hosts, pad_slots, cells, keys,
    balancer)`` work items.  A worker pool packs every bucket's arrays and
    AOT-compiles its shape class concurrently (``BatchedSimulator`` +
    ``compile()``); the main thread dispatches each bucket asynchronously
    the moment it is ready (``run_async`` -- no ``block_until_ready``
    between buckets), so while one bucket executes the next is already
    packing.  Results are harvested only at the end (in
    :func:`_harvest_order`), merged into the flat ``{(spec.name, policy):
    result}`` map, and one record per bucket lands in
    :data:`LAST_BATCH_INFO` in bucket order.
    """
    from concurrent.futures import ThreadPoolExecutor, as_completed

    from repro.sim.batch import BatchedSimulator

    enable_compilation_cache()

    def build(i):
        hp, jp, cells, _, balancer = buckets[i]
        sim = BatchedSimulator(cells, slot_slack=slot_slack,
                               balancer=balancer, n_devices=n_devices,
                               pad_hosts=hp, pad_slots=jp)
        sim.compile()
        return i, sim

    pendings = [None] * len(buckets)
    with ThreadPoolExecutor(
            max_workers=min(len(buckets), _PIPELINE_WORKERS)) as pool:
        futs = [pool.submit(build, i) for i in range(len(buckets))]
        for fut in as_completed(futs):
            i, sim = fut.result()
            pendings[i] = sim.run_async()
    flat: dict = {}
    infos = [None] * len(buckets)
    for i in _harvest_order(len(buckets)):
        res = pendings[i].result()
        hp, jp, cells, keys, _ = buckets[i]
        infos[i] = {
            "bucket": (hp or None, jp or None),
            "n_cells": len(cells),
            "n_devices": res.n_devices,
            "compile_s": res.compile_s,
            "pack_s": res.pack_s,
            "run_s": res.run_s,
            "wall_s": res.wall_s,
        }
        flat.update(_cell_results(res, keys))
    LAST_BATCH_INFO.extend(infos)
    return flat


def _run_buckets(cells, keys, n_devices: Optional[int] = None,
                 slot_slack: float = 3.0) -> dict:
    """Pad-bucket partitioner: group cells into pow2 (H, J) shape classes,
    one compiled program per bucket, each bucket's cells axis sharded over
    the device mesh, all buckets overlapped through the pipeline.  Returns
    the flat {(spec.name, policy): result} map."""
    by_bucket: dict[tuple[int, int], list] = {}
    for c, k in zip(cells, keys):
        by_bucket.setdefault(_bucket_key(c), []).append((c, k))
    work = []
    for (hp, jp), pairs in sorted(by_bucket.items()):
        bspecs = list(dict.fromkeys(k[0] for _, k in pairs))
        work.append((hp, jp, [c for c, _ in pairs], [k for _, k in pairs],
                     _grid_balancer(bspecs)))
    return _run_pipeline(work, n_devices=n_devices, slot_slack=slot_slack)


def _same_trace_specs(a: dict, b: dict, vm_ids: Sequence[str]) -> bool:
    """True when two trace dicts compile to the identical ``TraceBank``:
    every VM traced in both with structurally equal declarative specs
    (``TraceSpec`` is a frozen dataclass).  Hand-written callables have no
    spec and are never considered shareable."""
    if a is b:                    # memoized across policies by build_sweep
        return True
    for vid in vm_ids:
        sa = getattr(a.get(vid), "spec", None)
        sb = getattr(b.get(vid), "spec", None)
        if sa is None or sa != sb:
            return False
    return True


@contextlib.contextmanager
def _gc_pause():
    """Suspend cyclic garbage collection for a bounded construction phase.

    Building a grid's cells allocates tens of thousands of long-lived
    objects in one burst (VM dataclasses, trace closures, segment
    tuples); the allocation spike trips repeated full collections that
    rescan the entire heap -- jax's module graph included -- without ever
    finding reclaimable cycles, and those scans dominated end-to-end
    sweep wall time.  Collection resumes (if it was on) when the phase
    ends; nothing built here is cyclic garbage."""
    was = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was:
            gc.enable()


def _build_batch_cells(specs: Sequence[SweepSpec],
                       policies: Sequence[str]):
    """Materialize the grid's cells, packing each spec's ``TraceBank`` once.

    Policies of one spec usually share identical traces (`cpc`/`static`
    always do; `statichigh` differs only when the trace draw depends on the
    powered-on host count), so the bank -- the per-VM step-function
    compilation that dominated per-cell host-side packing -- is built for
    the first policy and reused wherever the specs compare equal, across
    policies and whatever pad bucket the cell later lands in.

    Construction itself is shared at two further levels, legal because
    the batched engine treats cell snapshots as read-only pack sources:
    ``policy`` only influences deployment through the ``statichigh``
    branch, so every spread-deployment policy (`cpc`/`static`) of one
    spec reuses a single ``build_sweep`` result (one snapshot, one trace
    dict, one bank for two cells), and a grid-wide ``vm_memo`` shares the
    ``VirtualMachine`` list across all cells with the same (VM count,
    powered-on hosts) -- host-side scenario construction sits on the
    end-to-end critical path the ``sweep_e2e`` bench clocks.
    """
    from repro.sim.batch import BatchCell
    from repro.sim.workloads import TraceBank
    cells, keys = [], []
    vm_memo: dict = {}
    with _gc_pause():
        for spec in specs:
            bank, bank_traces = None, None
            memo: dict = {}
            built: dict = {}            # deployment class -> build_sweep()
            for p in policies:
                dep = "statichigh" if p == "statichigh" else "spread"
                if dep not in built:
                    built[dep] = build_sweep(spec, p, trace_memo=memo,
                                             vm_memo=vm_memo)
                snap, traces, cfg = built[dep]
                vm_ids = list(snap.vms)
                if (bank is None or bank.vm_order != vm_ids
                        or not _same_trace_specs(bank_traces, traces,
                                                 vm_ids)):
                    bank = TraceBank.from_traces(traces, vm_ids)
                    bank_traces = traces
                cells.append(BatchCell(
                    name=f"{spec.name}/{p}", snapshot=snap, traces=traces,
                    config=cfg, powercap_enabled=(p == "cpc"),
                    dpm_enabled=spec.dpm_enabled,
                    balancer_enabled=spec.migration_enabled,
                    trace_bank=bank))
                keys.append((spec, p))
    return cells, keys


def run_sweep(specs: Sequence[SweepSpec],
              policies: Sequence[str] = POLICIES,
              engine: str = "vector",
              on_unsupported: str = "raise",
              n_devices: Optional[int] = None
              ) -> dict[str, dict[str, SweepCellResult]]:
    """Run the grid; returns results[spec.name][policy].

    ``engine="batch"`` routes the grid through the jit-compiled
    :class:`repro.sim.batch.BatchedSimulator` instead of cell-at-a-time
    Python execution.  Cells are first grouped into pow2-padded ``(hosts,
    VMs/host)`` shape classes (*pad buckets*): one compiled program per
    bucket, each sharded over the ``("cells",)`` device mesh, so a mixed
    10/100/1000-host grid neither pads every cell to the global max nor
    recompiles per unique size.  ``n_devices=None`` shards over every
    visible device; pass 1 to force single-device execution.

    A grid with cells requesting a regime the batched engine cannot replay
    exactly raises :class:`repro.sim.batch.BatchUnsupported` (the
    default); with ``on_unsupported="fallback"`` the grid is
    *partitioned* instead -- the supported cells run batched, only the
    offending cells (named in the warning) run on the sequential
    ``VectorSimulator``, and the results are merged -- never silently
    freezing the unsupported dimension.  Merged results always follow the
    input ``specs`` x ``policies`` order, whatever the partitioning.
    """
    if engine == "batch":
        from repro.sim.batch import BatchedSimulator, BatchUnsupported
        LAST_BATCH_INFO.clear()
        cells, keys = _build_batch_cells(specs, policies)
        reasons = BatchedSimulator.unsupported_cells(
            cells, _grid_balancer(specs))
        if reasons and on_unsupported != "fallback":
            # Probe the whole grid up front: bucketing could otherwise
            # mask e.g. a time-grid mismatch by splitting the disagreeing
            # cells into different buckets.
            name, why = min(reasons.items())
            raise BatchUnsupported(f"cell {name!r}: {why}")
        if reasons:
            warnings.warn(
                "batched engine cannot run cells "
                f"{sorted(reasons)[:5]}{'...' if len(reasons) > 5 else ''} "
                f"({next(iter(reasons.values()))}); running those on the "
                "sequential vector engine and batching the rest",
                RuntimeWarning, stacklevel=2)
        good = [(c, k) for c, k in zip(cells, keys)
                if f"{k[0].name}/{k[1]}" not in reasons]
        flat = (_run_buckets([c for c, _ in good], [k for _, k in good],
                             n_devices=n_devices)
                if good else {})
        out: dict[str, dict[str, SweepCellResult]] = {}
        for spec in specs:
            out[spec.name] = {
                p: flat.get((spec.name, p))
                or run_cell(spec, p, engine="vector")
                for p in policies}
        return out
    out = {}
    for spec in specs:
        out[spec.name] = {p: run_cell(spec, p, engine=engine)
                          for p in policies}
    return out


def run_sweep_batched(specs: Sequence[SweepSpec],
                      policies: Sequence[str] = POLICIES,
                      slot_slack: float = 3.0,
                      _prebuilt=None,
                      n_devices: Optional[int] = None
                      ) -> dict[str, dict[str, SweepCellResult]]:
    """One jitted program over the whole (spec x policy) grid.

    All specs must share ``duration_s``/``tick_s``/``drs_period_s`` (true
    for :func:`scenario_families` grids); cluster size, budget, spike
    family, host mix, churn family, rule family, and policy vary per cell.
    Unlike :func:`run_sweep`'s bucketed batch path, cells pack exactly to
    the grid max ``(H, J)`` (no pow2 padding) -- the predictable shape the
    committed benchmark baselines were measured against.  The cells axis is
    still sharded over ``n_devices`` (default: all visible devices).
    """
    # ``_prebuilt`` lets callers hand over a grid they already constructed
    # instead of rebuilding every cell.
    cells, keys = _prebuilt or _build_batch_cells(specs, policies)
    LAST_BATCH_INFO.clear()
    flat = _run_pipeline([(0, 0, cells, keys, _grid_balancer(specs))],
                         n_devices=n_devices, slot_slack=slot_slack)
    out: dict[str, dict[str, SweepCellResult]] = {}
    for spec, p in keys:
        out.setdefault(spec.name, {})[p] = flat[(spec.name, p)]
    return out


def scenario_families(sizes: Sequence[int] = (10, 100, 1000),
                      budgets_per_host_w: Sequence[float] = (250.0,),
                      spikes: Sequence[str] = ("burst", "prime"),
                      heterogeneous: Sequence[bool] = (False, True),
                      churns: Sequence[str] = ("none",),
                      rules: Sequence[str] = ("none",),
                      duration_s: float = 1200.0,
                      tick_s: float = 10.0) -> list[SweepSpec]:
    """The full grid: size x budget x spike x host mix x churn x rules."""
    specs = []
    for n in sizes:
        for b in budgets_per_host_w:
            for spike in spikes:
                for het in heterogeneous:
                    for churn in churns:
                        for rule in rules:
                            name = (f"h{n}_b{int(b)}w_{spike}"
                                    f"{'_het' if het else ''}"
                                    f"{'' if churn == 'none' else '_' + churn}"
                                    f"{'' if rule == 'none' else '_' + rule}")
                            specs.append(SweepSpec(
                                name=name, n_hosts=n, rack_budget_w=b * n,
                                spike=spike, heterogeneous=het, churn=churn,
                                rules=rule, duration_s=duration_s,
                                tick_s=tick_s))
    return specs


def row_contention_specs(sizes: Sequence[int] = (10, 100),
                         duration_s: float = 1200.0,
                         tick_s: float = 10.0) -> list[SweepSpec]:
    """The ``two_row`` budget-tree family: a row limit binds before the
    rack budget does (burst concentrated on row 0), in the cap-only
    management regime -- the grid where CloudPowerCap's tree-aware
    redistribution separates from Static within a row."""
    return [SweepSpec(name=f"h{n}_row_contention", n_hosts=n,
                      spike="burst", tree="two_row",
                      duration_s=duration_s, tick_s=tick_s)
            for n in sizes]


def scale_ladder(sizes: Sequence[int] = (10, 100, 1000),
                 spike: str = "burst",
                 duration_s: float = 600.0,
                 tick_s: float = 10.0) -> list[SweepSpec]:
    """The ``sweep_scale`` benchmark ladder: one spike family per size."""
    return [SweepSpec(name=f"h{n}_{spike}", n_hosts=n, spike=spike,
                      duration_s=duration_s, tick_s=tick_s)
            for n in sizes]
