"""Simulator core: tick-driven execution of the DRS + CloudPowerCap pipeline.

Mirrors the role of the DRS simulator in the paper's evaluation (Sec. V-A):
ESX-like host scheduling (waterfill delivery bounded by power-capped
capacity), vMotion with copy duration proportional to VM memory plus CPU
overhead on both endpoints, DPM power-on/off latencies, and Eq. 1 power
accounting.

This is the per-object *reference* engine; ``repro.sim.engine`` subclasses
it with the per-tick hot path vectorized (see ``docs/ARCHITECTURE.md``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.drs.entitlement import deliver
from repro.drs.snapshot import ClusterSnapshot
from repro.sim.metrics import Accumulators
from repro.sim.workloads import DemandTrace


@dataclasses.dataclass
class SimConfig:
    duration_s: float = 2100.0
    tick_s: float = 10.0
    drs_period_s: float = 300.0
    drs_first_at_s: float = 300.0
    vmotion_rate_mb_s: float = 128.0      # effective copy rate incl. recopy
    vmotion_overhead_mhz: float = 1500.0  # burned on src AND dst during copy
    max_concurrent_migrations: int = 4
    power_on_latency_s: float = 120.0
    power_off_latency_s: float = 30.0
    record_timeline: bool = True
    # Migrations complete at the tick they start, with no copy window and no
    # vMotion CPU overhead.  This is the capacity-churn regime the batched
    # engine models (evacuation as an atomic slot remap); enabling it here
    # keeps the object/vector engines on the identical protocol.
    instant_migrations: bool = False
    # Scripted host lifecycle events -- ((t_s, host_id, powered_on), ...) --
    # applied at the first tick with t >= t_s: maintenance windows, host
    # failures, capacity arriving.  External to the manager: no actions are
    # emitted and no budget is redistributed until the next DRS invocation
    # reacts to the new powered-on capacity.
    power_events: tuple = ()
    # Per-invocation migration-launch gates (None = ungated, 0 = none):
    # a host may be an endpoint of at most migration_slots_per_host
    # correction/balancer launches per manager invocation, and the cluster
    # at most migration_bandwidth in total.  Gated moves are simply not
    # emitted -- the manager re-scores them next invocation (cascading
    # churn).  Evacuations are exempt (power-off is all-or-nothing).  In
    # the gated regime every emitted migration starts at its invocation
    # tick (the launch gate replaces the runtime concurrency gate) and
    # migrations complete in emission order (FIFO), which is what lets the
    # batched engine replay the protocol as scan state bit-identically.
    migration_slots_per_host: Optional[int] = None
    migration_bandwidth: Optional[int] = None

    @property
    def migration_gated(self) -> bool:
        return (self.migration_slots_per_host is not None
                or self.migration_bandwidth is not None)

    @property
    def migration_limits(self):
        """The kernel layer's static twin of the launch gates (or None)."""
        if not self.migration_gated:
            return None
        from repro.core.kernels import MigrationLimits
        return MigrationLimits(slots_per_host=self.migration_slots_per_host,
                               bandwidth=self.migration_bandwidth)


@dataclasses.dataclass
class SimResult:
    acc: Accumulators
    timeline: list                         # (t, {host: (cap_w, util, n_vms)})
    events: list                           # (t, str)
    final: ClusterSnapshot
    window_acc: Optional[Accumulators] = None


class _Pending:
    def __init__(self, action):
        self.action = action
        self.state = "waiting"             # waiting | running | done
        self.end_time = 0.0


class Simulator:
    def __init__(self, snapshot: ClusterSnapshot, manager,
                 traces: dict[str, DemandTrace],
                 config: Optional[SimConfig] = None,
                 window: Optional[tuple[float, float]] = None):
        self.live = snapshot
        self.manager = manager
        self.traces = traces
        self.config = config or SimConfig()
        self.window = window               # optional payload sub-window
        self.acc = Accumulators()
        self.window_acc = Accumulators() if window else None
        self.pending: list[_Pending] = []
        self.done_ids: set[int] = set()
        self.low_since: dict[str, float] = {}
        self.last_config_change = -1e18
        self.timeline: list = []
        self.events: list = []
        self._power_events = sorted(self.config.power_events)
        self._next_power_event = 0
        # Bumped whenever executed actions mutate placement, power state, or
        # caps; array-backed subclasses use it to refresh their columns.
        self._topology_version = 0

    # ------------------------------------------------------------------
    def _update_demands(self, t: float) -> None:
        for vm_id, trace in self.traces.items():
            cpu, mem = trace(t)
            vm = self.live.vms[vm_id]
            vm.demand, vm.mem_demand = cpu, mem
        # Demand edits bypass move_vm: drop the cached per-host sums.
        self.live.invalidate_host_sums()

    def _migration_duration(self, vm) -> float:
        mb = max(vm.mem_demand, 64.0)
        return max(mb / self.config.vmotion_rate_mb_s, self.config.tick_s)

    def _apply_power_events(self, t: float) -> None:
        """Scripted host lifecycle: external power state flips at their
        scheduled tick (failures, maintenance).  Counts as a configuration
        change for DPM's stability window, like any power action."""
        while (self._next_power_event < len(self._power_events)
               and self._power_events[self._next_power_event][0] <= t):
            _, host_id, on = self._power_events[self._next_power_event]
            self._next_power_event += 1
            host = self.live.hosts[host_id]
            if host.powered_on != bool(on):
                if on:
                    # A returning host boots with at most the unallocated
                    # budget as its cap (the manager may have reabsorbed
                    # its watts while it was away); the next DRS redivvy
                    # funds its reserved floor.  Grants held by hosts with
                    # a power-on still in flight count as allocated, like
                    # the budget invariant counts them.
                    total = sum(h.power_cap
                                for h in self.live.powered_on_hosts())
                    allocated = {h.host_id
                                 for h in self.live.powered_on_hosts()}
                    for p in self.pending:
                        if p.action.kind == "power_on" and \
                                p.state in ("waiting", "running"):
                            tgt = self.live.hosts[p.action.target]
                            if not tgt.powered_on:
                                total += tgt.power_cap
                                allocated.add(tgt.host_id)
                    host.power_cap = min(
                        host.power_cap,
                        max(self.live.power_budget - total, 0.0))
                    tree = self.live.effective_tree()
                    if tree is not None:
                        # The returning host's cap must also fit under
                        # every limit on its root path, with pending
                        # power-on grants counted as allocated.
                        ids = list(self.live.hosts)
                        caps = np.array(
                            [self.live.hosts[h].power_cap for h in ids])
                        mask = np.array([h in allocated for h in ids])
                        slack = tree.host_slack(caps, mask)
                        host.power_cap = min(
                            host.power_cap,
                            max(float(slack[ids.index(host_id)]), 0.0))
                host.powered_on = bool(on)
                self._topology_version += 1
                self.last_config_change = t
                self.events.append(
                    (t, f"power_event {host_id} "
                        f"{'on' if on else 'off'}"))

    def _prereqs_done(self, p: _Pending) -> bool:
        return all(pid in self.done_ids for pid in p.action.prereqs)

    def _running_migrations(self) -> list:
        return [p for p in self.pending
                if p.state == "running" and p.action.kind == "migrate"]

    def _host_migration_overhead(self, host_id: str) -> float:
        n = 0
        for p in self._running_migrations():
            vm = self.live.vms[p.action.target]
            if vm.host_id == host_id or p.action.dest == host_id:
                n += 1
        return n * self.config.vmotion_overhead_mhz

    # ------------------------------------------------------------------
    def _complete_actions(self, t: float) -> None:
        # Gated regime: migrations drain FIFO in emission order -- a
        # migration may not complete before every migration emitted ahead
        # of it has, so its effective end is the running max of end times.
        # This is the discipline the batched engine replays as scan state
        # (commits in table order), keeping the planes bit-identical.
        fifo = self.config.migration_gated
        mig_block = False
        for p in self.pending:
            if p.state != "running":
                continue
            if p.action.kind == "migrate" and fifo:
                if mig_block or p.end_time > t:
                    mig_block = True
                    continue
            elif p.end_time > t:
                continue
            a = p.action
            if a.kind == "migrate":
                self.live.move_vm(a.target, a.dest)
                self._topology_version += 1
                self.acc.vmotions += 1
                if self.window_acc is not None and self._in_window(t):
                    self.window_acc.vmotions += 1
            elif a.kind == "power_on":
                self.live.hosts[a.target].powered_on = True
                self._topology_version += 1
                self.acc.power_ons += 1
                self.last_config_change = t
                self.events.append((t, f"power_on {a.target}"))
            elif a.kind == "power_off":
                self.live.hosts[a.target].powered_on = False
                self._topology_version += 1
                self.acc.power_offs += 1
                self.last_config_change = t
                self.events.append((t, f"power_off {a.target}"))
            p.state = "done"
            self.done_ids.add(a.action_id)

    def _start_actions(self, t: float) -> None:
        running_migrations = len(self._running_migrations())
        for p in self.pending:
            if p.state != "waiting" or not self._prereqs_done(p):
                continue
            a = p.action
            if a.kind == "set_power_cap":
                # <1 ms on the baseboard: effectively instantaneous.
                self.live.hosts[a.target].power_cap = a.value
                self._topology_version += 1
                self.acc.cap_changes += 1
                p.state = "done"
                self.done_ids.add(a.action_id)
                self.events.append((t, f"cap {a.target}={a.value:.0f}W"))
            elif a.kind == "migrate":
                vm = self.live.vms[a.target]
                if vm.host_id == a.dest:   # already there (stale rec)
                    p.state = "done"
                    self.done_ids.add(a.action_id)
                    continue
                if self.config.instant_migrations:
                    # Atomic remap: no copy window, no endpoint overhead.
                    self.live.move_vm(a.target, a.dest)
                    self._topology_version += 1
                    self.acc.vmotions += 1
                    if self.window_acc is not None and self._in_window(t):
                        self.window_acc.vmotions += 1
                    p.state = "done"
                    self.done_ids.add(a.action_id)
                    continue
                if (not self.config.migration_gated
                        and running_migrations
                        >= self.config.max_concurrent_migrations):
                    # Ungated regime: runtime concurrency gate.  Gated
                    # clusters bound concurrency at launch time instead
                    # (the manager's per-invocation LaunchBudget), so
                    # every emitted migration starts at its invocation
                    # tick and completes FIFO -- the deterministic
                    # schedule the batched engine precomputes.
                    continue
                p.state = "running"
                p.end_time = t + self._migration_duration(vm)
                running_migrations += 1
            elif a.kind == "power_on":
                p.state = "running"
                p.end_time = t + self.config.power_on_latency_s
            elif a.kind == "power_off":
                p.state = "running"
                p.end_time = t + self.config.power_off_latency_s

    def _actions_outstanding(self) -> bool:
        return any(p.state != "done" for p in self.pending)

    # ------------------------------------------------------------------
    def _in_window(self, t: float) -> bool:
        return (self.window is not None and
                self.window[0] <= t < self.window[1])

    def _deliver_and_account(self, t: float) -> None:
        dt = self.config.tick_s
        snap = self.live
        per_host = {}
        for host in snap.hosts.values():
            hid = host.host_id
            if not host.powered_on:
                per_host[hid] = (host.power_cap, 0.0, 0)
                continue
            vms = snap.vms_on(hid)
            overhead = self._host_migration_overhead(hid)
            capacity = max(host.managed_capacity - overhead, 0.0)
            alloc = deliver(capacity, vms)
            delivered = sum(alloc.values())
            demand = sum(min(v.demand, v.limit) for v in vms)
            self.acc.cpu_payload_mhz_s += delivered * dt
            self.acc.cpu_demand_mhz_s += demand * dt
            for v in vms:
                for tag in v.tags:
                    self.acc.tag_payload[tag] = (
                        self.acc.tag_payload.get(tag, 0.0)
                        + alloc[v.vm_id] * dt)
                    self.acc.tag_demand[tag] = (
                        self.acc.tag_demand.get(tag, 0.0)
                        + min(v.demand, v.limit) * dt)
            # Memory: proportional delivery under overcommit.
            mem_demand = sum(v.mem_demand for v in vms)
            mem_deliv = (mem_demand if mem_demand <= host.memory_mb
                         else host.memory_mb)
            self.acc.mem_payload_mb_s += mem_deliv * dt
            self.acc.mem_demand_mb_s += mem_demand * dt
            # Eq. 1 power, utilization measured against peak capacity.
            util = min((delivered + overhead) / host.spec.capacity_peak, 1.0)
            power = host.spec.power_consumed(util)
            self.acc.energy_j += power * dt
            if self.window_acc is not None and self._in_window(t):
                self.window_acc.cpu_payload_mhz_s += delivered * dt
                self.window_acc.cpu_demand_mhz_s += demand * dt
                self.window_acc.mem_payload_mb_s += mem_deliv * dt
                self.window_acc.mem_demand_mb_s += mem_demand * dt
                self.window_acc.energy_j += power * dt
            # DPM low-utilization tracking.
            cpu_util = snap.host_cpu_utilization(hid)
            mem_util = snap.host_mem_utilization(hid)
            low = (cpu_util < self.manager.config.dpm.low_util and
                   mem_util < self.manager.config.dpm.low_util)
            if low:
                self.low_since.setdefault(hid, t)
            else:
                self.low_since.pop(hid, None)
            per_host[hid] = (host.power_cap, cpu_util, len(vms))
        if self.config.record_timeline:
            self.timeline.append((t, per_host))

    def _budget_invariant(self) -> None:
        on_or_pending = {h.host_id for h in self.live.hosts.values()
                         if h.powered_on}
        for p in self.pending:
            if p.action.kind == "power_on" and p.state in ("waiting",
                                                           "running"):
                on_or_pending.add(p.action.target)
        total = sum(self.live.hosts[h].power_cap for h in on_or_pending)
        assert total <= self.live.power_budget + 1e-6, (
            f"budget violated during execution: {total:.1f} W > "
            f"{self.live.power_budget:.1f} W")
        tree = self.live.effective_tree()
        if tree is not None:
            ids = list(self.live.hosts)
            caps = np.array([self.live.hosts[h].power_cap for h in ids])
            mask = np.array([h in on_or_pending for h in ids])
            over = tree.max_overshoot(caps, mask)
            assert over <= 1e-6, (
                f"budget tree violated during execution: worst node over "
                f"by {over:.6f} W")

    def _invoke_manager(self, t: float) -> None:
        """One DRS + CloudPowerCap invocation; queues the emitted actions.

        Split out so array-backed engines can sync their demand columns into
        the object plane (which the manager pipeline operates on) first.
        """
        result = self.manager.run_invocation(
            self.live.clone(), now=t, low_since=self.low_since,
            last_config_change=self.last_config_change,
            limits=self.config.migration_limits)
        for a in result.actions:
            self.pending.append(_Pending(a))
        if result.actions:
            self.events.append(
                (t, f"drs: {len(result.actions)} actions "
                    f"({'; '.join(result.notes)})"))

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.config
        next_drs = cfg.drs_first_at_s
        t = 0.0
        while t < cfg.duration_s:
            self._apply_power_events(t)
            self._update_demands(t)
            self._complete_actions(t)
            self._start_actions(t)
            if t >= next_drs and not self._actions_outstanding():
                self._invoke_manager(t)
                next_drs = t + cfg.drs_period_s
            elif t >= next_drs:
                next_drs = t + cfg.tick_s   # defer while actions in flight
            self._start_actions(t)
            self._deliver_and_account(t)
            self._budget_invariant()
            t += cfg.tick_s
        return SimResult(acc=self.acc, timeline=self.timeline,
                         events=self.events, final=self.live,
                         window_acc=self.window_acc)
