"""Vectorized simulation engine: the per-tick hot path as array ops.

:class:`VectorSimulator` runs the exact same protocol as
:class:`repro.sim.cluster.Simulator` -- identical action execution, manager
invocations (both adapt over :class:`repro.core.manager_core.ManagerCore`
through the ``CloudPowerCapManager`` facade), accounting semantics,
scripted power events, and the host lifecycle (DPM power-on/off with
evacuations) -- but keeps host caps, VM demands, and Eq. 1 power
accounting in struct-of-arrays form.  Each tick costs one
batched-waterfill delivery pass plus a handful of ``bincount`` reductions
over all VMs, instead of a Python loop over hosts and VMs; a 1,000-host /
10,000-VM cluster ticks in milliseconds.

Division of labor:
  * per-tick work (demand updates, waterfill delivery, payload/energy
    accounting, DPM low-watermark tracking, budget invariant) -- arrays;
  * rare events (action execution, DRS invocations every ``drs_period_s``)
    -- the inherited object plane, with arrays refreshed lazily via the
    base class's ``_topology_version`` counter.

Parity with the per-object engine is asserted by
``tests/test_vector_parity.py`` on the paper's three evaluation scenarios.
See ``docs/ARCHITECTURE.md`` for the layout and ``repro.sim.sweep`` for the
scenario families that exercise this engine at scale.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import kernels
from repro.drs.entitlement import batched_waterfill
from repro.drs.snapshot import ClusterSnapshot
from repro.sim.cluster import SimConfig, Simulator
from repro.sim.workloads import DemandTrace, TraceBank


class VectorSimulator(Simulator):
    """Array-backed drop-in replacement for :class:`Simulator`."""

    def __init__(self, snapshot: ClusterSnapshot, manager,
                 traces: dict[str, DemandTrace],
                 config: Optional[SimConfig] = None,
                 window: Optional[tuple[float, float]] = None):
        super().__init__(snapshot, manager, traces, config, window)
        vms = list(self.live.vms.values())
        hosts = list(self.live.hosts.values())
        f64 = np.float64
        # Static VM columns.
        self._vm_ids = [v.vm_id for v in vms]
        self._vm_row = {vid: i for i, vid in enumerate(self._vm_ids)}
        self._reservation = np.array([v.reservation for v in vms], dtype=f64)
        self._limit = np.array([v.limit for v in vms], dtype=f64)
        self._shares = np.array([v.shares for v in vms], dtype=f64)
        self._vm_powered = np.array([v.powered_on for v in vms], dtype=bool)
        # Static host columns.
        self._host_ids = [h.host_id for h in hosts]
        self._host_idx = {hid: i for i, hid in enumerate(self._host_ids)}
        self._power_idle = np.array([h.spec.power_idle for h in hosts],
                                    dtype=f64)
        self._power_peak = np.array([h.spec.power_peak for h in hosts],
                                    dtype=f64)
        self._capacity_peak = np.array([h.spec.capacity_peak for h in hosts],
                                       dtype=f64)
        self._hyp_overhead = np.array(
            [h.spec.hypervisor_overhead for h in hosts], dtype=f64)
        self._host_mem = np.array([h.spec.memory_mb for h in hosts],
                                  dtype=f64)
        # Per-tag VM rows (tags are static).
        tag_rows: dict[str, list[int]] = {}
        for i, v in enumerate(vms):
            for tag in v.tags:
                tag_rows.setdefault(tag, []).append(i)
        self._tag_rows = {tag: np.asarray(rows, dtype=np.int64)
                          for tag, rows in tag_rows.items()}
        # Dynamic columns.
        self._cpu_dem = np.array([v.demand for v in vms], dtype=f64)
        self._mem_dem = np.array([v.mem_demand for v in vms], dtype=f64)
        self._bank = TraceBank.from_traces(traces, self._vm_ids)
        self._low_since_arr = np.full(len(hosts), np.nan)
        self._synced_version = -1
        self._refresh_topology()

    # ---------------------------------------------------------- topology
    def _refresh_topology(self) -> None:
        """Re-read placement / power state / caps from the object plane."""
        hosts = self.live.hosts
        self._host_on = np.array(
            [hosts[hid].powered_on for hid in self._host_ids], dtype=bool)
        self._power_cap = np.array(
            [hosts[hid].power_cap for hid in self._host_ids],
            dtype=np.float64)
        idx = self._host_idx
        self._vm_host = np.array(
            [idx.get(self.live.vms[vid].host_id, -1) for vid in self._vm_ids],
            dtype=np.int64)
        self._host_cols = kernels.HostCols(
            on=self._host_on[None],
            power_idle=self._power_idle[None],
            power_peak=self._power_peak[None],
            capacity_peak=self._capacity_peak[None],
            hyp_overhead=self._hyp_overhead[None])
        self._synced_version = self._topology_version

    def _arrays_current(self) -> None:
        if self._synced_version != self._topology_version:
            self._refresh_topology()

    # ------------------------------------------------------------- ticks
    def _update_demands(self, t: float) -> None:
        rows, cpu, mem = self._bank.eval(t)
        self._cpu_dem[rows] = cpu
        self._mem_dem[rows] = mem

    def _migration_duration(self, vm) -> float:
        mb = max(float(self._mem_dem[self._vm_row[vm.vm_id]]), 64.0)
        return max(mb / self.config.vmotion_rate_mb_s, self.config.tick_s)

    def _overhead_array(self) -> np.ndarray:
        """Per-host vMotion CPU overhead from in-flight migrations."""
        overhead = np.zeros(len(self._host_ids))
        for p in self._running_migrations():
            vm = self.live.vms[p.action.target]
            src = self._host_idx.get(vm.host_id, -1)
            dst = self._host_idx.get(p.action.dest, -1)
            if src >= 0:
                overhead[src] += self.config.vmotion_overhead_mhz
            if dst >= 0 and dst != src:
                overhead[dst] += self.config.vmotion_overhead_mhz
        return overhead

    def _managed_capacity(self) -> np.ndarray:
        return kernels.managed_capacity(np, self._host_cols,
                                        self._power_cap[None])[0]

    def _deliver_and_account(self, t: float) -> None:
        self._arrays_current()
        dt = self.config.tick_s
        n_hosts = len(self._host_ids)
        on = self._host_on

        managed = self._managed_capacity()
        overhead = self._overhead_array()
        capacity = np.maximum(managed - overhead, 0.0)

        placed = self._vm_host >= 0
        active = self._vm_powered & placed
        active[placed] &= on[self._vm_host[placed]]
        idx = np.nonzero(active)[0]
        seg = self._vm_host[idx]

        # Waterfill delivery: what each VM receives this tick (never above
        # instantaneous demand; reservations honored when demanded).
        dem = np.minimum(self._cpu_dem[idx], self._limit[idx])
        floors = np.minimum(self._reservation[idx], dem)
        alloc = batched_waterfill(capacity, floors, dem, self._shares[idx],
                                  seg, n_hosts)
        delivered = np.bincount(seg, weights=alloc, minlength=n_hosts)
        demand_h = np.bincount(seg, weights=dem, minlength=n_hosts)
        self.acc.cpu_payload_mhz_s += float(delivered.sum()) * dt
        self.acc.cpu_demand_mhz_s += float(demand_h.sum()) * dt

        if self._tag_rows:
            alloc_full = np.zeros(len(self._vm_ids))
            dem_full = np.zeros(len(self._vm_ids))
            alloc_full[idx] = alloc
            dem_full[idx] = dem
            for tag, rows in self._tag_rows.items():
                self.acc.tag_payload[tag] = (
                    self.acc.tag_payload.get(tag, 0.0)
                    + float(alloc_full[rows].sum()) * dt)
                self.acc.tag_demand[tag] = (
                    self.acc.tag_demand.get(tag, 0.0)
                    + float(dem_full[rows].sum()) * dt)

        # Memory: proportional delivery under overcommit.
        mem_dem_h = np.bincount(seg, weights=self._mem_dem[idx],
                                minlength=n_hosts)
        mem_deliv = np.minimum(mem_dem_h, np.where(on, self._host_mem, 0.0))
        self.acc.mem_payload_mb_s += float(mem_deliv.sum()) * dt
        self.acc.mem_demand_mb_s += float(mem_dem_h.sum()) * dt

        # Eq. 1 power, utilization measured against peak capacity.
        util = (delivered + overhead) / self._capacity_peak
        power = kernels.power_consumed(np, self._host_cols, util[None])[0]
        energy = float(power[on].sum()) * dt
        self.acc.energy_j += energy

        if self.window_acc is not None and self._in_window(t):
            self.window_acc.cpu_payload_mhz_s += float(delivered.sum()) * dt
            self.window_acc.cpu_demand_mhz_s += float(demand_h.sum()) * dt
            self.window_acc.mem_payload_mb_s += float(mem_deliv.sum()) * dt
            self.window_acc.mem_demand_mb_s += float(mem_dem_h.sum()) * dt
            self.window_acc.energy_j += energy

        # DPM low-utilization tracking (NaN == "not in the low band").
        eff = np.clip(self._cpu_dem, self._reservation, self._limit)
        eff_h = np.bincount(seg, weights=eff[idx], minlength=n_hosts)
        cpu_util = np.where(managed > 0.0,
                            eff_h / np.maximum(managed, 1e-300), 0.0)
        mem_ok = on & (self._host_mem > 0.0)
        mem_util = np.where(mem_ok,
                            mem_dem_h / np.maximum(self._host_mem, 1e-300),
                            0.0)
        cfg_dpm = self.manager.config.dpm
        low = on & (cpu_util < cfg_dpm.low_util) & (
            mem_util < cfg_dpm.low_util)
        entering = low & np.isnan(self._low_since_arr)
        self._low_since_arr = np.where(entering, t, self._low_since_arr)
        self._low_since_arr = np.where(on & ~low, np.nan,
                                       self._low_since_arr)

        if self.config.record_timeline:
            n_vms_h = np.bincount(seg, minlength=n_hosts)
            self.timeline.append((t, {
                hid: ((self._power_cap[i], float(cpu_util[i]),
                       int(n_vms_h[i])) if on[i]
                      else (self._power_cap[i], 0.0, 0))
                for i, hid in enumerate(self._host_ids)}))

    def _budget_invariant(self) -> None:
        self._arrays_current()
        total = float(self._power_cap[self._host_on].sum())
        for p in self.pending:
            if p.action.kind == "power_on" and p.state in ("waiting",
                                                           "running"):
                i = self._host_idx[p.action.target]
                if not self._host_on[i]:
                    total += float(self._power_cap[i])
        assert total <= self.live.power_budget + 1e-6, (
            f"budget violated during execution: {total:.1f} W > "
            f"{self.live.power_budget:.1f} W")
        tree = self.live.effective_tree()
        if tree is not None:
            mask = self._host_on.copy()
            for p in self.pending:
                if p.action.kind == "power_on" and p.state in ("waiting",
                                                               "running"):
                    mask[self._host_idx[p.action.target]] = True
            over = tree.max_overshoot(self._power_cap, mask)
            assert over <= 1e-6, (
                f"budget tree violated during execution: worst node over "
                f"by {over:.6f} W")

    # ----------------------------------------------------------- manager
    def _invoke_manager(self, t: float) -> None:
        # The manager pipeline runs on the object plane: push the array
        # demand columns and the low-watermark tracker back into it first.
        vms = self.live.vms
        for row, vid in enumerate(self._vm_ids):
            vm = vms[vid]
            vm.demand = float(self._cpu_dem[row])
            vm.mem_demand = float(self._mem_dem[row])
        self.live.invalidate_host_sums()
        self.low_since = {
            self._host_ids[i]: float(self._low_since_arr[i])
            for i in np.nonzero(~np.isnan(self._low_since_arr))[0]}
        super()._invoke_manager(t)
