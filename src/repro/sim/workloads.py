"""Demand-trace generators for the paper's three experiments.

Every generator returns a plain ``t -> (cpu, mem)`` callable for the
per-object simulator, and additionally attaches a declarative ``spec``
(:class:`TraceSpec`) describing the trace as a -- possibly periodic -- step
function.  :class:`TraceBank` compiles a whole cluster's specs into padded
arrays so the vectorized engine evaluates every VM's demand at time ``t``
with one ``searchsorted``-style pass instead of a Python call per VM.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

DemandTrace = Callable[[float], tuple[float, float]]  # t -> (cpu MHz, mem MB)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """A (periodic) step function: value of the last segment with t0 <= t.

    ``period`` is ``None`` for aperiodic traces; otherwise the segments are
    defined on ``t mod period``.  Segment boundaries use the same
    ``t >= t0`` comparison as the callable form, so both representations
    agree exactly on tick times.
    """

    segments: tuple                     # ((t0, cpu_mhz, mem_mb), ...) sorted
    period: Optional[float] = None


def _with_spec(fn: DemandTrace, spec: TraceSpec) -> DemandTrace:
    fn.spec = spec
    return fn


def spec_trace(spec: TraceSpec) -> DemandTrace:
    """The canonical callable for a declarative spec: the value of the last
    segment with ``t0 <= t`` (on ``t mod period`` when periodic) -- exactly
    the semantics :class:`TraceBank` compiles, so the callable and array
    forms agree at every evaluation point."""
    segments, period = spec.segments, spec.period

    def trace(t: float) -> tuple[float, float]:
        if period is not None:
            t = t % period
        cpu, mem = segments[0][1], segments[0][2]
        for t0, c, m in segments:
            if t >= t0:
                cpu, mem = c, m
            else:
                break
        return cpu, mem
    return _with_spec(trace, spec)


def traces_from_table(names: Sequence[str], segs: np.ndarray,
                      counts: Optional[np.ndarray] = None,
                      periods: Optional[np.ndarray] = None
                      ) -> dict[str, DemandTrace]:
    """Bulk trace factory: one array pass instead of n factory calls.

    ``segs`` is ``(n, k, 3)`` float rows of ``(t0, cpu_mhz, mem_mb)``
    segments, ``counts`` the per-row number of valid segments (default
    ``k``), ``periods`` the per-row period with non-finite meaning
    aperiodic.  Returns ``{name: trace}`` where each trace carries the same
    :class:`TraceSpec` the scalar factories would have attached -- the sweep
    layer builds tens of thousands of VM traces per grid, and the per-call
    normalization in :func:`step_trace` dominated cell construction.
    """
    segs = np.asarray(segs, dtype=np.float64)
    n, k = segs.shape[0], segs.shape[1]
    seg_rows = segs.tolist()
    # Convert everything to plain Python up front: per-element ndarray
    # indexing and np scalar ops in the loop cost more than the loop body.
    cnt = ([k] * n if counts is None
           else np.asarray(counts, dtype=np.int64).tolist())
    if periods is None:
        per = [None] * n
    else:
        pa = np.asarray(periods, dtype=np.float64)
        per = [p if f else None
               for p, f in zip(pa.tolist(), np.isfinite(pa).tolist())]
    out: dict[str, DemandTrace] = {}
    for name, row, c, p in zip(names, seg_rows, cnt, per):
        if c != k:
            row = row[:c]
        out[name] = spec_trace(TraceSpec(
            segments=tuple(tuple(s) for s in row), period=p))
    return out


def constant(cpu_mhz: float, mem_mb: float) -> DemandTrace:
    return _with_spec(lambda t: (cpu_mhz, mem_mb),
                      TraceSpec(segments=((0.0, cpu_mhz, mem_mb),)))


def step_trace(segments: list[tuple[float, float, float]]) -> DemandTrace:
    """``segments``: [(t_start, cpu_mhz, mem_mb), ...] sorted by t_start."""
    def trace(t: float) -> tuple[float, float]:
        cpu, mem = segments[0][1], segments[0][2]
        for t0, c, m in segments:
            if t >= t0:
                cpu, mem = c, m
            else:
                break
        return cpu, mem
    return _with_spec(trace, TraceSpec(segments=tuple(
        (float(t0), float(c), float(m)) for t0, c, m in segments)))


def burst(base_cpu: float, burst_cpu: float, mem_mb: float,
          t_start: float, t_end: float) -> DemandTrace:
    """Paper Sec. V-B: flat, spike in [t_start, t_end), flat again."""
    return step_trace([(0.0, base_cpu, mem_mb),
                       (t_start, burst_cpu, mem_mb),
                       (t_end, base_cpu, mem_mb)])


def prime_time(off_cpu: float, prime_cpu: float, off_mem: float,
               prime_mem: float, period_s: float = 86400.0,
               prime_start_frac: float = 0.0,
               prime_frac: float = 0.5) -> DemandTrace:
    """Paper Sec. V-D: trading VMs idle half the day, heavy the other half."""
    def trace(t: float) -> tuple[float, float]:
        phase = (t % period_s) / period_s
        in_prime = (prime_start_frac <= phase <
                    prime_start_frac + prime_frac)
        return ((prime_cpu, prime_mem) if in_prime else (off_cpu, off_mem))

    # Periodic step form on t mod period.
    t_on = prime_start_frac * period_s
    t_off = (prime_start_frac + prime_frac) * period_s
    prime_vals = (prime_cpu, prime_mem)
    off_vals = (off_cpu, off_mem)
    if prime_start_frac + prime_frac >= 1.0:
        # phase lives in [0, 1), so a window crossing 1.0 simply runs to the
        # period's end (the callable above never wraps it around).
        if prime_start_frac <= 0.0:
            segs = [(0.0, *prime_vals)]
        else:
            segs = [(0.0, *off_vals), (t_on, *prime_vals)]
    elif prime_start_frac <= 0.0:
        segs = [(0.0, *prime_vals), (t_off, *off_vals)]
    else:
        segs = [(0.0, *off_vals), (t_on, *prime_vals), (t_off, *off_vals)]
    return _with_spec(trace, TraceSpec(segments=tuple(segs), period=period_s))


class TraceBank:
    """Array-compiled demand traces for a whole cluster.

    Rows follow the ``vm_order`` given at construction.  Traces without a
    ``spec`` attribute (hand-written callables) fall back to per-VM Python
    evaluation, so the bank is always exhaustive over traced VMs.
    """

    def __init__(self, vm_order: Sequence[str]):
        self.vm_order = list(vm_order)
        self.rows = np.zeros(0, dtype=np.int64)       # traced, array-backed
        self.period = np.zeros(0)
        self.bps = np.zeros((0, 1))
        self.cpu_vals = np.zeros((0, 1))
        self.mem_vals = np.zeros((0, 1))
        self.fallback: list[tuple[int, DemandTrace]] = []

    @classmethod
    def from_traces(cls, traces: dict[str, DemandTrace],
                    vm_order: Sequence[str]) -> "TraceBank":
        bank = cls(vm_order)
        row_of = {vid: i for i, vid in enumerate(vm_order)}
        rows, specs = [], []
        for vm_id, trace in traces.items():
            if vm_id not in row_of:
                continue
            spec = getattr(trace, "spec", None)
            if spec is None:
                bank.fallback.append((row_of[vm_id], trace))
            else:
                rows.append(row_of[vm_id])
                specs.append(spec)
        if rows:
            # One flattened scatter over every (vm, segment) pair instead of
            # a per-spec Python loop: the bank packs whole sweep grids, and
            # host-side packing sat on the end-to-end critical path.
            n = len(rows)
            counts = np.fromiter((len(s.segments) for s in specs),
                                 dtype=np.int64, count=n)
            max_segs = int(counts.max())
            flat = np.asarray([seg for s in specs for seg in s.segments],
                              dtype=np.float64)         # (sum(counts), 3)
            r_idx = np.repeat(np.arange(n), counts)
            c_idx = (np.arange(flat.shape[0])
                     - np.repeat(np.cumsum(counts) - counts, counts))
            bps = np.full((n, max_segs), np.inf)
            cpu = np.zeros((n, max_segs))
            mem = np.zeros((n, max_segs))
            bps[r_idx, c_idx] = flat[:, 0]
            cpu[r_idx, c_idx] = flat[:, 1]
            mem[r_idx, c_idx] = flat[:, 2]
            # Padding repeats the last value so idx overshoot is benign.
            pad_src = np.minimum(np.arange(max_segs)[None, :],
                                 counts[:, None] - 1)
            take = np.arange(n)[:, None]
            cpu = cpu[take, pad_src]
            mem = mem[take, pad_src]
            period = np.fromiter(
                ((np.inf if s.period is None else s.period) for s in specs),
                dtype=np.float64, count=n)
            bank.rows = np.asarray(rows, dtype=np.int64)
            bank.period = period
            bank.bps = bps
            bank.cpu_vals = cpu
            bank.mem_vals = mem
        return bank

    def eval(self, t: float) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rows, cpu, mem) for every traced VM at time ``t``."""
        if self.rows.size:
            phase = np.mod(t, self.period)     # t mod inf == t
            idx = np.sum(self.bps <= phase[:, None], axis=1) - 1
            idx = np.clip(idx, 0, None)
            take = np.arange(self.rows.size)
            cpu = self.cpu_vals[take, idx]
            mem = self.mem_vals[take, idx]
        else:
            cpu = np.zeros(0)
            mem = np.zeros(0)
        rows = self.rows
        if self.fallback:
            fb_rows = np.array([r for r, _ in self.fallback], dtype=np.int64)
            fb = [fn(t) for _, fn in self.fallback]
            rows = np.concatenate([rows, fb_rows])
            cpu = np.concatenate([cpu, np.array([c for c, _ in fb])])
            mem = np.concatenate([mem, np.array([m for _, m in fb])])
        return rows, cpu, mem
