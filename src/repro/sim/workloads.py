"""Demand-trace generators for the paper's three experiments."""

from __future__ import annotations

from typing import Callable

DemandTrace = Callable[[float], tuple[float, float]]  # t -> (cpu MHz, mem MB)


def constant(cpu_mhz: float, mem_mb: float) -> DemandTrace:
    return lambda t: (cpu_mhz, mem_mb)


def step_trace(segments: list[tuple[float, float, float]]) -> DemandTrace:
    """``segments``: [(t_start, cpu_mhz, mem_mb), ...] sorted by t_start."""
    def trace(t: float) -> tuple[float, float]:
        cpu, mem = segments[0][1], segments[0][2]
        for t0, c, m in segments:
            if t >= t0:
                cpu, mem = c, m
            else:
                break
        return cpu, mem
    return trace


def burst(base_cpu: float, burst_cpu: float, mem_mb: float,
          t_start: float, t_end: float) -> DemandTrace:
    """Paper Sec. V-B: flat, spike in [t_start, t_end), flat again."""
    return step_trace([(0.0, base_cpu, mem_mb),
                       (t_start, burst_cpu, mem_mb),
                       (t_end, base_cpu, mem_mb)])


def prime_time(off_cpu: float, prime_cpu: float, off_mem: float,
               prime_mem: float, period_s: float = 86400.0,
               prime_start_frac: float = 0.0,
               prime_frac: float = 0.5) -> DemandTrace:
    """Paper Sec. V-D: trading VMs idle half the day, heavy the other half."""
    def trace(t: float) -> tuple[float, float]:
        phase = (t % period_s) / period_s
        in_prime = (prime_start_frac <= phase <
                    prime_start_frac + prime_frac)
        return ((prime_cpu, prime_mem) if in_prime else (off_cpu, off_mem))
    return trace
