"""Model zoo: decoder-only LM (dense / MoE / VLM-prefix), Mamba2 SSM,
Zamba2-style hybrid, and Whisper-style encoder-decoder.

Functional JAX throughout: parameters are pytrees of arrays; repeated layers
are stacked on a leading "layer" axis and applied with ``jax.lax.scan`` so
even 96-layer/340B configs lower to compact HLO.  Every parameter carries
logical sharding axes (see ``param_specs``) consumed by the launcher.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, moe, ssd
from repro.models.config import ModelConfig
from repro.runtime.sharding import shard

PyTree = Any


# =========================================================== param specs
def _stack(specs: dict, n: int) -> dict:
    """Prepend a stacked-layer axis to every spec in ``specs``."""
    return {k: ((n,) + shape, ("layer",) + axes)
            for k, (shape, axes) in specs.items()}


def block_param_specs(cfg: ModelConfig) -> dict:
    """One decoder block (attention + FFN/MoE) including norms."""
    specs = {
        "ln1": ((cfg.d_model,), (None,)),
        "ln2": ((cfg.d_model,), (None,)),
    }
    specs.update(layers.attention_param_specs(cfg))
    if cfg.family == "moe":
        specs.update(moe.moe_param_specs(cfg))
    else:
        specs.update(layers.mlp_param_specs(cfg))
    return specs


def param_specs(cfg: ModelConfig) -> dict:
    """Full pytree of (shape, logical_axes) for the model."""
    specs: dict = {
        "embed": {"table": ((cfg.vocab_size, cfg.d_model),
                            ("vocab", "embed_p"))},
        "final_norm": {"scale": ((cfg.d_model,), (None,))},
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = {"table": ((cfg.d_model, cfg.vocab_size),
                                      ("embed_p", "vocab"))}
    if cfg.family in ("dense", "moe", "vlm"):
        specs["blocks"] = _stack(block_param_specs(cfg), cfg.n_layers)
        if cfg.family == "vlm":
            specs["vision_proj"] = {
                "w": ((cfg.d_model, cfg.d_model), ("embed_p", None))}
    elif cfg.family == "ssm":
        blk = {"ln": ((cfg.d_model,), (None,))}
        blk.update(ssd.ssd_param_specs(cfg))
        specs["blocks"] = _stack(blk, cfg.n_layers)
    elif cfg.family == "hybrid":
        blk = {"ln": ((cfg.d_model,), (None,))}
        blk.update(ssd.ssd_param_specs(cfg))
        specs["blocks"] = _stack(blk, cfg.n_layers)
        specs["shared_attn"] = block_param_specs(cfg)
    elif cfg.family == "encdec":
        enc_blk = {
            "ln1": ((cfg.d_model,), (None,)),
            "ln2": ((cfg.d_model,), (None,)),
        }
        enc_blk.update(layers.attention_param_specs(cfg))
        enc_blk.update(layers.mlp_param_specs(cfg))
        specs["enc_blocks"] = _stack(enc_blk, cfg.enc_layers)
        dec_blk = dict(block_param_specs(cfg))
        dec_blk["ln_cross"] = ((cfg.d_model,), (None,))
        dec_blk.update({f"cross_{k}": v for k, v in
                        layers.attention_param_specs(cfg).items()})
        specs["dec_blocks"] = _stack(dec_blk, cfg.n_layers)
        specs["enc_norm"] = {"scale": ((cfg.d_model,), (None,))}
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return specs


def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    """Truncated-normal init honoring each spec's shape (smoke/examples)."""
    specs = param_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))
    keys = jax.random.split(key, len(flat))
    dtype = jnp.dtype(cfg.param_dtype)

    def init_one(k, spec):
        shape, _ = spec
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        if len(shape) == 1 or shape[-1] == 1:
            # Norm scales / scalars start at one; biases at zero handled
            # by name below is unnecessary -- scales dominate 1D params.
            return jnp.ones(shape, dtype)
        std = 1.0 / np.sqrt(fan_in)
        return (jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)
                * std).astype(dtype)

    leaves = [init_one(k, s) for k, s in zip(keys, flat)]
    params = jax.tree_util.tree_unflatten(treedef, leaves)
    # Fix-ups: a_log ~ log(uniform[1,16]), dt_bias small, conv bias zero.
    def fixup(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "a_log":
            return jnp.log(jnp.linspace(1.0, 16.0, x.shape[-1])
                           ).astype(x.dtype) * jnp.ones_like(x)
        if name in ("dt_bias", "conv_b"):
            return jnp.zeros_like(x)
        if name == "d_skip":
            return jnp.ones_like(x)
        return x
    return jax.tree_util.tree_map_with_path(fixup, params)


def abstract_params(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree_util.tree_map(
        lambda spec: jax.ShapeDtypeStruct(spec[0], dtype),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


def param_logical_axes(cfg: ModelConfig) -> PyTree:
    return jax.tree_util.tree_map(
        lambda spec: spec[1], param_specs(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


# ============================================================== forward
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _attn_block(blk, h, cfg, positions, cache, cross=None):
    # Norm outputs are constrained to the *inner* (full-seq) layout so the
    # SP all-gather happens on the bf16 normed tensor, not on the f32
    # upcast inside rms_norm (GSPMD otherwise hoists the gather above the
    # downcast and moves 2x the bytes).
    hn1 = shard(layers.rms_norm(h, blk["ln1"], cfg.norm_eps),
                "batch", "inner_seq", "embed")
    a, cache = layers.attention(blk, hn1, cfg, positions=positions,
                                kv_cache=cache)
    # Constrain block outputs back to the between-block layout *before* the
    # residual add: under SP (seq sharded over "model") this lets GSPMD fuse
    # the TP partial-sum all-reduce + slice into a reduce-scatter.
    a = shard(a, "batch", "seq", "embed")
    h = h + a
    if cross is not None:
        c, _ = layers.attention(
            {k[len("cross_"):]: v for k, v in blk.items()
             if k.startswith("cross_")},
            layers.rms_norm(h, blk["ln_cross"], cfg.norm_eps), cfg,
            cross_kv=cross)
        h = h + shard(c, "batch", "seq", "embed")
    hn = shard(layers.rms_norm(h, blk["ln2"], cfg.norm_eps),
               "batch", "inner_seq", "embed")
    if cfg.family == "moe":
        f, aux = moe.moe_ffn(blk, hn, cfg)
    else:
        f, aux = layers.mlp(blk, hn, cfg), jnp.zeros((), jnp.float32)
    f = shard(f, "batch", "seq", "embed")
    return h + f, cache, aux


def _make_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                stacked: bool = True) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, max_len, hkv, hd) if stacked else \
        (batch, max_len, hkv, hd)
    cursor = jnp.zeros((n_layers,) if stacked else (), jnp.int32)
    return {
        "k": jnp.zeros(shape, jnp.dtype(cfg.param_dtype)),
        "v": jnp.zeros(shape, jnp.dtype(cfg.param_dtype)),
        "cursor": cursor,
    }


@dataclasses.dataclass
class ForwardResult:
    hidden: jax.Array                  # (B, S, D) final hidden states
    aux_loss: jax.Array                # MoE auxiliary loss
    cache: Optional[PyTree] = None     # updated decode state


def decoder_forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
                    vision_embeds: Optional[jax.Array] = None,
                    cache: Optional[PyTree] = None,
                    positions: Optional[jax.Array] = None) -> ForwardResult:
    """Dense/MoE/VLM decoder-only forward (scan over stacked blocks)."""
    h = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.param_dtype))
    if cfg.family == "vlm" and vision_embeds is not None:
        ve = vision_embeds.astype(h.dtype) @ params["vision_proj"]["w"]
        h = jnp.concatenate([ve, h], axis=1)
    h = shard(h, "batch", "seq", "embed")
    s = h.shape[1]
    if positions is None:
        positions = jnp.arange(s)[None, :]

    def body(carry, xs):
        hh, aux = carry
        blk, layer_cache = xs
        hh, new_cache, aux_i = _attn_block(blk, hh, cfg, positions,
                                           layer_cache)
        hh = shard(hh, "batch", "seq", "embed")
        return (hh, aux + aux_i), new_cache

    body = _remat(body, cfg)
    layer_caches = None if cache is None else cache
    (h, aux), new_caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)),
        (params["blocks"], layer_caches))
    h = layers.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    return ForwardResult(hidden=h, aux_loss=aux, cache=new_caches)


def ssm_forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
                cache: Optional[PyTree] = None, **_) -> ForwardResult:
    h = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.param_dtype))
    h = shard(h, "batch", "seq", "embed")

    def body(carry, xs):
        hh = carry
        blk, st = xs
        out, new_st = ssd.ssd_block(
            blk, layers.rms_norm(hh, blk["ln"], cfg.norm_eps), cfg, state=st)
        hh = shard(hh + out, "batch", "seq", "embed")
        return hh, new_st

    body = _remat(body, cfg)
    h, new_states = jax.lax.scan(body, h, (params["blocks"], cache))
    h = layers.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    return ForwardResult(hidden=h, aux_loss=jnp.zeros((), jnp.float32),
                         cache=new_states)


def hybrid_forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
                   cache: Optional[PyTree] = None,
                   positions: Optional[jax.Array] = None, **_
                   ) -> ForwardResult:
    """Zamba2-style: Mamba2 backbone + one shared attention block applied
    every ``attn_every`` layers (its KV caches are per application site)."""
    h = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.param_dtype))
    h = shard(h, "batch", "seq", "embed")
    s = h.shape[1]
    if positions is None:
        positions = jnp.arange(s)[None, :]
    k = cfg.attn_every
    n_groups = cfg.n_layers // k          # shared-attn application sites
    rem = cfg.n_layers - n_groups * k
    aux = jnp.zeros((), jnp.float32)

    def slice_blocks(lo, hi):
        return jax.tree_util.tree_map(lambda x: x[lo:hi], params["blocks"])

    def mamba_body(carry, xs):
        hh = carry
        blk, st = xs
        out, new_st = ssd.ssd_block(
            blk, layers.rms_norm(hh, blk["ln"], cfg.norm_eps), cfg, state=st)
        hh = shard(hh + out, "batch", "seq", "embed")
        return hh, new_st

    mamba_body = _remat(mamba_body, cfg)
    new_ssm, new_kv = [], []
    cache = cache or {"ssm": None, "kv": None}
    for g in range(n_groups):
        st = None if cache["ssm"] is None else jax.tree_util.tree_map(
            lambda x: x[g * k:(g + 1) * k], cache["ssm"])
        h, ssm_g = jax.lax.scan(mamba_body, h,
                                (slice_blocks(g * k, (g + 1) * k), st))
        kv_g = None if cache["kv"] is None else jax.tree_util.tree_map(
            lambda x: x[g], cache["kv"])
        h2, kv_g, aux_g = _attn_block(params["shared_attn"], h, cfg,
                                      positions, kv_g)
        h, aux = h2, aux + aux_g
        new_ssm.append(ssm_g)
        new_kv.append(kv_g)
    if rem:
        st = None if cache["ssm"] is None else jax.tree_util.tree_map(
            lambda x: x[n_groups * k:], cache["ssm"])
        h, ssm_r = jax.lax.scan(mamba_body, h,
                                (slice_blocks(n_groups * k, cfg.n_layers),
                                 st))
        new_ssm.append(ssm_r)
    h = layers.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    new_cache = {
        "ssm": jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm),
        "kv": None if new_kv[0] is None else jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *new_kv),
    }
    return ForwardResult(hidden=h, aux_loss=aux, cache=new_cache)


def encdec_forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
                   frames: Optional[jax.Array] = None,
                   cache: Optional[PyTree] = None,
                   enc_out: Optional[jax.Array] = None,
                   positions: Optional[jax.Array] = None, **_
                   ) -> ForwardResult:
    """Whisper-style: encoder over precomputed frame embeddings (frontend
    stub), decoder with self + cross attention."""
    if enc_out is None:
        e = frames.astype(jnp.dtype(cfg.param_dtype))
        e = shard(e, "batch", "seq", "embed")
        enc_pos = jnp.arange(e.shape[1])[None, :]

        def enc_body(carry, blk):
            hh = carry
            a, _ = layers.attention(
                blk, layers.rms_norm(hh, blk["ln1"], cfg.norm_eps), cfg,
                causal=False, positions=enc_pos)
            hh = hh + a
            f = layers.mlp(blk, layers.rms_norm(hh, blk["ln2"],
                                                cfg.norm_eps), cfg)
            return shard(hh + f, "batch", "seq", "embed"), None

        e, _ = jax.lax.scan(_remat(enc_body, cfg), e, params["enc_blocks"])
        enc_out = layers.rms_norm(e, params["enc_norm"]["scale"],
                                  cfg.norm_eps)
    # Precompute per-layer cross K/V from encoder output.
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    b, se, _ = enc_out.shape

    h = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.param_dtype))
    h = shard(h, "batch", "seq", "embed")
    s = h.shape[1]
    if positions is None:
        positions = jnp.arange(s)[None, :]

    def dec_body(carry, xs):
        hh = carry
        blk, layer_cache = xs
        ck = (enc_out @ blk["cross_wk"]).reshape(b, se, hkv, hd)
        cv = (enc_out @ blk["cross_wv"]).reshape(b, se, hkv, hd)
        hh, new_cache, _ = _attn_block(blk, hh, cfg, positions, layer_cache,
                                       cross=(ck, cv))
        return shard(hh, "batch", "seq", "embed"), new_cache

    h, new_caches = jax.lax.scan(_remat(dec_body, cfg), h,
                                 (params["dec_blocks"], cache))
    h = layers.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    return ForwardResult(hidden=h, aux_loss=jnp.zeros((), jnp.float32),
                         cache=new_caches)


FORWARDS = {
    "dense": decoder_forward,
    "moe": decoder_forward,
    "vlm": decoder_forward,
    "ssm": ssm_forward,
    "hybrid": hybrid_forward,
    "encdec": encdec_forward,
}


def forward(params: PyTree, cfg: ModelConfig, **kwargs) -> ForwardResult:
    return FORWARDS[cfg.family](params, kwargs.pop("tokens"), cfg, **kwargs)


def unembed_weight(params: PyTree, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["unembed"]["table"]


# ======================================================== decode caches
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Family-appropriate decode state (KV caches / SSM states)."""
    if cfg.family in ("dense", "moe", "vlm"):
        return _make_cache(cfg, cfg.n_layers, batch, max_len)
    if cfg.family == "ssm":
        st = ssd.ssd_init_state(cfg, batch)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape),
            st)
    if cfg.family == "hybrid":
        st = ssd.ssd_init_state(cfg, batch)
        n_groups = cfg.n_layers // cfg.attn_every
        return {
            "ssm": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x[None], (cfg.n_layers,) + x.shape), st),
            "kv": _make_cache(cfg, n_groups, batch, max_len),
        }
    if cfg.family == "encdec":
        return _make_cache(cfg, cfg.n_layers, batch, max_len)
    raise ValueError(cfg.family)
