"""Mixture-of-Experts layer: token-choice top-k, sort-based dropless-ish
dispatch into per-expert capacity buckets, expert-parallel over the "model"
mesh axis.

The dispatch pipeline (all dense jnp, GSPMD-shardable):
  router probs -> top-k -> flatten (token,k) -> stable sort by expert id ->
  slot = rank-within-expert (overflow beyond capacity dropped) ->
  scatter tokens into (E, cap, D) buckets -> batched expert GEMMs ->
  gather back, weight by gate, sum over k.

FLOPs ~= tokens * top_k * capacity_factor * expert-FFN cost, matching the
paper-config MoE budgets (OLMoE 64e top-8, DeepSeekMoE 2 shared + 64 top-6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.runtime.sharding import current_context, shard


def moe_param_specs(cfg) -> dict:
    # Expert parallelism takes the "model" axis; the per-expert FFN dim is
    # small (1-1.4k) and stays unsharded -- sharding both would map one mesh
    # axis onto two dimensions of the same tensor.
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    specs = {
        "router": ((d, e), ("embed_p", "expert")),
        "w_gate": ((e, d, f), ("expert", "embed_p", None)),
        "w_up": ((e, d, f), ("expert", "embed_p", None)),
        "w_down": ((e, f, d), ("expert", None, "embed_p")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        specs.update({
            "shared_w_gate": ((d, fs), ("embed_p", "ffn")),
            "shared_w_up": ((d, fs), ("embed_p", "ffn")),
            "shared_w_down": ((fs, d), ("ffn", "embed_p")),
        })
    return specs


def expert_capacity(n_tokens: int, cfg) -> int:
    cap = int(n_tokens * cfg.moe_top_k * cfg.moe_capacity_factor
              // cfg.n_experts)
    return max(8, (cap + 7) // 8 * 8)


def moe_ffn(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    With a bound mesh whose expert axis is >1, dispatch runs inside
    shard_map: tokens are replicated across the expert (model) axis, so each
    shard builds capacity buckets for *its own* experts locally and only the
    combined output crosses the wire (one psum).  Letting GSPMD partition
    the naive scatter instead replicates the full global bucket tensor
    (measured 6.6 TB/device/step of all-reduce on olmoe train_4k -- see
    EXPERIMENTS.md SPerf iteration 1).
    """
    import os
    ctx = current_context()
    if ctx is not None and not os.environ.get("REPRO_MOE_DENSE"):
        mesh, rules = ctx
        expert_axes = rules.mesh_axes("expert", mesh)
        if expert_axes is not None:
            ax = expert_axes if isinstance(expert_axes, str) \
                else expert_axes[0]
            if cfg.n_experts % mesh.shape[ax] == 0 and mesh.shape[ax] > 1:
                return _moe_ffn_shard_map(params, x, cfg, mesh, rules, ax)
    return _moe_ffn_dense(params, x, cfg)


def _shared_experts(params: dict, xt: jax.Array) -> jax.Array:
    sh = jax.nn.silu(xt @ params["shared_w_gate"]) * (
        xt @ params["shared_w_up"])
    sh = shard(sh, None, "ffn")
    return sh @ params["shared_w_down"]


def _route(params: dict, xt: jax.Array, cfg):
    """Router probs -> (normalized gates (T,k), expert ids (T,k), aux)."""
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.moe_top_k
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    ce = jnp.zeros(e).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    return gate_vals, expert_idx, aux


def _moe_ffn_shard_map(params, x, cfg, mesh, rules, expert_ax: str
                       ) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    n_shards = mesh.shape[expert_ax]
    e_loc = e // n_shards
    bspec = rules.mesh_axes("batch", mesh)

    def local_fn(x_loc, router, wg, wu, wd, *shared_w):
        bl, sl, _ = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        gate_vals, expert_idx, aux = _route({"router": router}, xt, cfg)

        shard_id = jax.lax.axis_index(expert_ax)
        cap = expert_capacity(t, cfg)
        flat_expert = expert_idx.reshape(-1)                 # (T*k,)
        owner = flat_expert // e_loc
        owned = owner == shard_id
        local_expert = jnp.where(owned, flat_expert - shard_id * e_loc,
                                 e_loc)                      # e_loc = "drop"
        order = jnp.argsort(local_expert, stable=True)
        sorted_local = local_expert[order]
        counts = jnp.zeros(e_loc + 1, jnp.int32).at[sorted_local].add(1)
        starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(t * k) - starts[sorted_local]

        # Owned pairs sort to the front; everything this shard will compute
        # lives in the first  M = e_loc*cap  sorted positions (anything
        # beyond is over capacity or foreign), so gather/scatter traffic is
        # M*D instead of T*k*D -- 1/n_shards of the naive cost
        # (EXPERIMENTS.md SPerf iteration 2).
        m = min(e_loc * cap, t * k)
        take = order[:m]
        le_m = sorted_local[:m]
        rk_m = rank[:m]
        keep_m = (le_m < e_loc) & (rk_m < cap)
        token_m = take // k
        slot = jnp.where(keep_m, le_m * cap + jnp.minimum(rk_m, cap - 1),
                         e_loc * cap)

        xg = jnp.where(keep_m[:, None], xt[token_m], 0.0)    # (M, D)
        buckets = jnp.zeros((e_loc * cap + 1, d), xt.dtype)
        buckets = buckets.at[slot].add(xg)
        bk = buckets[:-1].reshape(e_loc, cap, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bk, wg)) * \
            jnp.einsum("ecd,edf->ecf", bk, wu)
        yb = jnp.einsum("ecf,efd->ecd", h, wd)
        y_flat = jnp.concatenate(
            [yb.reshape(e_loc * cap, d), jnp.zeros((1, d), yb.dtype)])

        gate_flat = gate_vals.reshape(-1)[take]              # (M,)
        gathered = y_flat[slot] * (gate_flat * keep_m)[:, None]
        y = jnp.zeros((t, d), yb.dtype).at[token_m].add(
            gathered.astype(yb.dtype))
        if shared_w:
            # Shared experts ride in the same psum: each expert shard holds
            # a 1/n_shards slice of the shared FFN dim, computes its partial
            # contribution locally, and the routed-output reduction sums it
            # -- zero additional collectives (DeepSeekMoE's always-on
            # experts would otherwise cost 2 ARs/layer outside shard_map).
            swg, swu, swd = shared_w
            hs = jax.nn.silu(xt @ swg) * (xt @ swu)
            y = y + (hs @ swd).astype(y.dtype)
        y = jax.lax.psum(y, expert_ax)      # sum expert-shard contributions
        if bspec is not None:
            # Per-shard routing stats -> deterministic cluster-wide aux.
            aux = jax.lax.pmean(aux, bspec)
        return y.reshape(bl, sl, d), aux

    in_specs = [P(bspec, None, None), P(None, None),
                P(expert_ax, None, None), P(expert_ax, None, None),
                P(expert_ax, None, None)]
    args = [x, params["router"], params["w_gate"], params["w_up"],
            params["w_down"]]
    if cfg.n_shared_experts:
        in_specs += [P(None, expert_ax), P(None, expert_ax),
                     P(expert_ax, None)]
        args += [params["shared_w_gate"], params["shared_w_up"],
                 params["shared_w_down"]]
    out_specs = (P(bspec, None, None), P())
    y, aux = shard_map(local_fn, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=out_specs, check_rep=False)(*args)
    return y, aux


def _moe_ffn_dense(params: dict, x: jax.Array, cfg
                   ) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    xt = x.reshape(t, d)

    # ---- routing ----------------------------------------------------------
    logits = (xt @ params["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros(e).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- dispatch: sort (token,k) pairs by expert -------------------------
    cap = expert_capacity(t, cfg)
    flat_expert = expert_idx.reshape(-1)                         # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    # Rank within expert group = position - first position of that expert.
    counts = jnp.zeros(e, jnp.int32).at[sorted_expert].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - starts[sorted_expert]
    keep = rank < cap
    slot = sorted_expert * cap + jnp.minimum(rank, cap - 1)      # (T*k,)
    token_of = order // k                                        # source token

    buckets = jnp.zeros((e * cap, d), xt.dtype)
    buckets = buckets.at[slot].add(
        jnp.where(keep[:, None], xt[token_of], 0.0))
    buckets = buckets.reshape(e, cap, d)
    buckets = shard(buckets, "expert", None, None)

    # ---- expert computation (batched GEMMs over the expert axis) ----------
    h_gate = jnp.einsum("ecd,edf->ecf", buckets, params["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buckets, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    h = shard(h, "expert", None, None)
    y_buckets = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y_buckets = shard(y_buckets, "expert", None, None)
    y_flat = y_buckets.reshape(e * cap, d)

    # ---- combine ----------------------------------------------------------
    gathered = y_flat[slot] * keep[:, None]                      # (T*k, D)
    inv = jnp.argsort(order, stable=True)                        # undo sort
    per_pair = gathered[inv].reshape(t, k, d)
    out = jnp.einsum("tkd,tk->td", per_pair,
                     gate_vals.astype(per_pair.dtype))

    # ---- shared experts (always-on) ---------------------------------------
    if cfg.n_shared_experts:
        sh = jax.nn.silu(xt @ params["shared_w_gate"]) * (
            xt @ params["shared_w_up"])
        sh = shard(sh, None, "ffn")
        out = out + sh @ params["shared_w_down"]
    return out.reshape(b, s, d), aux
