"""Core model primitives: RMSNorm, RoPE, GQA attention, MLP, streamed xent.

Attention uses an online-softmax formulation scanned over key blocks (the
pure-JAX twin of the Pallas flash kernel in ``repro.kernels``): memory stays
O(block) instead of O(seq^2), which is what lets the 32k prefill and 500k
decode shapes compile within v5e HBM in the dry-run.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import shard

NEG_INF = -1e30


# ----------------------------------------------------------------- normals
@partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with f32 internals and *narrow-dtype cotangents*.

    The custom VJP computes dx in f32 but hands back a bf16 cotangent, so
    under sequence-parallel sharding the backward reduce-scatter moves bf16
    bytes -- with plain autodiff, GSPMD places the collective on the f32
    upcast's cotangent and moves 2x the data (EXPERIMENTS.md SPerf,
    nemotron iteration 4).
    """
    y, _ = _rms_norm_fwd(x, scale, eps)
    return y


def _rms_norm_fwd(x, scale, eps):
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
                      + eps)
    y = ((xf * r) * scale).astype(x.dtype)
    return y, (x, scale, r)


def _rms_norm_bwd(eps, res, dy):
    x, scale, r = res
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32) * scale.astype(jnp.float32)
    d = x.shape[-1]
    dot = jnp.sum(dyf * xf, axis=-1, keepdims=True)
    dx = r * (dyf - xf * (r * r) * dot / d)
    dscale = jnp.sum(dy.astype(jnp.float32) * xf * r,
                     axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


# -------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (.., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
def _block_attend(q, k, v, mask, scale):
    """One (q-block x kv-block) online-softmax partial.

    q: (B, Hq, Sq, D)  k/v: (B, Hkv, Bk, D)  mask: (Sq, Bk) or None
    Returns (partial unnormalized out, row max, row sumexp).

    GQA via grouped einsum -- K/V are *not* materialized per query head
    (granite-20b MQA would otherwise 48x its KV traffic).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    groups = hq // hkv
    qg = q.reshape(b, hkv, groups, sq, d)
    # Narrow-dtype operands, f32 accumulation: the MXU accumulates in f32
    # natively, and bf16 reads halve score-producing HBM traffic vs
    # upcasting the operands first (EXPERIMENTS.md SPerf, granite iter. 2).
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    flat = lambda t: t.reshape((b, hq) + t.shape[3:])
    return flat(o), flat(m), flat(l)


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, q_offset: int = 0,
                        kv_len: jax.Array | None = None,
                        block_k: int = 1024) -> jax.Array:
    """Online-softmax attention, scanned over KV blocks.

    q: (B, Sq, Hq, D), k/v: (B, Skv, Hkv, D).  ``q_offset`` is the absolute
    position of q[0] (prefill continuation / decode).  ``kv_len`` optionally
    masks the tail of the KV buffer (ragged decode caches).
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    qt = jnp.swapaxes(q, 1, 2)                       # (B, Hq, Sq, D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if sq <= 8:
        # Decode fast path: one einsum over the whole (possibly seq-sharded)
        # KV; the softmax reductions over the sharded axis become the
        # cross-device combine of distributed flash-decode.
        q_pos = q_offset + jnp.arange(sq)
        k_pos = jnp.arange(skv)
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if kv_len is not None:
            mask &= (k_pos < kv_len)[None, :]
        o, m, l = _block_attend(qt, kt, vt, mask, scale)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return jnp.swapaxes(out, 1, 2).astype(q.dtype)
    block_k = min(block_k, skv)
    n_blocks = (skv + block_k - 1) // block_k
    pad = n_blocks * block_k - skv
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kt = kt.reshape(b, kt.shape[1], n_blocks, block_k, d)
    vt = vt.reshape(b, vt.shape[1], n_blocks, block_k, d)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        o, m, l = carry
        kb, vb, blk_idx = blk
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        mask = jnp.ones((sq, block_k), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        mask &= (k_pos[None, :] < skv)
        if kv_len is not None:
            mask &= (k_pos[None, :] < kv_len)
        ob, mb, lb = _block_attend(qt, kb, vb, mask, scale)
        m_new = jnp.maximum(m, mb)
        alpha = jnp.exp(m - m_new)
        beta = jnp.exp(mb - m_new)
        o = o * alpha[..., None] + ob * beta[..., None]
        l = l * alpha + lb * beta
        return (o, m_new, l), None

    o0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    kb = jnp.moveaxis(kt, 2, 0)                      # (n_blocks, B, H, bk, D)
    vb = jnp.moveaxis(vt, 2, 0)
    (o, m, l), _ = jax.lax.scan(
        step, (o0, m0, l0), (kb, vb, jnp.arange(n_blocks)))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttentionParamsSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int


def attention_param_specs(cfg) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ((d, hq * hd), ("embed_p", "heads")),
        "wk": ((d, hkv * hd), ("embed_p", "kv_heads")),
        "wv": ((d, hkv * hd), ("embed_p", "kv_heads")),
        "wo": ((hq * hd, d), ("heads", "embed_p")),
    }


def attention(params: dict, x: jax.Array, cfg, *, causal: bool = True,
              positions: jax.Array | None = None,
              kv_cache: dict | None = None,
              cross_kv: tuple | None = None,
              attn_impl: str = "xla") -> tuple[jax.Array, dict | None]:
    """GQA attention with optional KV cache (decode) or cross-KV (enc-dec).

    x: (B, S, D).  Returns (out, updated_cache).
    """
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = (x @ params["wq"]).reshape(b, s, hq, hd)
    if cross_kv is not None:
        k, v = cross_kv
        q = shard(q, "batch", "inner_seq", "heads", None)
        out = flash_attention_xla(q, k, v, causal=False)
    else:
        k = (x @ params["wk"]).reshape(b, s, hkv, hd)
        v = (x @ params["wv"]).reshape(b, s, hkv, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        q = shard(q, "batch", "inner_seq", "heads", None)
        k = shard(k, "batch", "kv_seq", "kv_heads", None)
        v = shard(v, "batch", "kv_seq", "kv_heads", None)
        if kv_cache is not None:
            # Decode: append at cursor, attend over the filled prefix.
            cur = kv_cache["cursor"]           # scalar int32
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), cur, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), cur, axis=1)
            ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
            cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
            kv_cache = {"k": ck, "v": cv, "cursor": cur + s}
            out = flash_attention_xla(q, ck, cv, causal=True, q_offset=cur,
                                      kv_len=cur + s)
        else:
            out = flash_attention_xla(q, k, v, causal=causal)
    out = shard(out, "batch", "inner_seq", "heads", None)
    out = out.reshape(b, s, hq * hd) @ params["wo"]
    return out, kv_cache


# --------------------------------------------------------------------- MLP
def mlp_param_specs(cfg, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "w_gate": ((d, f), ("embed_p", "ffn")),
            "w_up": ((d, f), ("embed_p", "ffn")),
            "w_down": ((f, d), ("ffn", "embed_p")),
        }
    return {
        "w_up": ((d, f), ("embed_p", "ffn")),
        "w_down": ((f, d), ("ffn", "embed_p")),
    }


def mlp(params: dict, x: jax.Array, cfg) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    h = shard(h, "batch", "inner_seq", "ffn")
    return h @ params["w_down"]


# -------------------------------------------------- streamed cross-entropy
def streamed_xent(h: jax.Array, w_out: jax.Array, labels: jax.Array,
                  weights: jax.Array, chunk: int = 2048
                  ) -> tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks: each step computes (B, chunk, V) logits,
    reduces to per-token loss, and discards them.  Returns (sum loss, sum
    weights).  h: (B, S, D), w_out: (D, V), labels/weights: (B, S).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    n = (s + chunk - 1) // chunk
    pad = n * chunk - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
    hc = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
    wc = jnp.moveaxis(weights.reshape(b, n, chunk), 1, 0)

    def step(carry, xs):
        loss_sum, w_sum = carry
        hh, ll, ww = xs
        logits = (hh @ w_out).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * ww
        return (loss_sum + loss.sum(), w_sum + ww.sum()), None

    (loss_sum, w_sum), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, wc))
    return loss_sum, w_sum
