"""Mamba2 / SSD (state-space duality) mixer, chunked-scan formulation.

Training/prefill uses the SSD chunked algorithm (arXiv:2405.21060): quadratic
attention-like compute *within* chunks of length Q, linear state carry
*between* chunks -- the same structure the Pallas ``ssd_scan`` kernel tiles
for VMEM.  Decode is the O(1) recurrent update on a (B, H, P, N) state.

Shapes: x (B,L,H,P), dt (B,L,H), B/C (B,L,G,N) with G groups broadcast over
heads (G=1 for the assigned configs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.runtime.sharding import shard


# --------------------------------------------------------------- SSD core
def ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk: int,
                init_state=None) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); a_log: (H,) with
    A = -exp(a_log); b_mat/c_mat: (B, L, H, N) (already head-expanded).
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, l)
    nc = (l + q - 1) // q
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,)
    log_decay = dt.astype(jnp.float32) * a                   # (B, L', H) <= 0

    def reshape_chunks(t):
        return jnp.moveaxis(
            t.reshape((bsz, nc, q) + t.shape[2:]), 1, 0)     # (nc, B, q, ...)

    xc, dtc, bc, cc = map(reshape_chunks, (x, dt, b_mat, c_mat))
    ldc = reshape_chunks(log_decay)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def chunk_step(state, inputs):
        xq, dtq, bq, cq, ld = inputs                         # per-chunk
        cum = jnp.cumsum(ld, axis=1)                         # (B, q, H)
        # ---- intra-chunk (quadratic within the chunk) ----------------
        # decay(t,s) = exp(cum_t - cum_s) for s <= t
        dec = cum[:, :, None, :] - cum[:, None, :, :]        # (B, q, q, H)
        tri = jnp.tril(jnp.ones((q, q), bool))
        dec = jnp.where(tri[None, :, :, None], dec, -jnp.inf)
        lmat = jnp.exp(dec)
        scores = jnp.einsum("bthn,bshn->btsh", cq.astype(jnp.float32),
                            bq.astype(jnp.float32))
        w = scores * lmat * dtq[:, None, :, :].astype(jnp.float32)
        y_intra = jnp.einsum("btsh,bshp->bthp", w,
                             xq.astype(jnp.float32))
        # ---- inter-chunk (carry state) --------------------------------
        y_inter = jnp.einsum("bthn,bhpn->bthp",
                             cq.astype(jnp.float32) *
                             jnp.exp(cum)[..., None],
                             state)
        # ---- state update ---------------------------------------------
        total = cum[:, -1:, :]                               # (B, 1, H)
        rem = jnp.exp(total - cum)                           # decay to end
        contrib = jnp.einsum(
            "bshn,bshp->bhpn",
            (bq.astype(jnp.float32) * (rem * dtq)[..., None]),
            xq.astype(jnp.float32))
        state = state * jnp.exp(total[:, 0, :])[:, :, None, None] + contrib
        return state, (y_intra + y_inter)

    state, yc = jax.lax.scan(chunk_step, init_state, (xc, dtc, bc, cc, ldc))
    y = jnp.moveaxis(yc, 0, 1).reshape(bsz, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), state


def ssd_decode_step(state, x, dt, a_log, b_mat, c_mat):
    """One-token recurrent update.

    state: (B,H,P,N); x: (B,1,H,P); dt: (B,1,H); b/c: (B,1,H,N).
    Returns (y (B,1,H,P), new state).
    """
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt[:, 0].astype(jnp.float32) * a)        # (B, H)
    contrib = jnp.einsum("bhn,bhp->bhpn",
                         b_mat[:, 0].astype(jnp.float32) *
                         dt[:, 0, :, None].astype(jnp.float32),
                         x[:, 0].astype(jnp.float32))
    new_state = state * decay[:, :, None, None] + contrib
    y = jnp.einsum("bhn,bhpn->bhp", c_mat[:, 0].astype(jnp.float32),
                   new_state)
    return y[:, None].astype(x.dtype), new_state


# ----------------------------------------------------------- Mamba2 block
def ssd_param_specs(cfg) -> dict:
    """Separate projections per component (z, x, B, C, dt).

    A single fused in_proj would put the z|x|B|C|dt split boundaries inside
    tensor-parallel shards (resharding copies every layer); separate
    matmuls keep each output axis cleanly sharded -- z/x over "heads"
    (d_inner = heads*head_dim), B/C/dt replicated (tiny).
    """
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    w = cfg.ssm_conv_width
    return {
        "in_z": ((d, di), ("embed_p", "heads")),
        "in_x": ((d, di), ("embed_p", "heads")),
        "in_b": ((d, n), ("embed_p", None)),
        "in_c": ((d, n), ("embed_p", None)),
        "in_dt": ((d, h), ("embed_p", "heads")),
        "conv_x_w": ((w, di), (None, "heads")),
        "conv_x_b": ((di,), ("heads",)),
        "conv_b_w": ((w, n), (None, None)),
        "conv_b_b": ((n,), (None,)),
        "conv_c_w": ((w, n), (None, None)),
        "conv_c_b": ((n,), (None,)),
        "a_log": ((h,), ("heads",)),
        "d_skip": ((h,), ("heads",)),
        "dt_bias": ((h,), ("heads",)),
        "norm_scale": ((di,), ("heads",)),
        "out_proj": ((di, d), ("heads", "embed_p")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, width W.  x: (B, L, C); w: (W, C).

    ``state``: (B, W-1, C) trailing context for decode; returns (y, new
    state)."""
    width = w.shape[0]
    if state is None:
        ctx = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(ctx[:, i:i + x.shape[1]] * w[i] for i in range(width)) + b
    new_state = ctx[:, -(width - 1):] if width > 1 else None
    return jax.nn.silu(y), new_state


def ssd_block(params, x, cfg, *, state=None):
    """Full Mamba2 mixer.  x: (B, L, D).

    ``state``: None (train/prefill from zeros) or dict(ssm, conv) for decode.
    Returns (out (B,L,D), new_state_dict).
    """
    bsz, l, _ = x.shape
    di, n, h, p = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                   cfg.ssm_head_dim)
    z = x @ params["in_z"]                                   # (B, L, di)
    xs = x @ params["in_x"]
    b_raw = x @ params["in_b"]                               # (B, L, N)
    c_raw = x @ params["in_c"]
    dt_raw = x @ params["in_dt"]                             # (B, L, H)
    xs = shard(xs, "batch", "inner_seq", "heads")

    cs = (None, None, None) if state is None else state["conv"]
    xs, new_cx = _causal_conv(xs, params["conv_x_w"], params["conv_x_b"],
                              cs[0])
    b_raw, new_cb = _causal_conv(b_raw, params["conv_b_w"],
                                 params["conv_b_b"], cs[1])
    c_raw, new_cc = _causal_conv(c_raw, params["conv_c_w"],
                                 params["conv_c_b"], cs[2])
    new_conv = (new_cx, new_cb, new_cc)

    xh = xs.reshape(bsz, l, h, p)
    xh = shard(xh, "batch", "inner_seq", "heads", None)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])         # (B, L, H)
    bh = jnp.broadcast_to(b_raw[:, :, None, :], (bsz, l, h, n))
    ch = jnp.broadcast_to(c_raw[:, :, None, :], (bsz, l, h, n))

    if state is None or l > 1:
        init = None if state is None else state["ssm"]
        y, new_ssm = ssd_chunked(xh, dt, params["a_log"], bh, ch,
                                 cfg.ssm_chunk, init_state=init)
    else:
        y, new_ssm = ssd_decode_step(state["ssm"], xh, dt, params["a_log"],
                                     bh, ch)
    y = y + xh * params["d_skip"][:, None].astype(y.dtype)
    y = y.reshape(bsz, l, di)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm_scale"],
                        cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"ssm": new_ssm, "conv": new_conv}


def ssd_init_state(cfg, batch: int) -> dict:
    h, p, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.ssm_conv_width - 1
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": (jnp.zeros((batch, w, cfg.d_inner), jnp.float32),
                 jnp.zeros((batch, w, n), jnp.float32),
                 jnp.zeros((batch, w, n), jnp.float32)),
    }
