"""Model configuration: one dataclass covering all assigned families.

Families: dense / moe / ssm / hybrid / encdec (audio) / vlm.  Every assigned
architecture is expressed as a ``ModelConfig``; reduced smoke variants are
derived with ``smoke()``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    activation: str = "swiglu"     # swiglu | squared_relu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (Zamba2-style shared attention) -----------------------------
    attn_every: int = 0            # apply the shared attn block every N blocks

    # --- frontends (stubs: precomputed embeddings as inputs) ----------------
    frontend: str = "none"         # none | vision | audio
    n_prefix_embeds: int = 0       # vision patches prepended to the sequence
    enc_layers: int = 0            # encoder depth (encdec)
    enc_seq: int = 0               # encoder sequence length (audio frames)

    # --- numerics / memory ---------------------------------------------------
    param_dtype: str = "bfloat16"
    optimizer_state_dtype: str = "float32"
    remat: str = "full"            # full | dots | none
    xent_chunk: int = 2048         # sequence chunk for streamed cross-entropy
    microbatches: int = 1          # gradient-accumulation steps per batch
    shard_activation_seq: bool = False  # Megatron-SP-style between-block seq
    # Parallelism policy for train shapes: "tp" = tensor parallel over the
    # model axis (default); "dp" = pure data parallel + ZeRO-3 when the
    # global batch divides the mesh (falls back to tp otherwise).
    parallelism: str = "tp"

    def __post_init__(self):
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------ dims
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_layers(self) -> int:
        """Number of attention applications in one forward pass."""
        if self.family in ("dense", "moe", "vlm"):
            return self.n_layers
        if self.family == "encdec":
            return self.enc_layers + 2 * self.n_layers  # self + cross
        if self.family == "hybrid" and self.attn_every:
            return self.n_layers // self.attn_every
        return 0

    @property
    def ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid":
            return self.n_layers
        return 0

    # ------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + \
            (self.n_heads * hd) * d if self.n_heads else 0

        def ffn_params(dff: int) -> int:
            mult = 3 if self.activation == "swiglu" else 2
            return mult * d * dff

        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn + ffn_params(self.d_ff)
            total += self.n_layers * per_layer
        elif self.family == "moe":
            experts = (self.n_experts + self.n_shared_experts) * \
                ffn_params(self.d_ff)
            router = d * self.n_experts
            total += self.n_layers * (attn + experts + router)
        elif self.family == "ssm":
            total += self.n_layers * self._ssm_block_params()
        elif self.family == "hybrid":
            total += self.n_layers * self._ssm_block_params()
            total += attn + ffn_params(self.d_ff)  # one shared attn+MLP block
        elif self.family == "encdec":
            total += self.enc_layers * (attn + ffn_params(self.d_ff))
            total += self.n_layers * (2 * attn + ffn_params(self.d_ff))
        return total

    def _ssm_block_params(self) -> int:
        d, di, s = self.d_model, self.d_inner, self.ssm_state
        # in_proj (x, z, B, C, dt) + conv + out_proj (Mamba2 structure).
        in_proj = d * (2 * di + 2 * s + self.n_ssm_heads)
        conv = self.ssm_conv_width * (di + 2 * s)
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * self.n_ssm_heads

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        mult = 3 if self.activation == "swiglu" else 2
        dense = self.param_count() - self.n_layers * (
            self.n_experts * mult * d * self.d_ff)
        active = self.n_layers * (self.moe_top_k * mult * d * self.d_ff)
        return dense + active

    def flops_per_token(self, seq_len: int = 0) -> float:
        """~6*N_active per trained token (+ attention quadratic term)."""
        base = 6.0 * self.active_param_count()
        if seq_len and self.attn_layers:
            # 12 * L_attn * d_head * n_heads * seq  (fwd+bwd QK^T and AV)
            base += 12.0 * self.attn_layers * self.n_heads * self.head_dim \
                * seq_len
        return base

    # ------------------------------------------------------------- variants
    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, min(self.n_layers, 2) if self.attn_every == 0
                         else 2 * self.attn_every),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=8 if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            n_prefix_embeds=8 if self.n_prefix_embeds else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=16 if self.enc_seq else 0,
            param_dtype="float32",
            remat="none",
            xent_chunk=64,
            microbatches=1,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def sub_quadratic(config: ModelConfig) -> bool:
    """long_500k eligibility: SSM/hybrid state keeps decode state bounded."""
    return config.family in ("ssm", "hybrid")


def shapes_for(config: ModelConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if sub_quadratic(config):
        out.append("long_500k")
    return out
