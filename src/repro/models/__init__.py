"""Model zoo: configs, layers, and family forwards."""

from repro.models.config import (ModelConfig, ShapeConfig, SHAPES,
                                 shapes_for, sub_quadratic)
from repro.models import layers, moe, ssd, transformer

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shapes_for",
           "sub_quadratic", "layers", "moe", "ssd", "transformer"]
