"""Public SSD op: Pallas intra-chunk kernel + jnp inter-chunk combine."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_chunk_kernel
from repro.kernels.ssd_scan import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, b_mat, c_mat, *, chunk: int = 256,
             init_state=None, interpret: bool | None = None):
    """Full SSD: y (B,L,H,P) f32 and final state (B,H,P,N) f32.

    Same contract as ``ref.ssd_ref``; the quadratic intra-chunk work runs in
    the Pallas kernel, the (tiny) inter-chunk recurrence in plain JAX.
    """
    if interpret is None:
        interpret = not _on_tpu()
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, l)
    nc = (l + q - 1) // q
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    a = -jnp.exp(a_log.astype(jnp.float32))
    log_decay = dt.astype(jnp.float32) * a

    y_intra, contrib, total = ssd_chunk_kernel(
        x, log_decay, dt, b_mat, c_mat, chunk=q, interpret=interpret)

    # Inter-chunk state recurrence: S_c = exp(total_c) S_{c-1} + contrib_c.
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def carry(state, inp):
        contrib_c, total_c = inp                   # (B,H,P,N), (B,H)
        prev = state
        state = state * jnp.exp(total_c)[..., None, None] + contrib_c
        return state, prev

    final, prev_states = jax.lax.scan(
        carry, init_state,
        (jnp.moveaxis(contrib, 1, 0), jnp.moveaxis(total, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,NC,H,P,N)

    # y_inter[t] = C_t . (exp(cum_t) * S_prev-of-chunk)
    cum = jnp.cumsum(log_decay.reshape(bsz, nc, q, h), axis=2)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         c_mat.reshape(bsz, nc, q, h, n).astype(jnp.float32),
                         jnp.exp(cum), prev_states)
    y = y_intra.reshape(bsz, nc, q, h, p) + y_inter
    return y.reshape(bsz, nc * q, h, p)[:, :l], final


ssd_ref = _ref.ssd_ref
