"""Pure-jnp oracle for the SSD scan: the sequential recurrence.

Deliberately the *naive* O(L) state recurrence (not the chunked algorithm),
so kernel and model implementations are checked against an independent,
obviously-correct formulation:

    S_t = exp(dt_t * A) * S_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . S_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a_log, b_mat, c_mat, init_state=None):
    """x: (B,L,H,P); dt: (B,L,H); a_log: (H,); b/c: (B,L,H,N).

    Returns (y (B,L,H,P) f32, final_state (B,H,P,N) f32)."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                      # (B,H,P), (B,H), (B,H,N)
        decay = jnp.exp(dtt.astype(jnp.float32) * a)
        contrib = jnp.einsum("bhn,bhp->bhpn",
                             bt.astype(jnp.float32) *
                             dtt[..., None].astype(jnp.float32),
                             xt.astype(jnp.float32))
        state = state * decay[..., None, None] + contrib
        y = jnp.einsum("bhn,bhpn->bhp", ct.astype(jnp.float32), state)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b_mat, 1, 0), jnp.moveaxis(c_mat, 1, 0))
    final, ys = jax.lax.scan(step, init_state, xs)
    return jnp.moveaxis(ys, 0, 1), final
