"""SSD intra-chunk kernel, Pallas TPU.

The SSD chunked algorithm splits the sequence into chunks of Q tokens:
quadratic attention-like compute *within* a chunk (MXU-friendly), linear
state carry *between* chunks.  This kernel computes, per (batch, chunk,
head-block) grid cell, entirely in VMEM:

  y_intra[t]    = sum_{s<=t} (C_t.B_s) exp(cum_t - cum_s) dt_s x_s
  contrib[p,n]  = sum_s exp(cum_Q - cum_s) dt_s B_s x_s   (chunk state)
  total[h]      = cum_Q                                    (chunk log-decay)

The O(NC) inter-chunk recurrence and the rank-1 y_inter correction are done
by the caller (ops.py) in plain JAX -- they are tiny (state is (H,P,N)).

VMEM working set per cell at Q=256, HB=4, P=64, N=128, f32:
  x 256KB + b/c 2x512KB + scores/decay 2x1MB + y 256KB + contrib 128KB
  ~ 3.7 MB  -- fits v5e VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, ld_ref, dt_ref, b_ref, c_ref,
                y_ref, contrib_ref, total_ref, *, q: int):
    # Blocks: x (1,Q,HB,P), ld/dt (1,Q,HB), b/c (1,Q,HB,N).
    x = x_ref[0].astype(jnp.float32)              # (Q, HB, P)
    ld = ld_ref[0].astype(jnp.float32)            # (Q, HB)
    dt = dt_ref[0].astype(jnp.float32)
    bm = b_ref[0].astype(jnp.float32)             # (Q, HB, N)
    cm = c_ref[0].astype(jnp.float32)

    cum = jnp.cumsum(ld, axis=0)                  # (Q, HB)

    # scores[h, t, s] = C_t . B_s   (batched over heads on the MXU)
    ct = jnp.swapaxes(cm, 0, 1)                   # (HB, Q, N)
    bt = jnp.swapaxes(bm, 0, 1)
    scores = jax.lax.dot_general(
        ct, bt, (((2,), (2,)), ((0,), (0,))))     # (HB, Q, Q)

    # decay[h, t, s] = exp(cum_t - cum_s) for s <= t, else 0
    cum_h = jnp.swapaxes(cum, 0, 1)               # (HB, Q)
    dec = cum_h[:, :, None] - cum_h[:, None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    w = scores * jnp.where(tri[None], jnp.exp(dec), 0.0)
    w = w * jnp.swapaxes(dt, 0, 1)[:, None, :]    # weight by dt_s

    xt = jnp.swapaxes(x, 0, 1)                    # (HB, Q, P)
    y = jax.lax.dot_general(
        w, xt, (((2,), (1,)), ((0,), (0,))))      # (HB, Q, P)
    y_ref[0] = jnp.swapaxes(y, 0, 1).astype(y_ref.dtype)

    # Chunk state contribution: sum_s exp(cum_Q - cum_s) dt_s B_s (x) x_s.
    rem = jnp.exp(cum_h[:, -1:] - cum_h)          # (HB, Q)
    bw = bt * (rem * jnp.swapaxes(dt, 0, 1))[..., None]   # (HB, Q, N)
    contrib = jax.lax.dot_general(
        jnp.swapaxes(xt, 1, 2), bw, (((2,), (1,)), ((0,), (0,))))  # (HB,P,N)
    contrib_ref[0, 0] = contrib.astype(contrib_ref.dtype)
    total_ref[0, 0] = cum_h[:, -1].astype(total_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "head_block", "interpret"))
def ssd_chunk_kernel(x, log_decay, dt, b_mat, c_mat, *, chunk: int = 256,
                     head_block: int = 4, interpret: bool = False):
    """Per-chunk SSD quantities.

    x: (B,L,H,P); log_decay/dt: (B,L,H); b/c: (B,L,H,N); L % chunk == 0.
    Returns (y_intra (B,L,H,P) f32, contrib (B,NC,H,P,N) f32,
             total (B,NC,H) f32).
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    if l % chunk:
        raise ValueError(f"L={l} not a multiple of chunk={chunk}")
    hb = min(head_block, h)
    if h % hb:
        hb = 1
    nc = l // chunk

    grid = (bsz, nc, h // hb)
    y, contrib, total = pl.pallas_call(
        functools.partial(_ssd_kernel, q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hb, p),
                         lambda ib, ic, ih: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, hb), lambda ib, ic, ih: (ib, ic, ih)),
            pl.BlockSpec((1, chunk, hb), lambda ib, ic, ih: (ib, ic, ih)),
            pl.BlockSpec((1, chunk, hb, n),
                         lambda ib, ic, ih: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, hb, n),
                         lambda ib, ic, ih: (ib, ic, ih, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hb, p),
                         lambda ib, ic, ih: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, hb, p, n),
                         lambda ib, ic, ih: (ib, ic, ih, 0, 0)),
            pl.BlockSpec((1, 1, hb), lambda ib, ic, ih: (ib, ic, ih)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, log_decay, dt, b_mat, c_mat)
    return y, contrib, total
