"""Flash attention backward, Pallas TPU (dq / dk / dv).

Standard two-kernel schedule with the forward's log-sum-exp:

  dKdV: grid (B, Hq, Skv/bk, Sq/bq) -- Q innermost; per (b,h,ik) cell the
        (bk, d) dk/dv accumulators live in VMEM scratch across Q blocks.
        p = exp(s - lse) is recomputed from q/k (no O(S^2) residuals).
  dQ:   grid (B, Hq, Sq/bq, Skv/bk) -- KV innermost, (bq, d) accumulator.

D = rowsum(dO * O) is precomputed in plain JAX (O(S*d)).  GQA: the kernels
produce per-query-head dk/dv; the wrapper sums over the group axis.
Causal block-skipping mirrors the forward (upper-triangle blocks never
touch the MXU).

VMEM per cell at 128x128xd=128 f32: q/do/k/v tiles ~0.4 MB + s/p/dp/ds
~0.26 MB + accumulators 0.13 MB -- comfortably double-buffered.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(block_q, block_k, q_start, k_start, seq_q, seq_kv, causal):
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 1)
    m = (k_pos < seq_kv) & (q_pos < seq_q)
    if causal:
        m &= k_pos <= q_pos
    return m


def _dkdv_kernel(q_ref, do_ref, lse_ref, dsum_ref, k_ref, v_ref,
                 dk_ref, dv_ref, dk_scr, dv_scr, *, scale, block_q, block_k,
                 seq_q, seq_kv, causal, q_offset):
    ik = pl.program_id(2)
    iq = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(iq == 0)
    def init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k
    live = True if not causal else k_start <= q_start + block_q - 1

    @pl.when(live)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (bq, d)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]                             # (bq,)
        dsum = dsum_ref[0, 0]                           # (bq,)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        m = _mask(block_q, block_k, q_start, k_start, seq_q + q_offset,
                  seq_kv, causal)
        p = jnp.where(m, jnp.exp(s - lse[:, None]), 0.0)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())))            # (bk, d)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - dsum[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())))            # (bk, d)

    @pl.when(iq == nq - 1)
    def finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, do_ref, lse_ref, dsum_ref, k_ref, v_ref, dq_ref,
               dq_scr, *, scale, block_q, block_k, seq_q, seq_kv, causal,
               q_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k
    live = True if not causal else k_start <= q_start + block_q - 1

    @pl.when(live)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        dsum = dsum_ref[0, 0]
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        m = _mask(block_q, block_k, q_start, k_start, seq_q + q_offset,
                  seq_kv, causal)
        p = jnp.where(m, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - dsum[:, None]) * scale
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())))            # (bq, d)

    @pl.when(ik == nk - 1)
    def finalize():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention_bwd_kernel(q, k, v, o, lse, do, *, causal=True,
                               q_offset=0, block_q=128, block_k=128,
                               interpret=False):
    """Returns (dq, dk, dv) with the input layouts of the forward:
    q/o/do: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D); lse: (B, Hq, Sq)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    groups = hq // hkv
    scale = 1.0 / np.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - skv

    dsum = jnp.einsum("bshd,bshd->bhs", do.astype(jnp.float32),
                      o.astype(jnp.float32))

    qt = jnp.swapaxes(q, 1, 2)
    dot = jnp.swapaxes(do, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    lse_p, dsum_p = lse, dsum
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        dot = jnp.pad(dot, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)))
        dsum_p = jnp.pad(dsum, ((0, 0), (0, 0), (0, pad_q)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    common = dict(scale=scale, block_q=block_q, block_k=block_k, seq_q=sq,
                  seq_kv=skv, causal=causal, q_offset=q_offset)
    # dKdV: q-index is the innermost grid dim.
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkdv_kernel, **common),
        grid=(b, hq, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, ik_, iq_: (ib, ih, iq_, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, ik_, iq_: (ib, ih, iq_, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda ib, ih, ik_, iq_: (ib, ih, iq_)),
            pl.BlockSpec((1, 1, block_q),
                         lambda ib, ih, ik_, iq_: (ib, ih, iq_)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik_, iq_, g=groups: (ib, ih // g,
                                                             ik_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik_, iq_, g=groups: (ib, ih // g,
                                                             ik_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik_, iq_: (ib, ih, ik_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik_, iq_: (ib, ih, ik_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, nk * block_k, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, nk * block_k, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        interpret=interpret,
    )(qt, dot, lse_p, dsum_p, kt, vt)

    dq_t = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq_, ik_: (ib, ih, iq_, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq_, ik_: (ib, ih, iq_, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda ib, ih, iq_, ik_: (ib, ih, iq_)),
            pl.BlockSpec((1, 1, block_q),
                         lambda ib, ih, iq_, ik_: (ib, ih, iq_)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq_, ik_, g=groups: (ib, ih // g,
                                                             ik_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq_, ik_, g=groups: (ib, ih // g,
                                                             ik_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq_, ik_: (ib, ih, iq_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, nq * block_q, d),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qt, dot, lse_p, dsum_p, kt, vt)

    dq = jnp.swapaxes(dq_t, 1, 2)[:, :sq].astype(q.dtype)
    # Sum per-query-head dk/dv over the GQA group.
    dk = dk_h[:, :, :skv].reshape(b, hkv, groups, skv, d).sum(2)
    dv = dv_h[:, :, :skv].reshape(b, hkv, groups, skv, d).sum(2)
    dk = jnp.swapaxes(dk, 1, 2).astype(k.dtype)
    dv = jnp.swapaxes(dv, 1, 2).astype(v.dtype)
    return dq, dk, dv
