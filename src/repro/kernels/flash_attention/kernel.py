"""Causal GQA flash attention, Pallas TPU.

Tiling: grid (batch, q_heads, Sq/block_q, Skv/block_k); the KV axis is the
innermost (sequential) grid dimension, so the online-softmax running state
(m, l, acc) lives in VMEM scratch across KV iterations of one (b, h, iq)
cell.  Block sizes default to MXU-aligned 128x128 tiles in the (q, k) plane;
the head dim is kept whole (<= 256 for all assigned configs).

VMEM working set per cell (bf16 in, f32 acc):
  q (bq, d) + k/v (bk, d) + scores (bq, bk) + acc (bq, d)
  ~ 0.25 MB at 128x128xd=128  << 16 MB v5e VMEM, leaving room for
  double-buffered HBM->VMEM prefetch of the next KV block.

Fully-masked KV blocks (k_start > q_end under the causal mask) are skipped
via @pl.when -- the triangular schedule halves prefill FLOPs vs. the naive
rectangle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                  acc_scr, *, scale: float, block_q: int, block_k: int,
                  seq_q: int, seq_kv: int, causal: bool, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k
    live = True if not causal else k_start <= q_start + block_q - 1

    @pl.when(live)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(l)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    groups = hq // hkv
    scale = 1.0 / np.sqrt(d)

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - skv

    # Layout: (B, H, S, D); grid iterates KV innermost (sequential on TPU).
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, seq_q=sq, seq_kv=skv,
                          causal=causal, q_offset=q_offset),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=groups: (ib, ih // g,
                                                           ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=groups: (ib, ih // g,
                                                           ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, nq * block_q, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, nq * block_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)[:, :sq], lse[:, :, :sq]
