"""Pure-jnp oracle for causal GQA flash attention."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True,
                  q_offset: int = 0) -> jnp.ndarray:
    """Naive softmax attention.

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    groups = hq // hkv
    kk = jnp.repeat(k, groups, axis=2)
    vv = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        k_pos = jnp.arange(skv)
        mask = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)
