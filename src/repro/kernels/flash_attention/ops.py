"""Public flash-attention op: Pallas forward + backward kernels wired into
a custom VJP (interpret mode off-TPU), with the pure-jnp oracle exposed for
tests."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.kernel_bwd import \
    flash_attention_bwd_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=None)
def _vjp_fn(causal: bool, q_offset: int, block_q: int, block_k: int,
            interpret: bool):
    kw = dict(causal=causal, q_offset=q_offset, block_q=block_q,
              block_k=block_k, interpret=interpret)

    @jax.custom_vjp
    def f(q, k, v):
        out, _ = flash_attention_kernel(q, k, v, **kw)
        return out

    def fwd(q, k, v):
        out, lse = flash_attention_kernel(q, k, v, **kw)
        return out, (q, k, v, out, lse)

    def bwd(res, do):
        q, k, v, out, lse = res
        return flash_attention_bwd_kernel(q, k, v, out, lse, do, **kw)

    f.defvjp(fwd, bwd)
    return f


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Differentiable flash attention with GQA.

    q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D).  Forward and
    backward both run as Pallas kernels (O(block) VMEM working set, causal
    block skipping); the LSE residual makes the backward exact.
    """
    if interpret is None:
        interpret = not _on_tpu()
    return _vjp_fn(causal, q_offset, block_q, block_k, interpret)(q, k, v)


attention_ref = _ref.attention_ref
