"""Pure-lax references for the differential harness.

These run the same math as the production lax executor but *never* consult
the executor globals (``repro.backend.pallas_enabled``), so the parity
tests can compare the Pallas kernels against them while the ``jax-pallas``
executor is globally active -- no risk of accidentally comparing the
kernels against themselves.

``lax_waterfill_dense`` / ``lax_balance_caps`` are exactly the production
lax paths (same pure-math bodies, same loop drivers); the Pallas executor
must be *bit-identical* to them in float64 when interpreting.
``lax_waterfill_segmented`` mirrors the CSR algorithm of
``pallas_waterfill_segmented`` (bit-identity target for the segmented
kernel); ``waterfill_core`` remains the semantic reference, matched to
reduction-order rounding.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import kernels as core_kernels
from repro.drs.entitlement import waterfill_dense_math


def _fori(n, body, init):
    return jax.lax.fori_loop(0, n, body, init)


@functools.partial(jax.jit, static_argnames=("iters",))
def _dense_ref(capacity, floors, ceilings, weights, active, *, iters):
    return waterfill_dense_math(jnp, _fori, capacity, floors, ceilings,
                                weights, iters=iters, active=active)


def lax_waterfill_dense(capacity, floors, ceilings, weights,
                        iters: int = 200, active=None):
    """The production lax dense waterfill (jitted, dispatch-free)."""
    fl = jnp.asarray(floors)
    act = (jnp.ones(fl.shape, bool) if active is None
           else jnp.asarray(active, bool))
    return _dense_ref(jnp.asarray(capacity), fl, jnp.asarray(ceilings),
                      jnp.asarray(weights), act, iters=iters)


@functools.partial(jax.jit, static_argnames=("iters", "params"))
def _balance_ref(hosts, caps, fl, ce, w, act, cpu_reserved, budget,
                 enabled, *, iters, params):
    def ents_at(c):
        managed = core_kernels.managed_capacity(jnp, hosts, c)
        alloc = waterfill_dense_math(jnp, _fori, managed, fl, ce, w,
                                     iters=iters, active=act)
        return jnp.sum(alloc, axis=-1)

    class _LaxBe:
        name = "jax"
        xp = jnp

        @staticmethod
        def while_loop(cond, body, init):
            return jax.lax.while_loop(cond, body, init)

    return core_kernels.balance_caps(_LaxBe, hosts, caps, ents_at,
                                     cpu_reserved, budget, enabled, params)


def lax_balance_caps(hosts, caps, dense, cpu_reserved, budget, enabled,
                     params=core_kernels.BalanceParams()):
    """The production lax BalancePowerCap loop over dense slot columns."""
    hosts = core_kernels.HostCols(*(jnp.asarray(c) for c in hosts))
    return _balance_ref(hosts, jnp.asarray(caps), jnp.asarray(dense.floors),
                        jnp.asarray(dense.ceils),
                        jnp.asarray(dense.weights),
                        jnp.asarray(dense.active, bool),
                        jnp.asarray(cpu_reserved), jnp.asarray(budget),
                        jnp.asarray(enabled, bool),
                        iters=int(dense.iters), params=params)


def lax_waterfill_segmented(capacity, floors, ceilings, weights, seg_ids,
                            n_segs: int, iters: int = 200):
    """Lax mirror of the segmented CSR algorithm (no Pallas, no dispatch):
    sort by segment, pad rows to the same ``JB``, run the dense primitive
    per host, scatter back.  Bit-identity target for
    ``pallas_waterfill_segmented``."""
    from jax.experimental import enable_x64

    from repro.kernels.powercap.ops import _jb_for

    capacity = np.asarray(capacity, dtype=np.float64)
    floors = np.asarray(floors, dtype=np.float64)
    ceilings = np.asarray(ceilings, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    seg_ids = np.asarray(seg_ids, dtype=np.int64)
    n = floors.shape[0]
    if n == 0 or n_segs == 0:
        return jnp.zeros((n,), jnp.float64)
    srt = np.argsort(seg_ids, kind="stable")
    seg_sorted = seg_ids[srt]
    counts = np.bincount(seg_sorted, minlength=n_segs).astype(np.int64)
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    jb = _jb_for(int(counts.max()))
    slot = np.arange(n, dtype=np.int64) - starts[seg_sorted]

    def dense_rows(col, fill=0.0):
        rows = np.full((n_segs, jb), fill, dtype=np.float64)
        rows[seg_sorted, slot] = col[srt]
        return rows

    active = np.zeros((n_segs, jb), dtype=bool)
    active[seg_sorted, slot] = True
    # Match the pallas entry point: the eager callers (delivery, tests) may
    # not have x64 on, so the mirror pins it the same way.
    with enable_x64():
        out_rows = _dense_ref(jnp.asarray(capacity),
                              jnp.asarray(dense_rows(floors)),
                              jnp.asarray(dense_rows(ceilings)),
                              jnp.asarray(dense_rows(weights, fill=1e-12)),
                              jnp.asarray(active), iters=iters)
        out = np.zeros(n, dtype=np.float64)
        out[srt] = np.asarray(out_rows)[seg_sorted, slot]
        return jnp.asarray(out)
