"""Pallas kernel bodies for the powercap allocation math.

Single-source-of-truth design: every kernel body calls the exact pure-math
functions the lax executor runs -- :func:`repro.drs.entitlement.
waterfill_dense_math` for the bisection waterfill and :func:`repro.core.
kernels.balance_round` for the BalancePowerCap progressive-filling round --
on its VMEM blocks.  In interpret mode (the automatic off-TPU fallback,
see ``ops.py``) the op sequence is therefore *identical* to the lax path,
which makes the two executors bit-identical in float64; the differential
harness ``tests/test_kernel_parity.py`` enforces this.

Grid layout: one grid step per scenario cell ``s`` over the ``(S, H, J)``
dense slot layout (host columns ``(1, H)`` blocks, slot columns
``(1, H, J)`` blocks, per-cell scalars ``(1,)`` blocks).  The segmented
variant instead walks one grid step per *host* over a CSR layout --
flat item arrays stably sorted by segment plus per-host ``(start, count)``
-- loading a ``(JB,)`` window with ``pl.ds`` so ragged host/VM counts pay
for the longest row only, not for ``H * J`` dense padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import kernels as core_kernels
from repro.drs.entitlement import waterfill_dense_math


def _fori(n, body, init):
    """The backend ``fori`` contract on the lax plane (kernel-internal)."""
    return jax.lax.fori_loop(0, n, body, init)


# ------------------------------------------------------- dense waterfill
def waterfill_kernel(cap_ref, fl_ref, ce_ref, w_ref, act_ref, out_ref, *,
                     iters: int):
    """One cell's dense bisection waterfill: ``(1, H)`` capacity against
    ``(1, H, J)`` slot columns, all segments bisecting in lockstep."""
    out_ref[0] = waterfill_dense_math(
        jnp, _fori, cap_ref[0], fl_ref[0], ce_ref[0], w_ref[0],
        iters=iters, active=act_ref[0])


def waterfill_call(capacity, floors, ceilings, weights, active, *,
                   iters: int, interpret: bool):
    """``pl.pallas_call`` wrapper: grid over cells, whole-cell blocks."""
    s, h, j = floors.shape
    kernel = functools.partial(waterfill_kernel, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h, j), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, j), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, j), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, j), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, j), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, h, j), floors.dtype),
        interpret=interpret,
    )(capacity, floors, ceilings, weights, active)


# --------------------------------------------- fused balance-round kernel
def balance_round_kernel(on_ref, idle_ref, peak_ref, cpk_ref, hyp_ref,
                         fl_ref, ce_ref, w_ref, act_ref,
                         res_ref, bud_ref, non_ref, pm_ref,
                         caps_ref, man_ref, ent_ref, ns_ref, done_ref,
                         did_ref,
                         caps_out, man_out, ent_out, ns_out, done_out,
                         did_out, *,
                         iters: int, params: core_kernels.BalanceParams):
    """One cell's fused BalancePowerCap round.

    A single pass over the ``(1, H, J)`` slot block: the progressive-filling
    transfer math *and* the candidate-cap entitlement waterfill it needs
    (``ents_at``) both run here, so the ``(H, J)`` allocation never
    round-trips through HBM between them.  The body is literally
    :func:`repro.core.kernels.balance_round` with a block-local ``ents_at``
    built from :func:`waterfill_dense_math`.
    """
    hosts = core_kernels.HostCols(on_ref[0], idle_ref[0], peak_ref[0],
                                  cpk_ref[0], hyp_ref[0])
    fl, ce, w, act = fl_ref[0], ce_ref[0], w_ref[0], act_ref[0]

    def ents_at(c):
        managed = core_kernels.managed_capacity(jnp, hosts, c)
        alloc = waterfill_dense_math(jnp, _fori, managed, fl, ce, w,
                                     iters=iters, active=act)
        return jnp.sum(alloc, axis=-1)

    caps, managed, ents, ns, done, did = core_kernels.balance_round(
        jnp, hosts, caps_ref[0], man_ref[0], ent_ref[0], ns_ref[0],
        done_ref[0], did_ref[0], ents_at, res_ref[0], bud_ref[0],
        non_ref[0], pm_ref[0], params)
    caps_out[0] = caps
    man_out[0] = managed
    ent_out[0] = ents
    ns_out[0] = ns
    done_out[0] = done
    did_out[0] = did


def balance_round_call(hosts, dense_cols, cpu_reserved, budget, n_on,
                       peak_managed, state, *, iters: int, params,
                       interpret: bool):
    """``pl.pallas_call`` wrapper for one fused balance round.

    ``state`` is the loop state ``(caps, managed, ents, ns, done, did)``;
    the loop-invariant columns ride along as extra inputs.  Returns the
    next state with the same shapes/dtypes.
    """
    caps, managed, ents, ns, done, did = state
    s, h = caps.shape
    j = dense_cols[0].shape[-1]

    def host_spec(i):
        return (i, 0)

    def slot_spec(i):
        return (i, 0, 0)

    def cell_spec(i):
        return (i,)

    hb = pl.BlockSpec((1, h), host_spec)
    sb = pl.BlockSpec((1, h, j), slot_spec)
    cb = pl.BlockSpec((1,), cell_spec)
    kernel = functools.partial(balance_round_kernel, iters=iters,
                               params=params)
    return pl.pallas_call(
        kernel,
        grid=(s,),
        in_specs=[hb, hb, hb, hb, hb,          # host columns
                  sb, sb, sb, sb,              # dense slot columns
                  hb, cb, cb, hb,              # cpu_res, budget, n_on, peak
                  hb, hb, hb, hb, cb, cb],     # loop state
        out_specs=[hb, hb, hb, hb, cb, cb],
        out_shape=[
            jax.ShapeDtypeStruct((s, h), caps.dtype),
            jax.ShapeDtypeStruct((s, h), managed.dtype),
            jax.ShapeDtypeStruct((s, h), ents.dtype),
            jax.ShapeDtypeStruct((s, h), ns.dtype),
            jax.ShapeDtypeStruct((s,), done.dtype),
            jax.ShapeDtypeStruct((s,), did.dtype),
        ],
        interpret=interpret,
    )(hosts.on, hosts.power_idle, hosts.power_peak, hosts.capacity_peak,
      hosts.hyp_overhead, dense_cols[0], dense_cols[1], dense_cols[2],
      dense_cols[3], cpu_reserved, budget, n_on, peak_managed,
      caps, managed, ents, ns, done, did)


# ---------------------------------------------------- segmented waterfill
def segmented_kernel(cap_ref, start_ref, count_ref, fl_ref, ce_ref, w_ref,
                     out_ref, *, iters: int, jb: int):
    """One host's waterfill over its CSR window of the flat item arrays.

    ``start``/``count`` index the segment-sorted flat columns; the window
    is loaded with a dynamic slice of static width ``JB`` (the padded
    longest row) and slots past ``count`` are masked via ``active``, so
    the math is the dense primitive on a ``(1, JB)`` row.
    """
    start = start_ref[0]
    count = count_ref[0]
    fl = fl_ref[pl.ds(start, jb)][None]
    ce = ce_ref[pl.ds(start, jb)][None]
    w = w_ref[pl.ds(start, jb)][None]
    active = (jnp.arange(jb) < count)[None]
    capacity = cap_ref[0][None]
    out = waterfill_dense_math(jnp, _fori, capacity, fl, ce, w,
                               iters=iters, active=active)
    out_ref[0, :] = out[0]


def segmented_call(capacity, starts, counts, floors, ceilings, weights, *,
                   iters: int, jb: int, interpret: bool):
    """``pl.pallas_call`` wrapper: grid over hosts, flat columns shared.

    Flat item columns must be tail-padded by at least ``JB`` so the
    ``pl.ds`` window of the last host never reads past the end.  Returns
    the ``(n_segs, JB)`` per-host allocation rows (masked slots are 0).
    """
    n_segs = capacity.shape[0]
    n_pad = floors.shape[0]
    kernel = functools.partial(segmented_kernel, iters=iters, jb=jb)

    def one(i):
        return (i,)

    def whole(i):
        return (0,)

    return pl.pallas_call(
        kernel,
        grid=(n_segs,),
        in_specs=[
            pl.BlockSpec((1,), one),
            pl.BlockSpec((1,), one),
            pl.BlockSpec((1,), one),
            pl.BlockSpec((n_pad,), whole),
            pl.BlockSpec((n_pad,), whole),
            pl.BlockSpec((n_pad,), whole),
        ],
        out_specs=pl.BlockSpec((1, jb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_segs, jb), floors.dtype),
        interpret=interpret,
    )(capacity, starts, counts, floors, ceilings, weights)
