"""Pallas kernels for the CloudPowerCap hot allocation math.

The third executor behind ``repro.backend`` (``REPRO_EXECUTOR=jax-pallas``):
the dense waterfill, the fused waterfill + BalancePowerCap round, and the
segmented (ragged CSR) waterfill, each running the *same* pure-math bodies
as the lax path (``waterfill_dense_math`` / ``balance_round``) inside
``pl.pallas_call`` blocks -- off-TPU they execute in interpret mode and are
bit-identical to lax by construction.
"""

from repro.kernels.powercap.ops import (
    pallas_balance_caps,
    pallas_waterfill_dense,
    pallas_waterfill_segmented,
)

__all__ = [
    "pallas_balance_caps",
    "pallas_waterfill_dense",
    "pallas_waterfill_segmented",
]
